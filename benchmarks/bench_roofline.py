"""Roofline terms per (arch x shape) from the dry-run artifacts (§Roofline).

Emits one CSV row per cell: name = roofline/<arch>/<shape>,
us_per_call = projected step time (max of the three terms, in us),
derived = the three terms + dominant + useful-compute ratio.
"""
from __future__ import annotations

import pathlib

from benchmarks import common
from repro.analysis import roofline


def run(art_dir: str = "artifacts/dryrun", mesh: str = "16x16"):
    cells = roofline.load_cells(pathlib.Path(art_dir), mesh=mesh)
    for c in cells:
        if "roofline" not in c:
            if str(c.get("status", "")).startswith("skip"):
                continue
            common.emit(f"roofline/{c.get('arch')}/{c.get('shape')}", 0.0,
                        str(c.get("status"))[:80])
            continue
        r = c["roofline"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        common.emit(
            f"roofline/{r['arch']}/{r['shape']}", step_s * 1e6,
            f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
            f"coll={r['collective_s']:.2e}s dom={r['dominant']} "
            f"roofline={r['roofline_fraction']:.2f} "
            f"useful={r['useful_compute_ratio']:.2f}")


if __name__ == "__main__":
    run()
