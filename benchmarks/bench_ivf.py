"""IVF trajectory benchmark: coarse partitioning vs the flat streaming
scan — throughput AND recall across the nprobe dial, plain vs residual
(IVFADC) encoding at a MATCHED code budget.

Writes ``BENCH_ivf.json`` (repo root by default):

  * ``flat``            — the linear streaming scan baseline over the
                          same quantizer: us/query, qps, recall@1/@10;
  * ``flat[default]``   — the same search with the autotuner DISABLED
                          (hand-pinned block params); ``tuned_vs_default``
                          compares the two;
  * ``flat/f16`` and ``flat/i8`` — the quantized-LUT fast path through
                          ``search(lut_dtype=..., overfetch=2)``
                          end-to-end (reduced-precision stage-1 scan,
                          exact f32 re-score) with the SAME recall
                          metrics, summarized in ``quantized_study``;
  * ``ivf/nprobe=P``    for P in {1, 8, 32} — probed search: us/query,
                          qps, recall@1/@10, plus ``probed_frac`` (the
                          average fraction of the database the probe
                          plan actually scans — the work saved) and
                          ``plan_width`` (the padded ragged width W);
  * ``ivf-res/nprobe=P`` — the SAME nprobe points over a residual
                          (``Residual`` factory token) index with the
                          identical quantizer spec — same bytes/vector,
                          so any recall gap is purely the encoding;
  * ``residual_study``  — the side-by-side recall@1/@10 deltas
                          (residual minus plain) per nprobe, plus the
                          two indexes' mean reconstruction MSE;
  * ``ivf-dispatch/nprobe=P`` and ``ivf-padded/nprobe=P`` for
                          P in {8, 32} — the two stage-1 faces head to
                          head over the SAME index and probe (both
                          bit-identical by contract, so only the cost
                          model differs): qps, the per-batch plan cost
                          (host-side padded plan build vs on-device
                          router), the padded plan's padding-waste
                          fraction (slots scored that are ragged pads)
                          and the dispatch face's per-cell batch
                          occupancy (routed pairs over bucketed slots);
  * ``headline``        — qps speedup of the best IVF point that holds
                          recall@10 within 0.02 of flat.

The recall@k here is against the dataset's true nearest neighbor
(recall@k = fraction of queries whose true NN appears in the top k), the
paper's Table 2-4 metric. At nprobe == nlist the plain-IVF results are
bit-identical to flat search (enforced by tests/test_ivf.py) and the
residual results are bit-identical to the centroid + decode oracle
(tests/test_residual.py); this benchmark tracks what the nprobe dial —
and the encoding choice — trade BELOW full probe.

Run via ``python -m benchmarks.run --only ivf`` (ci.sh records the json
on every PR alongside the stage-1/stage-2 trajectories).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.search import recall_at_k
from repro.index import index_factory
from repro.kernels import tune

_NLIST = {"quick": 64, "default": 256, "full": 1024}
_NPROBES = (1, 8, 32)
_OVERFETCH = 2


def _timed_search(index, queries, k, **kw):
    _, got = index.search(queries, k, **kw)          # warmup/compile
    t0 = time.time()
    _, got = index.search(queries, k, **kw)
    jax.block_until_ready(got)
    us = (time.time() - t0) * 1e6 / queries.shape[0]
    return got, us


def _recon_mse(ivf, base: np.ndarray) -> float:
    """Mean ||x - recon(x)||^2 over the database (recon includes the
    centroid in residual mode) — the quantity residual encoding buys."""
    rows = jnp.take(ivf._pos_dev, jnp.arange(ivf.ntotal))
    recon = np.asarray(ivf.reconstruct_rows(rows))
    return float(((recon - base) ** 2).sum(-1).mean())


def _probe_stats(ivf, queries, nprobe):
    lens = np.diff(ivf._offsets)
    probe = ivf.probe_cells(queries, nprobe)
    probed = float(np.mean(lens[probe].sum(axis=1)) / ivf.ntotal)
    rows, _, _ = ivf._probe_plan(probe)
    return probed, int(rows.shape[1])


def _nprobe_sweep(ivf, tag, queries, gt, k, results):
    nlist = ivf.nlist
    for nprobe in _NPROBES:
        nprobe = min(nprobe, nlist)
        # pinned to the padded face: these rows are the longitudinal
        # recall/qps trajectory (the faces are bit-identical; the
        # dispatch-vs-padded cost model has its own head-to-head rows)
        got, us = _timed_search(ivf, queries, k, nprobe=nprobe,
                                use_dispatch=False)
        rec = recall_at_k(got, gt, ks=(1, 10))
        probed, width = _probe_stats(ivf, queries, nprobe)
        results["paths"][f"{tag}/nprobe={nprobe}"] = {
            "us_per_query": round(us, 1), "qps": round(1e6 / us, 1),
            "recall@1": round(rec["recall@1"], 4),
            "recall@10": round(rec["recall@10"], 4),
            "probed_frac": round(probed, 4),
            "plan_width": width,
            "tuner_bucket": tune.bucket_key(
                tune.KERNELS["adc_gather_topl.xla"],
                {"w": width, "q": queries.shape[0], "topl": 100})}
        common.emit(f"{tag}/nprobe={nprobe}", us,
                    f"R@1={rec['recall@1']:.3f} "
                    f"R@10={rec['recall@10']:.3f} "
                    f"probed={probed * 100:.1f}%")


def _dispatch_sweep(ivf, queries, k, results):
    """Dispatch face vs padded face over the same index: search qps plus
    the per-batch plan cost each face pays (host numpy plan build vs
    on-device routing) and each face's waste metric."""
    from repro.index.dispatch import build_dispatch

    reps = 5
    for nprobe in (8, 32):
        nprobe = min(nprobe, ivf.nlist)
        probe_dev, _ = ivf._probe_with_dists(queries, nprobe)
        probe = np.asarray(probe_dev)
        q, p = probe.shape

        # padded face: host plan build (cold, memo cleared) + waste
        t0 = time.time()
        for _ in range(reps):
            ivf._plan_cache = {}
            rows, gids, _ = ivf._probe_plan(probe)
        plan_ms = (time.time() - t0) * 1e3 / reps
        real = int((gids != np.iinfo(np.int32).max).sum())
        waste = 1.0 - real / float(gids.size)
        _, us = _timed_search(ivf, queries, k, nprobe=nprobe,
                              use_dispatch=False)
        results["paths"][f"ivf-padded/nprobe={nprobe}"] = {
            "us_per_query": round(us, 1), "qps": round(1e6 / us, 1),
            "plan_build_ms": round(plan_ms, 3),
            "padding_waste_frac": round(waste, 4),
            "plan_width": int(rows.shape[1]),
            "tuner_bucket": tune.bucket_key(
                tune.KERNELS["adc_gather_topl.xla"],
                {"w": int(rows.shape[1]), "q": q, "topl": 100})}
        common.emit(f"ivf-padded/nprobe={nprobe}", us,
                    f"plan={plan_ms:.2f}ms waste={waste * 100:.1f}%")

        # dispatch face: on-device router + per-cell batch occupancy
        routing, stats = build_dispatch(probe_dev, ivf._offsets_dev)
        t0 = time.time()
        for _ in range(reps):
            routing, stats = build_dispatch(probe_dev, ivf._offsets_dev)
            jax.block_until_ready(routing.plan.qidx)
        route_ms = (time.time() - t0) * 1e3 / reps
        qidx = np.asarray(routing.plan.qidx)
        routed = int((qidx >= 0).sum())
        occupancy = routed / float((qidx.shape[0] - 1) * qidx.shape[1])
        _, us = _timed_search(ivf, queries, k, nprobe=nprobe,
                              use_dispatch=True)
        results["paths"][f"ivf-dispatch/nprobe={nprobe}"] = {
            "us_per_query": round(us, 1), "qps": round(1e6 / us, 1),
            "route_ms": round(route_ms, 3),
            "batch_occupancy": round(occupancy, 4),
            "routed_cells": int(stats[0]),
            "cap": int(qidx.shape[1]),
            "tuner_bucket": tune.bucket_key(
                tune.KERNELS["adc_dispatch_topl"],
                {"n": ivf.ntotal, "q": q})}
        common.emit(f"ivf-dispatch/nprobe={nprobe}", us,
                    f"route={route_ms:.2f}ms occ={occupancy * 100:.1f}% "
                    f"E={stats[0]}")


def run(scale: str = "quick", out_path: str | None = None) -> dict:
    s = common.SCALES[scale]
    nlist = _NLIST.get(scale, _NLIST["quick"])
    ds = common.dataset("deep", scale)
    queries = jnp.asarray(ds.queries)
    gt = jnp.asarray(ds.gt_nn)
    k = 100

    flat = index_factory("PQ8x64,Rerank100", dim=ds.dim)
    flat.train(ds.train, iters=s["kmeans_iters"])
    flat.add(ds.base)
    ivf = index_factory(f"IVF{nlist},PQ8x64,Rerank100", dim=ds.dim)
    ivf.train(ds.train, iters=s["kmeans_iters"])
    ivf.add(ds.base)
    res = index_factory(f"IVF{nlist},Residual,PQ8x64,Rerank100", dim=ds.dim)
    res.train(ds.train, iters=s["kmeans_iters"])
    res.add(ds.base)

    results = {"n": int(flat.ntotal), "q": int(queries.shape[0]),
               "nlist": nlist, "backend": jax.default_backend(),
               "tuning": tune.cache_fingerprint(), "paths": {}}

    # flat search stage 1 is the xla streaming scan over the whole base:
    # the tuner bucket its block params resolve in (rerank pool = 100)
    spec = tune.KERNELS["adc_scan_topl.xla"]
    nq = int(queries.shape[0])
    bucket = tune.bucket_key(spec, {"n": int(flat.ntotal), "q": nq,
                                    "topl": 100})

    # the four flat comparison rows are timed INTERLEAVED (tuned vs
    # default vs f16 vs i8): sequential end-to-end timings on a shared
    # CPU drift more than the deltas being measured
    qbucket = tune.bucket_key(spec, {"n": int(flat.ntotal), "q": nq,
                                     "topl": 100 * _OVERFETCH})
    flat_fns = {
        "flat": lambda: flat.search(queries, k)[1],
        "flat[default]": common.with_defaults(
            lambda: flat.search(queries, k)[1]),
        "flat/f16": lambda: flat.search(
            queries, k, lut_dtype="float16", overfetch=_OVERFETCH)[1],
        "flat/i8": lambda: flat.search(
            queries, k, lut_dtype="int8", overfetch=_OVERFETCH)[1],
    }
    timed = common.timed_group(flat_fns, repeats=10)
    flat_us = {name: us / nq for name, (_out, us) in timed.items()}
    for name in flat_fns:
        rec = recall_at_k(timed[name][0], gt, ks=(1, 10))
        row = {"us_per_query": round(flat_us[name], 1),
               "qps": round(1e6 / flat_us[name], 1),
               "recall@1": round(rec["recall@1"], 4),
               "recall@10": round(rec["recall@10"], 4),
               "tuner_bucket": qbucket if "/" in name else bucket}
        extra = f"R@1={rec['recall@1']:.3f} R@10={rec['recall@10']:.3f}"
        if "/" in name:
            row["overfetch"] = _OVERFETCH
            extra += f" overfetch={_OVERFETCH}"
        results["paths"][name] = row
        common.emit(f"ivf/{name}", flat_us[name], extra)
    results["tuned_vs_default"] = {
        "path": "flat", "tuner_bucket": bucket,
        # when the sweep kept the default at this bucket both rows run
        # the SAME config and |speedup - 1| is pure timing noise
        "identical_config": tune.best_config(
            "adc_scan_topl", "xla", n=int(flat.ntotal), q=nq,
            topl=100) == dict(spec.params),
        "tuned_us": round(flat_us["flat"], 1),
        "default_us": round(flat_us["flat[default]"], 1),
        "speedup": round(flat_us["flat[default]"] / flat_us["flat"], 3)}
    results["quantized_study"] = {
        "overfetch": _OVERFETCH, "vs": "flat",
        **{tag: {"us_per_query": round(flat_us[f"flat/{tag}"], 1),
                 "speedup_vs_f32": round(
                     flat_us["flat"] / flat_us[f"flat/{tag}"], 3),
                 "recall@10": results["paths"][f"flat/{tag}"]["recall@10"]}
           for tag in ("f16", "i8")}}

    _nprobe_sweep(ivf, "ivf", queries, gt, k, results)
    _nprobe_sweep(res, "ivf-res", queries, gt, k, results)
    _dispatch_sweep(ivf, queries, k, results)

    # residual-vs-plain at matched code budget: per-nprobe recall deltas
    study = {"code_bytes_per_vector": int(np.asarray(ivf.codes).shape[1]),
             "recon_mse_plain": round(_recon_mse(ivf, np.asarray(ds.base)),
                                      4),
             "recon_mse_residual": round(_recon_mse(res,
                                                    np.asarray(ds.base)),
                                         4),
             "per_nprobe": {}}
    for nprobe in _NPROBES:
        nprobe = min(nprobe, nlist)
        plain_row = results["paths"][f"ivf/nprobe={nprobe}"]
        res_row = results["paths"][f"ivf-res/nprobe={nprobe}"]
        study["per_nprobe"][str(nprobe)] = {
            "recall@1_plain": plain_row["recall@1"],
            "recall@1_residual": res_row["recall@1"],
            "recall@1_delta": round(
                res_row["recall@1"] - plain_row["recall@1"], 4),
            "recall@10_plain": plain_row["recall@10"],
            "recall@10_residual": res_row["recall@10"],
            "recall@10_delta": round(
                res_row["recall@10"] - plain_row["recall@10"], 4)}
    results["residual_study"] = study

    flat_row = results["paths"]["flat"]
    eligible = {
        name: p for name, p in results["paths"].items()
        if "/" in name and not name.startswith("flat")
        and "recall@10" in p
        and p["recall@10"] >= flat_row["recall@10"] - 0.02}
    best = max(eligible, key=lambda n: eligible[n]["qps"], default=None)
    results["headline"] = {
        "best": best,
        "qps_speedup_vs_flat": round(
            eligible[best]["qps"] / flat_row["qps"], 2) if best else None}

    if out_path is None:
        out_path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_ivf.json"
    pathlib.Path(out_path).write_text(json.dumps(results, indent=2) + "\n")
    print(f"# ivf: wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
