"""Paper Table 2: compressed-domain retrieval recall on Deep/BigANN-style
data at 8 and 16 bytes/vector — OPQ, PQ, RVQ (additive family), RVQ+rerank
(the LSQ+rerank analog) and UNQ. Every method runs behind the unified
``repro.index`` protocol (one factory string per table row), so this whole
table is one loop."""
from __future__ import annotations

from benchmarks import common


def run(scale: str = "default", datasets=("deep", "sift"), budgets=(8, 16)):
    rows = []
    for kind in datasets:
        ds = common.dataset(kind, scale)
        for m in budgets:
            for name, fn in (
                ("pq", lambda: common.run_pq(ds, m, scale)),
                ("opq", lambda: common.run_pq(ds, m, scale, opq=True)),
                ("rvq", lambda: common.run_rvq(ds, m, scale)),
                ("rvq+rerank", lambda: common.run_rvq(ds, m, scale,
                                                      rerank_decoder=True)),
                ("unq", lambda: common.run_unq(ds, m, scale)),
            ):
                rec, enc_us, search_us, _ = fn()
                tag = f"recall/{kind}{m}B/{name}"
                common.emit(tag, search_us, common.fmt_recalls(rec))
                rows.append((kind, m, name, rec, enc_us, search_us))
    return rows


if __name__ == "__main__":
    run()
