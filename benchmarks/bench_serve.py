"""Serving benchmark: open-loop arrival traces through ``repro.serve``.

Writes ``BENCH_serve.json`` (repo root by default):

  * ``cold_compile_ms`` — one row per warmed (query bucket, k bucket)
    shape: the jit cost the warm-up pass absorbed so the timed traces
    never pay it (the satellite bug this file exists to keep fixed:
    latency percentiles must NEVER include a compile);
  * ``rates/rate=R`` for each arrival rate R (req/s) — an OPEN-LOOP
    trace (submission times come from the trace clock, not from
    completions, so queueing delay is measured rather than hidden):
    p50/p95/p99 request latency, deadline-miss count/rate, batches cut,
    padding waste, and the dispatch-overflow counter delta. Each rate
    is primed with untimed passes over the trace until a full pass
    compiles nothing (batch shapes depend on the arrival pattern AND
    on prior service times, so a fixed prime count is not enough),
    then timed — the row is steady-state serving, and any compile that
    still lands inside the timed pass is counted in
    ``compiles_in_timed_pass``;
  * ``trace`` — the deterministic request-mix parameters (seeded widths,
    ks, per-request nprobe), so rows are comparable across PRs.

Run via ``python -m benchmarks.run --only serve`` (ci.sh records the
json on every PR alongside the stage-1/stage-2/ivf trajectories).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from benchmarks import common
from repro.analysis.compilecount import count_compiles
from repro.index import index_factory
from repro.serve import ServeConfig, ServeEngine

#: open-loop arrival rates (requests/second) — at least two points: one
#: comfortably inside capacity (per-request latency ~= service time),
#: one where coalescing visibly kicks in (fewer, fuller batches), one
#: pushing toward saturation so the deadline-miss column can move.
_RATES = {"quick": (25.0, 100.0, 400.0), "default": (50.0, 200.0, 800.0),
          "full": (50.0, 200.0, 800.0)}
_N_REQUESTS = {"quick": 60, "default": 200, "full": 500}
_DEADLINE_MS = 250.0


def _trace_requests(ds, n_requests: int, nlist: int, seed: int = 7):
    """Deterministic heterogeneous mix: widths 1-4, k from a small
    realistic menu, a third of requests pinning their own nprobe. The
    k/nprobe menus are deliberately SMALL: real traffic draws from a
    few endpoint configs, and a bounded (k bucket, probe width) product
    is what lets the priming passes reach a compile-free steady state
    before the timed pass."""
    rng = np.random.default_rng(seed)
    qpool = np.asarray(ds.queries, dtype=np.float32)
    reqs = []
    for t in range(n_requests):
        q = int(rng.integers(1, 5))
        rows = rng.integers(0, qpool.shape[0], size=q)
        r = {"queries": qpool[rows], "k": int(rng.choice((10, 30)))}
        if t % 3 == 1:
            r["nprobe"] = int(rng.choice((4, max(nlist // 8, 2))))
        reqs.append(r)
    return reqs


def _run_rate(engine, requests, rate_hz: float) -> dict:
    """One open-loop pass: submit on the trace clock, then drain."""
    engine.metrics.reset()
    period = 1.0 / rate_hz
    t_next = time.perf_counter()
    futures = []
    for r in requests:
        now = time.perf_counter()
        if now < t_next:
            time.sleep(t_next - now)
        futures.append(engine.submit(**r, deadline_ms=_DEADLINE_MS))
        t_next += period
    for f in futures:
        f.result(timeout=120)
    s = engine.metrics.summary()
    s.pop("cold_compile_ms")          # reported once, not per rate
    s["rate_hz"] = rate_hz
    s["deadline_ms"] = _DEADLINE_MS
    return s


def run(scale: str = "quick", out_path: str | None = None) -> dict:
    ds = common.dataset("deep", scale)
    nlist = {"quick": 64, "default": 256, "full": 1024}.get(scale, 64)
    s = common.SCALES[scale]
    index = index_factory(f"IVF{nlist},PQ8x64,Rerank100", dim=ds.dim)
    index.train(ds.train, iters=s["kmeans_iters"])
    index.add(ds.base)

    # Padded stage-1 face: the dispatch router's (E, cap, tiles) shape
    # buckets are data-dependent per batch, so serving traffic keeps
    # compiling new router shapes for many passes — and on CPU the
    # routed scan trails the padded gather anyway (see BENCH_ivf.json).
    # Revisit the default once the bench runs on real TPU.
    engine = ServeEngine(index, ServeConfig(
        max_batch_queries=32, linger_ms=2.0, default_k=10,
        deadline_slack_ms=2.0, use_dispatch=False))
    requests = _trace_requests(ds, _N_REQUESTS[scale], nlist)
    ks = sorted({1 << (r["k"] - 1).bit_length() for r in requests})
    t0 = time.time()
    cold = engine.warmup(buckets=(8, 16, 32), ks=ks)
    warm_s = time.time() - t0
    common.emit("serve/warmup", warm_s * 1e6,
                f"{len(cold)} shape buckets compiled")

    results = {"scale": scale, "n": int(index.ntotal), "nlist": nlist,
               "backend": jax.default_backend(),
               "trace": {"n_requests": len(requests), "seed": 7,
                         "widths": "1-4", "k": "10|30 (pow2-bucketed)",
                         "nprobe": f"default | 4 | {max(nlist // 8, 2)}",
                         "deadline_ms": _DEADLINE_MS},
               "cold_compile_ms": {k: round(v, 1) for k, v in cold.items()},
               "rates": {}}
    for rate in _RATES[scale]:
        # Untimed priming passes first: warmup() covered the
        # (Q bucket, k bucket) ladder at the default nprobe, but the
        # trace's per-request nprobe lands on probe-plan width rungs —
        # and rate-dependent batch compositions — the warm-up never
        # compiled, and each pass's coalescing depends on the previous
        # pass's service times, so one prime isn't always enough. Prime
        # until a full pass compiles NOTHING, then time; a compile
        # inside the timed pass is exactly the bug this bench guards,
        # so its count is recorded in the row.
        for _ in range(10):
            with count_compiles() as log:
                _run_rate(engine, requests, rate)
            if log.count == 0:
                break
        with count_compiles() as log:
            row = _run_rate(engine, requests, rate)
        row["compiles_in_timed_pass"] = log.count
        results["rates"][f"rate={rate:g}"] = {
            k: (round(v, 2) if isinstance(v, float) else v)
            for k, v in row.items()}
        common.emit(f"serve/rate={rate:g}", row["p50_ms"] * 1e3,
                    f"p95={row['p95_ms']:.1f}ms p99={row['p99_ms']:.1f}ms "
                    f"miss={row['deadline_misses']}/{row['deadline_total']} "
                    f"batches={row['batches']}")
    engine.close()

    if out_path is None:
        out_path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_serve.json"
    pathlib.Path(out_path).write_text(json.dumps(results, indent=2) + "\n")
    print(f"# serve: wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
