"""Stage-2 engine benchmark: streaming rerank (fused table kernel /
chunked xla / cross-query dedup) vs the materialized vmap baseline —
throughput AND peak-memory trajectory at the acceptance shape
Q=32, L=500.

Writes ``BENCH_stage2.json`` (repo root by default) with, per path:

  * ``us_per_call`` — one full d1 rerank of the (Q, L) candidate pool,
  * ``interpret`` — True when the Pallas kernel ran in interpret mode
    (off-TPU): a correctness datapoint excluded from the ``headline``,
  * ``peak_recon_bytes`` — the analytic reconstruction footprint
    (Q*L*D*4 for the vmap baseline, chunk-bounded for streaming paths),
  * ``temp_bytes`` — the compiler's measured temp allocation for the
    jitted rerank fn (None when unavailable or multi-jit),
  * section ``dedup`` additionally records ``unique_ratio`` — how many
    decoder calls cross-query dedup saved on the overlapping pool.

Two sections mirror the two engine families:

  * ``table``   — PQ-shaped additive decode table (M=8, K=256, D=96):
                  vmap vs chunked xla vs fused Pallas.
  * ``decoder`` — UNQ's MLP decoder on a hot-set candidate pool
                  (pools overlap across queries as they do after a real
                  stage 1): vmap vs cross-query dedup.

Run via ``python -m benchmarks.run --only stage2`` (ci.sh records the
json on every PR alongside the stage-1 trajectory).
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops, ref
from repro.kernels.rerank_dist import rerank_gather_dist_chunked_xla

_SIZES = {"quick": (60_000, 32, 500), "default": (200_000, 32, 500),
          "full": (1_000_000, 32, 500)}
_CHUNK_L = ops.DEFAULT_RERANK_CHUNK_L
_M, _K, _D = 8, 256, 96
_HOT_FRACTION = 8          # decoder pool drawn from a hot set of Q*L/8 ids


def _temp_bytes(fn, *avals):
    try:
        compiled = jax.jit(fn).lower(*avals).compile()
        return int(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:
        return None


def _bench_table(results, codes, queries, cand):
    q, topl = cand.shape
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(_M, _K, _D)), jnp.float32)
    cand_codes = jnp.take(codes, cand, axis=0)

    vmap_fn = jax.jit(jax.vmap(
        lambda qr, ci: jnp.sum(jnp.square(
            ref.decode_with_table(codes[ci], table) - qr[None, :]), axis=-1),
        in_axes=(0, 0)))
    interp = ops._interpret()
    paths = {
        "vmap/xla": (lambda: vmap_fn(queries, cand),
                     q * topl * _D * 4, False),
        "chunked/xla": (
            lambda: ops.rerank_gather_dist(cand_codes, queries, table,
                                           impl="xla", chunk_l=_CHUNK_L),
            q * _CHUNK_L * _D * 4, False),
        # interpret mode off-TPU: correctness path, not a perf claim
        "fused/pallas": (
            lambda: ops.rerank_gather_dist(cand_codes, queries, table,
                                           impl="pallas"),
            ops.DEFAULT_RERANK_BLOCK_Q * ops.DEFAULT_RERANK_BLOCK_L * _D * 4,
            interp),
    }
    temp = {
        "vmap/xla": _temp_bytes(
            vmap_fn,
            jax.ShapeDtypeStruct(queries.shape, jnp.float32),
            jax.ShapeDtypeStruct(cand.shape, jnp.int32)),
        "chunked/xla": _temp_bytes(
            lambda c, qs, t: rerank_gather_dist_chunked_xla(
                c, qs, t, chunk_l=_CHUNK_L),
            jax.ShapeDtypeStruct(cand_codes.shape, jnp.uint8),
            jax.ShapeDtypeStruct(queries.shape, jnp.float32),
            jax.ShapeDtypeStruct(table.shape, jnp.float32)),
    }
    for name, (fn, recon_bytes, interpret) in paths.items():
        _, us = common.timed(fn, repeats=3)
        results["table"][name] = {
            "us_per_call": round(us, 1), "interpret": bool(interpret),
            "peak_recon_bytes": recon_bytes,
            "temp_bytes": temp.get(name)}
        common.emit(f"stage2/table/{name}", us,
                    f"recon-mem={recon_bytes / 1e6:.2f}MB"
                    + (" [interpret]" if interpret else ""))


def _bench_decoder(results, n, queries, cand):
    from repro.core import unq
    from repro.index import DedupRerank, UNQIndex, VmapRerank

    q, topl = cand.shape
    rng = np.random.default_rng(2)
    cfg = unq.UNQConfig(dim=_D, num_codebooks=_M, codebook_size=_K)
    params, state = unq.init(jax.random.PRNGKey(0), cfg)
    index = UNQIndex.from_trained(params, state, cfg, rerank=topl)
    index._codes = jnp.asarray(rng.integers(0, _K, (n, _M)), jnp.uint8)

    n_unique = int(np.unique(np.asarray(cand)).size)
    vm, dd = VmapRerank(), DedupRerank()
    u_pad = -(-n_unique // dd.decode_chunk) * dd.decode_chunk
    paths = {
        "vmap/decoder": (lambda: vm.distances(index, queries, cand),
                         q * topl * _D * 4),
        # held deduped (U, D) reconstruction + gathered distance tiles
        "dedup/decoder": (
            lambda: dd.distances(index, queries, cand),
            (u_pad + q * dd.dist_chunk) * _D * 4),
    }
    for name, (fn, recon_bytes) in paths.items():
        _, us = common.timed(fn, repeats=3)
        results["decoder"][name] = {
            "us_per_call": round(us, 1), "interpret": False,
            "peak_recon_bytes": recon_bytes, "temp_bytes": None}
        common.emit(f"stage2/decoder/{name}", us,
                    f"recon-mem={recon_bytes / 1e6:.2f}MB")
    results["decoder"]["dedup/decoder"]["unique_ratio"] = round(
        q * topl / max(n_unique, 1), 2)


def run(scale: str = "quick", out_path: str | None = None) -> dict:
    n, q, topl = _SIZES.get(scale, _SIZES["quick"])
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, _K, (n, _M)), jnp.uint8)
    queries = jnp.asarray(rng.normal(size=(q, _D)), jnp.float32)
    # hot-set pool: stage-1 candidates overlap heavily across queries
    hot = rng.integers(0, n, max(q * topl // _HOT_FRACTION, 1))
    cand = jnp.asarray(hot[rng.integers(0, hot.size, (q, topl))], jnp.int32)

    results = {"n": n, "q": q, "topl": topl, "dim": _D, "chunk_l": _CHUNK_L,
               "backend": jax.default_backend(), "table": {}, "decoder": {}}
    _bench_table(results, codes, queries, cand)
    _bench_decoder(results, n, queries, cand)

    headline = {f"{sec}/{name}": p["us_per_call"]
                for sec in ("table", "decoder")
                for name, p in results[sec].items() if not p["interpret"]}
    results["headline"] = {
        "us_per_call": headline,
        "best_table": min((k for k in headline if k.startswith("table/")),
                          key=headline.get),
        "best_decoder": min((k for k in headline if k.startswith("decoder/")),
                            key=headline.get)}

    if out_path is None:
        out_path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_stage2.json"
    pathlib.Path(out_path).write_text(json.dumps(results, indent=2) + "\n")
    print(f"# stage2: wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
