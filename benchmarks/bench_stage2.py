"""Stage-2 engine benchmark: streaming rerank (fused table kernel /
chunked xla / cross-query dedup) vs the materialized vmap baseline —
throughput AND peak-memory trajectory at the acceptance shape
Q=32, L=500.

Writes ``BENCH_stage2.json`` (repo root by default) with, per path:

  * ``us_per_call`` — one full d1 rerank of the (Q, L) candidate pool,
  * ``interpret`` — True when the Pallas kernel ran in interpret mode
    (off-TPU): a correctness datapoint excluded from the ``headline``,
  * ``peak_recon_bytes`` — the analytic reconstruction footprint
    (Q*L*D*4 for the vmap baseline, chunk-bounded for streaming paths),
  * ``temp_bytes`` — the compiler's measured temp allocation for the
    jitted rerank fn (None when unavailable or multi-jit),
  * section ``dedup`` additionally records ``unique_ratio`` — how many
    decoder calls cross-query dedup saved on the overlapping pool,
  * ``tuner_bucket`` — the autotuner shape bucket the row's block params
    resolved in (compare longitudinal rows only within one bucket).

Two sections mirror the two engine families:

  * ``table``   — PQ-shaped additive decode table (M=8, K=256, D=96):
                  vmap vs chunked xla (tuner-resolved AND
                  ``chunked/xla[default]`` with the tuner disabled — the
                  ``tuned_vs_default`` block compares them) vs fused
                  Pallas.
  * ``decoder`` — UNQ's MLP decoder on a hot-set candidate pool
                  (pools overlap across queries as they do after a real
                  stage 1): vmap vs cross-query dedup.

A third section, ``gathered_quantized``, benches the gathered candidate
scan (``adc_gather_topl`` — the kernel that scores stage-2-shaped
per-query slot lists) f32 vs fp16 vs int8 LUTs at ``overfetch=2``,
recording recall@L of each quantized row against the exact f32 ids.

Run via ``python -m benchmarks.run --only stage2`` (ci.sh records the
json on every PR alongside the stage-1 trajectory).
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops, ref, tune
from repro.kernels.rerank_dist import rerank_gather_dist_chunked_xla

_SIZES = {"quick": (60_000, 32, 500), "default": (200_000, 32, 500),
          "full": (1_000_000, 32, 500)}
_M, _K, _D = 8, 256, 96
_HOT_FRACTION = 8          # decoder pool drawn from a hot set of Q*L/8 ids
_OVERFETCH = 2


def _temp_bytes(fn, *avals):
    try:
        compiled = jax.jit(fn).lower(*avals).compile()
        return int(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:
        return None


def _bench_table(results, codes, queries, cand):
    q, topl = cand.shape
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(_M, _K, _D)), jnp.float32)
    cand_codes = jnp.take(codes, cand, axis=0)

    bucket = tune.bucket_key(tune.KERNELS["rerank_gather_dist.xla"],
                             {"l": topl, "q": q, "d": _D})
    # the chunk the tuner resolves for this shape (winner or default)
    chunk_l = tune.best_config("rerank_gather_dist", "xla",
                               l=topl, q=q, d=_D)["chunk_l"]
    default_l = tune.KERNELS["rerank_gather_dist.xla"].params["chunk_l"]

    vmap_fn = jax.jit(jax.vmap(
        lambda qr, ci: jnp.sum(jnp.square(
            ref.decode_with_table(codes[ci], table) - qr[None, :]), axis=-1),
        in_axes=(0, 0)))
    interp = ops._interpret()

    def chunked_xla(**kw):
        return ops.rerank_gather_dist(cand_codes, queries, table,
                                      impl="xla", **kw)
    paths = {
        "vmap/xla": (lambda: vmap_fn(queries, cand),
                     q * topl * _D * 4, False),
        "chunked/xla": (chunked_xla, q * chunk_l * _D * 4, False),
        # same rerank, tuner disabled: the hand-pinned baseline
        "chunked/xla[default]": (
            common.with_defaults(chunked_xla),
            q * default_l * _D * 4, False),
        # interpret mode off-TPU: correctness path, not a perf claim
        "fused/pallas": (
            lambda: ops.rerank_gather_dist(cand_codes, queries, table,
                                           impl="pallas"),
            ops.DEFAULT_RERANK_BLOCK_Q * ops.DEFAULT_RERANK_BLOCK_L * _D * 4,
            interp),
    }
    temp = {
        "vmap/xla": _temp_bytes(
            vmap_fn,
            jax.ShapeDtypeStruct(queries.shape, jnp.float32),
            jax.ShapeDtypeStruct(cand.shape, jnp.int32)),
        "chunked/xla": _temp_bytes(
            lambda c, qs, t: rerank_gather_dist_chunked_xla(
                c, qs, t, chunk_l=chunk_l),
            jax.ShapeDtypeStruct(cand_codes.shape, jnp.uint8),
            jax.ShapeDtypeStruct(queries.shape, jnp.float32),
            jax.ShapeDtypeStruct(table.shape, jnp.float32)),
    }
    # the interpret-mode row is not a comparison row and its ~50ms body
    # would both slow the rotation and trash caches mid-round: time it
    # alone, and give the three comparison rows a longer rotation
    timed = common.timed_group(
        {name: fn for name, (fn, *_r) in paths.items()
         if name != "fused/pallas"}, repeats=10)
    timed["fused/pallas"] = (None, common.timed(paths["fused/pallas"][0])[1])
    for name, (fn, recon_bytes, interpret) in paths.items():
        _, us = timed[name]
        results["table"][name] = {
            "us_per_call": round(us, 1), "interpret": bool(interpret),
            "peak_recon_bytes": recon_bytes,
            "temp_bytes": temp.get(name),
            "tuner_bucket": bucket}
        common.emit(f"stage2/table/{name}", us,
                    f"recon-mem={recon_bytes / 1e6:.2f}MB"
                    + (" [interpret]" if interpret else ""))
    results["tuned_vs_default"] = {
        "path": "table/chunked/xla", "tuner_bucket": bucket,
        # when the sweep kept the default at this bucket both rows run the
        # SAME config and |speedup - 1| is pure timing noise
        "identical_config": chunk_l == default_l,
        "tuned_us": results["table"]["chunked/xla"]["us_per_call"],
        "default_us": results["table"]["chunked/xla[default]"]
        ["us_per_call"],
        "speedup": round(
            results["table"]["chunked/xla[default]"]["us_per_call"]
            / results["table"]["chunked/xla"]["us_per_call"], 3)}


def _bench_decoder(results, n, queries, cand):
    from repro.core import unq
    from repro.index import DedupRerank, UNQIndex, VmapRerank

    q, topl = cand.shape
    rng = np.random.default_rng(2)
    cfg = unq.UNQConfig(dim=_D, num_codebooks=_M, codebook_size=_K)
    params, state = unq.init(jax.random.PRNGKey(0), cfg)
    index = UNQIndex.from_trained(params, state, cfg, rerank=topl)
    index._codes = jnp.asarray(rng.integers(0, _K, (n, _M)), jnp.uint8)

    n_unique = int(np.unique(np.asarray(cand)).size)
    vm, dd = VmapRerank(), DedupRerank()
    u_pad = -(-n_unique // dd.decode_chunk) * dd.decode_chunk
    paths = {
        "vmap/decoder": (lambda: vm.distances(index, queries, cand),
                         q * topl * _D * 4),
        # held deduped (U, D) reconstruction + gathered distance tiles
        "dedup/decoder": (
            lambda: dd.distances(index, queries, cand),
            (u_pad + q * dd.dist_chunk) * _D * 4),
    }
    for name, (fn, recon_bytes) in paths.items():
        _, us = common.timed(fn, repeats=3)
        results["decoder"][name] = {
            "us_per_call": round(us, 1), "interpret": False,
            "peak_recon_bytes": recon_bytes, "temp_bytes": None}
        common.emit(f"stage2/decoder/{name}", us,
                    f"recon-mem={recon_bytes / 1e6:.2f}MB")
    results["decoder"]["dedup/decoder"]["unique_ratio"] = round(
        q * topl / max(n_unique, 1), 2)


def _bench_gathered_quantized(results, codes, n, q, topl):
    """f32 vs fp16 vs int8 LUTs over the gathered candidate scan at the
    stage-2 pool shape: (Q, W=topl) unique ascending slot lists, scan
    top-L = topl // 5, quantized rows over-fetched and exactly
    re-scored (recall@L measured against the exact f32 ids)."""
    rng = np.random.default_rng(3)
    luts = jnp.asarray(rng.normal(size=(q, _M, _K)), jnp.float32)
    gids_np = np.stack([np.sort(rng.choice(n, size=topl, replace=False))
                        for _ in range(q)]).astype(np.int32)
    gids = jnp.asarray(gids_np)
    rows = gids                    # flat world: row index == global id
    topl_s = max(topl // 5, 1)

    def gather(**kw):
        return ops.adc_gather_topl(codes, rows, gids, luts, topl=topl_s,
                                   impl="xla", **kw)

    exact_ids = np.asarray(gather()[1])
    spec = tune.KERNELS["adc_gather_topl.xla"]
    pool = min(topl_s * _OVERFETCH, topl)
    pool_bucket = tune.bucket_key(spec, {"w": topl, "q": q, "topl": pool})
    rows_cfg = {
        "f32": (gather, tune.bucket_key(
            spec, {"w": topl, "q": q, "topl": topl_s})),
        "f16": (lambda: gather(lut_dtype="float16", overfetch=_OVERFETCH),
                pool_bucket),
        "i8": (lambda: gather(lut_dtype="int8", overfetch=_OVERFETCH),
               pool_bucket),
        # matched-pipeline control: the f32 BRIDGE path (same L' pool,
        # re-score, exact select) — only the table dtype differs from
        # the quantized rows
        "f32@pool": (
            lambda: gather(lut_dtype="float32", overfetch=_OVERFETCH),
            pool_bucket),
    }
    timed = common.timed_group(
        {name: fn for name, (fn, _b) in rows_cfg.items()}, repeats=10)
    f32_us = matched_us = None
    for name, (fn, bucket) in rows_cfg.items():
        out, us = timed[name]
        row = {"us_per_call": round(us, 1), "interpret": False,
               "tuner_bucket": bucket}
        extra = ""
        if name == "f32":
            f32_us = us
        elif name == "f32@pool":
            matched_us = us
        else:
            got = np.asarray(out[1])
            hits = sum(np.intersect1d(g, e).size
                       for g, e in zip(got, exact_ids))
            row["overfetch"] = _OVERFETCH
            row["recall@L"] = round(hits / exact_ids.size, 5)
            row["speedup_vs_f32"] = round(f32_us / us, 3)
            extra = f" R@L={row['recall@L']:.4f} overfetch={_OVERFETCH}"
        results["gathered_quantized"][name] = row
        common.emit(f"stage2/gathered/{name}", us,
                    f"topl={topl_s} W={topl}" + extra)
    for name in ("f16", "i8"):
        results["gathered_quantized"][name]["speedup_vs_f32_matched"] = \
            round(matched_us
                  / results["gathered_quantized"][name]["us_per_call"], 3)


def run(scale: str = "quick", out_path: str | None = None) -> dict:
    n, q, topl = _SIZES.get(scale, _SIZES["quick"])
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, _K, (n, _M)), jnp.uint8)
    queries = jnp.asarray(rng.normal(size=(q, _D)), jnp.float32)
    # hot-set pool: stage-1 candidates overlap heavily across queries
    hot = rng.integers(0, n, max(q * topl // _HOT_FRACTION, 1))
    cand = jnp.asarray(hot[rng.integers(0, hot.size, (q, topl))], jnp.int32)

    results = {"n": n, "q": q, "topl": topl, "dim": _D,
               "backend": jax.default_backend(),
               "tuning": tune.cache_fingerprint(),
               "table": {}, "decoder": {}, "gathered_quantized": {}}
    _bench_table(results, codes, queries, cand)
    _bench_decoder(results, n, queries, cand)
    _bench_gathered_quantized(results, codes, n, q, topl)

    headline = {f"{sec}/{name}": p["us_per_call"]
                for sec in ("table", "decoder")
                for name, p in results[sec].items()
                if not p["interpret"] and "[" not in name}
    results["headline"] = {
        "us_per_call": headline,
        "best_table": min((k for k in headline if k.startswith("table/")),
                          key=headline.get),
        "best_decoder": min((k for k in headline if k.startswith("decoder/")),
                            key=headline.get)}

    if out_path is None:
        out_path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_stage2.json"
    pathlib.Path(out_path).write_text(json.dumps(results, indent=2) + "\n")
    print(f"# stage2: wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
