"""Benchmark harness: one module per paper table + roofline readout.

    PYTHONPATH=src python -m benchmarks.run [--scale quick|default|full]
        [--only recall,scale,ablation,timings,roofline]
    PYTHONPATH=src python -m benchmarks.run --smoke

Prints ``name,us_per_call,derived`` CSV rows. ``--smoke`` is the CI path:
it exercises ``Index.search`` on ALL registered scan backends (xla /
onehot / pallas-interpret) over a tiny factory-built index and fails
loudly if any backend disagrees with the xla oracle — perf regressions
and backend drift in the new surface both surface here. Under the
candidate-generator resolution this covers both stage-1 engines: xla and
pallas route through the streaming scan+top-L (bit-exact pair), onehot
through the materialized full-matrix scan — and all three stage-2
rerankers: xla/pallas resolve the streaming rerank engine (chunked/fused
table decode for PQ, cross-query dedup for UNQ), onehot the materialized
vmap reranker. ``--only stage1`` / ``--only stage2`` write
``BENCH_stage1.json`` / ``BENCH_stage2.json`` (throughput + peak-memory
trajectories).
"""
from __future__ import annotations

import argparse
import time
import traceback


def smoke() -> None:
    """Tiny end-to-end pass over the unified index API, per scan backend."""
    import numpy as np
    import jax.numpy as jnp

    from benchmarks import common
    from repro.index import available_scan_backends, index_factory

    ds = common.dataset("deep", "quick")
    queries = jnp.asarray(ds.queries[:64])

    for spec, train_kw in (
        ("PQ8x64,Rerank64", dict(iters=4)),
        ("UNQ8x64,Rerank64", dict(epochs=2, log_every=1000)),
    ):
        index = index_factory(spec, dim=ds.dim)
        index.train(ds.train, **train_kw)
        index.add(ds.base)
        want = None
        for backend in sorted(available_scan_backends()):
            index.backend = backend
            _, got = index.search(queries, 10)           # warmup/compile
            t0 = time.time()
            _, got = index.search(queries, 10)
            got.block_until_ready()
            us = (time.time() - t0) * 1e6 / queries.shape[0]
            if backend == "xla":
                want = np.asarray(got)
            common.emit(f"smoke/{spec}/search[{backend}]", us,
                        f"ntotal={index.ntotal}")
        for backend in available_scan_backends():
            index.backend = backend
            _, got = index.search(queries, 10)
            got = np.asarray(got)
            if backend in ("xla", "pallas"):
                if not np.array_equal(got, want):   # bit-exact scan pair
                    raise AssertionError(
                        f"{spec}: backend {backend!r} disagrees with xla")
            else:   # reassociated reductions may swap exact d2 ties
                overlap = np.mean([len(set(a) & set(b)) / len(a)
                                   for a, b in zip(got, want)])
                if overlap < 0.99:
                    raise AssertionError(
                        f"{spec}: backend {backend!r} overlap {overlap:.3f}")
        print(f"# smoke {spec}: all backends agree with xla")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=["quick", "default", "full"])
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    ap.add_argument("--smoke", action="store_true",
                    help="CI path: Index.search on every scan backend")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.smoke:
        smoke()
        return

    from benchmarks import (bench_ablation, bench_recall, bench_roofline,
                            bench_scale, bench_stage1, bench_stage2,
                            bench_timings)

    benches = {
        "timings": lambda: bench_timings.run(args.scale),
        "recall": lambda: bench_recall.run(args.scale),
        "scale": lambda: bench_scale.run(args.scale),
        "ablation": lambda: bench_ablation.run(args.scale),
        "roofline": lambda: bench_roofline.run(),
        "stage1": lambda: bench_stage1.run(args.scale),
        "stage2": lambda: bench_stage2.run(args.scale),
    }
    selected = (args.only.split(",") if args.only else list(benches))

    for name in selected:
        t0 = time.time()
        try:
            benches[name]()
            print(f"# {name}: done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"# {name}: FAILED {type(e).__name__}: {e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
