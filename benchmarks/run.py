"""Benchmark harness: one module per paper table + roofline readout.

    PYTHONPATH=src python -m benchmarks.run [--scale quick|default|full]
        [--only recall,scale,ablation,timings,roofline,stage1,stage2,ivf,
               serve]
    PYTHONPATH=src python -m benchmarks.run --smoke [--specs PQ8x64,...]

Prints ``name,us_per_call,derived`` CSV rows. ``--smoke`` is the CI path:
it exercises ``Index.search`` on ALL registered scan backends (xla /
onehot / pallas-interpret) over tiny factory-built indexes — flat AND
IVF-wrapped at full probe — and EXITS NON-ZERO if any backend disagrees
with the xla oracle (every mismatch is still reported before exiting, so
one run surfaces all drift). Under the candidate-generator resolution
this covers both stage-1 engines and their gathered (IVF) faces: xla and
pallas route through the streaming scan+top-L / gathered scan (bit-exact
pair), onehot through the materialized full-matrix scan — and all three
stage-2 rerankers: xla/pallas resolve the streaming rerank engine
(chunked/fused table decode for PQ, cross-query dedup for UNQ), onehot
the materialized vmap reranker. ``--only stage1`` / ``--only stage2`` /
``--only ivf`` / ``--only serve`` write ``BENCH_stage1.json`` /
``BENCH_stage2.json`` / ``BENCH_ivf.json`` / ``BENCH_serve.json``
(throughput + peak-memory / recall / serving-latency trajectories).

Failures in the ``--only``/full bench loop are reported per bench and
the process exits non-zero at the end if any bench failed — CI can no
longer green-light a broken harness.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

#: smoke specs: name -> (factory string, train kwargs). IVF at
#: nprobe == nlist so backend parity is exact, not probe-dependent; the
#: Residual spec additionally exercises the IVFADC correction streams
#: (per-row cross bias + per-(query, cell) bias) and the extended-table
#: residual reranker on every backend.
SMOKE_SPECS = {
    "PQ8x64,Rerank64": dict(iters=4),
    "IVF32,NProbe32,PQ8x64,Rerank64": dict(iters=4),
    "IVF32,NProbe32,Residual,PQ8x64,Rerank64": dict(iters=4),
    "UNQ8x64,Rerank64": dict(epochs=2, log_every=1000),
}


def smoke(specs=None) -> list[str]:
    """Tiny end-to-end pass over the unified index API, per scan backend.

    Returns the list of parity-failure descriptions (empty = all green);
    every backend is checked even after a failure so one run reports all
    drift. ``REPRO_SMOKE_FORCE_FAIL=1`` injects a synthetic failure — the
    hook the exit-code regression test uses.
    """
    import numpy as np
    import jax.numpy as jnp

    from benchmarks import common
    from repro.index import available_scan_backends, index_factory

    ds = common.dataset("deep", "quick")
    queries = jnp.asarray(ds.queries[:64])
    failures: list[str] = []

    for spec, train_kw in (SMOKE_SPECS if specs is None else
                           {s: SMOKE_SPECS[s] for s in specs}).items():
        spec_failures_before = len(failures)
        index = index_factory(spec, dim=ds.dim)
        index.train(ds.train, **train_kw)
        index.add(ds.base)
        want = None
        for backend in sorted(available_scan_backends()):
            index.backend = backend
            _, got = index.search(queries, 10)           # warmup/compile
            t0 = time.time()
            _, got = index.search(queries, 10)
            got.block_until_ready()
            us = (time.time() - t0) * 1e6 / queries.shape[0]
            if backend == "xla":
                want = np.asarray(got)
            common.emit(f"smoke/{spec}/search[{backend}]", us,
                        f"ntotal={index.ntotal}")
        for backend in available_scan_backends():
            index.backend = backend
            _, got = index.search(queries, 10)
            got = np.asarray(got)
            if backend in ("xla", "pallas"):
                if not np.array_equal(got, want):   # bit-exact scan pair
                    failures.append(
                        f"{spec}: backend {backend!r} disagrees with xla")
            else:   # reassociated reductions may swap exact d2 ties
                overlap = np.mean([len(set(a) & set(b)) / len(a)
                                   for a, b in zip(got, want)])
                if overlap < 0.99:
                    failures.append(
                        f"{spec}: backend {backend!r} overlap "
                        f"{overlap:.3f}")
        if len(failures) > spec_failures_before:
            for f in failures[spec_failures_before:]:
                print(f"# SMOKE-FAIL {f}")
        else:
            print(f"# smoke {spec}: all backends agree with xla")
    if os.environ.get("REPRO_SMOKE_FORCE_FAIL", "") not in ("", "0"):
        failures.append("forced failure (REPRO_SMOKE_FORCE_FAIL)")
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=["quick", "default", "full"])
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    ap.add_argument("--smoke", action="store_true",
                    help="CI path: Index.search on every scan backend; "
                         "exits non-zero on any parity failure")
    ap.add_argument("--specs", default=None,
                    help="semicolon-separated subset of smoke specs "
                         f"(known: {list(SMOKE_SPECS)})")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if args.smoke:
        failures = smoke(args.specs.split(";") if args.specs else None)
        if failures:
            print(f"# smoke: {len(failures)} parity failure(s)")
            sys.exit(1)
        return

    from benchmarks import (bench_ablation, bench_ivf, bench_recall,
                            bench_roofline, bench_scale, bench_serve,
                            bench_stage1, bench_stage2, bench_timings)

    benches = {
        "timings": lambda: bench_timings.run(args.scale),
        "recall": lambda: bench_recall.run(args.scale),
        "scale": lambda: bench_scale.run(args.scale),
        "ablation": lambda: bench_ablation.run(args.scale),
        "roofline": lambda: bench_roofline.run(),
        "stage1": lambda: bench_stage1.run(args.scale),
        "stage2": lambda: bench_stage2.run(args.scale),
        "ivf": lambda: bench_ivf.run(args.scale),
        "serve": lambda: bench_serve.run(args.scale),
    }
    selected = (args.only.split(",") if args.only else list(benches))

    failed = []
    for name in selected:
        t0 = time.time()
        try:
            benches[name]()
            print(f"# {name}: done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failed.append(name)
            print(f"# {name}: FAILED {type(e).__name__}: {e}")
            traceback.print_exc()
    if failed:
        print(f"# benches failed: {','.join(failed)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
