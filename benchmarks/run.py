"""Benchmark harness: one module per paper table + roofline readout.

    PYTHONPATH=src python -m benchmarks.run [--scale quick|default|full]
        [--only recall,scale,ablation,timings,roofline]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=["quick", "default", "full"])
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    args = ap.parse_args()

    from benchmarks import (bench_ablation, bench_recall, bench_roofline,
                            bench_scale, bench_timings)

    benches = {
        "timings": lambda: bench_timings.run(args.scale),
        "recall": lambda: bench_recall.run(args.scale),
        "scale": lambda: bench_scale.run(args.scale),
        "ablation": lambda: bench_ablation.run(args.scale),
        "roofline": lambda: bench_roofline.run(),
    }
    selected = (args.only.split(",") if args.only else list(benches))

    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        try:
            benches[name]()
            print(f"# {name}: done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"# {name}: FAILED {type(e).__name__}: {e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
