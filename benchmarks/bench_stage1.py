"""Stage-1 engine benchmark: streaming scan+top-L vs the materialized
full-matrix scan — throughput AND peak-memory trajectory.

Writes ``BENCH_stage1.json`` (repo root by default) with, per path:

  * ``us_per_call`` / ``mqps`` — query-vectors scanned per second,
  * ``interpret`` — True when the Pallas path ran in interpret mode
    (off-TPU): a correctness datapoint, NOT a perf one, so it is
    excluded from the ``headline`` mqps comparison,
  * ``peak_score_bytes`` — the analytic stage-1 score footprint
    (Q*N*4 for materialized, Q*(L+chunk)*4 for streaming),
  * ``temp_bytes`` — the compiler's measured temp-buffer allocation for
    the jitted stage-1 fn (None when the backend doesn't report it),
  * ``materializes_qn`` — whether a (Q, N) f32 buffer exists in the HLO.

The top-level ``headline`` block compares mqps over the compiled paths
only — interpret-mode timings never pollute the trajectory.

The HLO facts are measured on the two XLA-compiled paths only; the
Pallas row carries no HLO claim (the fused kernel's memory behavior is a
Mosaic property — its VMEM heap bound is the analytic number, and the
no-(Q, N)-buffer guarantee is enforced by tests/test_topl.py).

Run via ``python -m benchmarks.run --only stage1`` (ci.sh records the
json on every PR so the trajectory of the hot path is tracked).
"""
from __future__ import annotations

import json
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops, ref
from repro.kernels.topl_scan import adc_scan_topl_stream_xla

_SIZES = {"quick": (60_000, 32, 100), "default": (200_000, 64, 300),
          "full": (1_000_000, 64, 500)}
_CHUNK = 4096


def _hlo_probe(n: int, q: int, topl: int) -> dict:
    """Compile both stage-1 paths and read buffer facts off the HLO."""
    codes = jax.ShapeDtypeStruct((n, 8), jnp.uint8)
    luts = jax.ShapeDtypeStruct((q, 8, 256), jnp.float32)
    bias = jax.ShapeDtypeStruct((n,), jnp.float32)

    def streaming(c, l, b):
        return adc_scan_topl_stream_xla(c, l, b, topl=topl, n_valid=n,
                                        chunk_n=_CHUNK)

    def materialized(c, l, b):
        s = ref.adc_scan_batch_ref(c, l) + b[None, :]
        neg, idx = jax.lax.top_k(-s, topl)
        return -neg, idx

    qn = re.compile(rf"f32\[{q},{n}\]")
    out = {}
    for name, fn in (("streaming/xla", streaming),
                     ("materialized/xla", materialized)):
        compiled = jax.jit(fn).lower(codes, luts, bias).compile()
        try:
            temp = int(compiled.memory_analysis().temp_size_in_bytes)
        except Exception:
            temp = None
        out[name] = {"materializes_qn": bool(qn.search(compiled.as_text())),
                     "temp_bytes": temp}
    return out


def run(scale: str = "quick", out_path: str | None = None) -> dict:
    n, q, topl = _SIZES.get(scale, _SIZES["quick"])
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 256, (n, 8)), jnp.uint8)
    luts = jnp.asarray(rng.normal(size=(q, 8, 256)), jnp.float32)

    results = {"n": n, "q": q, "topl": topl, "chunk_n": _CHUNK,
               "backend": jax.default_backend(), "paths": {}}
    probe = _hlo_probe(n, q, topl)

    paths = {
        "materialized/xla": (
            lambda: jax.lax.top_k(
                -ref.adc_scan_batch_ref(codes, luts), topl),
            q * n * 4, False),
        "streaming/xla": (
            lambda: ops.adc_scan_topl(codes, luts, topl=topl, impl="xla",
                                      chunk_n=_CHUNK),
            q * (topl + _CHUNK) * 4, False),
        # interpret mode off-TPU: correctness path, not a perf claim —
        # flagged and excluded from the headline comparison below
        "streaming/pallas": (
            lambda: ops.adc_scan_topl(codes, luts, topl=topl, impl="pallas"),
            q * (topl + ops.DEFAULT_TOPL_BLOCK_N) * 4, ops._interpret()),
    }
    for name, (fn, score_bytes, interpret) in paths.items():
        _, us = common.timed(fn, repeats=1)
        mqps = q * n / (us / 1e6) / 1e6
        hlo = probe.get(name, {})
        results["paths"][name] = {
            "us_per_call": round(us, 1), "mqps": round(mqps, 2),
            "interpret": bool(interpret),
            "peak_score_bytes": score_bytes, **hlo}
        common.emit(f"stage1/{name}", us,
                    f"{mqps:.1f} Mquery-vec/s "
                    f"score-mem={score_bytes / 1e6:.1f}MB"
                    + (" [interpret]" if interpret else ""))

    headline = {name: p["mqps"] for name, p in results["paths"].items()
                if not p["interpret"]}
    results["headline"] = {
        "mqps": headline,
        "best": max(headline, key=headline.get) if headline else None}

    if out_path is None:
        out_path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_stage1.json"
    pathlib.Path(out_path).write_text(json.dumps(results, indent=2) + "\n")
    print(f"# stage1: wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
