"""Stage-1 engine benchmark: streaming scan+top-L vs the materialized
full-matrix scan — throughput AND peak-memory trajectory.

Writes ``BENCH_stage1.json`` (repo root by default) with, per path:

  * ``us_per_call`` / ``mqps`` — query-vectors scanned per second,
  * ``interpret`` — True when the Pallas path ran in interpret mode
    (off-TPU): a correctness datapoint, NOT a perf one, so it is
    excluded from the ``headline`` mqps comparison,
  * ``peak_score_bytes`` — the analytic stage-1 score footprint
    (Q*N*4 for materialized, Q*(L+chunk)*4 for streaming),
  * ``temp_bytes`` — the compiler's measured temp-buffer allocation for
    the jitted stage-1 fn (None when the backend doesn't report it),
  * ``materializes_qn`` — whether a (Q, N) f32 buffer exists in the HLO,
  * ``tuner_bucket`` — the autotuner shape bucket the row's block params
    resolved in (longitudinal rows stay comparable across default /
    cache changes: compare rows only within one bucket).

Three study rows ride along:

  * ``streaming/xla[default]`` — the same scan with the tuner DISABLED
    (hand-pinned ``DEFAULT_*`` block params); the ``tuned_vs_default``
    block compares it against the tuner-resolved row.  Acceptance:
    tuned is never slower than default (up to timing noise).
  * ``streaming/xla/f16`` / ``streaming/xla/i8`` — the quantized-LUT
    fast path (reduced-precision scan, over-fetched pool, exact f32
    re-score; see ``kernels/lut_quant.py``) at ``overfetch=2``, each
    recording ``recall@L`` against the exact f32 top-L ids,
  * ``streaming/xla/f32@pool`` — the f32 BRIDGE path at the same
    ``overfetch``: pool by exact scores at L' = overfetch * L, then
    re-score + exact select, i.e. the quantized rows' pipeline with
    only the table dtype changed.  ``speedup_vs_f32_matched`` (vs this
    row) isolates quantization itself, while ``speedup_vs_f32`` (vs
    the L-wide exact row) additionally pays the pool-width cost of CPU
    ``lax.top_k`` being linear in k — see docs/BENCHMARKS.md.

The comparison rows are timed INTERLEAVED (``common.timed_group``) so
relative numbers survive the ±30% ambient drift of a shared CPU.

The top-level ``headline`` block compares mqps over the compiled EXACT
paths only — interpret-mode timings and the study rows never pollute
the trajectory.

The HLO facts are measured on the two XLA-compiled paths only; the
Pallas row carries no HLO claim (the fused kernel's memory behavior is a
Mosaic property — its VMEM heap bound is the analytic number, and the
no-(Q, N)-buffer guarantee is enforced by tests/test_topl.py).

Run via ``python -m benchmarks.run --only stage1`` (ci.sh records the
json on every PR so the trajectory of the hot path is tracked).
"""
from __future__ import annotations

import json
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import lut_quant, ops, ref, tune
from repro.kernels.topl_scan import adc_scan_topl_stream_xla

_SIZES = {"quick": (60_000, 32, 100), "default": (200_000, 64, 300),
          "full": (1_000_000, 64, 500)}
_OVERFETCH = 2


def _scan_bucket(n: int, q: int, topl: int) -> str:
    """The tuner bucket this row's xla-scan block params resolve in."""
    return tune.bucket_key(tune.KERNELS["adc_scan_topl.xla"],
                           {"n": n, "q": q, "topl": topl})


def _resolved_chunk(n: int, q: int, topl: int) -> int:
    """The chunk the xla streaming scan actually runs with: the tuner's
    winner (or registry default), clamped exactly as ``ops`` clamps it."""
    cap = tune.best_config("adc_scan_topl", "xla",
                           n=n, q=q, topl=topl)["chunk_n"]
    return tune.clamp_chunk(n, cap=cap, floor=topl)


def _recall_at_l(got_ids, exact_ids) -> float:
    got, exact = np.asarray(got_ids), np.asarray(exact_ids)
    hits = sum(np.intersect1d(g, e).size for g, e in zip(got, exact))
    return hits / exact.size


def _hlo_probe(n: int, q: int, topl: int, chunk: int) -> dict:
    """Compile both stage-1 paths and read buffer facts off the HLO."""
    codes = jax.ShapeDtypeStruct((n, 8), jnp.uint8)
    luts = jax.ShapeDtypeStruct((q, 8, 256), jnp.float32)
    bias = jax.ShapeDtypeStruct((n,), jnp.float32)

    def streaming(c, l, b):
        return adc_scan_topl_stream_xla(c, l, b, topl=topl, n_valid=n,
                                        chunk_n=chunk)

    def materialized(c, l, b):
        s = ref.adc_scan_batch_ref(c, l) + b[None, :]
        neg, idx = jax.lax.top_k(-s, topl)
        return -neg, idx

    qn = re.compile(rf"f32\[{q},{n}\]")
    out = {}
    for name, fn in (("streaming/xla", streaming),
                     ("materialized/xla", materialized)):
        compiled = jax.jit(fn).lower(codes, luts, bias).compile()
        try:
            temp = int(compiled.memory_analysis().temp_size_in_bytes)
        except Exception:
            temp = None
        out[name] = {"materializes_qn": bool(qn.search(compiled.as_text())),
                     "temp_bytes": temp}
    return out


def run(scale: str = "quick", out_path: str | None = None) -> dict:
    n, q, topl = _SIZES.get(scale, _SIZES["quick"])
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 256, (n, 8)), jnp.uint8)
    luts = jnp.asarray(rng.normal(size=(q, 8, 256)), jnp.float32)

    chunk = _resolved_chunk(n, q, topl)
    default_chunk = tune.clamp_chunk(
        n, cap=tune.KERNELS["adc_scan_topl.xla"].params["chunk_n"],
        floor=topl)
    pool = lut_quant.pool_width(topl, _OVERFETCH, n)
    bucket = _scan_bucket(n, q, topl)
    results = {"n": n, "q": q, "topl": topl, "chunk_n": chunk,
               "backend": jax.default_backend(),
               "tuning": tune.cache_fingerprint(), "paths": {}}
    probe = _hlo_probe(n, q, topl, chunk)

    def scan_xla(**kw):
        return ops.adc_scan_topl(codes, luts, topl=topl, impl="xla", **kw)

    # the exact top-L ids the quantized rows' recall@L is scored against
    exact_ids = np.asarray(scan_xla()[1])

    pool_bucket = _scan_bucket(n, q, pool)
    paths = {
        "materialized/xla": (
            lambda: jax.lax.top_k(
                -ref.adc_scan_batch_ref(codes, luts), topl),
            q * n * 4, False, bucket),
        "streaming/xla": (scan_xla, q * (topl + chunk) * 4, False, bucket),
        # same scan, tuner disabled: the hand-pinned DEFAULT_* baseline
        "streaming/xla[default]": (
            common.with_defaults(scan_xla),
            q * (topl + default_chunk) * 4, False, bucket),
        # interpret mode off-TPU: correctness path, not a perf claim —
        # flagged and excluded from the headline comparison below
        "streaming/pallas": (
            lambda: ops.adc_scan_topl(codes, luts, topl=topl, impl="pallas"),
            q * (topl + ops.DEFAULT_TOPL_BLOCK_N) * 4, ops._interpret(),
            bucket),
        # quantized-LUT fast path: reduced-precision scan over an
        # over-fetched pool, exact f32 re-score (the scan's heap is the
        # POOL width, so its bucket differs from the exact rows')
        "streaming/xla/f16": (
            lambda: scan_xla(lut_dtype="float16", overfetch=_OVERFETCH),
            q * (pool + chunk) * 4, False, pool_bucket),
        "streaming/xla/i8": (
            lambda: scan_xla(lut_dtype="int8", overfetch=_OVERFETCH),
            q * (pool + chunk) * 4, False, pool_bucket),
        # matched-pipeline control: the f32 BRIDGE path (pool by exact
        # scores at the same L', re-score, exact select) — identical
        # pipeline to the quantized rows with only the table dtype
        # changed, so the _matched speedup isolates quantization itself
        "streaming/xla/f32@pool": (
            lambda: scan_xla(lut_dtype="float32", overfetch=_OVERFETCH),
            q * (pool + chunk) * 4, False, pool_bucket),
    }
    # the interpret-mode pallas row is ~1s/call off-TPU — not a
    # comparison row; keep it out of the rotation (it would trash caches
    # mid-round) and time it alone
    timed = common.timed_group(
        {name: fn for name, (fn, *_rest) in paths.items()
         if name != "streaming/pallas"}, repeats=10)
    timed["streaming/pallas"] = (
        None, common.timed(paths["streaming/pallas"][0])[1])
    for name, (fn, score_bytes, interpret, row_bucket) in paths.items():
        out, us = timed[name]
        mqps = q * n / (us / 1e6) / 1e6
        hlo = probe.get(name, {})
        row = {"us_per_call": round(us, 1), "mqps": round(mqps, 2),
               "interpret": bool(interpret),
               "peak_score_bytes": score_bytes,
               "tuner_bucket": row_bucket, **hlo}
        extra = ""
        if "/f16" in name or "/i8" in name:
            row["overfetch"] = _OVERFETCH
            row["recall@L"] = round(_recall_at_l(out[1], exact_ids), 5)
            extra = f" R@L={row['recall@L']:.4f} overfetch={_OVERFETCH}"
        results["paths"][name] = row
        common.emit(f"stage1/{name}", us,
                    f"{mqps:.1f} Mquery-vec/s "
                    f"score-mem={score_bytes / 1e6:.1f}MB"
                    + extra + (" [interpret]" if interpret else ""))

    tuned = results["paths"]["streaming/xla"]
    default = results["paths"]["streaming/xla[default]"]
    results["tuned_vs_default"] = {
        "path": "streaming/xla", "tuner_bucket": bucket,
        # when the sweep kept the default at this bucket both rows run the
        # SAME config and |speedup - 1| is pure timing noise
        "identical_config": chunk == default_chunk,
        "tuned_us": tuned["us_per_call"],
        "default_us": default["us_per_call"],
        "speedup": round(default["us_per_call"] / tuned["us_per_call"], 3)}
    f32_us = tuned["us_per_call"]
    matched_us = results["paths"]["streaming/xla/f32@pool"]["us_per_call"]
    results["quantized_study"] = {
        "overfetch": _OVERFETCH, "vs": "streaming/xla",
        "vs_matched": "streaming/xla/f32@pool",
        **{dt: {"us_per_call": results["paths"][f"streaming/xla/{dt}"]
                ["us_per_call"],
                "recall@L": results["paths"][f"streaming/xla/{dt}"]
                ["recall@L"],
                "speedup_vs_f32": round(
                    f32_us / results["paths"][f"streaming/xla/{dt}"]
                    ["us_per_call"], 3),
                "speedup_vs_f32_matched": round(
                    matched_us / results["paths"][f"streaming/xla/{dt}"]
                    ["us_per_call"], 3)}
           for dt in ("f16", "i8")}}

    headline = {name: p["mqps"] for name, p in results["paths"].items()
                if not p["interpret"] and "[" not in name
                and "/f16" not in name and "/i8" not in name
                and "@" not in name}
    results["headline"] = {
        "mqps": headline,
        "best": max(headline, key=headline.get) if headline else None}

    if out_path is None:
        out_path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_stage1.json"
    pathlib.Path(out_path).write_text(json.dumps(results, indent=2) + "\n")
    print(f"# stage1: wrote {out_path}")
    return results


if __name__ == "__main__":
    run()
