"""Shared benchmark plumbing: datasets, method runners, CSV emission.

Scales: --quick (CI, ~1 min), default (a few minutes/table), --full
(closest to the paper's 500k-train/1M-base protocol this container can do).
The synthetic Deep/BigANN stand-ins come from repro.data.descriptors.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import search, training, unq
from repro.data import descriptors as dd

SCALES = {
    "quick": dict(n_train=3000, n_base=8000, n_query=300, epochs=30,
                  kmeans_iters=8, opq_iters=3, rerank=100),
    "default": dict(n_train=15000, n_base=40000, n_query=800, epochs=40,
                    kmeans_iters=15, opq_iters=5, rerank=300),
    "full": dict(n_train=60000, n_base=200000, n_query=2000, epochs=60,
                 kmeans_iters=25, opq_iters=8, rerank=500),
}


@functools.lru_cache(maxsize=4)
def dataset(kind: str, scale: str):
    s = SCALES[scale]
    return dd.make_synthetic_dataset(
        kind, n_train=s["n_train"], n_base=s["n_base"],
        n_query=s["n_query"], seed=0)


def emit(name: str, us_per_call: float, derived: str):
    """The harness CSV contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeats: int = 1, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.time() - t0) / repeats * 1e6


# ---------------------------------------------------------------------------
# method runners: each returns (recalls dict, encode_time_us, search_time_us)
# ---------------------------------------------------------------------------

def run_unq(ds, num_books: int, scale: str, *, tcfg_overrides=None,
            search_overrides=None, scan_impl: str = "xla"):
    s = SCALES[scale]
    cfg = unq.UNQConfig(dim=ds.dim, num_codebooks=num_books)
    tkw = dict(epochs=s["epochs"], batch_size=256, lr=5e-3, alpha=0.01,
               log_every=200)
    tkw.update(tcfg_overrides or {})
    tcfg = training.TrainConfig(**tkw)
    params, state, hist = training.train_unq(ds, cfg, tcfg)

    base = jnp.asarray(ds.base)
    t0 = time.time()
    codes = search.encode_database(params, state, cfg, base)
    jax.block_until_ready(codes)
    encode_us = (time.time() - t0) * 1e6

    skw = dict(rerank=s["rerank"], topk=100, scan_impl=scan_impl)
    skw.update(search_overrides or {})
    scfg = search.SearchConfig(**skw)
    queries = jnp.asarray(ds.queries)
    t0 = time.time()
    retrieved = search.search(params, state, cfg, scfg, queries, codes)
    jax.block_until_ready(retrieved)
    search_us = (time.time() - t0) * 1e6 / len(ds.queries)
    rec = search.recall_at_k(retrieved, jnp.asarray(ds.gt_nn))
    return rec, encode_us, search_us, (params, state, cfg, codes)


def run_pq(ds, num_books: int, scale: str, *, opq: bool = False):
    s = SCALES[scale]
    key = jax.random.PRNGKey(0)
    train = jnp.asarray(ds.train)
    if opq:
        model = bl.train_opq(key, train, num_books,
                             outer_iters=s["opq_iters"],
                             kmeans_iters=max(s["kmeans_iters"] // 2, 4))
    else:
        model = bl.train_pq(key, train, num_books, iters=s["kmeans_iters"])
    base = jnp.asarray(ds.base)
    t0 = time.time()
    codes = model.encode(base)
    jax.block_until_ready(codes)
    encode_us = (time.time() - t0) * 1e6
    t0 = time.time()
    retrieved = bl.search_pq(model, jnp.asarray(ds.queries), codes, topk=100)
    jax.block_until_ready(retrieved)
    search_us = (time.time() - t0) * 1e6 / len(ds.queries)
    rec = search.recall_at_k(retrieved, jnp.asarray(ds.gt_nn))
    return rec, encode_us, search_us, (model, codes)


def run_rvq(ds, num_books: int, scale: str, *, rerank_decoder: bool = False):
    s = SCALES[scale]
    key = jax.random.PRNGKey(0)
    train = jnp.asarray(ds.train)
    model = bl.train_rvq(key, train, num_books, iters=s["kmeans_iters"])
    base = jnp.asarray(ds.base)
    t0 = time.time()
    codes = model.encode(base)
    recon_base = model.decode(codes)
    norms = jnp.sum(recon_base * recon_base, axis=-1)
    jax.block_until_ready(norms)
    encode_us = (time.time() - t0) * 1e6

    queries = jnp.asarray(ds.queries)
    if not rerank_decoder:
        t0 = time.time()
        retrieved = bl.search_rvq(model, queries, codes, norms, topk=100)
        jax.block_until_ready(retrieved)
        search_us = (time.time() - t0) * 1e6 / len(ds.queries)
        rec = search.recall_at_k(retrieved, jnp.asarray(ds.gt_nn))
        return rec, encode_us, search_us, (model, codes)

    # "LSQ + rerank"-style: learned MLP decoder reranks the shallow top-L
    recon_train = model.decode(model.encode(train))
    dec_params, apply_fn = bl.train_rerank_decoder(
        jax.random.PRNGKey(1), recon_train, train, steps=1500)
    t0 = time.time()
    cand = bl.search_rvq(model, queries, codes, norms, topk=s["rerank"])
    retrieved = bl.rerank_with_decoder(apply_fn, dec_params, model, queries,
                                       codes, cand, topk=100)
    jax.block_until_ready(retrieved)
    search_us = (time.time() - t0) * 1e6 / len(ds.queries)
    rec = search.recall_at_k(retrieved, jnp.asarray(ds.gt_nn))
    return rec, encode_us, search_us, (model, codes)


def fmt_recalls(rec: dict) -> str:
    return (f"R@1={rec['recall@1']:.3f} R@10={rec['recall@10']:.3f} "
            f"R@100={rec['recall@100']:.3f}")
