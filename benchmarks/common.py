"""Shared benchmark plumbing: datasets, method runners, CSV emission.

Every method now runs through the unified ``repro.index`` API (one
factory-built index per paper row), so the per-method runners are thin
wrappers around one timed train/add/search harness.

Scales: --quick (CI, ~1 min), default (a few minutes/table), --full
(closest to the paper's 500k-train/1M-base protocol this container can do).
The synthetic Deep/BigANN stand-ins come from repro.data.descriptors.
"""
from __future__ import annotations

import functools
import os
import random
import time

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core.search import recall_at_k
from repro.data import descriptors as dd
from repro.index import index_factory

SCALES = {
    "quick": dict(n_train=3000, n_base=8000, n_query=300, epochs=30,
                  kmeans_iters=8, opq_iters=3, rerank=100),
    "default": dict(n_train=15000, n_base=40000, n_query=800, epochs=40,
                    kmeans_iters=15, opq_iters=5, rerank=300),
    "full": dict(n_train=60000, n_base=200000, n_query=2000, epochs=60,
                 kmeans_iters=25, opq_iters=8, rerank=500),
}


@functools.lru_cache(maxsize=4)
def dataset(kind: str, scale: str):
    s = SCALES[scale]
    return dd.make_synthetic_dataset(
        kind, n_train=s["n_train"], n_base=s["n_base"],
        n_query=s["n_query"], seed=0)


def emit(name: str, us_per_call: float, derived: str):
    """The harness CSV contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeats: int = 1, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.time() - t0) / repeats * 1e6


def timed_group(fns: dict, *, repeats: int = 6) -> dict:
    """Time a set of comparison rows INTERLEAVED (one warmup each, then
    ``repeats`` rounds visiting every fn) and return {name: (out, us)}
    with min-of-rounds us. Sequential timing on a shared/virtualized CPU
    drifts ±30% between calls, which is enough to flip a comparison row;
    interleaving exposes every fn to the same ambient conditions, so the
    RELATIVE numbers (the whole point of tuned-vs-default and
    f32-vs-f16-vs-i8 rows) are stable.

    The visit order is SHUFFLED each round (fixed seed, deterministic):
    any static order hands some row a systematically better context — a
    fixed cyclic order gives every fn a fixed predecessor (a row right
    after its identical twin runs warm), and forward/reversed
    alternation gives the first/last rows back-to-back self-repeats at
    the round boundaries that middle rows never get (a measured ~6%
    edge for an edge row over its identical middle twin). Shuffling
    spreads predecessors evenly; min-of-rounds then keeps each fn's
    best context."""
    outs = {name: fn() for name, fn in fns.items()}      # warmup/compile
    jax.block_until_ready(list(outs.values()))
    best = {name: float("inf") for name in fns}
    order = list(fns)
    shuffle = random.Random(0x5eed).shuffle
    for _ in range(max(repeats, 1)):
        shuffle(order)
        for name in order:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[name]())
            best[name] = min(best[name],
                             (time.perf_counter() - t0) * 1e6)
    return {name: (outs[name], best[name]) for name in fns}


def latency_summary(latencies_ms) -> dict:
    """p50/p95/p99 rows for a serving latency sample (ms). One shared
    helper so every latency reporter (bench_serve, the example driver)
    quotes the same percentile math — and none of them ever folds the
    first batch's jit compile into the distribution: callers warm up per
    shape bucket first (``ServeEngine.warmup``) and report cold-compile
    as its own line."""
    from repro.serve.metrics import latency_percentiles
    return latency_percentiles(latencies_ms)


def with_defaults(fn):
    """Run ``fn`` with the autotuner disabled (``REPRO_TUNE_DISABLE=1``),
    so every block param resolves to the hand-pinned ``DEFAULT_*``
    registry fallback — the baseline side of the tuned-vs-default rows."""
    def wrapped(*args, **kw):
        from repro.kernels import tune
        prev = os.environ.get(tune.DISABLE_ENV)
        os.environ[tune.DISABLE_ENV] = "1"
        try:
            return fn(*args, **kw)
        finally:
            if prev is None:
                os.environ.pop(tune.DISABLE_ENV, None)
            else:
                os.environ[tune.DISABLE_ENV] = prev
    return wrapped


# ---------------------------------------------------------------------------
# method runners: each returns (recalls, encode_us, search_us, index)
# ---------------------------------------------------------------------------

def _timed_add_search(index, ds, *, topk: int = 100, search_kw=None):
    """Shared harness: time index.add over the base set and index.search
    over the query set; returns (recalls, encode_us, search_us)."""
    base = jnp.asarray(ds.base)
    t0 = time.time()
    index.add(base)
    jax.block_until_ready(index.codes)
    encode_us = (time.time() - t0) * 1e6

    queries = jnp.asarray(ds.queries)
    t0 = time.time()
    _, retrieved = index.search(queries, topk, **(search_kw or {}))
    jax.block_until_ready(retrieved)
    search_us = (time.time() - t0) * 1e6 / len(ds.queries)
    rec = recall_at_k(retrieved, jnp.asarray(ds.gt_nn))
    return rec, encode_us, search_us


def run_unq(ds, num_books: int, scale: str, *, tcfg_overrides=None,
            search_overrides=None, scan_impl: str = "xla"):
    s = SCALES[scale]
    so = dict(search_overrides or {})
    rerank = so.pop("rerank", s["rerank"])
    topk = so.pop("topk", 100)
    scan_impl = so.pop("scan_impl", scan_impl)   # old SearchConfig field
    index = index_factory(f"UNQ{num_books}x256,Rerank{rerank}",
                          dim=ds.dim, backend=scan_impl)
    tkw = dict(epochs=s["epochs"], batch_size=256, lr=5e-3, alpha=0.01,
               log_every=200)
    tkw.update(tcfg_overrides or {})
    index.train(ds.train, **tkw)
    rec, encode_us, search_us = _timed_add_search(index, ds, topk=topk,
                                                  search_kw=so)
    return rec, encode_us, search_us, index


def run_pq(ds, num_books: int, scale: str, *, opq: bool = False,
           scan_impl: str = "auto"):
    s = SCALES[scale]
    spec = ("OPQ" if opq else "PQ") + f"{num_books}x256"
    index = index_factory(spec, dim=ds.dim, backend=scan_impl)
    if opq:
        index.train(ds.train, outer_iters=s["opq_iters"],
                    kmeans_iters=max(s["kmeans_iters"] // 2, 4))
    else:
        index.train(ds.train, iters=s["kmeans_iters"])
    rec, encode_us, search_us = _timed_add_search(index, ds)
    return rec, encode_us, search_us, index


def run_rvq(ds, num_books: int, scale: str, *, rerank_decoder: bool = False,
            scan_impl: str = "auto"):
    s = SCALES[scale]
    index = index_factory(f"RVQ{num_books}x256", dim=ds.dim,
                          backend=scan_impl)
    index.train(ds.train, iters=s["kmeans_iters"])
    if not rerank_decoder:
        rec, encode_us, search_us = _timed_add_search(index, ds)
        return rec, encode_us, search_us, index

    # "LSQ + rerank"-style: learned MLP decoder reranks the shallow top-L
    rec, encode_us, _ = _timed_add_search(index, ds)   # populates codes
    train = jnp.asarray(ds.train)
    recon_train = index.model.decode(index.model.encode(train))
    dec_params, apply_fn = bl.train_rerank_decoder(
        jax.random.PRNGKey(1), recon_train, train, steps=1500)
    queries = jnp.asarray(ds.queries)
    t0 = time.time()
    _, cand = index.search(queries, s["rerank"], use_rerank=False)
    retrieved = bl.rerank_with_decoder(apply_fn, dec_params, index.model,
                                       queries, index.codes, cand, topk=100)
    jax.block_until_ready(retrieved)
    search_us = (time.time() - t0) * 1e6 / len(ds.queries)
    rec = recall_at_k(retrieved, jnp.asarray(ds.gt_nn))
    return rec, encode_us, search_us, index


def fmt_recalls(rec: dict) -> str:
    return (f"R@1={rec['recall@1']:.3f} R@10={rec['recall@10']:.3f} "
            f"R@100={rec['recall@100']:.3f}")
