"""Paper Table 5: ablation of the UNQ training objective / search stages
(8 bytes, BigANN-style data).

  unq                  — the full method
  exhaustive-rerank    — stage 2 (d1) over the whole base, no d2 scan
  no-rerank            — d2 scan only
  no-triplet           — alpha = 0
  triplet-only         — no reconstruction objective term in search (d2 only
                         search on a model trained with alpha=1)
  no-hard              — soft Gumbel (no ST discretization) during training
  no-gumbel            — deterministic softmax relaxation (no Gumbel noise)
  no-regularizer       — beta = 0

The search-stage ablations are now just ``Index.search`` flags
(``use_rerank`` / ``use_d2``).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.core.search import recall_at_k


def run(scale: str = "default", kind: str = "sift", num_books: int = 8):
    ds = common.dataset(kind, scale)

    variants = {
        "unq": dict(),
        "exhaustive-rerank": dict(search_kw=dict(use_d2=False)),
        "no-rerank": dict(search_kw=dict(use_rerank=False)),
        "no-triplet": dict(tcfg_overrides=dict(alpha=0.0)),
        "triplet-only": dict(tcfg_overrides=dict(alpha=1.0)),
        "no-hard": dict(tcfg_overrides=dict(hard_gumbel=False)),
        "no-gumbel": dict(tcfg_overrides=dict(gumbel_noise=False)),
        "no-regularizer": dict(tcfg_overrides=dict(use_regularizer=False)),
    }

    for name, kw in variants.items():
        rec, enc_us, search_us, index = common.run_unq(
            ds, num_books, scale, tcfg_overrides=kw.get("tcfg_overrides"))
        if "search_kw" in kw:
            _, got = index.search(jnp.asarray(ds.queries), 100,
                                  **kw["search_kw"])
            rec = recall_at_k(got, jnp.asarray(ds.gt_nn))
        common.emit(f"ablation/{kind}{num_books}B/{name}", search_us,
                    common.fmt_recalls(rec))


if __name__ == "__main__":
    run()
