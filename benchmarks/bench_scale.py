"""Paper Tables 3/4: does the UNQ advantage persist as the base set grows?
One trained model per method; recall measured on nested base subsets."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.core import search
from repro.data import descriptors as dd


def run(scale: str = "default", kind: str = "deep", num_books: int = 8):
    ds = common.dataset(kind, scale)
    sizes = [ds.base.shape[0] // 8, ds.base.shape[0] // 2, ds.base.shape[0]]

    rec_u, _, _, (params, state, cfg, codes_full) = common.run_unq(
        ds, num_books, scale)
    rec_p, _, _, (pq_model, pq_codes) = common.run_pq(ds, num_books, scale)

    for n in sizes:
        base = ds.base[:n]
        gt = dd.exact_knn(ds.queries, base, k=1)[:, 0]
        scfg = search.SearchConfig(
            rerank=min(common.SCALES[scale]["rerank"], n), topk=100)
        got = search.search(params, state, cfg, scfg,
                            jnp.asarray(ds.queries), codes_full[:n])
        rec = search.recall_at_k(got, jnp.asarray(gt))
        common.emit(f"scale/{kind}{num_books}B/unq/n={n}", 0.0,
                    common.fmt_recalls(rec))

        from repro.core import baselines as bl
        got_pq = bl.search_pq(pq_model, jnp.asarray(ds.queries),
                              pq_codes[:n], topk=100)
        rec_pq = search.recall_at_k(got_pq, jnp.asarray(gt))
        common.emit(f"scale/{kind}{num_books}B/pq/n={n}", 0.0,
                    common.fmt_recalls(rec_pq))


if __name__ == "__main__":
    run()
