"""Paper Tables 3/4: does the UNQ advantage persist as the base set grows?
One trained model per method; recall measured on nested base subsets
(``Index.with_codes`` gives a truncated view over the same quantizer)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.core.search import recall_at_k
from repro.data import descriptors as dd


def run(scale: str = "default", kind: str = "deep", num_books: int = 8):
    ds = common.dataset(kind, scale)
    sizes = [ds.base.shape[0] // 8, ds.base.shape[0] // 2, ds.base.shape[0]]

    _, _, _, unq_index = common.run_unq(ds, num_books, scale)
    _, _, _, pq_index = common.run_pq(ds, num_books, scale)

    queries = jnp.asarray(ds.queries)
    for n in sizes:
        base = ds.base[:n]
        gt = jnp.asarray(dd.exact_knn(ds.queries, base, k=1)[:, 0])

        sub = unq_index.subset(n)
        sub.rerank = min(common.SCALES[scale]["rerank"], n)
        _, got = sub.search(queries, 100)
        common.emit(f"scale/{kind}{num_books}B/unq/n={n}", 0.0,
                    common.fmt_recalls(recall_at_k(got, gt)))

        _, got_pq = pq_index.subset(n).search(queries, 100)
        common.emit(f"scale/{kind}{num_books}B/pq/n={n}", 0.0,
                    common.fmt_recalls(recall_at_k(got_pq, gt)))


if __name__ == "__main__":
    run()
