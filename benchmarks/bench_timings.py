"""Paper §4.4: encoding + search timings, plus per-implementation ADC scan
microbenchmarks (xla gather vs onehot-MXU vs Pallas-interpret).

NOTE: this container is CPU-only, so absolute numbers are NOT the paper's
GPU/TPU numbers; the derived columns (vectors/s, relative impl cost) are
the portable signal, and the Pallas timing is interpret-mode (correctness
path) — on TPU the kernel is the fast path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import unq
from repro.index import UNQIndex
from repro.kernels import ops


def run(scale: str = "default"):
    ds = common.dataset("deep", scale)
    cfg = unq.UNQConfig(dim=ds.dim, num_codebooks=8)
    key = jax.random.PRNGKey(0)
    params, state = unq.init(key, cfg)
    base = jnp.asarray(ds.base)
    rerank = common.SCALES[scale]["rerank"]
    index = UNQIndex.from_trained(params, state, cfg, rerank=rerank,
                                  backend="xla")

    # --- encode throughput (one feed-forward pass; the paper's headline
    # advantage over iterative additive encoders) ---
    t0 = time.time()
    index.add(base)
    codes = index.codes
    jax.block_until_ready(codes)
    dt = time.time() - t0
    common.emit("timings/encode", dt * 1e6,
                f"{base.shape[0] / dt:.0f} vectors/s")

    # --- ADC scan implementations ---
    rng = np.random.default_rng(0)
    n = base.shape[0]
    lut = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
    for impl in ("xla", "onehot", "pallas"):
        fn = jax.jit(lambda c, l, impl=impl: ops.adc_scan(c, l, impl=impl))
        _, us = common.timed(fn, codes, lut, repeats=3)
        common.emit(f"timings/adc_scan/{impl}", us,
                    f"{n / (us / 1e6) / 1e6:.1f} Mvec/s")

    # --- batched multi-query scan (the Index.search hot path): one code
    # stream amortized over all Q LUTs vs Q per-query scans ---
    qn = 32
    luts = jnp.asarray(rng.normal(size=(qn, 8, 256)), jnp.float32)
    for impl in ("xla", "onehot", "pallas"):
        fn = jax.jit(
            lambda c, l, impl=impl: ops.adc_scan_batch(c, l, impl=impl))
        _, us = common.timed(fn, codes, luts, repeats=3)
        common.emit(f"timings/adc_scan_batch/{impl}", us,
                    f"{qn * n / (us / 1e6) / 1e6:.1f} Mquery-vec/s")

    # --- top-L + rerank stage cost (paper: rerank is ~negligible), through
    # the streaming stage-1 engine via Index.search ---
    queries = jnp.asarray(ds.queries[:64])
    t0 = time.time()
    _, r1 = index.search(queries, 100, use_rerank=False)
    jax.block_until_ready(r1)
    scan_us = (time.time() - t0) / 64 * 1e6
    t0 = time.time()
    _, r2 = index.search(queries, 100, use_rerank=True)
    jax.block_until_ready(r2)
    full_us = (time.time() - t0) / 64 * 1e6
    common.emit("timings/search/no-rerank", scan_us, "per-query d2 scan")
    common.emit("timings/search/with-rerank", full_us,
                f"rerank overhead {full_us - scan_us:.0f}us "
                f"({(full_us / max(scan_us, 1e-9) - 1) * 100:.0f}%)")


if __name__ == "__main__":
    run()
