"""Quickstart: the three-line ``index_factory -> train -> search`` flow —
train UNQ on synthetic descriptors, compress a base set, run the two-stage
compressed-domain search, report Recall@k.

    PYTHONPATH=src python examples/quickstart.py [--epochs 30]
"""
import argparse
import time

import jax.numpy as jnp

from repro.core.search import recall_at_k
from repro.data.descriptors import make_synthetic_dataset
from repro.index import index_factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--bytes", type=int, default=8, choices=[8, 16])
    ap.add_argument("--factory", default=None,
                    help="override the index factory string, e.g. "
                         "'OPQ8x256,Rerank200' or 'UNQ8x256,Scan(onehot)'")
    args = ap.parse_args()

    print("== 1. data (Deep1M-style synthetic) ==")
    ds = make_synthetic_dataset("deep", n_train=5000, n_base=20000,
                                n_query=500)
    print(f"train={ds.train.shape} base={ds.base.shape} "
          f"queries={ds.queries.shape}")

    spec = args.factory or f"UNQ{args.bytes}x256,Rerank200"
    print(f"== 2. build index: {spec} ==")
    index = index_factory(spec, dim=ds.dim)
    t0 = time.time()
    index.train(ds.train, epochs=args.epochs, lr=5e-3, log_every=100)
    print(f"trained in {time.time() - t0:.0f}s")

    print("== 3. compress the base set (index.add) ==")
    index.add(ds.base)
    codes = index.codes
    print(f"codes {codes.shape} {codes.dtype} -> "
          f"{codes.size / 2**20:.2f} MB for "
          f"{ds.base.nbytes / 2**20:.1f} MB of vectors; {index}")

    print("== 4. two-stage search (batched LUT scan + decoder rerank) ==")
    t0 = time.time()
    _, retrieved = index.search(jnp.asarray(ds.queries), 100)
    dt = (time.time() - t0) / len(ds.queries) * 1e3
    rec = recall_at_k(retrieved, jnp.asarray(ds.gt_nn))
    print(f"recall: {rec}  ({dt:.1f} ms/query on CPU)")


if __name__ == "__main__":
    main()
