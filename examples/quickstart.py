"""Quickstart: train UNQ on synthetic descriptors, compress a base set,
run the two-stage compressed-domain search, report Recall@k.

    PYTHONPATH=src python examples/quickstart.py [--epochs 30]
"""
import argparse
import time

import jax.numpy as jnp

from repro.configs import unq_paper
from repro.core import search, training, unq
from repro.data.descriptors import make_synthetic_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--bytes", type=int, default=8, choices=[8, 16])
    args = ap.parse_args()

    print("== 1. data (Deep1M-style synthetic) ==")
    ds = make_synthetic_dataset("deep", n_train=5000, n_base=20000,
                                n_query=500)
    print(f"train={ds.train.shape} base={ds.base.shape} "
          f"queries={ds.queries.shape}")

    print("== 2. train UNQ ==")
    cfg = unq.UNQConfig(dim=ds.dim, num_codebooks=args.bytes)
    tcfg = training.TrainConfig(epochs=args.epochs, lr=5e-3, log_every=100)
    t0 = time.time()
    params, state, hist = training.train_unq(
        ds, cfg, tcfg,
        callback=lambda s, m: print(
            f"  step {s:5d} recon={m['recon']:.3f} cv2={m['cv2']:.3f}"))
    print(f"trained in {time.time() - t0:.0f}s; "
          f"model {unq.model_size_bytes(params) / 2**20:.1f} MB")

    print("== 3. compress the base set ==")
    codes = search.encode_database(params, state, cfg, jnp.asarray(ds.base))
    print(f"codes {codes.shape} {codes.dtype} -> "
          f"{codes.size / 2**20:.2f} MB for "
          f"{ds.base.nbytes / 2**20:.1f} MB of vectors")

    print("== 4. two-stage search (LUT scan + decoder rerank) ==")
    scfg = search.SearchConfig(rerank=200, topk=100)
    t0 = time.time()
    retrieved = search.search(params, state, cfg, scfg,
                              jnp.asarray(ds.queries), codes)
    dt = (time.time() - t0) / len(ds.queries) * 1e3
    rec = search.recall_at_k(retrieved, jnp.asarray(ds.gt_nn))
    print(f"recall: {rec}  ({dt:.1f} ms/query on CPU)")


if __name__ == "__main__":
    main()
