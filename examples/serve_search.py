"""End-to-end serving driver (the paper's deployment shape): build a
compressed ANN index, then serve a request trace through ``repro.serve``
— deadline-aware queue, pow2-bucket dynamic batching, double-buffered
dispatch — with honest latency stats: one warm-up batch per shape bucket
runs BEFORE the timed trace, and the jit cold-compile cost is reported
as its own line instead of polluting p50/p95 (the first batch of a cold
process used to dominate both percentiles).

    PYTHONPATH=src python examples/serve_search.py [--shards 8]
        [--placement auto|host|device] [--rate 200]

(Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise
the device-resident sharded path on a CPU-only host.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.search import recall_at_k
from repro.data.descriptors import make_synthetic_dataset
from repro.index import ShardedIndex, index_factory
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="open-loop arrival rate (req/s)")
    ap.add_argument("--factory", default="UNQ8x256,Rerank200")
    ap.add_argument("--placement", default="auto",
                    choices=["auto", "host", "device"])
    args = ap.parse_args()

    print(f"== build index: {args.factory} x{args.shards} shards "
          f"({len(jax.devices())} devices) ==")
    ds = make_synthetic_dataset("deep", n_train=5000, n_base=40000,
                                n_query=args.batch * args.requests)
    index = ShardedIndex(index_factory(args.factory, dim=ds.dim),
                         num_shards=args.shards, placement=args.placement)
    index.train(ds.train, epochs=15, lr=5e-3, log_every=1000)
    print(f"stage-1 placement: {index.resolved_placement}")

    t0 = time.time()
    index.add(ds.base)
    dt = time.time() - t0
    print(f"encoded {index.ntotal} vectors in {dt:.1f}s "
          f"({index.ntotal / dt:.0f} vec/s)")

    engine = ServeEngine(index, ServeConfig(
        max_batch_queries=args.batch, default_k=100))

    # warm-up: compile each shape bucket the trace will hit, OUTSIDE the
    # timed loop, and report the compile bill as its own line
    cold = engine.warmup(ks=(100,))
    print("cold-compile (excluded from latency): "
          + ", ".join(f"{k}={v:.0f}ms" for k, v in cold.items()))
    engine.metrics.reset()

    print(f"== serve {args.requests} requests of {args.batch} queries "
          f"open-loop at {args.rate:g} req/s ==")
    futures, spans = [], []
    period = 1.0 / args.rate
    t_next = time.perf_counter()
    for r in range(args.requests):
        now = time.perf_counter()
        if now < t_next:
            time.sleep(t_next - now)
        lo, hi = r * args.batch, (r + 1) * args.batch
        futures.append(engine.submit(ds.queries[lo:hi], k=100))
        spans.append((lo, hi))
        t_next += period

    hits = 0
    for f, (lo, hi) in zip(futures, spans):
        _, retrieved = f.result(timeout=300)
        rec = recall_at_k(jnp.asarray(retrieved),
                          jnp.asarray(ds.gt_nn[lo:hi]), ks=(10,))
        hits += rec["recall@10"] * (hi - lo)
    engine.close()

    s = engine.metrics.summary()
    print(f"latency/request: p50={s['p50_ms']:.1f}ms "
          f"p95={s['p95_ms']:.1f}ms p99={s['p99_ms']:.1f}ms "
          f"({s['batches']} batches, {s['padded_queries']} pad rows)")
    print(f"R@10 over served queries: "
          f"{hits / (args.requests * args.batch):.3f}")


if __name__ == "__main__":
    main()
