"""End-to-end serving driver (the paper's deployment shape): build a
compressed ANN index, then serve batched similarity queries with latency
stats — index sharded as it would be across a pod (one shard per device;
on this CPU container the shards are logical).

    PYTHONPATH=src python examples/serve_search.py [--shards 8]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import search, training, unq
from repro.data.descriptors import make_synthetic_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    print("== build index ==")
    ds = make_synthetic_dataset("deep", n_train=5000, n_base=40000,
                                n_query=args.batch * args.requests)
    cfg = unq.UNQConfig(dim=ds.dim, num_codebooks=8)
    tcfg = training.TrainConfig(epochs=15, lr=5e-3, log_every=1000)
    params, state, _ = training.train_unq(ds, cfg, tcfg)

    base = jnp.asarray(ds.base)
    t0 = time.time()
    codes = search.encode_database(params, state, cfg, base)
    print(f"encoded {base.shape[0]} vectors in {time.time() - t0:.1f}s "
          f"({base.shape[0] / (time.time() - t0):.0f} vec/s)")

    n = codes.shape[0]
    per = n // args.shards
    shards = [codes[i * per:(i + 1) * per] for i in range(args.shards)]
    offsets = [i * per for i in range(args.shards)]
    scfg = search.SearchConfig(rerank=200, topk=100)

    print(f"== serve {args.requests} batches of {args.batch} queries "
          f"({args.shards} index shards) ==")
    lat = []
    hits = 0
    for r in range(args.requests):
        q = jnp.asarray(ds.queries[r * args.batch:(r + 1) * args.batch])
        gt = ds.gt_nn[r * args.batch:(r + 1) * args.batch]
        t0 = time.time()
        cand = search.search_sharded(params, state, cfg, scfg, q,
                                     shards, offsets)
        # stage 2: exact rerank of merged candidates with the decoder
        final = []
        for i in range(q.shape[0]):
            recon = unq.decode_codes(params, state, cfg, codes[cand[i]])
            d1 = jnp.sum(jnp.square(recon - q[i]), axis=-1)
            order = jnp.argsort(d1)[:100]
            final.append(np.asarray(cand[i])[np.asarray(order)])
        lat.append((time.time() - t0) / args.batch * 1e3)
        hits += sum(gt[i] in final[i][:10] for i in range(args.batch))
    lat = np.array(lat)
    print(f"latency/query: p50={np.percentile(lat, 50):.1f}ms "
          f"p95={np.percentile(lat, 95):.1f}ms")
    print(f"R@10 over served queries: "
          f"{hits / (args.requests * args.batch):.3f}")


if __name__ == "__main__":
    main()
