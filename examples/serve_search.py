"""End-to-end serving driver (the paper's deployment shape): build a
compressed ANN index, then serve batched similarity queries with latency
stats. The index is wrapped in ``ShardedIndex``: with more than one device
visible the code shards live DEVICE-RESIDENT under shard_map — per-device
streaming scan+top-L, all-gather merge, one rerank — exactly the pod
layout; on a single host it falls back to logical shards.

    PYTHONPATH=src python examples/serve_search.py [--shards 8]
        [--placement auto|host|device]

(Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise
the device-resident path on a CPU-only host.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.search import recall_at_k
from repro.data.descriptors import make_synthetic_dataset
from repro.index import ShardedIndex, index_factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--factory", default="UNQ8x256,Rerank200")
    ap.add_argument("--placement", default="auto",
                    choices=["auto", "host", "device"])
    args = ap.parse_args()

    print(f"== build index: {args.factory} x{args.shards} shards "
          f"({len(jax.devices())} devices) ==")
    ds = make_synthetic_dataset("deep", n_train=5000, n_base=40000,
                                n_query=args.batch * args.requests)
    index = ShardedIndex(index_factory(args.factory, dim=ds.dim),
                         num_shards=args.shards, placement=args.placement)
    index.train(ds.train, epochs=15, lr=5e-3, log_every=1000)
    print(f"stage-1 placement: {index.resolved_placement}")

    t0 = time.time()
    index.add(ds.base)
    dt = time.time() - t0
    print(f"encoded {index.ntotal} vectors in {dt:.1f}s "
          f"({index.ntotal / dt:.0f} vec/s)")

    print(f"== serve {args.requests} batches of {args.batch} queries "
          f"({args.shards} index shards) ==")
    lat = []
    hits = 0
    for r in range(args.requests):
        q = jnp.asarray(ds.queries[r * args.batch:(r + 1) * args.batch])
        gt = ds.gt_nn[r * args.batch:(r + 1) * args.batch]
        t0 = time.time()
        _, retrieved = index.search(q, 100)
        retrieved.block_until_ready()
        lat.append((time.time() - t0) / args.batch * 1e3)
        rec = recall_at_k(retrieved, jnp.asarray(gt), ks=(10,))
        hits += rec["recall@10"] * args.batch
    lat = np.array(lat)
    print(f"latency/query: p50={np.percentile(lat, 50):.1f}ms "
          f"p95={np.percentile(lat, 95):.1f}ms")
    print(f"R@10 over served queries: "
          f"{hits / (args.requests * args.batch):.3f}")


if __name__ == "__main__":
    main()
