"""The paper's technique inside an LM: decode with an MCQ-compressed KV
cache (compressed-domain attention scoring) vs the exact cache, comparing
memory and output agreement.

    PYTHONPATH=src python examples/kv_cache_compression.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import registry
from repro.utils.pytree import param_bytes


def cache_bytes(c):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c)
               if x.dtype == jnp.uint8 or jnp.issubdtype(x.dtype,
                                                         jnp.floating))


def run(arch="gemma3-12b", steps=24):
    base_cfg = configs.get(arch, smoke=True)
    kvq_cfg = base_cfg.with_(kvq=True, kvq_books=4, kvq_book_size=64)
    key = jax.random.PRNGKey(0)
    params = registry.init(key, base_cfg)
    print(f"arch={base_cfg.name} params={param_bytes(params)/2**20:.1f}MB")

    b, max_len = 2, 64
    toks = jax.random.randint(key, (b, steps), 0, base_cfg.vocab_size)

    outs = {}
    for tag, cfg in (("exact", base_cfg), ("kvq", kvq_cfg)):
        caches = registry.init_cache(cfg, b, max_len, dtype=jnp.float32)
        kv_bytes = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))
        step = jax.jit(lambda p, c, t, pos, cfg=cfg: registry.decode_step(
            p, cfg, c, t, pos))
        logits_seq = []
        for pos in range(steps):
            logits, caches = step(params, caches, toks[:, pos],
                                  jnp.asarray(pos, jnp.int32))
            logits_seq.append(logits)
        outs[tag] = jnp.stack(logits_seq, 1)
        print(f"{tag:6s}: cache={kv_bytes/2**20:.2f}MB")

    # agreement: top-1 next-token match between exact and compressed KV
    top_exact = jnp.argmax(outs["exact"], -1)
    top_kvq = jnp.argmax(outs["kvq"], -1)
    agree = float(jnp.mean((top_exact == top_kvq).astype(jnp.float32)))
    print(f"top-1 agreement (untrained net, hard case): {agree:.2f}")
    print("note: global-attention layers store uint8 codes (2*M bytes "
          "per token per kv-head instead of 2*dh*2 bf16 bytes)")


if __name__ == "__main__":
    run()
