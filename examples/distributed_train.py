"""Data+tensor-parallel training with fault tolerance, on 8 forced host
devices (run this script directly — it sets XLA_FLAGS before importing jax):

  * pjit train step on a (2, 4) ("data", "model") mesh
  * gradient compression (int8 + error feedback) on the DP reduction
  * checkpoint mid-run, kill (simulated), auto-resume, finish

    PYTHONPATH=src python examples/distributed_train.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import shutil
import tempfile

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, optim
from repro.data.tokens import TokenStream
from repro.models import registry
from repro.parallel import hints, sharding as shard_lib
from repro.parallel import steps as steps_lib
from repro.runtime import Trainer, TrainerConfig
from repro.utils.pytree import param_count


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="repro_dist_")
    cfg = configs.get("deepseek-moe-16b", smoke=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = dict(shard_lib.RULES_SINGLE_POD)
    print(f"devices={len(jax.devices())} mesh={dict(mesh.shape)} "
          f"arch={cfg.name}")

    params_ps = shard_lib.params_pspecs(registry.logical_axes(cfg), rules)
    train_step, opt = steps_lib.make_train_step(
        cfg, lr_fn=optim.constant(3e-4), grad_compress="int8",
        microbatches=2)

    def build():
        with mesh, hints.activation_sharding(rules, mesh):
            params = jax.jit(
                lambda: registry.init(jax.random.PRNGKey(0), cfg),
                out_shardings=jax.tree.map(
                    lambda s: NamedSharding(mesh, s), params_ps,
                    is_leaf=lambda x: isinstance(x, P)))()
            opt_state = jax.jit(opt.init)(params)
        return params, opt_state

    params, opt_state = build()
    print(f"params={param_count(params):,} (sharded over {mesh.size} dev)")
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8)

    # --- phase 1: run until an injected failure at step 7 ---
    tcfg = TrainerConfig(total_steps=12, checkpoint_every=3,
                         checkpoint_dir=ckpt_dir, crash_at_step=7,
                         log_every=2, async_checkpoint=False)
    with mesh, hints.activation_sharding(rules, mesh):
        t1 = Trainer(tcfg, jax.jit(train_step), params, opt_state, stream)
        try:
            t1.run()
        except RuntimeError as e:
            print(f"!! {e} — restarting from the latest checkpoint")

    # --- phase 2: fresh process state, auto-resume, finish ---
    params, opt_state = build()
    stream2 = TokenStream(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8)
    tcfg2 = TrainerConfig(total_steps=12, checkpoint_every=3,
                          checkpoint_dir=ckpt_dir, log_every=2,
                          async_checkpoint=False)
    with mesh, hints.activation_sharding(rules, mesh):
        t2 = Trainer(tcfg2, jax.jit(train_step), params, opt_state, stream2)
        final = t2.run()
    print(f"resumed at step {6}, finished at {t2.step}: "
          f"loss={final['loss']:.4f}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("OK")


if __name__ == "__main__":
    main()
