#!/usr/bin/env bash
# CI entry point: tier-1 tests + backend-parity smoke + stage-1 trajectory.
#
# REPRO_PALLAS_INTERPRET=1 pins the Pallas kernels to interpret mode so the
# fused scan+top-L (and every other kernel body) is exercised on every PR
# even on CPU-only runners; on a real TPU runner export
# REPRO_PALLAS_INTERPRET=0 (or leave it unset) to compile them.
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
if [ "$(python -c 'import jax; print(jax.default_backend())')" != "tpu" ]; then
  export REPRO_PALLAS_INTERPRET="${REPRO_PALLAS_INTERPRET:-1}"
fi

echo "== static analysis (HLO contracts + repo lint + compile discipline) =="
python -m repro.analysis.check
# the gate must also be able to FAIL: on the seeded-violation fixtures
# (oracle-less kernel, recompile hazards, a materialized (Q, N) scan) a
# zero exit means the detectors went blind
if python -m repro.analysis.check --seeded-violations > /dev/null 2>&1; then
  echo "ERROR: --seeded-violations exited 0 (detectors missed seeded defects)"
  exit 1
fi
echo "seeded-violation fixtures correctly rejected"
if command -v ruff > /dev/null 2>&1; then
  ruff check src tests benchmarks
else
  echo "(ruff not installed in this container; baseline lives in pyproject.toml)"
fi

echo "== tier-1 tests (docs suite runs in its own gate below) =="
python -m pytest -x -q --ignore=tests/test_docs.py

echo "== docs gate (snippet tests + dead intra-repo links) =="
python -m pytest -q tests/test_docs.py

echo "== autotuner quick sweep (self-checks + cache roundtrip, tmp cache) =="
# --quick sweeps one small bucket per engine kernel into a THROWAWAY cache
# path: proves the sweep driver, the determinism/schema self-checks and the
# cache I/O on every PR without touching the committed TUNE_CACHE.json
REPRO_TUNE_CACHE="$(mktemp -d)/tune_cache.json" python -m repro.tune --quick

echo "== backend-parity smoke (all scan backends vs xla oracle) =="
python -m benchmarks.run --smoke

echo "== stage-1 engine trajectory (writes BENCH_stage1.json) =="
python -m benchmarks.run --only stage1 --scale quick

echo "== stage-2 engine trajectory (writes BENCH_stage2.json) =="
python -m benchmarks.run --only stage2 --scale quick

echo "== IVF trajectory: nprobe dial + residual study (writes BENCH_ivf.json) =="
python -m benchmarks.run --only ivf --scale quick

echo "== serving smoke (batched-vs-solo parity + zero deadline misses) =="
# deterministic trace through repro.serve on flat + IVF indexes; exits
# non-zero if any batched request drifts bit-wise from searching it
# alone, or if any generously-deadlined request misses
python -m repro.serve --smoke

echo "== serving trajectory: latency under load (writes BENCH_serve.json) =="
python -m benchmarks.run --only serve --scale quick

echo "CI OK"
