"""UNQ training loop (paper §3.4).

Minibatch SGD on L = L1 + alpha*L2 + beta*CV^2 with QHAdam and a One-Cycle
learning-rate schedule; beta is annealed linearly 1.0 -> 0.05; triplet
positives/negatives are resampled from the exact neighbor lists at the
offset of every epoch, exactly as in the paper.

The step function is a single jitted pure function of
(params, state, opt_state, batch, step) so it drops into pjit unchanged for
data-parallel training (see repro/launch/train_unq.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, unq
from repro.data import descriptors as ddata
from repro import optim


# ---------------------------------------------------------------------------
# Ordered trainer pipeline (the Index.train substrate)
# ---------------------------------------------------------------------------
#
# ``repro.index.base.Index.train`` no longer hardcodes "fit one quantizer":
# every index declares an ORDERED list of TrainStages and the shared driver
# runs them front to back, feeding each stage the (possibly transformed)
# training vectors the previous stage returned. Plain quantizers are a
# single stage; composite indexes sequence theirs — IVF fits the coarse
# k-means FIRST and, in residual mode (IVFADC), hands ``x - centroid(x)``
# to the wrapped quantizer's stage, so codebook capacity is spent on the
# low-variance residual distribution instead of the raw vectors.


@dataclasses.dataclass(frozen=True)
class TrainStage:
    """One step of an index's ordered training pipeline.

    ``fit(xs, **kw)`` consumes the current training vectors plus the
    caller's keyword arguments (each stage picks out the ones it knows,
    swallowing the rest with ``**_``) and either returns ``None`` — the
    next stage sees the same vectors — or returns a TRANSFORMED array the
    downstream stages train on instead (IVF's coarse stage returning
    per-vector residuals is the canonical use).
    """

    name: str
    fit: Callable[..., Any]


def run_train_pipeline(stages, xs, kw: dict):
    """Run ``stages`` in order over training vectors ``xs``.

    Stage order is load-bearing, not cosmetic: a stage may transform the
    vectors every LATER stage sees (and may rely on the model state its
    predecessors installed — IVF's quantizer stage encodes residuals
    against the centroids the coarse stage just fit). Returns the vectors
    the final stage saw, mostly for tests.
    """
    for stage in stages:
        out = stage.fit(xs, **kw)
        if out is not None:
            xs = out
    return xs


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    epochs: int = 10
    batch_size: int = 256
    lr: float = 1e-3
    # QHAdam per the paper; b1=0.995 is Ma & Yarats' recommendation for
    # long schedules — at the few-thousand-step budgets this container can
    # afford, that 200-step momentum horizon slows convergence ~3x
    # (measured), so the default here is 0.9 (still QHAdam).
    qh_b1: float = 0.9
    alpha: float = 0.01          # triplet weight (paper grid {0.1,0.01,0.001})
    beta_start: float = 1.0      # CV^2 weight anneal (paper: 1.0 -> 0.05)
    beta_end: float = 0.05
    triplet_margin: float = 1.0
    commit_coef: float = 0.0     # optional VQ-VAE auxiliary (off: measured
                                 # to slow the paper objective down)
    hard_gumbel: bool = True     # ablation: "UNQ w/o hard"
    gumbel_noise: bool = True    # ablation: "UNQ w/o Gumbel"
    use_triplet: bool = True     # ablation: "No triplet loss"
    use_regularizer: bool = True # ablation: "No regularizer"
    # data-dependent codebook init: k-means over the initial encoder-head
    # outputs. OFF by default: measured on the synthetic benchmark it traps
    # the learned d2 space in a worse basin than the paper's random init
    # once the optimizer horizon is fixed (see EXPERIMENTS.md §Repro,
    # refuted-hypothesis log). Kept for experimentation.
    kmeans_init: bool = False
    seed: int = 0
    log_every: int = 50


def kmeans_init_codebooks(key, params, state, cfg: unq.UNQConfig, train_x,
                          sample: int = 8192, iters: int = 10):
    """Initialize each codebook with k-means over the initial encoder-head
    outputs (one warm-up pass also seeds the BatchNorm running stats).

    Codebook m is supported on its own d_c/M-dim slice of the code space,
    so the decoder input (the SUM of selected codewords, paper §3.2) is a
    concatenation at init — without this, all M codebooks start in the
    same region of the shared head space and their sum destructively
    interferes (measured: codes carry PQ-level information under a linear
    probe while the sum-decoder path stays at the variance floor).
    Training is free to rotate away from the block structure afterwards.
    """
    from repro.core.baselines import kmeans

    x = jnp.asarray(train_x[:sample])
    heads, enc_state = unq.encode_heads(params, state, cfg, x, train=True)
    keys = jax.random.split(key, cfg.num_codebooks)
    m_books = []
    if cfg.code_dim % cfg.num_codebooks == 0:
        d_sub = cfg.code_dim // cfg.num_codebooks
        for m in range(cfg.num_codebooks):
            sl = slice(m * d_sub, (m + 1) * d_sub)
            cent = kmeans(keys[m], heads[:, m, sl], cfg.codebook_size, iters)
            full = jnp.zeros((cfg.codebook_size, cfg.code_dim), cent.dtype)
            m_books.append(full.at[:, sl].set(cent))
    else:  # fall back to full-space k-means
        for m in range(cfg.num_codebooks):
            m_books.append(kmeans(keys[m], heads[:, m, :],
                                  cfg.codebook_size, iters))
    books = jnp.stack(m_books)

    # Temperature calibration: k-means codewords produce dot products with
    # std ~50-100, which saturates the softmax and kills the straight-
    # through gradient (measured: encoder stops learning entirely). Set
    # tau_m so the effective logits have std ~TARGET — sharp enough for
    # stable assignments, soft enough for gradient flow; tau stays a
    # learned parameter from here (paper Eq. 2).
    TARGET = 4.0
    dots = jnp.einsum("bmd,mkd->bmk", heads, books)
    dot_std = jnp.std(dots, axis=(0, 2))                     # (M,)
    log_tau = jnp.log(jnp.maximum(dot_std / TARGET, 1e-3)).astype(cfg.dtype)
    return ({**params, "codebooks": books.astype(cfg.dtype),
             "log_tau": log_tau},
            {**state, "encoder": enc_state})


def make_train_step(cfg: unq.UNQConfig, tcfg: TrainConfig, total_steps: int):
    lr_fn = optim.one_cycle(tcfg.lr, total_steps)
    beta_fn = optim.linear_anneal(tcfg.beta_start, tcfg.beta_end, total_steps)
    opt = optim.qhadam(b1=tcfg.qh_b1)

    @jax.jit
    def train_step(key, params, state, opt_state, batch, step):
        beta = beta_fn(step) if tcfg.use_regularizer else 0.0

        def loss_fn(p):
            return losses.unq_loss(
                key, p, state, cfg, batch,
                alpha=tcfg.alpha, beta=beta, margin=tcfg.triplet_margin,
                hard=tcfg.hard_gumbel, use_triplet=tcfg.use_triplet,
                gumbel_noise=tcfg.gumbel_noise,
                commit_coef=tcfg.commit_coef)

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = opt.apply(params, grads, opt_state, lr_fn(step))
        return params, aux["state"], opt_state, aux["metrics"]

    return train_step, opt


def train_unq(dataset: ddata.DescriptorDataset, cfg: unq.UNQConfig,
              tcfg: TrainConfig, *,
              callback: Callable[[int, dict], None] | None = None):
    """Train UNQ on a descriptor dataset. Returns (params, state, history)."""
    rng = np.random.default_rng(tcfg.seed)
    key = jax.random.PRNGKey(tcfg.seed)
    key, init_key = jax.random.split(key)
    params, state = unq.init(init_key, cfg)
    if tcfg.kmeans_init:
        key, km_key = jax.random.split(key)
        params, state = kmeans_init_codebooks(
            km_key, params, state, cfg, dataset.train)

    n = dataset.train.shape[0]
    steps_per_epoch = max(n // tcfg.batch_size, 1)
    total_steps = steps_per_epoch * tcfg.epochs
    train_step, opt = make_train_step(cfg, tcfg, total_steps)
    opt_state = opt.init(params)

    # Exact neighbor lists for triplet sampling (paper: once, re-sampled
    # per-epoch). Top-200 per training point.
    neighbors = None
    if tcfg.use_triplet and tcfg.alpha > 0:
        neighbors = ddata.epoch_neighbors(dataset.train, k=201)

    train_x = jnp.asarray(dataset.train)
    history: list[dict] = []
    step = 0
    for epoch in range(tcfg.epochs):
        if neighbors is not None:
            pos_idx, neg_idx = ddata.sample_triplets(rng, dataset.train,
                                                     neighbors)
        perm = rng.permutation(n)
        for it in range(steps_per_epoch):
            sel = perm[it * tcfg.batch_size:(it + 1) * tcfg.batch_size]
            batch = {"x": train_x[sel]}
            if neighbors is not None:
                batch["pos"] = train_x[pos_idx[sel]]
                batch["neg"] = train_x[neg_idx[sel]]
            else:
                batch["pos"] = batch["x"]
                batch["neg"] = batch["x"]
            key, step_key = jax.random.split(key)
            params, state, opt_state, metrics = train_step(
                step_key, params, state, opt_state, batch,
                jnp.asarray(step, jnp.int32))
            if step % tcfg.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(epoch=epoch, step=step, time=time.time())
                history.append(m)
                if callback:
                    callback(step, m)
            step += 1
    return params, state, history
