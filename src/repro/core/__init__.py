# The paper's primary contribution: Unsupervised Neural Quantization —
# model (unq), objective (losses), two-stage compressed-domain search
# (search), shallow MCQ baselines (baselines), and the trainer (training).
from repro.core.unq import UNQConfig
from repro.core.search import SearchConfig, recall_at_k
from repro.core.training import TrainConfig, train_unq

__all__ = ["UNQConfig", "SearchConfig", "TrainConfig", "train_unq",
           "recall_at_k"]
