"""Shallow MCQ baselines from the paper's comparison (Table 2-4).

  * PQ   — Product Quantization (Jegou et al. 2011): per-subspace k-means.
  * OPQ  — Optimized PQ (Ge et al. 2013): alternating rotation (procrustes)
           + PQ, the "OPQ" row of Table 2.
  * RVQ  — Residual Vector Quantization (Chen et al. 2010): greedy additive
           quantization; stands in for the additive/LSQ family (the paper's
           strongest shallow baseline is LSQ — same encoding/ADC structure;
           LSQ's ILS codebook refinement is noted as out of scope, so RVQ
           recall should be read as a slightly conservative stand-in).
  * rerank decoders — "LSQ + rerank": an MLP decoder trained on
           reconstruction (Eq. 9) used to re-rank the shallow top-L, the
           paper's strongest non-UNQ configuration.

All baselines reuse the same ADC scan kernel as UNQ (repro.kernels.ops),
so every method in the benchmark shares one compressed-domain scan path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


# ---------------------------------------------------------------------------
# k-means substrate (JAX, chunked Lloyd iterations)
# ---------------------------------------------------------------------------

def kmeans(key, x: jax.Array, k: int, iters: int = 25) -> jax.Array:
    """Lloyd's algorithm; returns centroids (k, d). Empty clusters are
    re-seeded from random points (standard practice for 256-way codebooks)."""
    n = x.shape[0]
    init_idx = jax.random.choice(key, n, (k,), replace=n < k)
    cent = x[init_idx]

    @jax.jit
    def step(cent, rkey):
        d = (jnp.sum(x * x, axis=1)[:, None] - 2.0 * x @ cent.T
             + jnp.sum(cent * cent, axis=1)[None, :])
        assign = jnp.argmin(d, axis=1)                       # (n,)
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)    # (n, k)
        counts = jnp.sum(onehot, axis=0)                     # (k,)
        sums = onehot.T @ x                                  # (k, d)
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # re-seed empties
        reseed = x[jax.random.randint(rkey, (k,), 0, n)]
        return jnp.where(counts[:, None] > 0, new, reseed)

    for i in range(iters):
        key, rkey = jax.random.split(key)
        cent = step(cent, rkey)
    return cent


@jax.jit
def _assign(x: jax.Array, cent: jax.Array) -> jax.Array:
    d = (jnp.sum(x * x, axis=1)[:, None] - 2.0 * x @ cent.T
         + jnp.sum(cent * cent, axis=1)[None, :])
    return jnp.argmin(d, axis=1)


# ---------------------------------------------------------------------------
# PQ
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PQModel:
    codebooks: jax.Array          # (M, K, D/M)
    rotation: jax.Array | None = None   # OPQ: (D, D)

    @property
    def num_books(self) -> int:
        return self.codebooks.shape[0]

    def _maybe_rotate(self, x):
        return x @ self.rotation if self.rotation is not None else x

    def encode(self, x: jax.Array) -> jax.Array:
        """(N, D) -> (N, M) uint8."""
        x = self._maybe_rotate(x)
        m, k, d_sub = self.codebooks.shape
        xs = x.reshape(x.shape[0], m, d_sub)
        codes = jax.vmap(_assign, in_axes=(1, 0), out_axes=1)(xs, self.codebooks)
        return codes.astype(jnp.uint8)

    def decode(self, codes: jax.Array) -> jax.Array:
        m, k, d_sub = self.codebooks.shape
        m_idx = jnp.arange(m)[None, :]
        cw = self.codebooks[m_idx, codes.astype(jnp.int32)]   # (N, M, d_sub)
        x = cw.reshape(codes.shape[0], m * d_sub)
        return x @ self.rotation.T if self.rotation is not None else x

    def lut(self, q: jax.Array) -> jax.Array:
        """Squared-L2 distance tables for one query: (M, K)."""
        q = self._maybe_rotate(q[None, :])[0]
        m, k, d_sub = self.codebooks.shape
        qs = q.reshape(m, 1, d_sub)
        return jnp.sum(jnp.square(qs - self.codebooks), axis=-1)


def train_pq(key, train: jax.Array, num_books: int, book_size: int = 256,
             iters: int = 25) -> PQModel:
    d = train.shape[1]
    assert d % num_books == 0
    d_sub = d // num_books
    xs = train.reshape(train.shape[0], num_books, d_sub)
    keys = jax.random.split(key, num_books)
    books = jnp.stack([kmeans(keys[m], xs[:, m, :], book_size, iters)
                       for m in range(num_books)])
    return PQModel(books)


def train_opq(key, train: jax.Array, num_books: int, book_size: int = 256,
              outer_iters: int = 8, kmeans_iters: int = 10) -> PQModel:
    """OPQ-NP: alternate procrustes rotation and PQ codebooks."""
    d = train.shape[1]
    rot = jnp.eye(d, dtype=train.dtype)
    model = None
    for it in range(outer_iters):
        key, sub = jax.random.split(key)
        xr = train @ rot
        model = train_pq(sub, xr, num_books, book_size, kmeans_iters)
        recon = model.decode(model.encode(xr))       # in rotated space
        # procrustes: argmin_R ||X R - recon||_F, R orthogonal
        u, _, vt = jnp.linalg.svd(train.T @ recon, full_matrices=False)
        rot = u @ vt
    final = train_pq(key, train @ rot, num_books, book_size, iters=25)
    final.rotation = rot
    return final


# ---------------------------------------------------------------------------
# RVQ (additive family)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RVQModel:
    codebooks: jax.Array          # (M, K, D) — full-dimensional codewords

    def encode(self, x: jax.Array) -> jax.Array:
        res = x
        codes = []
        for m in range(self.codebooks.shape[0]):
            c = _assign(res, self.codebooks[m])
            codes.append(c)
            res = res - self.codebooks[m][c]
        return jnp.stack(codes, axis=1).astype(jnp.uint8)

    def decode(self, codes: jax.Array) -> jax.Array:
        m_idx = jnp.arange(self.codebooks.shape[0])[None, :]
        cw = self.codebooks[m_idx, codes.astype(jnp.int32)]   # (N, M, D)
        return jnp.sum(cw, axis=1)

    def lut_ip(self, q: jax.Array) -> jax.Array:
        """Inner-product tables <q, c_mk>: (M, K)."""
        return jnp.einsum("d,mkd->mk", q, self.codebooks)


def train_rvq(key, train: jax.Array, num_books: int, book_size: int = 256,
              iters: int = 20) -> RVQModel:
    res = train
    books = []
    for m in range(num_books):
        key, sub = jax.random.split(key)
        cent = kmeans(sub, res, book_size, iters)
        books.append(cent)
        res = res - cent[_assign(res, cent)]
    return RVQModel(jnp.stack(books))


# ---------------------------------------------------------------------------
# Search with shallow models (shares the ADC kernel with UNQ)
# ---------------------------------------------------------------------------

def search_pq(model: PQModel, queries: jax.Array, codes: jax.Array,
              topk: int, *, scan_impl: str = "xla") -> jax.Array:
    @jax.jit
    def _one(q):
        scores = ops.adc_scan(codes, model.lut(q), impl=scan_impl)
        _, idx = jax.lax.top_k(-scores, topk)
        return idx

    return jax.vmap(_one)(queries)


def search_rvq(model: RVQModel, queries: jax.Array, codes: jax.Array,
               code_norms: jax.Array, topk: int, *,
               scan_impl: str = "xla") -> jax.Array:
    """ADC for additive codes: ||q - x~||^2 = ||x~||^2 - 2<q, x~> + const(q).

    code_norms: (N,) precomputed ||decode(codes)||^2 (stored alongside codes,
    the standard extra-4-bytes trick for additive quantizers)."""

    @jax.jit
    def _one(q):
        ip = ops.adc_scan(codes, model.lut_ip(q), impl=scan_impl)  # sum <q, c>
        scores = code_norms - 2.0 * ip
        _, idx = jax.lax.top_k(-scores, topk)
        return idx

    return jax.vmap(_one)(queries)


# ---------------------------------------------------------------------------
# Learned rerank decoder ("LSQ + rerank" baseline)
# ---------------------------------------------------------------------------

def train_rerank_decoder(key, recon_train: jax.Array, target: jax.Array,
                         hidden: int = 1024, steps: int = 2000,
                         batch: int = 256, lr: float = 1e-3):
    """MLP (two 1024-unit hidden layers, as the paper's LSQ+rerank) trained
    to map shallow reconstructions -> original vectors, minimizing Eq. 9."""
    from repro import optim as _optim
    d_in, d_out = recon_train.shape[1], target.shape[1]
    k1, k2, k3 = jax.random.split(key, 3)

    def lin(k, i, o):
        return {"w": (jax.random.normal(k, (i, o)) * jnp.sqrt(2.0 / i)
                      ).astype(jnp.float32), "b": jnp.zeros((o,), jnp.float32)}

    params = {"l1": lin(k1, d_in, hidden), "l2": lin(k2, hidden, hidden),
              "l3": lin(k3, hidden, d_out)}

    def apply_fn(p, x):
        h = jax.nn.relu(x @ p["l1"]["w"] + p["l1"]["b"])
        h = jax.nn.relu(h @ p["l2"]["w"] + p["l2"]["b"])
        return h @ p["l3"]["w"] + p["l3"]["b"]

    opt = _optim.adam()
    opt_state = opt.init(params)
    lr_fn = _optim.one_cycle(lr, steps)
    n = recon_train.shape[0]

    @jax.jit
    def step_fn(p, s, xb, yb, step):
        def loss(p):
            return jnp.mean(jnp.sum(jnp.square(apply_fn(p, xb) - yb), axis=-1))
        l, g = jax.value_and_grad(loss)(p)
        p, s = opt.apply(p, g, s, lr_fn(step))
        return p, s, l

    rng = np.random.default_rng(0)
    for i in range(steps):
        sel = rng.integers(0, n, batch)
        params, opt_state, _ = step_fn(params, opt_state,
                                       recon_train[sel], target[sel],
                                       jnp.asarray(i))
    return params, apply_fn


def rerank_with_decoder(apply_fn, dec_params, model, queries, codes,
                        cand: jax.Array, topk: int) -> jax.Array:
    """Re-rank candidate lists with ||q - decoder(decode(codes))||^2."""

    @jax.jit
    def _one(q, c_idx):
        recon = apply_fn(dec_params, model.decode(codes[c_idx]))
        d = jnp.sum(jnp.square(recon - q[None, :]), axis=-1)
        _, order = jax.lax.top_k(-d, min(topk, d.shape[0]))
        return c_idx[order]

    return jax.vmap(_one)(queries, cand)
