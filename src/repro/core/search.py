"""Two-stage compressed-domain nearest-neighbor search (paper §3.3).

Stage 1 — candidate generation with d2 (Eq. 8): build a (M, K) lookup table
    ``lut[m, k] = -<net(q)_m, c_mk>`` with one encoder pass + M*K dot
    products, then scan the compressed database (M adds per point) and take
    the top-L candidates.
Stage 2 — reranking with d1 (Eq. 7): reconstruct only the L candidates with
    the decoder and re-score with exact distances ``||q - g(i)||^2``.

The scan supports sharded databases: each device scans its own code shard
with the (replicated) LUT and the per-shard top-L are merged — the same
pattern scales the paper's billion-vector experiments across a pod.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import unq
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    rerank: int = 500         # L: candidates reranked with d1 (paper: 500 @ 1M)
    topk: int = 100           # neighbors returned (recall@k evaluated up to this)
    scan_impl: str = "xla"    # "xla" | "onehot" | "pallas"


def build_lut(params, state, cfg, queries) -> jax.Array:
    """(Q, D) queries -> (Q, M, K) tables of -<net(q)_m, c_mk>."""
    heads, _ = unq.encode_heads(params, state, cfg, queries, train=False)
    return -unq.head_logits(params, heads)


def encode_database(params, state, cfg, base, *, batch_size: int = 8192,
                    impl: str = "xla") -> jax.Array:
    """Compress the base set: (N, D) -> uint8 codes (N, M).

    One feed-forward pass per batch (the paper's headline encoding speed:
    no iterative optimization, unlike AQ/LSQ).
    """
    @jax.jit
    def _encode(xb):
        heads, _ = unq.encode_heads(params, state, cfg, xb, train=False)
        return ops.unq_encode(heads, params["codebooks"], impl=impl).astype(jnp.uint8)

    n = base.shape[0]
    outs = []
    for s in range(0, n, batch_size):
        outs.append(_encode(base[s:s + batch_size]))
    return jnp.concatenate(outs, axis=0)


@functools.partial(jax.jit, static_argnames=("topl", "scan_impl"))
def candidates_for_query(lut: jax.Array, codes: jax.Array, *, topl: int,
                         scan_impl: str = "xla"):
    """Stage 1 for one query: lut (M, K), codes (N, M) -> (scores, idx) top-L.

    Scores are d2 up to const(q): lower = closer.
    """
    scores = ops.adc_scan(codes, lut, impl=scan_impl)   # (N,)
    neg, idx = jax.lax.top_k(-scores, topl)
    return -neg, idx


def _rerank_one(params, state, cfg, q, cand_codes):
    """Stage 2: d1(q, i) = ||q - g(i)||^2 over the L candidates."""
    recon = unq.decode_codes(params, state, cfg, cand_codes)   # (L, D)
    return jnp.sum(jnp.square(recon - q[None, :]), axis=-1)    # (L,)


def search(params, state, cfg, search_cfg: SearchConfig, queries, codes,
           *, use_rerank: bool = True, use_d2: bool = True):
    """Full two-stage search. queries (Q, D), codes (N, M) -> indices (Q, k).

    ``use_rerank=False`` reproduces the "No reranking" ablation;
    ``use_d2=False`` (exhaustive d1) reproduces "Exhaustive reranking".
    """
    topl = search_cfg.rerank if use_rerank else search_cfg.topk
    luts = build_lut(params, state, cfg, queries)     # (Q, M, K)

    @jax.jit
    def _one(q, lut):
        if use_d2:
            _, cand = candidates_for_query(lut, codes, topl=topl,
                                           scan_impl=search_cfg.scan_impl)
        else:
            cand = jnp.arange(codes.shape[0])         # exhaustive d1
        if not use_rerank and use_d2:
            return cand[: search_cfg.topk]
        d1 = _rerank_one(params, state, cfg, q, codes[cand])
        k = min(search_cfg.topk, d1.shape[0])
        _, order = jax.lax.top_k(-d1, k)
        return cand[order]

    return jax.vmap(_one)(queries, luts)


def search_sharded(params, state, cfg, search_cfg: SearchConfig, queries,
                   codes_shards: list[jax.Array], shard_offsets: list[int]):
    """Distributed stage 1: per-shard top-L merged across shards, then a
    single stage-2 rerank over the merged candidate pool. Host-side driver
    used by the serving example; on a real pod each shard lives on its own
    device and the merge is an all-gather of (L, 2) tuples.
    """
    luts = build_lut(params, state, cfg, queries)
    all_scores, all_idx = [], []
    for shard, off in zip(codes_shards, shard_offsets):
        s, i = jax.vmap(
            lambda lut: candidates_for_query(
                lut, shard, topl=min(search_cfg.rerank, shard.shape[0]),
                scan_impl=search_cfg.scan_impl)
        )(luts)
        all_scores.append(s)
        all_idx.append(i + off)
    scores = jnp.concatenate(all_scores, axis=1)       # (Q, n_shards*L)
    idx = jnp.concatenate(all_idx, axis=1)
    _, order = jax.lax.top_k(-scores, min(search_cfg.rerank, scores.shape[1]))
    return jnp.take_along_axis(idx, order, axis=1)     # (Q, L) global candidates


def recall_at_k(retrieved: jax.Array, gt_nn: jax.Array, ks=(1, 10, 100)) -> dict:
    """Recall@k (paper §4): P[true NN among the k closest retrieved].

    retrieved: (Q, >=max(ks)) indices; gt_nn: (Q,) true nearest neighbor.
    """
    out = {}
    for k in ks:
        kk = min(k, retrieved.shape[1])
        hit = jnp.any(retrieved[:, :kk] == gt_nn[:, None], axis=1)
        out[f"recall@{k}"] = float(jnp.mean(hit.astype(jnp.float32)))
    return out
