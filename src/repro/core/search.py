"""Two-stage compressed-domain nearest-neighbor search (paper §3.3).

.. deprecated::
    This module is now a thin compatibility shim. The canonical
    implementation lives behind the FAISS-style ``repro.index`` API::

        from repro.index import index_factory
        index = index_factory("UNQ8x256,Rerank500", dim=96)
        index.train(xs); index.add(base)
        distances, indices = index.search(queries, k)

    ``search`` / ``search_sharded`` / ``encode_database`` below delegate to
    ``repro.index.UNQIndex`` / ``ShardedIndex`` and return the same values
    they always did, so existing callers keep working. New code should use
    the index objects directly — they own the batched multi-query ADC scan
    (``ops.adc_scan_batch``) and per-device scan-backend resolution.

Stage 1 — candidate generation with d2 (Eq. 8): build a (M, K) lookup table
    ``lut[m, k] = -<net(q)_m, c_mk>`` with one encoder pass + M*K dot
    products, then scan the compressed database (M adds per point) and take
    the top-L candidates.
Stage 2 — reranking with d1 (Eq. 7): reconstruct only the L candidates with
    the decoder and re-score with exact distances ``||q - g(i)||^2``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    rerank: int = 500         # L: candidates reranked with d1 (paper: 500 @ 1M)
    topk: int = 100           # neighbors returned (recall@k evaluated up to this)
    scan_impl: str = "xla"    # scan backend: "xla" | "onehot" | "pallas" | "auto"


def build_lut(params, state, cfg, queries) -> jax.Array:
    """(Q, D) queries -> (Q, M, K) tables of -<net(q)_m, c_mk>."""
    from repro.index.unq_index import build_luts
    return build_luts(params, state, cfg, queries)


def encode_database(params, state, cfg, base, *, batch_size: int = 8192,
                    impl: str = "xla") -> jax.Array:
    """Compress the base set: (N, D) -> uint8 codes (N, M).

    One feed-forward pass per batch (the paper's headline encoding speed:
    no iterative optimization, unlike AQ/LSQ).
    """
    from repro.index.unq_index import encode_database as _encode
    return _encode(params, state, cfg, base, batch_size=batch_size, impl=impl)


@functools.partial(jax.jit, static_argnames=("topl", "scan_impl"))
def candidates_for_query(lut: jax.Array, codes: jax.Array, *, topl: int,
                         scan_impl: str = "xla"):
    """Stage 1 for one query: lut (M, K), codes (N, M) -> (scores, idx) top-L.

    Scores are d2 up to const(q): lower = closer. Kept for single-query
    callers; batched search goes through ``ops.adc_scan_batch``.
    """
    scores = ops.adc_scan(codes, lut, impl=scan_impl)   # (N,)
    neg, idx = jax.lax.top_k(-scores, topl)
    return -neg, idx


def _index_for(params, state, cfg, search_cfg: SearchConfig, codes=None):
    from repro.index import UNQIndex
    return UNQIndex.from_trained(params, state, cfg, codes=codes,
                                 rerank=search_cfg.rerank,
                                 backend=search_cfg.scan_impl)


def search(params, state, cfg, search_cfg: SearchConfig, queries, codes,
           *, use_rerank: bool = True, use_d2: bool = True):
    """Full two-stage search. queries (Q, D), codes (N, M) -> indices (Q, k).

    ``use_rerank=False`` reproduces the "No reranking" ablation;
    ``use_d2=False`` (exhaustive d1) reproduces "Exhaustive reranking".

    Deprecated shim over ``UNQIndex.search`` (see module docstring).
    """
    index = _index_for(params, state, cfg, search_cfg, codes)
    _, indices = index.search(jnp.asarray(queries), search_cfg.topk,
                              use_rerank=use_rerank, use_d2=use_d2)
    return indices


def search_sharded(params, state, cfg, search_cfg: SearchConfig, queries,
                   codes_shards: list[jax.Array], shard_offsets: list[int]):
    """Distributed stage 1: per-shard top-L merged across shards; the
    caller reranks the merged pool. Returns (Q, L) global candidates.

    Deprecated shim over ``ShardedIndex.stage1_candidates``.
    """
    from repro.index import ShardedIndex
    index = _index_for(params, state, cfg, search_cfg)
    sharded = ShardedIndex.from_shards(index, codes_shards, shard_offsets)
    _, cand = sharded.stage1_candidates(jnp.asarray(queries),
                                        topl=search_cfg.rerank)
    return cand


def recall_at_k(retrieved: jax.Array, gt_nn: jax.Array, ks=(1, 10, 100)) -> dict:
    """Recall@k (paper §4): P[true NN among the k closest retrieved].

    retrieved: (Q, >=max(ks)) indices; gt_nn: (Q,) true nearest neighbor.
    """
    out = {}
    for k in ks:
        kk = min(k, retrieved.shape[1])
        hit = jnp.any(retrieved[:, :kk] == gt_nn[:, None], axis=1)
        out[f"recall@{k}"] = float(jnp.mean(hit.astype(jnp.float32)))
    return out
