"""Search configuration + retrieval metrics for the two-stage
compressed-domain search (paper §3.3).

The search implementation itself lives behind the FAISS-style
``repro.index`` API (the PR-1 migration is complete and the old
``search`` / ``search_sharded`` / ``encode_database`` deprecation shims
are gone)::

    from repro.index import index_factory
    index = index_factory("UNQ8x256,Rerank500", dim=96)
    index.train(xs); index.add(base)
    distances, indices = index.search(queries, k)

Stage 1 — candidate generation with d2 (Eq. 8): build a (M, K) lookup table
    ``lut[m, k] = -<net(q)_m, c_mk>`` with one encoder pass + M*K dot
    products, then stream the compressed database through the fused
    scan+top-L engine (``repro.index.candidates``).
Stage 2 — reranking with d1 (Eq. 7): reconstruct only the L candidates with
    the decoder and re-score with exact distances ``||q - g(i)||^2``.

This module keeps the two pieces that are configuration/evaluation rather
than retrieval: ``SearchConfig`` (the paper's search hyperparameters,
referenced by ``repro.configs``) and ``recall_at_k`` (the §4 metric).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    rerank: int = 500         # L: candidates reranked with d1 (paper: 500 @ 1M)
    topk: int = 100           # neighbors returned (recall@k evaluated up to this)
    scan_impl: str = "auto"   # scan backend: "xla" | "onehot" | "pallas" | "auto"


def recall_at_k(retrieved, gt_nn, ks=(1, 10, 100)) -> dict:
    """Recall@k (paper §4): P[true NN among the k closest retrieved].

    retrieved: (Q, >=max(ks)) indices; gt_nn: (Q,) true nearest neighbor.
    """
    out = {}
    for k in ks:
        kk = min(k, retrieved.shape[1])
        hit = jnp.any(retrieved[:, :kk] == gt_nn[:, None], axis=1)
        out[f"recall@{k}"] = float(jnp.mean(hit.astype(jnp.float32)))
    return out
