"""Unsupervised Neural Quantization (UNQ) — Morozov & Babenko, CVPR 2019.

The model (paper §3.2):

  encoder ``net(x)``: MLP with M output heads mapping a descriptor
      ``x ∈ R^D`` into a product of M learned spaces (each head ``d_c``-dim).
  codebooks ``C ∈ R^{M×K×d_c}``: K codewords per learned space.
  assignment: ``p(c_mk | x) = softmax_k( <net(x)_m, c_mk> / tau_m )``  (Eq. 2)
      with learned per-codebook temperature ``tau_m``.
  bottleneck: hard Gumbel-Softmax with straight-through gradients  (Eq. 5).
  decoder ``g``: MLP reconstructing x from the SUM of selected codewords
      (the additive-quantization view; the decoder input is ``d_c``-dim,
      which matches the paper's reported model sizes: 19.8 MB @ M=8,
      30.1 MB @ M=16 — a concat decoder would grow by 2x that delta).

Everything is a plain pytree + pure functions so the model composes with
pjit/shard_map and the AOT dry-run without a module framework.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any
State = Any


@dataclasses.dataclass(frozen=True)
class UNQConfig:
    """Hyper-parameters of the UNQ model (paper §4.1 defaults)."""

    dim: int = 96              # D: descriptor dimensionality (Deep1M: 96)
    num_codebooks: int = 8     # M: bytes per vector (K=256 -> 1 byte/codebook)
    codebook_size: int = 256   # K
    code_dim: int = 256        # d_c: dimensionality of the learned spaces
    hidden_dim: int = 1024     # two 1024-unit hidden layers (paper §4.1)
    num_hidden_layers: int = 2
    init_temperature: float = 1.0
    bn_momentum: float = 0.9
    dtype: Any = jnp.float32

    @property
    def bytes_per_vector(self) -> int:
        # K=256 -> one uint8 per codebook.
        assert self.codebook_size <= 256
        return self.num_codebooks

    def with_(self, **kw) -> "UNQConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# MLP + BatchNorm substrate (paper: Linear -> BN -> ReLU blocks)
# ---------------------------------------------------------------------------

def _init_linear(key, d_in: int, d_out: int, dtype) -> Params:
    # He/Kaiming init, suitable for the ReLU stacks used throughout the paper.
    w_key, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / d_in)
    return {
        "w": (jax.random.normal(w_key, (d_in, d_out)) * scale).astype(dtype),
        "b": jnp.zeros((d_out,), dtype),
    }


def _init_bn(d: int, dtype) -> tuple[Params, State]:
    params = {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    state = {"mean": jnp.zeros((d,), jnp.float32), "var": jnp.ones((d,), jnp.float32)}
    return params, state


def _bn_apply(params, state, x, *, train: bool, momentum: float):
    """BatchNorm over the leading (batch) axis. Returns (y, new_state)."""
    if train:
        mean = jnp.mean(x.astype(jnp.float32), axis=0)
        var = jnp.var(x.astype(jnp.float32), axis=0)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    inv = jax.lax.rsqrt(var + 1e-5)
    y = (x - mean.astype(x.dtype)) * inv.astype(x.dtype)
    y = y * params["scale"] + params["bias"]
    return y, new_state


def _init_mlp(key, d_in: int, hidden: int, n_hidden: int, d_out: int, dtype):
    """Linear->BN->ReLU (x n_hidden) -> Linear head."""
    keys = jax.random.split(key, n_hidden + 1)
    layers, bn_params, bn_state = [], [], []
    d = d_in
    for i in range(n_hidden):
        layers.append(_init_linear(keys[i], d, hidden, dtype))
        p, s = _init_bn(hidden, dtype)
        bn_params.append(p)
        bn_state.append(s)
        d = hidden
    head = _init_linear(keys[-1], d, d_out, dtype)
    params = {"layers": layers, "bn": bn_params, "head": head}
    return params, {"bn": bn_state}


def _mlp_apply(params, state, x, *, train: bool, momentum: float):
    new_bn = []
    for lin, bn_p, bn_s in zip(params["layers"], params["bn"], state["bn"]):
        x = x @ lin["w"] + lin["b"]
        x, s = _bn_apply(bn_p, bn_s, x, train=train, momentum=momentum)
        new_bn.append(s)
        x = jax.nn.relu(x)
    x = x @ params["head"]["w"] + params["head"]["b"]
    return x, {"bn": new_bn}


# ---------------------------------------------------------------------------
# UNQ model
# ---------------------------------------------------------------------------

def init(key, cfg: UNQConfig) -> tuple[Params, State]:
    """Initialize UNQ parameters and BatchNorm state."""
    k_enc, k_dec, k_cb = jax.random.split(key, 3)
    enc_params, enc_state = _init_mlp(
        k_enc, cfg.dim, cfg.hidden_dim, cfg.num_hidden_layers,
        cfg.num_codebooks * cfg.code_dim, cfg.dtype)
    dec_params, dec_state = _init_mlp(
        k_dec, cfg.code_dim, cfg.hidden_dim, cfg.num_hidden_layers,
        cfg.dim, cfg.dtype)
    codebooks = (jax.random.normal(
        k_cb, (cfg.num_codebooks, cfg.codebook_size, cfg.code_dim))
        * (1.0 / jnp.sqrt(cfg.code_dim))).astype(cfg.dtype)
    params = {
        "encoder": enc_params,
        "decoder": dec_params,
        "codebooks": codebooks,
        # tau_m in (0, inf), learned; parameterized on the log scale.
        "log_tau": jnp.full((cfg.num_codebooks,), jnp.log(cfg.init_temperature),
                            cfg.dtype),
    }
    state = {"encoder": enc_state, "decoder": dec_state}
    return params, state


def encode_heads(params, state, cfg: UNQConfig, x, *, train: bool):
    """``net(x)``: (B, D) -> (B, M, d_c) plus new BN state."""
    h, new_state = _mlp_apply(params["encoder"], state["encoder"], x,
                              train=train, momentum=cfg.bn_momentum)
    heads = h.reshape(x.shape[0], cfg.num_codebooks, cfg.code_dim)
    return heads, new_state


def head_logits(params, heads):
    """Raw dot products ``<net(x)_m, c_mk>``: (B, M, d_c) -> (B, M, K)."""
    return jnp.einsum("bmd,mkd->bmk", heads, params["codebooks"])


def assignment_log_probs(params, heads):
    """``log p(c_mk | x)`` (Eq. 2): temperature-scaled log-softmax, (B, M, K)."""
    tau = jnp.exp(params["log_tau"])  # (M,)
    logits = head_logits(params, heads) / tau[None, :, None]
    return jax.nn.log_softmax(logits, axis=-1)


def encode(params, state, cfg: UNQConfig, x) -> jax.Array:
    """Deterministic encoder ``f(x)`` (Eq. 4): (B, D) -> uint8 codes (B, M).

    argmax over the dot products (temperature does not change the argmax).
    """
    heads, _ = encode_heads(params, state, cfg, x, train=False)
    logits = head_logits(params, heads)
    return jnp.argmax(logits, axis=-1).astype(jnp.uint8)


def gumbel_softmax_st(key, log_probs, *, hard: bool = True,
                      noise: bool = True):
    """Hard Gumbel-Softmax with straight-through gradients (Eq. 5).

    log_probs: (..., K). Returns a (soft or hard-ST) simplex vector (..., K).
    The Gumbel-Softmax temperature is fixed at 1 as in the paper.
    ``noise=False`` gives the deterministic softmax relaxation (the
    "UNQ w/o Gumbel" ablation, cf. soft-to-hard quantization [1]).
    """
    if noise:
        gumbel = -jnp.log(-jnp.log(
            jax.random.uniform(key, log_probs.shape, minval=1e-20,
                               maxval=1.0)) + 1e-20)
        logits = log_probs + gumbel.astype(log_probs.dtype)
    else:
        logits = log_probs
    y_soft = jax.nn.softmax(logits, axis=-1)
    if not hard:
        return y_soft
    idx = jnp.argmax(y_soft, axis=-1)
    y_hard = jax.nn.one_hot(idx, log_probs.shape[-1], dtype=y_soft.dtype)
    # Straight-through: forward = one-hot, backward = d(soft)/d(inputs).
    return y_hard + y_soft - jax.lax.stop_gradient(y_soft)


def decode_from_onehot(params, state, cfg: UNQConfig, onehots, *, train: bool):
    """Decoder ``g``: one-hot selections (B, M, K) -> reconstruction (B, D).

    The decoder input is the SUM over codebooks of the selected codewords
    ("the decoder adds the corresponding codewords", paper §3.2).
    """
    z = jnp.einsum("bmk,mkd->bd", onehots, params["codebooks"])
    recon, new_state = _mlp_apply(params["decoder"], state["decoder"], z,
                                  train=train, momentum=cfg.bn_momentum)
    return recon, new_state


def decode_codes(params, state, cfg: UNQConfig, codes) -> jax.Array:
    """Decoder on integer codes (B, M) -> (B, D), eval mode (for reranking)."""
    cw = codewords_for_codes(params, codes)      # (B, M, d_c)
    z = jnp.sum(cw, axis=1)                      # (B, d_c)
    recon, _ = _mlp_apply(params["decoder"], state["decoder"], z,
                          train=False, momentum=cfg.bn_momentum)
    return recon


def codewords_for_codes(params, codes) -> jax.Array:
    """Gather selected codewords: codes (B, M) -> (B, M, d_c)."""
    cb = params["codebooks"]                      # (M, K, d_c)
    m_idx = jnp.arange(cb.shape[0])[None, :]      # (1, M)
    return cb[m_idx, codes.astype(jnp.int32)]    # (B, M, d_c)


def forward_train(key, params, state, cfg: UNQConfig, x, *, hard: bool = True,
                  gumbel_noise: bool = True):
    """One training-mode pass: returns dict with everything the losses need."""
    heads, enc_state = encode_heads(params, state, cfg, x, train=True)
    log_p = assignment_log_probs(params, heads)          # (B, M, K)
    onehots = gumbel_softmax_st(key, log_p, hard=hard,
                                noise=gumbel_noise)      # (B, M, K)
    recon, dec_state = decode_from_onehot(
        params, {**state, "encoder": enc_state}, cfg, onehots, train=True)
    new_state = {"encoder": enc_state, "decoder": dec_state}
    return {
        "heads": heads,          # net(x): (B, M, d_c)
        "log_probs": log_p,      # log p(c|x): (B, M, K)
        "onehots": onehots,      # hard-ST selections: (B, M, K)
        "recon": recon,          # g(f~(x)): (B, D)
        "state": new_state,
    }


def model_size_bytes(params) -> int:
    from repro.utils.pytree import param_bytes
    return param_bytes(params)
