"""UNQ training objective (paper §3.4).

    L = L1 + alpha * L2 + beta * (1/M) sum_m CV^2(i_m)        (Eq. 12)

  L1  — reconstruction MSE through the hard-ST Gumbel bottleneck   (Eq. 9)
  L2  — triplet loss on d2 in the learned space                    (Eq. 10)
  CV² — squared coefficient of variation of batch-averaged
        codeword probabilities (load-balance regularizer, from the
        sparsely-gated MoE literature)                             (Eq. 11)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import unq


def reconstruction_loss(x, recon) -> jax.Array:
    """L1 (Eq. 9): mean squared reconstruction error."""
    return jnp.mean(jnp.sum(jnp.square(recon - x), axis=-1))


def d2_scores(params, heads, codes) -> jax.Array:
    """d2(q, i) up to const(q) (Eq. 8): -sum_m <net(q)_m, c_{m,i_m}>.

    heads: (B, M, d_c) = net(q); codes: (B, M) integer codes of the
    comparison points. Returns (B,).
    """
    cw = unq.codewords_for_codes(params, codes)       # (B, M, d_c)
    return -jnp.sum(heads * cw, axis=(1, 2))


def triplet_loss(params, heads, pos_codes, neg_codes, *, margin: float) -> jax.Array:
    """L2 (Eq. 10): max(0, delta + d2(x, f(x+)) - d2(x, f(x-)))."""
    d_pos = d2_scores(params, heads, pos_codes)
    d_neg = d2_scores(params, heads, neg_codes)
    return jnp.mean(jax.nn.relu(margin + d_pos - d_neg))


def cv_squared_regularizer(log_probs) -> jax.Array:
    """(1/M) sum_m CV^2 over batch-averaged codeword probabilities (Eq. 11).

    log_probs: (B, M, K). CV^2(m) = Var_k[p_avg(k|X)] / (E_k[p_avg(k|X)])^2.
    """
    p_avg = jnp.mean(jnp.exp(log_probs), axis=0)       # (M, K)
    mean = jnp.mean(p_avg, axis=-1)                    # (M,)
    var = jnp.var(p_avg, axis=-1)                      # (M,)
    cv2 = var / (jnp.square(mean) + 1e-10)
    return jnp.mean(cv2)


def commitment_loss(heads, onehots, codebooks):
    """VQ-VAE-style auxiliary (van den Oord et al. [32], the paper's cited
    lineage): pull selected codewords toward the head vectors and commit
    heads to their codewords. Dramatically accelerates the joint
    optimization that the straight-through estimator alone crawls through
    (training stabilizer; the model/search are unchanged).

    heads: (B, M, d_c); onehots: (B, M, K); codebooks: (M, K, d_c).
    """
    selected = jnp.einsum("bmk,mkd->bmd", onehots, codebooks)
    codebook_term = jnp.mean(jnp.sum(
        jnp.square(selected - jax.lax.stop_gradient(heads)), axis=-1))
    commit_term = jnp.mean(jnp.sum(
        jnp.square(heads - jax.lax.stop_gradient(selected)), axis=-1))
    return codebook_term + 0.25 * commit_term


def unq_loss(key, params, state, cfg, batch, *, alpha: float, beta,
             margin: float = 1.0, hard: bool = True, use_triplet: bool = True,
             gumbel_noise: bool = True, commit_coef: float = 0.0):
    """Full UNQ objective on one minibatch.

    batch: dict with
      "x"   (B, D)  anchors
      "pos" (B, D)  positive examples (sampled from top-3 true NNs)
      "neg" (B, D)  negative examples (sampled from ranks 100..200)
    Returns (loss, aux) where aux carries the new BN state and metrics.
    """
    out = unq.forward_train(key, params, state, cfg, batch["x"], hard=hard,
                            gumbel_noise=gumbel_noise)
    l1 = reconstruction_loss(batch["x"], out["recon"])
    cv = cv_squared_regularizer(out["log_probs"])

    if use_triplet and alpha > 0.0:
        # Positives/negatives are encoded with the deterministic encoder f(x),
        # exactly how database points would be stored (stop-grad: the codes
        # are discrete indices; gradients flow via heads and codewords).
        pos_codes = unq.encode(params, out["state"], cfg, batch["pos"])
        neg_codes = unq.encode(params, out["state"], cfg, batch["neg"])
        l2 = triplet_loss(params, out["heads"],
                          jax.lax.stop_gradient(pos_codes),
                          jax.lax.stop_gradient(neg_codes), margin=margin)
    else:
        l2 = jnp.zeros((), jnp.float32)

    commit = commitment_loss(out["heads"], jax.lax.stop_gradient(
        out["onehots"]), params["codebooks"]) if commit_coef else 0.0

    loss = l1 + alpha * l2 + beta * cv + commit_coef * commit
    aux = {
        "state": out["state"],
        "metrics": {
            "loss": loss,
            "recon": l1,
            "triplet": l2,
            "cv2": cv,
            # codebook usage entropy: how many codes are effectively in use.
            "usage_entropy": _usage_entropy(out["log_probs"]),
        },
    }
    return loss, aux


def _usage_entropy(log_probs) -> jax.Array:
    p_avg = jnp.mean(jnp.exp(log_probs), axis=0)  # (M, K)
    p_avg = p_avg / (jnp.sum(p_avg, axis=-1, keepdims=True) + 1e-10)
    ent = -jnp.sum(p_avg * jnp.log(p_avg + 1e-10), axis=-1)  # (M,)
    return jnp.mean(ent)
