"""Roofline derivation from the dry-run artifacts (DESIGN.md §6).

Per (arch x shape x mesh) cell:

    compute    = executed_FLOPs_per_device / PEAK_FLOPS
    memory     = executed_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

(the dry-run module is the per-partition SPMD program, so "per device" is
what the artifacts already contain). The dominant term is the projected
bottleneck; roofline fraction = compute / max(all terms) — the share of
step time the MXUs would be busy if overlap were perfect.

MODEL_FLOPS uses 6*N*D (train, dense), 6*N_active*D (train, MoE) and
2*N*B (+attention KV term) for decode; the ratio MODEL_FLOPS /
(executed_FLOPs * devices) exposes remat/redundancy waste.

Usage:
    PYTHONPATH=src python -m repro.analysis.roofline \
        [--dir artifacts/dryrun] [--mesh 16x16] [--format md]
"""
from __future__ import annotations

import argparse
import json
import pathlib

# TPU v5e hardware constants (per chip) — from the assignment sheet.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

# active params for MoE archs (attn + shared + top-k experts + embeddings)
_N_ACTIVE = {
    "deepseek-moe-16b": 2.8e9,
    "moonshot-v1-16b-a3b": 4.1e9,
}


def model_flops(info: dict, arch_params: int) -> float:
    """Global useful flops for the step (6ND train / 2NB decode)."""
    arch = info["arch"].replace("-kvq", "")
    n = _N_ACTIVE.get(arch, float(arch_params))
    shape = info["shape"]
    step = info["step"]
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
           "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
             "long_500k": 1}[shape]
    tokens = seq * batch
    if step == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens          # prefill/decode forward-only


def cell_roofline(info: dict) -> dict:
    ex = info["executed"]
    compute = ex["flops"] / PEAK_FLOPS
    memory = ex["bytes"] / HBM_BW
    collective = ex["collective_bytes"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(info, info["param_count"])
    useful = mf / max(ex["flops"] * info["devices"], 1.0)
    return {
        "arch": info["arch"],
        "shape": info["shape"],
        "mesh": info["mesh"],
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "roofline_fraction": compute / max(terms.values()) if max(
            terms.values()) > 0 else 0.0,
        "model_flops": mf,
        "useful_compute_ratio": useful,
        "hbm_bytes_per_device": info["memory"]["argument_bytes"]
        + info["memory"]["temp_bytes"],
    }


def load_cells(art_dir: pathlib.Path, mesh: str | None = None) -> list[dict]:
    cells = []
    for p in sorted(art_dir.glob("*.json")):
        info = json.loads(p.read_text())
        if info.get("status") != "ok":
            cells.append(info)
            continue
        if mesh and info["mesh"] != mesh:
            continue
        cells.append({**info, "roofline": cell_roofline(info)})
    return cells


def format_table(cells: list[dict], fmt: str = "md") -> str:
    rows = []
    header = ("| arch | shape | mesh | compute(s) | memory(s) | coll(s) | "
              "dominant | roofline | useful |")
    sep = "|---" * 9 + "|"
    rows.append(header)
    rows.append(sep)
    for c in cells:
        if "roofline" not in c:
            rows.append(
                f"| {c.get('arch','?')} | {c.get('shape','?')} | "
                f"{c.get('mesh','?')} | — | — | — | "
                f"{c.get('status','?')[:60]} | — | — |")
            continue
        r = c["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['dominant']} "
            f"| {r['roofline_fraction']:.2f} "
            f"| {r['useful_compute_ratio']:.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    cells = load_cells(pathlib.Path(args.dir), mesh=args.mesh)
    print(format_table(cells))
    if args.json_out:
        pathlib.Path(args.json_out).write_text(json.dumps(
            [c.get("roofline", c) for c in cells], indent=2))


if __name__ == "__main__":
    main()
