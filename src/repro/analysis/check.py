"""``python -m repro.analysis.check`` — the repo's static-analysis gate.

Three sections, each independently selectable via ``--only``:

  contracts  compile every registered engine path over its shape buckets
             and verify the declared streaming-memory/HLO contract
             (repro.analysis.contracts);
  lint       run the repo-specific AST rules over the live tree
             (repro.analysis.lint);
  compile    the compile-count discipline scenario: one encoder compile
             per ENCODE_BUCKETS bucket, zero compiles on repeat search
             (repro.analysis.compilecount).

All violations are printed before the non-zero exit (the same convention
as ``ci.sh --smoke``). ``--seeded-violations`` inverts the role: it runs
the detectors against the known-bad fixtures (the oracle-less kernel, the
recompile hazards, a deliberately materialized (Q, N) scan) and exits
non-zero WITH findings / zero without — CI asserts it fails, proving the
gate can actually catch what it claims to.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys

# The sharded contract needs >= 2 devices; force a 2-way CPU split before
# jax initializes (harmless under a real multi-device runtime, skipped if
# the caller already imported jax or set their own flags).
if "jax" not in sys.modules and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

_REPO = pathlib.Path(__file__).resolve().parents[3]
_SECTIONS = ("contracts", "lint", "compile")


def run_contracts(only_ids=None) -> tuple[list[str], int]:
    from repro.analysis import contracts
    lines, bad = [], 0
    for pid, contract in contracts.REGISTRY.items():
        if only_ids and pid not in only_ids:
            continue
        res = contracts.check_contract(pid)
        if res.skipped:
            lines.append(f"  SKIP {pid}: {res.reason}")
        elif res.violations:
            bad += 1
            lines.append(f"  FAIL {pid}")
            lines.extend(f"       {v}" for v in res.violations)
        else:
            lines.append(f"  ok   {pid}")
    return lines, bad


def run_lint_section(tree=None) -> tuple[list[str], int]:
    from repro.analysis.lint import run_lint
    findings = run_lint(tree)
    lines = [f"  {f}" for f in findings]
    if not findings:
        lines.append("  ok   all lint rules clean")
    return lines, len(findings)


def run_compile_section() -> tuple[list[str], int]:
    from repro.analysis.compilecount import encode_ladder_violations
    violations = encode_ladder_violations()
    lines = [f"  FAIL {v}" for v in violations]
    if not violations:
        lines.append("  ok   encode-ladder / repeat-search discipline holds")
    return lines, len(violations)


def run_seeded_violations() -> tuple[list[str], int]:
    """Detectors vs the known-bad fixtures: MUST find everything seeded."""
    import dataclasses

    from repro.analysis import contracts
    from repro.analysis.lint import LintTree, run_lint

    lines, found = [], 0

    fixtures = _REPO / "tests" / "fixtures" / "lint" / "bad"
    findings = run_lint(LintTree(src=fixtures / "src",
                                 tests=fixtures / "tests"))
    lines.append(f"  lint findings on bad fixture tree: {len(findings)}")
    lines.extend(f"    {f}" for f in findings)
    found += len(findings)
    seeded_rules = {"kernel-oracle", "capability-consumed",
                    "recompile-hazard", "host-sync", "tuned-block-params"}
    missing = seeded_rules - {f.rule for f in findings}
    if missing:
        lines.append(f"  MISSED seeded lint rules: {sorted(missing)}")

    # the streaming stage-1 contract pointed at the materialized build:
    # the verifier must reject the (Q, N) scan it deliberately contains
    control = contracts.REGISTRY["stage1.materialized.control"]
    seeded = dataclasses.replace(
        contracts.REGISTRY["stage1.stream.xla"],
        path_id="seeded.materialized-qn-scan",
        build=control.build, buckets=control.buckets, max_temp=None)
    res = contracts.verify(seeded)
    lines.append(f"  contract violations on materialized (Q, N) scan: "
                 f"{len(res.violations)}")
    lines.extend(f"    {v}" for v in res.violations)
    found += len(res.violations)
    if not any(v.kind == "materialization" for v in res.violations):
        lines.append("  MISSED seeded (Q, N) materialization")
        missing.add("qn-materialization")

    return lines, found if not missing else 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="static-analysis gate: HLO contracts + repo lint + "
                    "compile-count discipline")
    parser.add_argument("--only", default=None,
                        help="comma-separated sections to run "
                             f"({','.join(_SECTIONS)}) and/or contract "
                             "path ids")
    parser.add_argument("--list", action="store_true",
                        help="list registered contracts and lint rules")
    parser.add_argument("--seeded-violations", action="store_true",
                        help="run detectors against the known-bad fixtures; "
                             "exits non-zero iff everything seeded is found")
    args = parser.parse_args(argv)

    if args.list:
        from repro.analysis import contracts
        from repro.analysis.lint import ALL_RULES
        print("contracts:")
        for pid, c in contracts.REGISTRY.items():
            print(f"  {pid:32s} {c.description.splitlines()[0]}")
        print("lint rules:")
        for rule in ALL_RULES:
            print(f"  {rule}")
        return 0

    if args.seeded_violations:
        lines, found = run_seeded_violations()
        print("== seeded violations ==")
        for line in lines:
            print(line)
        if found:
            print(f"seeded-violation check: detectors caught everything "
                  f"({found} findings) -> exit 1 by design")
            return 1
        print("seeded-violation check: detectors MISSED seeded defects "
              "-> exit 0 (CI treats this as failure)")
        return 0

    selected = set(_SECTIONS)
    only_ids = None
    if args.only:
        tokens = {t.strip() for t in args.only.split(",") if t.strip()}
        selected = tokens & set(_SECTIONS)
        only_ids = tokens - set(_SECTIONS) or None
        if only_ids and not selected:
            selected = {"contracts"}

    total_bad = 0
    if "contracts" in selected:
        print("== contracts ==")
        lines, bad = run_contracts(only_ids)
        for line in lines:
            print(line)
        total_bad += bad
    if "lint" in selected:
        print("== lint ==")
        lines, bad = run_lint_section()
        for line in lines:
            print(line)
        total_bad += bad
    if "compile" in selected:
        print("== compile discipline ==")
        lines, bad = run_compile_section()
        for line in lines:
            print(line)
        total_bad += bad

    if total_bad:
        print(f"static analysis: {total_bad} violation(s)")
        return 1
    print("static analysis: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
