"""HLO-text cost analysis with while-loop (scan) awareness.

``compiled.cost_analysis()`` counts each while body ONCE (verified: an
8-iteration scan and a 1-iteration scan report identical flops), which
under-counts scanned-layer models by a factor of num_layers. This module
re-derives *executed* statistics by walking the computation graph:

  executed(comp) = own + sum_fusion callee_flops        (flops descend)
                       + sum_call executed(callee)
                       + sum_while trip_count * executed(body)

Trip counts come from the while op's ``backend_config known_trip_count``
(present on CPU-compiled scans), with a fallback to the constant compared
against in the loop-condition computation.

Per-op accounting:
  flops       — dot ops: 2 * |result| * prod(lhs contracting dims).
  bytes       — result + operands per top-level op, with slicing ops
                (dynamic-slice/gather/DUS/scatter) counted at the moved
                sub-tensor, not the full operand (a scan body reads one
                layer slice, not the whole stacked param).
  collectives — result bytes per all-gather / all-reduce / reduce-scatter /
                all-to-all / collective-permute (per-device traffic, since
                the module is the per-partition SPMD program).

This is the data source for repro/analysis/roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    # narrow dtypes current jaxlib can emit: sub-byte ints at their packed
    # width, the fnuz/b11 float8 family, mx float4/float8-scale formats
    "s4": 0.5, "u4": 0.5, "s2": 0.25, "u2": 0.25, "s1": 0.125, "u1": 0.125,
    "f8e4m3": 1, "f8e3m4": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "f8e8m0fnu": 1, "f4e2m1fn": 0.5,
    # zero-width bookkeeping types (token/opaque carry no payload)
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

# ops whose "bytes" are the moved sub-tensor, not the big operand
_SLICING = {"dynamic-slice", "gather", "slice"}
_UPDATING = {"dynamic-update-slice", "scatter"}
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "iota"}
# ops whose result merely routes existing buffers: excluded from the peak
# single-buffer statistic (a while's carry tuple is not a fresh allocation)
_PASSTHROUGH = {"parameter", "get-tuple-element", "tuple", "while",
                "conditional", "bitcast", "copy", "copy-start", "copy-done",
                "optimization-barrier", "after-all"}


def _shapes(shape_str: str) -> list[tuple[str, int]]:
    """Parse a (possibly tuple) shape string -> [(dtype, n_elems), ...]."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dtype, n))
    return out


def _shape_bytes(shape_str: str) -> int:
    return sum(n * _DTYPE_BYTES.get(dt, 4) for dt, n in _shapes(shape_str))


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    whiles: list = dataclasses.field(default_factory=list)  # (cond, body, trip)
    fusions: list = dataclasses.field(default_factory=list)
    # fusion byte records: (result_bytes, [operand shape strs], callee name)
    fusion_ops: list = dataclasses.field(default_factory=list)
    calls: list = dataclasses.field(default_factory=list)
    constants: dict = dataclasses.field(default_factory=dict)
    compare_operands: list = dataclasses.field(default_factory=list)
    # parameter-read analysis: how each parameter index is consumed
    params: dict = dataclasses.field(default_factory=dict)   # idx -> name
    sliced_reads: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))          # name -> bytes
    full_use: set = dataclasses.field(default_factory=set)   # names read fully

    def param_read_bytes(self, idx: int, full_bytes: float) -> float:
        """Bytes a caller should charge for passing operand ``idx``: the
        sliced amount when the parameter is only consumed through slicing
        ops (a scan body dynamic-slicing its stacked weights), else the
        full operand size."""
        name = self.params.get(idx)
        if name is None:
            return full_bytes
        if name in self.full_use:
            return full_bytes
        if name in self.sliced_reads:
            return self.sliced_reads[name]
        return 0.0  # parameter unused


@dataclasses.dataclass
class Diagnostics:
    """Parser health report: what the walker could NOT account for.

    ``unparsed`` lists (computation, lineno, snippet) for op lines inside a
    computation body that matched no parser regex — before this existed they
    silently vanished from the byte/flop accounting. ``unknown_dtypes`` are
    dtype tokens missing from ``_DTYPE_BYTES`` (billed at 4 bytes/elem).
    ``peak_buffer_bytes`` is the largest single buffer produced by any
    compute op in any computation (pass-through ops like tuple/while/copy
    excluded) — the coarse "biggest live tensor" statistic contracts bound.
    """
    unparsed: list = dataclasses.field(default_factory=list)
    unknown_dtypes: set = dataclasses.field(default_factory=set)
    peak_buffer_bytes: float = 0.0


@dataclasses.dataclass(frozen=True)
class OpLine:
    """One parsed HLO instruction, as seen by the graph walker."""
    comp: str       # computation the op lives in
    name: str       # SSA value name (no leading %)
    op: str         # opcode, e.g. "fusion", "dot", "all-gather"
    shape: str      # result shape string (may be a tuple shape)
    lineno: int     # 1-based line number in the module text
    raw: str        # the stripped source line


def iter_ops(text: str):
    """Yield every parseable instruction in the module as an ``OpLine``.

    This is the raw-op view used by ``analysis/contracts.py`` to scan for
    forbidden materializations and host-transfer ops; it deliberately does
    no executed-cost scaling.
    """
    cur = None
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.rstrip()
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = m.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _LINE_RE.match(line)
        if m:
            name, shape_str, op, _rest = m.groups()
            yield OpLine(comp=cur, name=name, op=op, shape=shape_str,
                         lineno=lineno, raw=line.strip())


def _note_dtypes(shape_str: str, diag: Diagnostics) -> None:
    for dtype, _dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            diag.unknown_dtypes.add(dtype)


def _parse(text: str) -> tuple[dict[str, _Comp], str | None, Diagnostics]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    symbols: dict[str, str] = {}
    diag = Diagnostics()

    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.rstrip()
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = _Comp(m.group(2))
                symbols = {}
                if m.group(1):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _LINE_RE.match(line)
        if not m:
            stripped = line.strip()
            if stripped and not stripped.startswith("//"):
                diag.unparsed.append((cur.name, lineno, stripped[:120]))
            continue
        name, shape_str, op, rest = m.groups()
        symbols[name] = shape_str
        _note_dtypes(shape_str, diag)
        if op not in _PASSTHROUGH:
            diag.peak_buffer_bytes = max(diag.peak_buffer_bytes,
                                         _shape_bytes(shape_str))

        cm = _CONST_RE.search(line)
        if op == "constant" and cm:
            cur.constants[name] = int(cm.group(1))
        if op == "parameter":
            pm = re.match(r"(\d+)", rest)
            if pm:
                cur.params[int(pm.group(1))] = name

        # parameter-consumption analysis (for fusion byte accounting)
        operand_names = _OPERAND_RE.findall(rest.split(", metadata")[0])
        if op in _SLICING and operand_names:
            cur.sliced_reads[operand_names[0]] += _shape_bytes(shape_str)
            for o in operand_names[1:]:
                cur.full_use.add(o)     # index operands (tiny)
        elif op == "dynamic-update-slice" and operand_names:
            cur.full_use.update(operand_names[1:])
            cur.sliced_reads.setdefault(operand_names[0], 0.0)
        elif op not in _FREE:
            cur.full_use.update(operand_names)

        if op == "while":
            wm = _WHILE_RE.search(line)
            tm = _TRIP_RE.search(line)
            if wm:
                cur.whiles.append(
                    (wm.group(1), wm.group(2),
                     int(tm.group(1)) if tm else None))
            continue
        if op == "compare":
            cur.compare_operands.extend(_OPERAND_RE.findall(rest)[:2])
        if op in ("fusion", "call", "conditional", "map", "reduce",
                  "reduce-window", "sort", "scatter", "select-and-scatter"):
            for callee in _CALLS_RE.findall(line):
                (cur.fusions if op == "fusion" else cur.calls).append(callee)

        # --- collectives ---
        base = op
        if base.endswith("-start"):
            base = base[:-6]
        if base.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            b = _shape_bytes(shape_str)
            cur.coll[base] += b
            cur.coll_counts[base] += 1

        # --- flops (dot) ---
        if op == "dot":
            res = _shapes(shape_str)
            res_elems = sum(n for _, n in res)
            k = 1
            lhs_name = (_OPERAND_RE.findall(rest) or [None])[0]
            lhs_shape = symbols.get(lhs_name, "")
            lm = _LHS_CONTRACT_RE.search(line)
            if lhs_shape and lm and lm.group(1):
                dims_str = _SHAPE_RE.search(lhs_shape)
                if dims_str:
                    lhs_dims = [int(d) for d in dims_str.group(2).split(",")
                                if d]
                    for ci in lm.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
            cur.flops += 2.0 * res_elems * k

        # --- bytes ---
        if op in _FREE:
            continue
        if op == "fusion":
            callee = (_CALLS_RE.findall(line) or [None])[0]
            operand_shapes = [symbols.get(o, "") for o in operand_names]
            cur.fusion_ops.append(
                (_shape_bytes(shape_str), operand_shapes, callee))
        elif op in _SLICING:
            cur.bytes += 2.0 * _shape_bytes(shape_str)
        elif op == "dynamic-update-slice":
            upd = (symbols.get(operand_names[1], "")
                   if len(operand_names) > 1 else shape_str)
            cur.bytes += 2.0 * _shape_bytes(upd)
        elif op == "scatter":
            upd = (symbols.get(operand_names[-1], "")
                   if operand_names else shape_str)
            cur.bytes += 3.0 * _shape_bytes(upd)
        else:
            b = _shape_bytes(shape_str)
            for o in operand_names:
                if o in symbols:
                    b += _shape_bytes(symbols[o])
            cur.bytes += b

    return comps, entry, diag


def _trip_count(comps, cond_name, annotated):
    if annotated is not None:
        return annotated
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    # counter LT constant: resolve constants referenced by the compare
    for operand in cond.compare_operands:
        if operand in cond.constants:
            return cond.constants[operand]
    if cond.constants:
        return max(cond.constants.values())
    return 1


def analyze(text: str) -> dict:
    """Walk the module from ENTRY; returns executed flops/bytes/collectives
    plus parser diagnostics (unparsed lines, unknown dtypes, peak buffer)."""
    comps, entry, diag = _parse(text)
    memo: dict[str, dict] = {}
    diag_fields = {
        "unparsed_lines": len(diag.unparsed),
        "unparsed_sample": list(diag.unparsed[:8]),
        "unknown_dtypes": sorted(diag.unknown_dtypes),
        "peak_buffer_bytes": float(diag.peak_buffer_bytes),
    }

    def walk(name: str) -> dict:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}, "coll_counts": {}}
        memo[name] = {"flops": 0.0, "bytes": 0.0, "coll": {},
                      "coll_counts": {}}  # cycle guard
        flops = comp.flops
        nbytes = comp.bytes
        # fusion bytes: result + per-operand reads, where operands consumed
        # only through slicing ops inside the callee are charged at the
        # slice size (a scan body reads one layer slice, not the stack)
        for res_bytes, operand_shapes, callee in comp.fusion_ops:
            nbytes += res_bytes
            callee_comp = comps.get(callee)
            for i, oshape in enumerate(operand_shapes):
                full = _shape_bytes(oshape) if oshape else 0.0
                if callee_comp is not None:
                    nbytes += callee_comp.param_read_bytes(i, full)
                else:
                    nbytes += full
        coll = defaultdict(float, comp.coll)
        counts = defaultdict(float, comp.coll_counts)
        for callee in comp.fusions:        # flops hide inside fusions
            sub = walk(callee)
            flops += sub["flops"]          # bytes intentionally NOT added
        for callee in comp.calls:
            sub = walk(callee)
            flops += sub["flops"]
            nbytes += sub["bytes"]
            for k, v in sub["coll"].items():
                coll[k] += v
            for k, v in sub["coll_counts"].items():
                counts[k] += v
        for cond, body, trip in comp.whiles:
            n = _trip_count(comps, cond, trip)
            sub = walk(body)
            flops += n * sub["flops"]
            nbytes += n * sub["bytes"]
            for k, v in sub["coll"].items():
                coll[k] += n * v
            for k, v in sub["coll_counts"].items():
                counts[k] += n * v
        memo[name] = {"flops": flops, "bytes": nbytes, "coll": dict(coll),
                      "coll_counts": dict(counts)}
        return memo[name]

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "coll": {}, "coll_counts": {},
                **diag_fields}
    return {**walk(entry), **diag_fields}


def collective_bytes(text: str) -> dict:
    """Executed collective traffic (scan-scaled), per kind + total."""
    stats = analyze(text)
    return {
        "per_kind_bytes": {k: float(v) for k, v in stats["coll"].items()},
        "counts": {k: float(v) for k, v in stats["coll_counts"].items()},
        "total_bytes": float(sum(stats["coll"].values())),
    }


def executed_cost(text: str) -> dict:
    """Executed flops / bytes / collective bytes for the roofline."""
    stats = analyze(text)
    return {
        "flops": float(stats["flops"]),
        "bytes": float(stats["bytes"]),
        "collective_bytes": float(sum(stats["coll"].values())),
        "collectives": {k: float(v) for k, v in stats["coll"].items()},
        "collective_counts": {k: float(v)
                              for k, v in stats["coll_counts"].items()},
        "unparsed_lines": stats["unparsed_lines"],
        "unparsed_sample": stats["unparsed_sample"],
        "unknown_dtypes": stats["unknown_dtypes"],
        "peak_buffer_bytes": stats["peak_buffer_bytes"],
    }
