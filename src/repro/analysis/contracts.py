"""Declarative streaming-memory / HLO contracts for the engine paths.

The paper's value proposition is compressed-domain search: stage 1 scores
the database through LUTs without a (Q, N) score matrix, stage 2 reranks
candidates without a (Q, L, D) reconstruction tensor. Before this module
those guarantees were ad-hoc regex greps scattered across tests; here each
engine path declares ONE contract — forbidden materializations as symbolic
shapes over the path's size parameters, forbidden host-transfer ops, the
expected collective set for sharded paths, and an optional bound on the
compiler's own temp-memory estimate — and the verifier proves it by
jit-compiling the path over a small shape-bucket matrix and walking the
compiled HLO with ``repro.analysis.hlo``.

Grammar (see docs/ANALYSIS.md):

  Contract(
      path_id="stage1.stream.xla",          # registry key, dotted path name
      build=<fn: params dict -> jax Compiled>,
      buckets=({"Q": 8, "N": 4096, ...}, ...),   # shape matrix to compile
      forbid=(("f32", ("Q", "N")),),        # shapes that must NOT be
                                            #   produced by any compute op
      require=(...),                        # shapes that MUST appear
                                            #   (detector controls)
      forbidden_ops=("infeed", ...),        # opcodes that must not appear
      collectives=frozenset({...}),         # exact executed-collective set
      max_temp=lambda p: p["Q"]*p["N"]*4,   # strict bound on the backend's
                                            #   temp_size_in_bytes estimate
      min_devices=1,                        # skip (not fail) below this
  )

Dims in ``forbid``/``require`` are ints, parameter names, or eval-able
expressions over the bucket parameters ("N//2"). Only COMPUTE-op results
count as materializations: parameters, tuple plumbing, while carries and
copies route existing buffers and legitimately carry forbidden shapes
(e.g. the (Q, N) qbias stream enters as a parameter by design).

Pallas paths compile through interpret mode off-TPU (``ops._interpret``),
which yields real HLO for the kernel body — forbidden-shape checks apply —
but its scratch accounting does not model TPU VMEM, so ``max_temp`` bounds
are declared on the xla paths only.

``check_contract(path_id)`` memoizes per path: tests and the CLI share one
compile per contract per process. ``verify(contract)`` runs an ad-hoc
(unregistered) contract — the negative tests and the seeded-violation CLI
mode use it to prove the detector actually rejects materialized paths.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo

_SDS = jax.ShapeDtypeStruct

#: ops that move data across the host boundary — never allowed in a
#: compiled search path (the engine is eager at the API edge only)
HOST_TRANSFER_OPS = ("infeed", "outfeed", "send", "recv")

#: result-producing ops that merely route existing buffers; their results
#: are not fresh materializations
_PASSTHROUGH = frozenset({
    "parameter", "get-tuple-element", "tuple", "while", "conditional",
    "bitcast", "copy", "copy-start", "copy-done", "optimization-barrier",
    "after-all",
})


@dataclasses.dataclass(frozen=True)
class Violation:
    path_id: str
    bucket: str          # rendered bucket params, e.g. "Q=8 N=4096 ..."
    kind: str            # materialization | missing-shape | forbidden-op |
                         # collectives | temp-memory | parser
    message: str

    def __str__(self):
        return f"[{self.path_id} @ {self.bucket}] {self.kind}: {self.message}"


@dataclasses.dataclass(frozen=True)
class ContractResult:
    path_id: str
    skipped: bool = False
    reason: str = ""
    violations: tuple = ()

    @property
    def ok(self) -> bool:
        return not self.skipped and not self.violations


@dataclasses.dataclass(frozen=True)
class Contract:
    path_id: str
    description: str
    build: callable          # params dict -> jax Compiled
    buckets: tuple           # tuple of params dicts
    forbid: tuple = ()       # ((dtype, (dim, ...)), ...)
    require: tuple = ()
    forbidden_ops: tuple = HOST_TRANSFER_OPS
    collectives: frozenset = frozenset()
    max_temp: callable | None = None
    min_devices: int = 1


REGISTRY: dict[str, Contract] = {}


def register(contract: Contract) -> Contract:
    REGISTRY[contract.path_id] = contract
    return contract


def _dim(expr, params) -> int:
    if isinstance(expr, int):
        return expr
    return int(eval(expr, {"__builtins__": {}}, dict(params)))


def _bucket_str(params) -> str:
    return " ".join(f"{k}={v}" for k, v in params.items())


def _shape_hits(ops_list, dtype: str, dims) -> list:
    """Compute ops whose result shape contains dtype[d0,d1,...]."""
    pat = re.compile(
        rf"(?<![a-z0-9]){re.escape(dtype)}"
        rf"\[{','.join(str(d) for d in dims)}\](?![0-9])")
    return [op for op in ops_list
            if op.op not in _PASSTHROUGH and pat.search(op.shape)]


def verify(contract: Contract) -> ContractResult:
    """Compile every bucket of ``contract`` and check all clauses."""
    if len(jax.devices()) < contract.min_devices:
        return ContractResult(
            contract.path_id, skipped=True,
            reason=(f"needs >= {contract.min_devices} devices, have "
                    f"{len(jax.devices())}"))
    violations = []
    for params in contract.buckets:
        bucket = _bucket_str(params)
        compiled = contract.build(dict(params))
        text = compiled.as_text()
        ops_list = list(hlo.iter_ops(text))

        for dtype, dims in contract.forbid:
            rdims = [_dim(d, params) for d in dims]
            hits = _shape_hits(ops_list, dtype, rdims)
            if hits:
                extra = f" (+{len(hits) - 1} more)" if len(hits) > 1 else ""
                violations.append(Violation(
                    contract.path_id, bucket, "materialization",
                    f"forbidden {dtype}[{','.join(map(str, rdims))}] "
                    f"produced by {hits[0].op} %{hits[0].name} in "
                    f"%{hits[0].comp}{extra}"))

        for dtype, dims in contract.require:
            rdims = [_dim(d, params) for d in dims]
            if not _shape_hits(ops_list, dtype, rdims):
                violations.append(Violation(
                    contract.path_id, bucket, "missing-shape",
                    f"expected {dtype}[{','.join(map(str, rdims))}] buffer "
                    "not found (detector control would pass vacuously)"))

        forbidden = set(contract.forbidden_ops)
        for op in ops_list:
            base = op.op
            for suffix in ("-start", "-done"):
                if base.endswith(suffix):
                    base = base[: -len(suffix)]
            if base in forbidden:
                violations.append(Violation(
                    contract.path_id, bucket, "forbidden-op",
                    f"{op.op} %{op.name} in %{op.comp} (line {op.lineno})"))

        got_coll = set(hlo.collective_bytes(text)["counts"])
        if got_coll != set(contract.collectives):
            violations.append(Violation(
                contract.path_id, bucket, "collectives",
                f"executed collective set {sorted(got_coll)} != declared "
                f"{sorted(contract.collectives)}"))

        if contract.max_temp is not None:
            bound = contract.max_temp(dict(params))
            try:
                temp = compiled.memory_analysis().temp_size_in_bytes
            except Exception:
                temp = None              # backend without memory_analysis
            if temp is not None and temp >= bound:
                violations.append(Violation(
                    contract.path_id, bucket, "temp-memory",
                    f"compiler temp estimate {temp} >= bound {bound}"))

        stats = hlo.analyze(text)
        if stats["unparsed_lines"]:
            violations.append(Violation(
                contract.path_id, bucket, "parser",
                f"{stats['unparsed_lines']} HLO lines matched no parser "
                f"regex; first: {stats['unparsed_sample'][:1]}"))

    return ContractResult(contract.path_id, violations=tuple(violations))


_RESULTS: dict[str, ContractResult] = {}


def check_contract(path_id: str, *, force: bool = False) -> ContractResult:
    """Verify a registered contract (memoized per process)."""
    if force or path_id not in _RESULTS:
        _RESULTS[path_id] = verify(REGISTRY[path_id])
    return _RESULTS[path_id]


def assert_contract(path_id: str) -> ContractResult:
    """Raise AssertionError listing every violation; returns the result
    (callers can inspect ``.skipped`` for min_devices contracts)."""
    res = check_contract(path_id)
    assert not res.violations, "\n".join(str(v) for v in res.violations)
    return res


# ---------------------------------------------------------------------------
# builders — each closes over nothing and compiles one engine path from
# abstract shapes (no data, no training)
# ---------------------------------------------------------------------------

def _build_stage1_stream_xla(p):
    from repro.kernels.topl_scan import adc_scan_topl_stream_xla
    codes = _SDS((p["N"], p["M"]), jnp.uint8)
    luts = _SDS((p["Q"], p["M"], p["K"]), jnp.float32)
    bias = _SDS((p["N"],), jnp.float32)

    def f(c, l, b):
        return adc_scan_topl_stream_xla(c, l, b, None, topl=p["L"],
                                        n_valid=p["N"], chunk_n=p["CHUNK"])

    return jax.jit(f).lower(codes, luts, bias).compile()


def _build_stage1_fused_pallas(p):
    from repro.kernels import ops
    codes = _SDS((p["N"], p["M"]), jnp.uint8)
    luts = _SDS((p["Q"], p["M"], p["K"]), jnp.float32)
    bias = _SDS((p["N"],), jnp.float32)

    def f(c, l, b):
        return ops.adc_scan_topl(c, l, topl=p["L"], bias=b, impl="pallas",
                                 block_n=p["BN"], block_q=8)

    return jax.jit(f).lower(codes, luts, bias).compile()


def _build_stage1_materialized(p):
    from repro.kernels import ref
    codes = _SDS((p["N"], p["M"]), jnp.uint8)
    luts = _SDS((p["Q"], p["M"], p["K"]), jnp.float32)
    bias = _SDS((p["N"],), jnp.float32)

    def f(c, l, b):
        s = ref.adc_scan_batch_ref(c, l) + b[None, :]       # (Q, N) — control
        neg, idx = jax.lax.top_k(-s, p["L"])
        return -neg, idx

    return jax.jit(f).lower(codes, luts, bias).compile()


def _build_stage1_quantized(p, impl, lut_dtype):
    from repro.kernels import ops
    codes = _SDS((p["N"], p["M"]), jnp.uint8)
    luts = _SDS((p["Q"], p["M"], p["K"]), jnp.float32)
    bias = _SDS((p["N"],), jnp.float32)

    def f(c, l, b):
        return ops.adc_scan_topl(c, l, topl=p["L"], bias=b, impl=impl,
                                 block_n=p.get("BN"), block_q=8,
                                 chunk_n=p.get("CHUNK"),
                                 lut_dtype=lut_dtype, overfetch=p["OF"])

    return jax.jit(f).lower(codes, luts, bias).compile()


def _build_stage1_gathered_xla(p):
    from repro.kernels.gather_topl import adc_gather_topl_stream_xla
    codes = _SDS((p["N"], p["M"]), jnp.uint8)
    rows = _SDS((p["Q"], p["W"]), jnp.int32)
    gids = _SDS((p["Q"], p["W"]), jnp.int32)
    rowbias = _SDS((p["Q"], p["W"]), jnp.float32)
    luts = _SDS((p["Q"], p["M"], p["K"]), jnp.float32)

    def f(c, r, g, rb, l):
        return adc_gather_topl_stream_xla(c, r, g, rb, l, topl=p["L"],
                                          chunk_w=p["CHUNK"])

    return jax.jit(f).lower(codes, rows, gids, rowbias, luts).compile()


def _build_stage1_gathered_pallas(p):
    from repro.kernels import ops
    codes = _SDS((p["N"], p["M"]), jnp.uint8)
    rows = _SDS((p["Q"], p["W"]), jnp.int32)
    gids = _SDS((p["Q"], p["W"]), jnp.int32)
    rowbias = _SDS((p["Q"], p["W"]), jnp.float32)
    luts = _SDS((p["Q"], p["M"], p["K"]), jnp.float32)

    def f(c, r, g, rb, l):
        return ops.adc_gather_topl(c, r, g, l, topl=p["L"], rowbias=rb,
                                   impl="pallas", block_w=p["BW"], block_q=8)

    return jax.jit(f).lower(codes, rows, gids, rowbias, luts).compile()


def _build_stage2_table_xla(p):
    from repro.kernels.rerank_dist import rerank_gather_dist_chunked_xla
    cand = _SDS((p["Q"], p["L"], p["M"]), jnp.uint8)
    queries = _SDS((p["Q"], p["D"]), jnp.float32)
    table = _SDS((p["M"], p["K"], p["D"]), jnp.float32)

    def f(c, q, t):
        return rerank_gather_dist_chunked_xla(c, q, t, chunk_l=p["CHUNK"])

    return jax.jit(f).lower(cand, queries, table).compile()


def _build_stage2_fused_pallas(p):
    from repro.kernels import ops
    cand = _SDS((p["Q"], p["L"], p["M"]), jnp.uint8)
    queries = _SDS((p["Q"], p["D"]), jnp.float32)
    table = _SDS((p["M"], p["K"], p["D"]), jnp.float32)

    def f(c, q, t):
        return ops.rerank_gather_dist(c, q, t, impl="pallas",
                                      block_l=p["BL"], block_q=8)

    return jax.jit(f).lower(cand, queries, table).compile()


def _build_stage2_dedup_xla(p):
    from repro.index.rerank import _gathered_dist_chunked
    recon_u = _SDS((p["U"], p["D"]), jnp.float32)
    queries = _SDS((p["Q"], p["D"]), jnp.float32)
    inv = _SDS((p["Q"], p["L"]), jnp.int32)

    def f(r, q, i):
        return _gathered_dist_chunked(r, q, i, chunk_l=p["CHUNK"])

    return jax.jit(f).lower(recon_u, queries, inv).compile()


def _build_stage2_exhaustive_xla(p):
    from repro.index.rerank import exhaustive_topk
    from repro.kernels import ref
    codes = _SDS((p["N"], p["M"]), jnp.uint8)
    queries = _SDS((p["Q"], p["D"]), jnp.float32)
    table = _SDS((p["M"], p["K"], p["D"]), jnp.float32)

    def f(c, q, t):
        return exhaustive_topk(lambda ch: ref.decode_with_table(ch, t),
                               c, q, k=p["TOPK"], chunk_n=p["CHUNK"])

    return jax.jit(f).lower(codes, queries, table).compile()


def _build_stage2_vmap_control(p):
    from repro.kernels import ref
    cand = _SDS((p["Q"], p["L"], p["M"]), jnp.uint8)
    queries = _SDS((p["Q"], p["D"]), jnp.float32)
    table = _SDS((p["M"], p["K"], p["D"]), jnp.float32)
    return jax.jit(ref.rerank_gather_dist_ref).lower(
        cand, queries, table).compile()


def _dispatch_shapes(p):
    """Shared abstract-shape set of the cell-batched dispatch face."""
    return (
        _SDS((p["N"], p["M"]), jnp.uint8),          # cell-grouped codes
        _SDS((p["N"],), jnp.int32),                 # row -> global id
        _SDS((p["N"],), jnp.float32),               # rowbias stream
        _SDS((p["Q"], p["M"], p["K"]), jnp.float32),
        _SDS((p["EB"] + 1, p["CAP"]), jnp.float32),  # cellterm
        _SDS((p["EB"] + 1, p["CAP"]), jnp.int32),    # qidx
        _SDS((p["T"],), jnp.int32),                  # tile_e
        _SDS((p["T"],), jnp.int32),                  # tile_block
        _SDS((p["T"],), jnp.int32),                  # tile_first
        _SDS((p["T"],), jnp.int32),                  # tile_lo
        _SDS((p["T"],), jnp.int32),                  # tile_hi
    )


def _build_stage1_dispatch(p, impl):
    from repro.kernels import ops
    from repro.kernels.dispatch_topl import DispatchPlan

    def f(codes, ids, rowbias, luts, cellterm, qidx, te, tb, tf, tlo, thi):
        plan = DispatchPlan(qidx, te, tb, tf, tlo, thi)
        return ops.adc_dispatch_topl(codes, ids, rowbias, luts, cellterm,
                                     plan, topl=p["L"], impl=impl,
                                     chunk=p["CHUNK"])

    return jax.jit(f).lower(*_dispatch_shapes(p)).compile()


def _build_dispatch_materialized(p):
    from repro.kernels import ref
    codes, ids, rowbias, luts, cellterm, qidx, *_ = _dispatch_shapes(p)
    lo = _SDS((p["EB"] + 1,), jnp.int32)
    hi = _SDS((p["EB"] + 1,), jnp.int32)

    def f(c, i, rb, l, ct, q, a, b):
        return ref.adc_dispatch_topl_ref(c, i, rb, l, ct, q, a, b, p["L"])

    return jax.jit(f).lower(codes, ids, rowbias, luts, cellterm, qidx,
                            lo, hi).compile()


def _build_ivf_router(p):
    from repro.index import dispatch
    probe = _SDS((p["Q"], p["P"]), jnp.int32)
    offsets = _SDS((p["NLIST"] + 1,), jnp.int32)

    def f(pr, off):
        return dispatch._route(pr, off, e_b=p["EB"], cap=p["CAP"],
                               t_b=p["T"], chunk=p["CHUNK"])

    return jax.jit(f).lower(probe, offsets).compile()


def _build_sharded_stage1_dispatch(p):
    from repro.parallel import search as ps
    devices = jax.devices()[:2]
    mesh = jax.sharding.Mesh(np.asarray(devices), ("shard",))
    ns = p["N"] // 2
    fn = ps._device_dispatch_fn(mesh, p["L"], "xla", False)
    two = lambda s, dt: _SDS((2,) + s, dt)
    args = (
        two((ns, p["M"]), jnp.uint8),                 # codes
        two((ns,), jnp.int32),                        # ids
        two((ns,), jnp.float32),                      # rowbias
        two((p["EB"] + 1, p["CAP"]), jnp.int32),      # qidx
        two((p["T"],), jnp.int32),                    # tile_e
        two((p["T"],), jnp.int32),                    # tile_block
        two((p["T"],), jnp.int32),                    # tile_first
        two((p["T"],), jnp.int32),                    # tile_lo
        two((p["T"],), jnp.int32),                    # tile_hi
        two((p["Q"], p["P"]), jnp.int32),             # comb_e
        two((p["Q"], p["P"]), jnp.int32),             # comb_slot
        two((p["EB"] + 1, p["CAP"]), jnp.float32),    # cellterm
        _SDS((p["Q"], p["M"], p["K"]), jnp.float32),  # luts (replicated)
    )
    return fn.lower(*args).compile()


def _build_serving_batched(p):
    """The shape the serving engine compiles per query bucket: streaming
    scan+top-L at a QUERY_BUCKETS-padded Q with a (Q, N) qbias stream
    entering as a PARAMETER (the coalesced filter-mask lowering — pad
    rows and per-request masks ride it). The contract pins that batching
    never re-materializes the (Q, N) score matrix the streaming engine
    exists to avoid: only the input mask may be (Q, N)-shaped."""
    from repro.kernels import ops
    codes = _SDS((p["N"], p["M"]), jnp.uint8)
    luts = _SDS((p["Q"], p["M"], p["K"]), jnp.float32)
    bias = _SDS((p["N"],), jnp.float32)
    qbias = _SDS((p["Q"], p["N"]), jnp.float32)

    def f(c, l, b, qb):
        return ops.adc_scan_topl(c, l, topl=p["L"], bias=b, qbias=qb,
                                 impl="xla", chunk_n=p["CHUNK"])

    return jax.jit(f).lower(codes, luts, bias, qbias).compile()


def _build_sharded_stage1(p):
    from repro.parallel import search as ps
    devices = jax.devices()[:2]
    mesh = jax.sharding.Mesh(np.asarray(devices), ("shard",))
    shard_rows = p["N"] // 2
    fn = ps._device_topl_fn(mesh, min(p["L"], shard_rows), shard_rows,
                            "xla", False)
    codes = _SDS((p["N"], p["M"]), jnp.uint8)
    bias = _SDS((p["N"],), jnp.float32)
    luts = _SDS((p["Q"], p["M"], p["K"]), jnp.float32)
    return fn.lower(codes, bias, luts).compile()


# ---------------------------------------------------------------------------
# the registry: one contract per engine path
# ---------------------------------------------------------------------------

register(Contract(
    path_id="stage1.stream.xla",
    description="chunked lax.scan stage 1: no (Q, N) score matrix, temp "
                "memory strictly below the matrix footprint",
    build=_build_stage1_stream_xla,
    buckets=({"Q": 8, "N": 4096, "M": 8, "K": 64, "L": 32, "CHUNK": 512},
             {"Q": 5, "N": 2816, "M": 4, "K": 32, "L": 48, "CHUNK": 384}),
    forbid=(("f32", ("Q", "N")),),
    max_temp=lambda p: p["Q"] * p["N"] * 4,
))

register(Contract(
    path_id="stage1.fused.pallas",
    description="fused scan+top-L kernel (interpret off-TPU): no (Q, N) "
                "score matrix in the kernel HLO",
    build=_build_stage1_fused_pallas,
    buckets=({"Q": 8, "N": 2048, "M": 8, "K": 64, "L": 32, "BN": 256},
             {"Q": 8, "N": 1024, "M": 4, "K": 32, "L": 16, "BN": 128}),
    forbid=(("f32", ("Q", "N")),),
))

register(Contract(
    path_id="stage1.quantized.f16.xla",
    description="quantized-LUT stage 1 (fp16 tables, over-fetched pool + "
                "exact f32 re-score): still no (Q, N) score matrix, and "
                "the f16 table the scan consumes must actually exist",
    build=lambda p: _build_stage1_quantized(p, "xla", "float16"),
    buckets=({"Q": 8, "N": 4096, "M": 8, "K": 64, "L": 32, "CHUNK": 512,
              "OF": 2},),
    forbid=(("f32", ("Q", "N")),),
    require=(("f16", ("Q", "M", "K")),),
))

register(Contract(
    path_id="stage1.quantized.i8.xla",
    description="quantized-LUT stage 1 (int8 tables + pow2 scales): no "
                "(Q, N) score matrix, and the s8 table must actually "
                "exist (the scan is not silently falling back to f32)",
    build=lambda p: _build_stage1_quantized(p, "xla", "int8"),
    buckets=({"Q": 8, "N": 4096, "M": 8, "K": 64, "L": 32, "CHUNK": 512,
              "OF": 2},),
    forbid=(("f32", ("Q", "N")),),
    require=(("s8", ("Q", "M", "K")),),
))

register(Contract(
    path_id="stage1.quantized.f16.pallas",
    description="quantized-LUT fused kernel (interpret off-TPU): f16 "
                "tables reach the kernel, no (Q, N) matrix in its HLO",
    build=lambda p: _build_stage1_quantized(p, "pallas", "float16"),
    buckets=({"Q": 8, "N": 2048, "M": 8, "K": 64, "L": 32, "BN": 256,
              "OF": 2},),
    forbid=(("f32", ("Q", "N")),),
    require=(("f16", ("Q", "M", "K")),),
))

register(Contract(
    path_id="stage1.quantized.i8.pallas",
    description="quantized-LUT fused kernel (int8 + pow2 scales, "
                "interpret off-TPU): s8 tables reach the kernel, no "
                "(Q, N) matrix in its HLO",
    build=lambda p: _build_stage1_quantized(p, "pallas", "int8"),
    buckets=({"Q": 8, "N": 2048, "M": 8, "K": 64, "L": 32, "BN": 256,
              "OF": 2},),
    forbid=(("f32", ("Q", "N")),),
    require=(("s8", ("Q", "M", "K")),),
))

register(Contract(
    path_id="stage1.materialized.control",
    description="DETECTOR CONTROL: the materialized full-matrix scan must "
                "show the (Q, N) buffer the streaming contracts forbid",
    build=_build_stage1_materialized,
    buckets=({"Q": 8, "N": 4096, "M": 8, "K": 64, "L": 32},),
    require=(("f32", ("Q", "N")),),
))

register(Contract(
    path_id="stage1.gathered.xla",
    description="chunked gather-scan (IVF probing): no (Q, W) slot-score "
                "batch and no (Q, N) matrix",
    build=_build_stage1_gathered_xla,
    buckets=({"Q": 8, "N": 4096, "W": 960, "M": 4, "K": 32, "L": 50,
              "CHUNK": 128},),
    forbid=(("f32", ("Q", "W")), ("f32", ("Q", "N"))),
    # peak = the (rows, gids, rowbias) chunk restacks (O(Q*W), <=16 B per
    # slot across the three streams) + the O(Q*chunk_w*M) gathered working
    # set — chunk-scaled, never the (Q, W, M) f32 gather a materialized
    # path would hold
    max_temp=lambda p: (p["Q"] * -(-p["W"] // p["CHUNK"]) * p["CHUNK"] * 16
                        + p["Q"] * p["CHUNK"] * p["M"] * 16),
))

register(Contract(
    path_id="stage1.gathered.pallas",
    description="fused gathered kernel (interpret off-TPU): no (Q, W) "
                "slot-score batch and no (Q, N) matrix",
    build=_build_stage1_gathered_pallas,
    buckets=({"Q": 8, "N": 4096, "W": 900, "M": 4, "K": 32, "L": 50,
              "BW": 128},),
    forbid=(("f32", ("Q", "W")), ("f32", ("Q", "N"))),
))

register(Contract(
    path_id="stage2.table.xla",
    description="chunked table-decode rerank: no (Q, L, D) reconstruction",
    build=_build_stage2_table_xla,
    buckets=({"Q": 8, "L": 512, "M": 8, "K": 64, "D": 96, "CHUNK": 64},),
    forbid=(("f32", ("Q", "L", "D")),),
    max_temp=lambda p: p["Q"] * p["L"] * p["D"] * 4,
))

register(Contract(
    path_id="stage2.fused.pallas",
    description="fused gather-decode-distance kernel (interpret off-TPU): "
                "no (Q, L, D) reconstruction",
    build=_build_stage2_fused_pallas,
    buckets=({"Q": 8, "L": 512, "M": 8, "K": 64, "D": 96, "BL": 64},),
    forbid=(("f32", ("Q", "L", "D")),),
))

register(Contract(
    path_id="stage2.dedup.xla",
    description="cross-query dedup gather-back: no (Q, L, D) gathered "
                "reconstruction (held memory is the deduped (U, D))",
    build=_build_stage2_dedup_xla,
    buckets=({"Q": 8, "L": 512, "U": 777, "D": 96, "CHUNK": 64},),
    forbid=(("f32", ("Q", "L", "D")),),
    max_temp=lambda p: p["Q"] * p["L"] * p["D"] * 4,
))

register(Contract(
    path_id="stage2.exhaustive.xla",
    description="chunked exhaustive rerank: no (Q, N, D) reconstruction "
                "and no (Q, N) distance matrix",
    build=_build_stage2_exhaustive_xla,
    buckets=({"Q": 8, "N": 4096, "M": 4, "K": 32, "D": 96, "TOPK": 30,
              "CHUNK": 256},),
    forbid=(("f32", ("Q", "N", "D")), ("f32", ("Q", "N"))),
    # peak = a few (Q, chunk_n, D) distance-working tensors per scan step;
    # chunk-scaled — the materialized (Q, N, D) reconstruction would be
    # N/chunk_n times larger
    max_temp=lambda p: 3 * p["Q"] * p["CHUNK"] * p["D"] * 4,
))

register(Contract(
    path_id="stage2.vmap.control",
    description="DETECTOR CONTROL: the materialized vmap reranker must "
                "show the (Q, L, D) reconstruction the streaming "
                "contracts forbid",
    build=_build_stage2_vmap_control,
    buckets=({"Q": 8, "L": 128, "M": 8, "K": 64, "D": 96},),
    require=(("f32", ("Q", "L", "D")),),
))

register(Contract(
    path_id="stage1.dispatch.xla",
    description="cell-batched dispatch scan (chunked lax.scan over the "
                "routed tile work-list): no (Q, N) score matrix and no "
                "(E+1, cap, N) materialized per-cell batch",
    build=lambda p: _build_stage1_dispatch(p, "xla"),
    buckets=({"Q": 8, "N": 2048, "M": 8, "K": 64, "L": 32, "EB": 8,
              "CAP": 8, "T": 32, "CHUNK": 128},
             {"Q": 8, "N": 1920, "M": 4, "K": 32, "L": 16, "EB": 4,
              "CAP": 16, "T": 16, "CHUNK": 128}),
    forbid=(("f32", ("Q", "N")), ("f32", ("EB+1", "CAP", "N"))),
))

register(Contract(
    path_id="stage1.dispatch.pallas",
    description="fused dispatch kernel (interpret off-TPU): no (Q, N) "
                "score matrix and no (E+1, cap, N) per-cell batch in the "
                "kernel HLO",
    build=lambda p: _build_stage1_dispatch(p, "pallas"),
    buckets=({"Q": 8, "N": 2048, "M": 8, "K": 64, "L": 32, "EB": 8,
              "CAP": 8, "T": 32, "CHUNK": 128},),
    forbid=(("f32", ("Q", "N")), ("f32", ("EB+1", "CAP", "N"))),
))

register(Contract(
    path_id="stage1.dispatch.control",
    description="DETECTOR CONTROL: the materialized dispatch oracle must "
                "show the (E+1, cap, N) per-cell score batch the dispatch "
                "contracts forbid",
    build=_build_dispatch_materialized,
    buckets=({"Q": 8, "N": 1024, "M": 4, "K": 32, "L": 16, "EB": 4,
              "CAP": 8, "T": 16, "CHUNK": 128},),
    require=(("f32", ("EB+1", "CAP", "N")),),
))

register(Contract(
    path_id="ivf.router",
    description="device-resident probe router: pure on-device jnp/lax "
                "(no host transfers), emits the bucketed s32[E+1, cap] "
                "query-batch table and never touches a score-sized buffer",
    build=_build_ivf_router,
    buckets=({"Q": 16, "P": 4, "NLIST": 32, "EB": 8, "CAP": 8, "T": 16,
              "CHUNK": 128},
             {"Q": 64, "P": 8, "NLIST": 64, "EB": 16, "CAP": 32, "T": 64,
              "CHUNK": 128}),
    require=(("s32", ("EB+1", "CAP")),),
    # the router's entire working set is O(Q*P) index arithmetic
    max_temp=lambda p: 64 * p["Q"] * p["P"] + 4096,
))

register(Contract(
    path_id="serving.batched",
    description="batched serving entry (QUERY_BUCKETS-padded Q, coalesced "
                "(Q, N) filter-mask stream entering as a parameter): the "
                "batched path stays on the streaming scan — no fresh "
                "(Q, N) score matrix under batching; temp memory admits "
                "only the mask parameter's chunk-major restage (<= 2 "
                "input-sized copies), never a score matrix on top",
    build=_build_serving_batched,
    buckets=({"Q": 64, "N": 8192, "M": 8, "K": 64, "L": 128, "CHUNK": 1024},
             {"Q": 16, "N": 4096, "M": 8, "K": 64, "L": 100, "CHUNK": 512}),
    forbid=(("f32", ("Q", "N")),),
    max_temp=lambda p: 2 * p["Q"] * p["N"] * 4 + 4096,
))

register(Contract(
    path_id="sharded.stage1.dispatch",
    description="shard_map dispatch stage 1: per-shard routed scan + "
                "local combine, exactly one collective kind (the (D, Q, L) "
                "pool all-gather), no (Q, N) or (Q, N/2) matrix",
    build=_build_sharded_stage1_dispatch,
    buckets=({"Q": 8, "P": 4, "N": 2048, "M": 4, "K": 32, "L": 16,
              "EB": 4, "CAP": 8, "T": 16},),
    forbid=(("f32", ("Q", "N")), ("f32", ("Q", "N//2"))),
    collectives=frozenset({"all-gather"}),
    min_devices=2,
))

register(Contract(
    path_id="sharded.stage1.device",
    description="shard_map stage 1 (per-partition SPMD program): streaming "
                "per shard, exactly one collective kind (the (D, Q, L) "
                "candidate all-gather), no (Q, N) or (Q, N/2) matrix",
    build=_build_sharded_stage1,
    buckets=({"Q": 4, "N": 4096, "M": 8, "K": 64, "L": 16},),
    forbid=(("f32", ("Q", "N")), ("f32", ("Q", "N//2"))),
    collectives=frozenset({"all-gather"}),
    min_devices=2,
))
