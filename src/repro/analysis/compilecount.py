"""Compile-count discipline: prove the bucket ladder actually buckets.

``Index.add`` pads every batch to the ``ENCODE_BUCKETS`` ladder so the
encoder compiles once per bucket instead of once per batch size, and
``Index.search`` runs fixed-shape jitted paths that must hit the trace
cache on every repeat call. Neither property is visible to a unit test
that only checks results — a silently broken ladder still returns correct
codes, just N times slower. This harness counts XLA compiles directly
(``jax_log_compiles`` emits one log record per cache-miss compilation)
and asserts the discipline:

  * a repeat ``add`` of an already-seen batch size within an already-seen
    bucket compiles nothing but unavoidable shape-varying glue (the
    ``concatenate`` growing the code buffer — ``ntotal`` changes shape
    every add by design);
  * the first batch landing in a NEW bucket compiles the encoder exactly
    then (events mentioning the bucket's padded shape appear);
  * a repeat ``search`` with identical query shape compiles NOTHING.

The harness self-checks its counter first (a fresh jitted lambda must
produce >= 1 event) so a broken logging hookup can never pass vacuously.
"""
from __future__ import annotations

import contextlib
import logging
import re

#: compile events whose trigger is an input-shape-dependent glue op, not
#: the encoder body: the code-buffer concatenate (ntotal grows every add),
#: the raw-batch pad to the bucket, and the unpad slice back out
_ADD_GLUE = ("concatenate", "_pad", "dynamic_slice", "convert_element_type")

_NAME_RE = re.compile(r"Compiling ([\w.<>\-]+)")


class CompileLog:
    """Captured compile events from one ``count_compiles()`` window."""

    def __init__(self):
        self.events: list[str] = []

    @property
    def count(self) -> int:
        return len(self.events)

    def names(self) -> list[str]:
        out = []
        for e in self.events:
            m = _NAME_RE.search(e)
            out.append(m.group(1) if m else e[:60])
        return out


class _Capture(logging.Handler):
    def __init__(self, log: CompileLog):
        super().__init__(level=logging.DEBUG)
        self.log = log

    def emit(self, record):
        msg = record.getMessage()
        if "Compiling" in msg:
            self.log.events.append(msg)


def _mute(record) -> bool:
    return False


@contextlib.contextmanager
def count_compiles():
    """Count XLA compilations triggered inside the ``with`` block.

    Pre-existing handlers on the jax logger are muted for the duration so
    enabling ``jax_log_compiles`` doesn't spray the terminal; only the
    capture handler sees the records.
    """
    import jax
    log = CompileLog()
    handler = _Capture(log)
    logger = logging.getLogger("jax")
    prev_level = logger.level
    prev = jax.config.jax_log_compiles
    muted = list(logger.handlers)
    for h in muted:
        h.addFilter(_mute)
    jax.config.update("jax_log_compiles", True)
    if logger.level > logging.WARNING:
        logger.setLevel(logging.WARNING)
    logger.addHandler(handler)
    try:
        yield log
    finally:
        logger.removeHandler(handler)
        for h in muted:
            h.removeFilter(_mute)
        logger.setLevel(prev_level)
        jax.config.update("jax_log_compiles", prev)


def _counter_sane() -> bool:
    """A fresh jitted function must register >= 1 compile event (fresh
    function object -> guaranteed trace-cache miss)."""
    import jax
    import jax.numpy as jnp
    with count_compiles() as log:
        jax.jit(lambda x: x * 2 + 1)(jnp.arange(13, dtype=jnp.float32))
    return log.count >= 1


def encode_ladder_violations() -> list[str]:
    """Run the add/search discipline scenario; returns violation strings
    (empty = disciplined). Uses a distinctive dim so a shared process's
    earlier trace-cache entries cannot mask a missing compile."""
    import numpy as np

    from repro.index import index_factory

    violations: list[str] = []
    if not _counter_sane():
        return ["compile counter captured no event for a fresh jitted "
                "function — the jax_log_compiles hookup is broken, all "
                "discipline checks would pass vacuously"]

    dim = 21                         # distinctive: avoids cross-test caches
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((1900, dim)).astype(np.float32)
    queries = rng.standard_normal((3, dim)).astype(np.float32)

    index = index_factory("PQ3x16,Rerank10", dim=dim)
    index.train(xs[:600], iters=3)

    index.add(xs[:250])              # warm: first 256-bucket compile
    with count_compiles() as log:
        index.add(xs[250:500])       # repeat size, same bucket
    bad = [n for n in log.names()
           if not any(n.startswith(g) for g in _ADD_GLUE)]
    if bad:
        violations.append(
            "same-size add in an already-compiled bucket recompiled "
            f"non-glue computations: {bad} (bucket ladder broken?)")

    with count_compiles() as log:
        index.add(xs[500:1100])      # 600 rows -> first hit of bucket 1024
    if not any("1024" in e for e in log.events):
        violations.append(
            "first add into the 1024 bucket compiled nothing shaped by the "
            "bucket — either the ladder is bypassed or the counter missed "
            "the encoder compile")

    with count_compiles() as log:
        index.add(xs[1100:1700])     # repeat size, bucket 1024 already hot
    bad = [n for n in log.names()
           if not any(n.startswith(g) for g in _ADD_GLUE)]
    if bad:
        violations.append(
            "repeat add in the 1024 bucket recompiled non-glue "
            f"computations: {bad}")

    index.search(queries, 5)         # warm every search-path shape
    with count_compiles() as log:
        index.search(queries, 5)
    if log.count:
        violations.append(
            f"repeat search with identical shapes compiled {log.count} "
            f"computations ({log.names()[:5]}) — the search path must be "
            "fully trace-cached")
    return violations
