"""Repo-specific AST lint rules for the compressed-domain search engine.

Five rules, each guarding an invariant the test suite cannot see locally
(they are properties of the whole tree, not of one function):

  kernel-oracle        every ``pallas_call`` kernel under ``kernels/`` is
                       named ``<base>_pallas``, has a ``<base>_ref`` oracle
                       in ``kernels/ref.py``, and some test references the
                       oracle together with the pallas path (the parity
                       harness that keeps the kernel honest).
  capability-consumed  every capability flag declared by a
                       ``register_scan_backend`` call in
                       ``index/backend.py`` is consumed by at least one
                       ``backend_supports(..., "<flag>")`` resolution site
                       outside backend.py — a declared-but-unread flag is
                       dead configuration that silently stops meaning
                       anything.
  recompile-hazard     no ``float()`` / ``.item()`` / ``np.*`` calls inside
                       traced functions under ``kernels/``, ``index/``,
                       ``parallel/`` — host round-trips inside jit bodies
                       either crash on tracers or silently force
                       per-call recompiles.
  host-sync            no ``jax.device_get`` / ``block_until_ready`` in the
                       search hot paths (``index/``, ``kernels/``,
                       ``parallel/``) — synchronization belongs to
                       benchmarks and the API edge, never inside the
                       engine.
  tuned-block-params   kernel-facing call sites in ``kernels/ops.py`` must
                       resolve block/chunk parameters through the
                       autotuner registry (``repro.kernels.tune``), never
                       hand-pin them: no integer-literal ``block_*`` /
                       ``chunk*`` keyword at a ``*_pallas`` /
                       ``*_stream_xla`` / ``*_chunked_xla`` call, no
                       integer-literal default on ops' own block/chunk
                       parameters, and at least one ``tune.best_config``
                       resolution in the module. A pinned literal silently
                       forks engine speed away from the tuner cache.

"Traced" for recompile-hazard means: decorated with ``jax.jit`` (including
``functools.partial(jax.jit, ...)``), passed by name into ``jit`` / ``scan``
/ ``vmap`` / ``pmap`` / ``shard_map`` / ``fori_loop`` / ``while_loop``,
nested inside a traced function, or called by a traced function in the same
module (one-module transitive closure).

Suppression: append ``# lint: allow(<rule>)`` to the offending line.

``run_lint()`` lints the live repo tree; tests point ``LintTree`` at the
known-good/known-bad fixture trees under ``tests/fixtures/lint/``.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

ALL_RULES = ("kernel-oracle", "capability-consumed", "recompile-hazard",
             "host-sync", "tuned-block-params")

#: directories (relative to the src root) whose compiled functions are the
#: search hot path
_HOT_DIRS = ("kernels", "index", "parallel")

#: transforms whose function-valued arguments are traced
_TRACING_CALLS = {"jit", "scan", "vmap", "pmap", "shard_map", "fori_loop",
                  "while_loop", "checkpoint", "remat", "custom_vjp",
                  "custom_jvp"}

#: np.<attr> accesses that are trace-safe (dtype objects and constants,
#: resolved at trace time, never at run time)
_NP_SAFE = {
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "complex64",
    "complex128", "inf", "nan", "pi", "e", "newaxis", "iinfo", "finfo",
    "dtype", "ndarray",
}

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([\w\-,\s]+)\)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class LintTree:
    """The pair of roots a lint run sees: engine sources + their tests."""
    src: pathlib.Path
    tests: pathlib.Path


def default_tree() -> LintTree:
    root = pathlib.Path(__file__).resolve().parents[3]
    return LintTree(src=root / "src" / "repro", tests=root / "tests")


def _allowed_rules(source_line: str) -> set[str]:
    m = _ALLOW_RE.search(source_line)
    if not m:
        return set()
    return {r.strip() for r in m.group(1).split(",")}


class _FileLint:
    """Shared parse + pragma machinery for one source file."""

    def __init__(self, path: pathlib.Path):
        self.path = path
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))

    def suppressed(self, rule: str, lineno: int) -> bool:
        if 1 <= lineno <= len(self.lines):
            return rule in _allowed_rules(self.lines[lineno - 1])
        return False


def _iter_py(root: pathlib.Path):
    if root.is_dir():
        yield from sorted(root.rglob("*.py"))


def _dotted(node: ast.AST) -> str:
    """'jax.lax.scan' for an Attribute/Name chain (empty if not one)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# ---------------------------------------------------------------------------
# rule: kernel-oracle
# ---------------------------------------------------------------------------

def _contains_pallas_call(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = _dotted(node.func)
            if name.split(".")[-1] == "pallas_call":
                return True
    return False


def _rule_kernel_oracle(tree: LintTree) -> list[Finding]:
    findings = []
    kernels_dir = tree.src / "kernels"
    ref_path = kernels_dir / "ref.py"
    ref_names: set[str] = set()
    if ref_path.exists():
        for node in ast.parse(ref_path.read_text()).body:
            if isinstance(node, ast.FunctionDef):
                ref_names.add(node.name)
    test_texts = [p.read_text() for p in _iter_py(tree.tests)]

    for path in _iter_py(kernels_dir):
        if path.name == "ref.py":
            continue
        fl = _FileLint(path)
        for node in fl.tree.body:
            if not isinstance(node, ast.FunctionDef):
                continue
            if not _contains_pallas_call(node):
                continue
            if fl.suppressed("kernel-oracle", node.lineno):
                continue
            rel = str(path)
            if not node.name.endswith("_pallas"):
                findings.append(Finding(
                    "kernel-oracle", rel, node.lineno,
                    f"pallas_call kernel {node.name!r} must follow the "
                    "'<base>_pallas' naming convention"))
                continue
            base = node.name[: -len("_pallas")]
            oracle = f"{base}_ref"
            if oracle not in ref_names:
                findings.append(Finding(
                    "kernel-oracle", rel, node.lineno,
                    f"kernel {node.name!r} has no oracle {oracle!r} in "
                    "kernels/ref.py"))
                continue
            has_parity = any(oracle in t and "pallas" in t
                             for t in test_texts)
            if not has_parity:
                findings.append(Finding(
                    "kernel-oracle", rel, node.lineno,
                    f"no parity test references both {oracle!r} and the "
                    f"pallas path of {node.name!r}"))
    return findings


# ---------------------------------------------------------------------------
# rule: capability-consumed
# ---------------------------------------------------------------------------

def _declared_capabilities(backend_py: pathlib.Path) -> list[tuple[str, int]]:
    """(capability, declaration line) for every register_scan_backend call."""
    out = []
    for node in ast.walk(ast.parse(backend_py.read_text())):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func).split(".")[-1] != "register_scan_backend":
            continue
        for kw in node.keywords:
            if kw.arg != "capabilities":
                continue
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value,
                                                                str):
                    out.append((sub.value, sub.lineno))
    return out


def _rule_capability_consumed(tree: LintTree) -> list[Finding]:
    backend_py = tree.src / "index" / "backend.py"
    if not backend_py.exists():
        return []
    declared = _declared_capabilities(backend_py)
    if not declared:
        return []
    consumed: set[str] = set()
    for path in _iter_py(tree.src):
        if path == backend_py:
            continue
        for node in ast.walk(ast.parse(path.read_text())):
            if not isinstance(node, ast.Call):
                continue
            if _dotted(node.func).split(".")[-1] != "backend_supports":
                continue
            if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant):
                consumed.add(node.args[1].value)
    fl = _FileLint(backend_py)
    findings = []
    for cap, lineno in declared:
        if cap in consumed:
            continue
        if fl.suppressed("capability-consumed", lineno):
            continue
        findings.append(Finding(
            "capability-consumed", str(backend_py), lineno,
            f"capability {cap!r} is declared but no resolution path "
            "consumes it via backend_supports(...)"))
    # dedupe per capability (declared by several backends)
    seen, unique = set(), []
    for f in findings:
        if f.message not in seen:
            seen.add(f.message)
            unique.append(f)
    return unique


# ---------------------------------------------------------------------------
# rule: recompile-hazard
# ---------------------------------------------------------------------------

def _is_jit_decorator(dec: ast.AST) -> bool:
    name = _dotted(dec)
    if name.split(".")[-1] == "jit":
        return True
    if isinstance(dec, ast.Call):
        head = _dotted(dec.func).split(".")[-1]
        if head == "jit":
            return True
        if head == "partial" and dec.args:
            return _dotted(dec.args[0]).split(".")[-1] == "jit"
    return False


def _module_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    out = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            out.setdefault(node.name, node)
    return out


def _traced_functions(mod: ast.Module) -> set[ast.FunctionDef]:
    """Functions whose bodies run under trace (see module docstring)."""
    funcs = _module_functions(mod)
    traced: set[ast.FunctionDef] = set()
    for fn in funcs.values():
        if any(_is_jit_decorator(d) for d in fn.decorator_list):
            traced.add(fn)
    # names passed into tracing transforms anywhere in the module
    for node in ast.walk(mod):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func).split(".")[-1] not in _TRACING_CALLS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name) and arg.id in funcs:
                traced.add(funcs[arg.id])
    # nested defs inherit; same-module callees of traced functions join
    changed = True
    while changed:
        changed = False
        for fn in list(traced):
            for node in ast.walk(fn):
                if (isinstance(node, ast.FunctionDef)
                        and node not in traced):
                    traced.add(node)
                    changed = True
                if isinstance(node, ast.Call):
                    callee = _dotted(node.func)
                    if ("." not in callee and callee in funcs
                            and funcs[callee] not in traced):
                        traced.add(funcs[callee])
                        changed = True
    return traced


def _hazards_in(fn: ast.FunctionDef, fl: _FileLint) -> list[Finding]:
    findings = []

    def emit(node, msg):
        if not fl.suppressed("recompile-hazard", node.lineno):
            findings.append(Finding("recompile-hazard", str(fl.path),
                                    node.lineno, msg))

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) and node.func.id == "float":
                emit(node, f"float(...) inside traced {fn.name!r} forces a "
                           "host round-trip / per-value recompile")
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item"):
                emit(node, f".item() inside traced {fn.name!r} forces a "
                           "host round-trip")
        if isinstance(node, ast.Attribute):
            root = node
            while isinstance(root.value, ast.Attribute):
                root = root.value
            if (isinstance(root.value, ast.Name)
                    and root.value.id in ("np", "numpy")
                    and root.attr not in _NP_SAFE):
                emit(node, f"np.{root.attr} inside traced {fn.name!r}: host "
                           "numpy in a jit body computes at trace time or "
                           "crashes on tracers")
    return findings


def _rule_recompile_hazard(tree: LintTree) -> list[Finding]:
    findings = []
    for sub in _HOT_DIRS:
        for path in _iter_py(tree.src / sub):
            fl = _FileLint(path)
            seen_lines = set()
            for fn in _traced_functions(fl.tree):
                for f in _hazards_in(fn, fl):
                    if (f.line, f.message) not in seen_lines:
                        seen_lines.add((f.line, f.message))
                        findings.append(f)
    return findings


# ---------------------------------------------------------------------------
# rule: host-sync
# ---------------------------------------------------------------------------

def _rule_host_sync(tree: LintTree) -> list[Finding]:
    findings = []
    for sub in _HOT_DIRS:
        for path in _iter_py(tree.src / sub):
            fl = _FileLint(path)
            for node in ast.walk(fl.tree):
                name = ""
                if isinstance(node, ast.Call):
                    name = _dotted(node.func).split(".")[-1]
                if name not in ("device_get", "block_until_ready"):
                    continue
                if fl.suppressed("host-sync", node.lineno):
                    continue
                findings.append(Finding(
                    "host-sync", str(fl.path), node.lineno,
                    f"{name}() in a search hot path — synchronization "
                    "belongs to benchmarks/ or the API edge"))
    return findings


# ---------------------------------------------------------------------------
# rule: tuned-block-params
# ---------------------------------------------------------------------------

#: call-name suffixes that dispatch into a concrete kernel implementation
_KERNEL_CALL_SUFFIXES = ("_pallas", "_stream_xla", "_chunked_xla")

_BLOCK_PARAM_RE = re.compile(r"^(block_\w+|chunk(_\w+)?)$")


def _rule_tuned_block_params(tree: LintTree) -> list[Finding]:
    """ops.py (the kernel dispatch layer) must route every block/chunk
    decision through ``tune.best_config`` — see module docstring."""
    findings = []
    for path in _iter_py(tree.src / "kernels"):
        if path.name != "ops.py":
            continue
        fl = _FileLint(path)
        kernel_calls = 0
        resolves = False

        def emit(node, msg):
            if not fl.suppressed("tuned-block-params", node.lineno):
                findings.append(Finding("tuned-block-params", str(path),
                                        node.lineno, msg))

        for node in ast.walk(fl.tree):
            if isinstance(node, ast.Call):
                name = _dotted(node.func)
                if name.split(".")[-1] == "best_config":
                    resolves = True
                tail = name.split(".")[-1]
                if tail.endswith(_KERNEL_CALL_SUFFIXES):
                    kernel_calls += 1
                    for kw in node.keywords:
                        if (kw.arg and _BLOCK_PARAM_RE.match(kw.arg)
                                and isinstance(kw.value, ast.Constant)
                                and isinstance(kw.value.value, int)):
                            emit(kw.value,
                                 f"hand-pinned {kw.arg}={kw.value.value} at "
                                 f"kernel call {tail!r}; resolve block "
                                 "parameters via repro.tune "
                                 "(tune.best_config)")
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                a = node.args
                pos = list(zip(reversed(a.posonlyargs + a.args),
                               reversed(a.defaults)))
                kwo = [(arg, d) for arg, d in zip(a.kwonlyargs, a.kw_defaults)
                       if d is not None]
                for arg, default in pos + kwo:
                    if (_BLOCK_PARAM_RE.match(arg.arg)
                            and isinstance(default, ast.Constant)
                            and isinstance(default.value, int)):
                        emit(default,
                             f"integer-literal default {arg.arg}="
                             f"{default.value} on {node.name!r}; default to "
                             "None and resolve via repro.tune")
        if kernel_calls and not resolves:
            findings.append(Finding(
                "tuned-block-params", str(path), 1,
                "ops.py dispatches kernels but never resolves "
                "tune.best_config(...) — block parameters cannot be tuned"))
    return findings


_RULE_FNS = {
    "kernel-oracle": _rule_kernel_oracle,
    "capability-consumed": _rule_capability_consumed,
    "recompile-hazard": _rule_recompile_hazard,
    "host-sync": _rule_host_sync,
    "tuned-block-params": _rule_tuned_block_params,
}


def run_lint(tree: LintTree | None = None,
             rules: tuple = ALL_RULES) -> list[Finding]:
    """Run the selected rules over ``tree`` (default: the live repo)."""
    tree = tree or default_tree()
    findings = []
    for rule in rules:
        findings.extend(_RULE_FNS[rule](tree))
    return sorted(findings, key=lambda f: (f.path, f.line))
