import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh and record the compiled artifact's
memory/cost/collective statistics.

The two lines above MUST stay first: jax locks the device count at first
backend init, and the production mesh needs 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k \
      [--multi-pod] [--out artifacts/dryrun]

Per cell this writes artifacts/dryrun/<arch>__<shape>__<mesh>.json with
bytes-per-device, HLO flops/bytes, and the per-collective byte totals the
roofline analysis (repro/analysis/roofline.py) consumes.
"""
import argparse
import dataclasses
import math
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.models.config import ModelConfig
from repro.parallel import sharding as shard_lib
from repro.parallel import steps as steps_lib
from repro.parallel import hints
from repro import optim as optim_lib
from repro.analysis import hlo as hlo_lib
from repro.utils.pytree import param_count


# gradient-accumulation factors per arch for train_4k, sized so the saved
# per-layer activation stacks fit 16 GB/chip (derivation + before/after in
# EXPERIMENTS.md §Perf). global_batch 256 stays divisible by mb * data size.
_MICROBATCHES = {
    "yi-6b": 8,
    "minitron-8b": 8,
    "mistral-large-123b": 16,
    "gemma3-12b": 8,
    "deepseek-moe-16b": 8,
    "moonshot-v1-16b-a3b": 16,
    "hubert-xlarge": 4,
    "chameleon-34b": 16,
    "rwkv6-1.6b": 8,
    "recurrentgemma-2b": 8,
}


def _rules(multi_pod: bool, *, batch_shardable: bool = True,
           serving: bool = False):
    rules = dict(shard_lib.RULES_MULTI_POD if multi_pod
                 else shard_lib.RULES_SINGLE_POD)
    if serving:
        # inference keeps weights resident in the TP layout: FSDP-sharding
        # the embed axis would re-gather every weight every decoded token
        # (measured 7e8 B/token on gemma3-kvq long_500k — §Perf iter. 7).
        rules["embed"] = None
        rules["seq_act"] = None
    if not batch_shardable:
        # e.g. long_500k: global_batch=1 -> keep batch replicated and give
        # the cache sequence axis the whole mesh instead.
        rules["batch"] = None
        rules["kv_seq"] = (("pod", "data", "model") if multi_pod
                           else ("data", "model"))
    return rules


def _named(mesh, tree_pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# best-known beyond-baseline settings per arch (EXPERIMENTS.md §Perf)
_OPTIMIZED = {
    "rwkv6-1.6b": dict(rwkv_chunk=256),
    "deepseek-moe-16b": dict(moe_ep=True),
    "moonshot-v1-16b-a3b": dict(moe_ep=True),
}
_OPTIMIZED_MB = {"rwkv6-1.6b": 1, "mistral-large-123b": 8, "yi-6b": 2}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               cfg: ModelConfig | None = None, donate: bool = True,
               optimized: bool = False):
    """Lower + compile one cell. Returns (compiled, lowered, info dict)."""
    if cfg is None:
        if arch == "gemma3-12b-kvq":
            cfg = configs.get("gemma3-12b", variant="FULL_KVQ")
        else:
            cfg = configs.get(arch)
    if optimized and arch.replace("-kvq", "") in _OPTIMIZED:
        cfg = cfg.with_(**_OPTIMIZED[arch.replace("-kvq", "")])
    shape = configs.SHAPES[shape_name]
    if shape.step != "train":
        # serving reality: inference weights are bf16 (halves weight HBM)
        cfg = cfg.with_(param_dtype=jnp.bfloat16)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    batch_shardable = shape.global_batch % (
        mesh.shape["data"] * (mesh.shape.get("pod", 1))) == 0
    # TP-resident weights for SINGLE-STREAM transformer decode (long_500k):
    # kills per-token FSDP re-gathers (109x collective win on gemma3-kvq).
    # Batched decode_32k keeps FSDP-sharded weights — measured better
    # there (weight reads amortize over the batch, and replication
    # regresses per-device temp memory); same for rwkv6/griffin decode,
    # where GSPMD's partial-contraction + tiny-activation all-reduce is
    # optimal (EXPERIMENTS.md §Perf iteration 7).
    rules = _rules(multi_pod, batch_shardable=batch_shardable,
                   serving=(shape.step == "decode"
                            and not batch_shardable
                            and cfg.family == "transformer"))

    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(lambda: registry.init(key, cfg))
    params_ps = shard_lib.params_pspecs_shaped(
        registry.logical_axes(cfg), params_struct, rules, mesh)
    batch_struct = configs.input_specs(cfg, shape)
    batch_ps = shard_lib.batch_pspec(batch_struct, rules)

    t0 = time.time()
    with mesh, hints.activation_sharding(rules, mesh):
        if shape.step == "train":
            opt = optim_lib.adamw()
            mb = _MICROBATCHES.get(arch.replace("-kvq", ""), 1)
            if optimized:
                mb = _OPTIMIZED_MB.get(arch.replace("-kvq", ""), mb)
            train_step, opt = steps_lib.make_train_step(
                cfg, opt=opt, microbatches=mb)
            opt_struct = jax.eval_shape(opt.init, params_struct)
            # optimizer state shards exactly like the params (m/v mirror
            # the param tree; scalars replicated)
            opt_ps = {
                "m": params_ps, "v": params_ps, "count": P(),
            }
            step_fn = jax.jit(
                train_step,
                in_shardings=(_named(mesh, params_ps), _named(mesh, opt_ps),
                              _named(mesh, batch_ps), None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = step_fn.lower(params_struct, opt_struct, batch_struct,
                                    jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.step == "prefill":
            prefill_step = steps_lib.make_prefill_step(cfg)
            step_fn = jax.jit(
                prefill_step,
                in_shardings=(_named(mesh, params_ps),
                              _named(mesh, batch_ps)),
            )
            lowered = step_fn.lower(params_struct, batch_struct)
        else:  # decode
            decode_step = steps_lib.make_decode_step(cfg)
            cache_struct = jax.eval_shape(
                lambda: registry.init_cache(cfg, shape.global_batch,
                                            shape.seq_len))
            cache_ps = shard_lib.params_pspecs_shaped(
                registry.cache_logical_axes(cfg, cache_struct),
                cache_struct, rules, mesh)
            step_fn = jax.jit(
                decode_step,
                in_shardings=(_named(mesh, params_ps),
                              _named(mesh, cache_ps),
                              _named(mesh, batch_ps["tokens"]), None),
                donate_argnums=(1,) if donate else (),
            )
            lowered = step_fn.lower(
                params_struct, cache_struct, batch_struct["tokens"],
                jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    executed = hlo_lib.executed_cost(compiled.as_text())
    info = {
        "arch": arch,
        "config": cfg.name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "step": shape.step,
        "param_count": sum(
            math.prod(x.shape) for x in jax.tree.leaves(params_struct)),
        "microbatches": (_MICROBATCHES.get(arch.replace("-kvq", ""), 1)
                         if shape.step == "train" else None),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        # raw XLA cost_analysis (counts each while body ONCE — kept for
        # reference); "executed" is the scan-scaled walk from analysis/hlo.py
        "cost": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        },
        "executed": executed,
        "collectives": {
            "per_kind_bytes": executed["collectives"],
            "counts": executed["collective_counts"],
            "total_bytes": executed["collective_bytes"],
        },
    }
    return compiled, lowered, info


def run_cell(arch: str, shape_name: str, out_dir: pathlib.Path, *,
             multi_pod: bool, optimized: bool = False) -> dict:
    status = (configs.cell_status(arch, shape_name)
              if arch in configs.ARCH_IDS else "run")
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_tag}"
    if status != "run":
        info = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": status}
        (out_dir / f"{tag}.json").write_text(json.dumps(info, indent=2))
        print(f"[dryrun] {tag}: {status}")
        return info
    try:
        compiled, lowered, info = lower_cell(arch, shape_name,
                                             multi_pod=multi_pod,
                                             optimized=optimized)
        info["status"] = "ok"
        print(f"[dryrun] {tag}: ok  "
              f"flops={info['executed']['flops']:.3e} "
              f"coll={info['executed']['collective_bytes']:.3e}B "
              f"compile={info['compile_s']}s")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        info = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": f"error: {type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}
        print(f"[dryrun] {tag}: FAILED {type(e).__name__}: {e}")
    (out_dir / f"{tag}.json").write_text(json.dumps(info, indent=2))
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply best-known per-arch perf settings (§Perf)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = [(a, s) for (a, s, _) in configs.all_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for multi_pod in meshes:
        for arch, shape in cells:
            results.append(run_cell(arch, shape, out_dir,
                                    multi_pod=multi_pod,
                                    optimized=args.optimized))
    ok = sum(1 for r in results if r.get("status") == "ok")
    skip = sum(1 for r in results if str(r.get("status", "")).startswith("skip"))
    fail = len(results) - ok - skip
    print(f"[dryrun] done: {ok} ok, {skip} skipped, {fail} failed "
          f"of {len(results)} cells")
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
