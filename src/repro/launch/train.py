"""LM training launcher: ``--arch <id>`` selects an assigned architecture.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 [--grad-compress int8] [--ckpt-dir /tmp/ckpt]

Full configs train on the production mesh (requires real hardware; on this
container use --smoke, which runs the same code path on the reduced
config over whatever local devices exist, data-parallel via pjit +
elastic mesh). The loop is the fault-tolerant Trainer (auto-resume,
atomic checkpoints, straggler watchdog).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, optim
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_elastic_mesh, make_production_mesh
from repro.models import registry
from repro.parallel import hints, sharding as shard_lib
from repro.parallel import steps as steps_lib
from repro.runtime import Trainer, TrainerConfig
from repro.utils.pytree import param_count


class _FrameStream:
    """Masked-frame batches for encoder archs (hubert)."""

    def __init__(self, cfg, batch, frames, seed=0):
        self.cfg, self.batch, self.frames = cfg, batch, frames
        self.step = 0
        self.seed = seed

    def next_batch(self):
        from repro.data.tokens import masked_frame_batch
        b = masked_frame_batch((self.seed, self.step), self.batch,
                               self.frames, self.cfg.frame_dim,
                               self.cfg.vocab_size)
        self.step += 1
        return b

    def state_dict(self):
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, s):
        self.step = int(s["step"])


def build(arch: str, *, smoke: bool, batch: int, seq: int,
          grad_compress: str | None, lr: float, total_steps: int):
    cfg = configs.get(arch, smoke=smoke)
    mesh = make_elastic_mesh() if smoke else make_production_mesh()
    rules = dict(shard_lib.RULES_SINGLE_POD)

    params_ps = shard_lib.params_pspecs(registry.logical_axes(cfg), rules)
    opt = optim.adamw(weight_decay=0.1)
    lr_fn = optim.linear_warmup_cosine(lr, total_steps,
                                       warmup=max(total_steps // 20, 1))
    train_step, opt = steps_lib.make_train_step(
        cfg, opt=opt, lr_fn=lr_fn, grad_compress=grad_compress)

    with mesh, hints.activation_sharding(rules, mesh):
        key = jax.random.PRNGKey(0)
        params = jax.jit(
            lambda: registry.init(key, cfg),
            out_shardings=jax.tree.map(
                lambda s: NamedSharding(mesh, s), params_ps,
                is_leaf=lambda x: isinstance(x, P)))()
        opt_state = jax.jit(opt.init)(params)
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    if cfg.input_mode == "frames":
        stream = _FrameStream(cfg, batch, seq)
    else:
        stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=seq,
                             batch_size=batch)
    return cfg, mesh, rules, params, opt_state, step_fn, stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compress", default=None, choices=[None, "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--metrics", default=None)
    args = ap.parse_args()

    cfg, mesh, rules, params, opt_state, step_fn, stream = build(
        args.arch, smoke=args.smoke, batch=args.batch, seq=args.seq,
        grad_compress=args.grad_compress, lr=args.lr,
        total_steps=args.steps)
    print(f"[train] arch={cfg.name} params={param_count(params):,} "
          f"devices={len(jax.devices())} mesh={dict(mesh.shape)}")

    tcfg = TrainerConfig(total_steps=args.steps,
                         checkpoint_every=args.ckpt_every,
                         checkpoint_dir=args.ckpt_dir)
    with mesh, hints.activation_sharding(rules, mesh):
        trainer = Trainer(tcfg, step_fn, params, opt_state, stream,
                          metrics_path=args.metrics)
        final = trainer.run()
    print(f"[train] done at step {trainer.step}: "
          + " ".join(f"{k}={v:.4f}" for k, v in final.items()))


if __name__ == "__main__":
    main()
