"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
forces 512 host devices via XLA_FLAGS while tests/benches must see 1.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 ("data", "model") single-pod or 2x16x16 ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(devices=None, *, model_parallel: int | None = None):
    """Best-effort (data, model) mesh from whatever devices are alive.

    Used by the elastic-restart path: after a failure the job restarts with
    however many devices remain; the mesh is re-factorized (model axis kept
    as large as divides the device count, capped at the configured TP) and
    the checkpoint is resharded on load.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if model_parallel is None:
        model_parallel = min(16, n)
    while n % model_parallel:
        model_parallel -= 1
    dp = n // model_parallel
    arr = np.array(devices).reshape(dp, model_parallel)
    return jax.sharding.Mesh(arr, ("data", "model"))
