"""Serving launcher: batched prefill + decode for any decoder arch.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --batch 4 --prompt-len 32 --gen 16 [--kvq]

Runs prefill on the prompt batch, then step-wise decode with greedy
sampling. With --kvq the global-attention KV cache is MCQ-compressed and
scored in the compressed domain (the paper's technique; transformer
family only).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import registry
from repro.parallel import steps as steps_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kvq", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    if cfg.kind == "encoder":
        raise SystemExit(f"{args.arch} is encoder-only; no decode serving")
    if args.kvq:
        cfg = cfg.with_(kvq=True, kvq_books=4, kvq_book_size=16)

    key = jax.random.PRNGKey(0)
    params = registry.init(key, cfg)
    max_len = args.prompt_len + args.gen
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    decode_step = jax.jit(steps_lib.make_decode_step(cfg))

    # prefill via decode steps when caches must match decode layout exactly
    # (works for every family); families also expose bulk prefill().
    caches = registry.init_cache(cfg, args.batch, max_len,
                                 dtype=jnp.float32)
    t0 = time.time()
    logits = None
    for pos in range(args.prompt_len):
        logits, caches = decode_step(params, caches, prompts[:, pos],
                                     jnp.asarray(pos, jnp.int32))
    t_prefill = time.time() - t0

    generated = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        generated.append(tok)
        logits, caches = decode_step(
            params, caches, tok,
            jnp.asarray(args.prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_gen = time.time() - t0

    out = jnp.stack(generated, axis=1)
    print(f"[serve] arch={cfg.name} kvq={cfg.kvq} batch={args.batch}")
    print(f"[serve] prefill {args.prompt_len} tok: {t_prefill:.2f}s; "
          f"decode {args.gen} tok: {t_gen:.2f}s "
          f"({args.gen * args.batch / max(t_gen, 1e-9):.1f} tok/s)")
    print(f"[serve] sample generation (batch 0): {out[0].tolist()}")


if __name__ == "__main__":
    main()
