"""The serving engine: queue + scheduler + double-buffered dispatch.

``ServeEngine`` owns one index (flat ``Index``, ``IVFIndex``, or
``ShardedIndex``) and a single worker thread running the dispatch loop.
The loop is double-buffered around JAX's async dispatch: batch t's
device scan is launched (non-blocking), then the HOST work for batch
t+1 — queue drain, coalescing, probe-plan/routing construction inside
``index.search`` — proceeds while t runs; only then does the worker
block on t's result to fan it out. Steady state therefore keeps the
device busy whenever two batches are in flight. Completion of t never
waits on t+1's coalescing window: the worker fans t out eagerly when
its result is already ready, and otherwise arms the scheduler's linger
interrupt so the wait for t+1's followers is cut the moment t finishes
— which also keeps the observed service times (the scheduler's
deadline-reserve EWMA) honest instead of folding linger into them.

Bit-parity contract: every result delivered through ``submit`` /
``search_requests`` is bitwise-equal to calling ``index.search`` on
that request alone (ties included). ``batching`` documents why each
padding step preserves this; ``tests/test_serve.py`` enforces it.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from typing import Any

import numpy as np

from repro.index.base import Index
from repro.index.ivf import IVFIndex, _INDEX_CAPACITY
from repro.index.sharded import ShardedIndex
from repro.serve import batching
from repro.serve.batching import Batch, Request, coalesce, split_results
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import RequestQueue
from repro.serve.scheduler import Scheduler


@dataclasses.dataclass
class ServeConfig:
    """Engine policy knobs (shape/compile policy + search defaults)."""
    max_batch_queries: int = 128         # queue drain budget per batch
    linger_ms: float = 2.0               # coalescing window
    deadline_slack_ms: float = 1.0       # reserve under each deadline
    default_k: int = 10
    default_deadline_ms: float | None = None
    pow2_k: bool = True                  # bucket k_max to pow2 per batch
    query_buckets: tuple = batching.QUERY_BUCKETS
    use_rerank: bool | None = None       # None = index default
    use_dispatch: bool | None = None     # IVF face pin (None = capability)
    dispatch_capacity: Any = _INDEX_CAPACITY   # load-shed override
    lut_dtype: str = "float32"
    overfetch: int = 1


class ServeEngine:
    """Async serving facade over one trained, populated index."""

    def __init__(self, index, config: ServeConfig | None = None):
        self.index = index
        self.config = config or ServeConfig()
        if self.config.max_batch_queries > self.config.query_buckets[-1]:
            raise ValueError(
                f"max_batch_queries={self.config.max_batch_queries} "
                f"exceeds the largest query bucket "
                f"{self.config.query_buckets[-1]}")
        self._ivf = self._resolve_ivf(index)
        if not isinstance(index, (Index, IVFIndex, ShardedIndex)):
            raise TypeError(f"unsupported index type {type(index).__name__}")
        if isinstance(index, ShardedIndex) and (
                self.config.lut_dtype != "float32"
                or self.config.overfetch != 1
                or self.config.dispatch_capacity is not _INDEX_CAPACITY):
            raise ValueError(
                "ShardedIndex serving does not thread lut_dtype/overfetch/"
                "dispatch_capacity; keep those at their defaults")
        self.queue = RequestQueue()
        self.scheduler = Scheduler(
            self.queue, max_batch_queries=self.config.max_batch_queries,
            linger_ms=self.config.linger_ms,
            deadline_slack_ms=self.config.deadline_slack_ms)
        self.metrics = ServeMetrics()
        self._worker: threading.Thread | None = None
        self._worker_lock = threading.Lock()

    @staticmethod
    def _resolve_ivf(index):
        """The IVFIndex whose nprobe semantics apply, or None (flat)."""
        if isinstance(index, IVFIndex):
            return index
        if isinstance(index, ShardedIndex) and \
                isinstance(index.inner, IVFIndex):
            return index.inner
        return None

    # -- request intake ----------------------------------------------------

    def submit(self, queries, *, k: int | None = None, nprobe=None,
               filter_mask=None,
               deadline_ms: float | None = None) -> concurrent.futures.Future:
        """Enqueue one request; returns a Future resolving to this
        request's own (distances, indices) numpy pair. Starts the worker
        on first use. ``deadline_ms`` defaults from the config (None =
        best-effort)."""
        request = self._make_request(queries, k=k, nprobe=nprobe,
                                     filter_mask=filter_mask,
                                     deadline_ms=deadline_ms)
        request.future = concurrent.futures.Future()
        self._ensure_worker()
        self.queue.submit(request)
        return request.future

    def _make_request(self, queries, *, k, nprobe, filter_mask,
                      deadline_ms) -> Request:
        queries = np.asarray(queries, dtype=np.float32)
        if queries.ndim == 1:
            queries = queries[None, :]
        if queries.ndim != 2 or queries.shape[1] != self.index.dim:
            raise ValueError(
                f"queries must be (q, {self.index.dim}), got "
                f"{queries.shape}")
        q = queries.shape[0]
        if q < 1 or q > self.config.max_batch_queries:
            raise ValueError(
                f"request width {q} outside [1, "
                f"{self.config.max_batch_queries}] (max_batch_queries)")
        if nprobe is not None:
            if self._ivf is None:
                raise ValueError("nprobe applies to IVF-backed indexes only")
            if np.ndim(nprobe) not in (0, 1) or (
                    np.ndim(nprobe) == 1 and len(nprobe) != q):
                raise ValueError(
                    f"nprobe must be a scalar or a ({q},) vector")
        if filter_mask is not None:
            filter_mask = np.asarray(filter_mask, dtype=bool)
            if filter_mask.shape != (q, self.index.ntotal):
                raise ValueError(
                    f"filter_mask must be ({q}, {self.index.ntotal}), "
                    f"got {filter_mask.shape}")
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        return Request(queries=queries,
                       k=self.config.default_k if k is None else int(k),
                       nprobe=nprobe, filter_mask=filter_mask,
                       deadline_ms=deadline_ms)

    # -- synchronous parity surface ----------------------------------------

    def search_requests(self, requests) -> list:
        """Coalesce + execute + fan-in one request group synchronously —
        the deterministic surface the parity suite and the smoke check
        drive (no queue/timing in the loop, same batch math as the
        worker). ``requests`` are dicts of ``submit`` kwargs or
        ``Request`` objects; returns one (distances, indices) numpy pair
        per request, in order."""
        reqs = [r if isinstance(r, Request) else self._make_request(
                    r.get("queries"), k=r.get("k"), nprobe=r.get("nprobe"),
                    filter_mask=r.get("filter_mask"),
                    deadline_ms=r.get("deadline_ms"))
                for r in requests]
        total = sum(r.num_queries for r in reqs)
        if total > self.config.max_batch_queries:
            raise ValueError(
                f"group of {total} queries exceeds max_batch_queries="
                f"{self.config.max_batch_queries}; split the group")
        batch = self._coalesce(reqs)
        d, i = self._execute(batch)
        return split_results(batch, np.asarray(d), np.asarray(i),
                             self.index.ntotal)

    # -- batch construction / execution ------------------------------------

    def _coalesce(self, requests) -> Batch:
        return coalesce(
            requests, ntotal=self.index.ntotal,
            default_nprobe=None if self._ivf is None else self._ivf.nprobe,
            pow2_k=self.config.pow2_k, buckets=self.config.query_buckets)

    def _execute(self, batch: Batch):
        """Launch the batched search; returns DEVICE arrays (JAX async
        dispatch pending) so the worker can overlap the next batch's
        host work before blocking on them."""
        cfg = self.config
        kw = dict(use_rerank=cfg.use_rerank, filter_mask=batch.filter_mask)
        if isinstance(self.index, IVFIndex):
            kw.update(nprobe=batch.nprobe, use_dispatch=cfg.use_dispatch,
                      dispatch_capacity=cfg.dispatch_capacity,
                      lut_dtype=cfg.lut_dtype, overfetch=cfg.overfetch)
        elif isinstance(self.index, ShardedIndex):
            kw.update(nprobe=batch.nprobe, use_dispatch=cfg.use_dispatch)
        else:
            kw.update(lut_dtype=cfg.lut_dtype, overfetch=cfg.overfetch)
        return self.index.search(batch.queries, batch.k_eff, **kw)

    # -- warmup ------------------------------------------------------------

    def warmup(self, buckets=None, ks=None, *, masks: bool = False,
               nprobe_vectors: bool = False) -> dict:
        """Compile every (query bucket, k bucket) the serving loop will
        hit, through the SAME coalesce+execute path, before any timed
        traffic: the cold-compile cost lands here, in its own metric
        line, instead of inside the first requests' p95. Returns
        {label: ms} (also recorded on ``self.metrics``).

        The base pass covers maskless, default-nprobe traffic only — a
        ``filter_mask`` adds a (Q, ntotal) operand and (on the dispatch
        face) a per-query nprobe vector adds a probe-lengths operand, so
        those variants trace DIFFERENT programs. Traffic carrying them
        must opt in here (``masks=True`` warms an all-True-mask batch
        per bucket, ``nprobe_vectors=True`` a non-uniform probe vector
        at the default width; IVF-backed only) or its first request per
        bucket pays the jit inside the timed path. IVF probe-PLAN widths
        remain data-dependent either way — the ladder pins the shapes it
        can (see docs/SERVING.md for the exact coverage)."""
        cfg = self.config
        if buckets is None:
            buckets = [b for b in cfg.query_buckets
                       if b <= cfg.max_batch_queries]
        if ks is None:
            ks = [cfg.default_k]
        if nprobe_vectors and self._ivf is None:
            raise ValueError(
                "nprobe_vectors warmup applies to IVF-backed indexes only")
        timings = {}
        for b in buckets:
            for k in ks:
                variants = [("", None, None)]
                if masks:
                    variants.append(
                        ("_masked", None,
                         np.ones((b, self.index.ntotal), dtype=bool)))
                if nprobe_vectors:
                    # non-uniform on purpose: a uniform vector collapses
                    # to its scalar and would trace the base program
                    dflt = max(1, min(self._ivf.nprobe, self._ivf.nlist))
                    lens = np.full(b, dflt, dtype=np.int32)
                    if self._ivf.nlist > 1 and b > 1:
                        lens[0] = dflt - 1 if dflt > 1 else dflt + 1
                    variants.append(("_vnprobe", lens, None))
                for suffix, nprobe, mask in variants:
                    req = self._make_request(
                        np.zeros((b, self.index.dim), np.float32),
                        k=k, nprobe=nprobe, filter_mask=mask,
                        deadline_ms=None)
                    t0 = time.perf_counter()
                    batch = self._coalesce([req])
                    d, i = self._execute(batch)
                    np.asarray(d), np.asarray(i)    # block for compile+run
                    ms = (time.perf_counter() - t0) * 1e3
                    kb = batching.k_bucket(k) if cfg.pow2_k else k
                    label = f"q{b}_k{kb}{suffix}"
                    timings[label] = ms
                    self.metrics.record_cold_compile(label, ms)
        return timings

    # -- worker loop -------------------------------------------------------

    def _ensure_worker(self) -> None:
        with self._worker_lock:
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(
                    target=self._run_worker, name="repro-serve-worker",
                    daemon=True)
                self._worker.start()

    @staticmethod
    def _pending_ready(pending) -> bool:
        """True when batch t's result no longer needs a device wait, so
        completing it now costs (almost) nothing. Plain numpy results
        (no ``is_ready``) are host-resident by definition; if readiness
        cannot be probed, answer False and keep the blocking order."""
        _, d, i, _ = pending
        try:
            return all(getattr(a, "is_ready", lambda: True)()
                       for a in (d, i))
        except Exception:        # noqa: BLE001 — probe only, never fatal
            return False

    def _run_worker(self) -> None:
        pending = None        # (batch, device distances, device indices, t0)
        while True:
            # an already-finished batch t fans out BEFORE the next
            # linger window opens: waiting for t+1's followers must
            # never delay results that are sitting ready
            if pending is not None and self._pending_ready(pending):
                self._complete(*pending)
                pending = None
            # host work for t+1 overlaps the device scan of t: only
            # block for fresh items when nothing is in flight, and let
            # t's completion interrupt the linger the moment t is ready
            interrupt = None if pending is None else \
                (lambda p=pending: self._pending_ready(p))
            items = self.scheduler.next_items(block=pending is None,
                                              interrupt=interrupt)
            nxt = None
            if items:
                try:
                    batch = self._coalesce(items)
                    t0 = time.perf_counter()
                    d, i = self._execute(batch)
                    nxt = (batch, d, i, t0)
                except Exception as exc:     # noqa: BLE001 — fan the
                    for r in items:          # failure out per-request
                        if r.future is not None:
                            r.future.set_exception(exc)
            if pending is not None:
                self._complete(*pending)
            pending = nxt
            if pending is None and not items and self.queue.drained():
                return

    def _complete(self, batch: Batch, d, i, t0: float) -> None:
        """Block on the device result, fan out, account. The service
        sample fed to the scheduler spans launch -> result ready; the
        worker's eager-completion/linger-interrupt discipline keeps the
        gap between "device done" and this call at poll granularity, so
        the EWMA tracks service, not linger."""
        try:
            d_np, i_np = np.asarray(d), np.asarray(i)
        except Exception as exc:             # noqa: BLE001
            for r in batch.requests:
                if r.future is not None:
                    r.future.set_exception(exc)
            return
        t_done = time.perf_counter()
        self.scheduler.observe_service((t_done - t0) * 1e3)
        self.metrics.record_batch(batch)
        parts = split_results(batch, d_np, i_np, self.index.ntotal)
        for r, part in zip(batch.requests, parts):
            self.metrics.record_request(r, t_done)
            if r.future is not None:
                r.future.set_result(part)

    def close(self, drain: bool = True) -> None:
        """Stop intake; with ``drain`` (default) the worker finishes
        every pending request before the thread exits."""
        self.queue.close()
        worker = self._worker
        if worker is not None and worker.is_alive():
            if drain:
                worker.join()
            else:
                worker.join(timeout=0.1)

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=True)
