"""CLI driver: `python -m repro.serve [--smoke]`.

Default mode runs a small demo trace against a quick index and prints
the metrics summary. ``--smoke`` is the CI gate: a fixed, seeded
arrival trace over flat and IVF indexes asserting (a) batched results
are bitwise-equal to each request searched alone, (b) zero deadline
misses at quick scale under a generous budget, (c) warm-up recorded
cold-compile lines so the timed trace never pays a jit. Non-zero exit
on any drift.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.index import index_factory
from repro.serve import ServeConfig, ServeEngine

_DIM = 32


def _build(spec: str, n_base: int = 4000, n_train: int = 1500):
    rng = np.random.default_rng(0)
    train = rng.normal(size=(n_train, _DIM)).astype(np.float32)
    base = rng.normal(size=(n_base, _DIM)).astype(np.float32)
    ix = index_factory(spec, _DIM)
    ix.train(train, iters=4)
    ix.add(base)
    return ix


def _trace(rng, ntotal: int, n_requests: int, ivf: bool):
    """A deterministic heterogeneous request mix: widths, ks, per-request
    nprobe (IVF), and sparse filter masks."""
    reqs = []
    for t in range(n_requests):
        q = int(rng.integers(1, 5))
        r = {"queries": rng.normal(size=(q, _DIM)).astype(np.float32),
             "k": int(rng.integers(1, 20))}
        if ivf and t % 3 == 1:
            r["nprobe"] = int(rng.integers(1, 8))
        if t % 4 == 2:
            r["filter_mask"] = rng.random((q, ntotal)) > 0.5
        reqs.append(r)
    return reqs


def _solo(index, r):
    kw = {}
    if r.get("nprobe") is not None:
        kw["nprobe"] = r["nprobe"]
    if r.get("filter_mask") is not None:
        kw["filter_mask"] = r["filter_mask"]
    d, i = index.search(r["queries"], r["k"], **kw)
    return np.asarray(d), np.asarray(i)


def _check_parity(index, engine, requests, label: str) -> int:
    bad = 0
    for group_lo in range(0, len(requests), 8):
        group = requests[group_lo:group_lo + 8]
        got = engine.search_requests(group)
        for r, (d, i) in zip(group, got):
            d_ref, i_ref = _solo(index, r)
            if not (np.array_equal(d, d_ref) and np.array_equal(i, i_ref)):
                bad += 1
                print(f"PARITY DRIFT [{label}] request k={r['k']} "
                      f"q={r['queries'].shape[0]}", file=sys.stderr)
    return bad


def smoke() -> int:
    failures = 0
    for spec, ivf in (("PQ4x16,Rerank32,Scan(xla)", False),
                      ("PQ4x16,IVF32,NProbe4,Rerank32,Scan(xla)", True)):
        index = _build(spec)
        engine = ServeEngine(index, ServeConfig(
            max_batch_queries=32, linger_ms=1.0, default_k=10))
        # masks=True: the trace carries filter_mask requests, whose
        # (Q, ntotal) operand traces a different program per bucket
        cold = engine.warmup(buckets=(8, 16, 32), ks=(16,), masks=True)
        print(f"[{spec}] cold-compile ms: "
              + ", ".join(f"{k}={v:.1f}" for k, v in cold.items()))
        rng = np.random.default_rng(7)
        requests = _trace(rng, index.ntotal, 24, ivf)
        failures += _check_parity(index, engine, requests, spec)

        # async trace under a generous deadline: zero misses expected
        futures = [engine.submit(**r, deadline_ms=10_000.0)
                   for r in _trace(rng, index.ntotal, 16, ivf)]
        for f in futures:
            f.result(timeout=60)
        engine.close()
        s = engine.metrics.summary()
        print(f"[{spec}] requests={s['requests']} p50={s['p50_ms']:.2f}ms "
              f"p95={s['p95_ms']:.2f}ms misses={s['deadline_misses']} "
              f"batches={s['batches']} overflows={s['dispatch_overflows']}")
        if s["deadline_misses"] != 0:
            print(f"SMOKE FAIL [{spec}]: {s['deadline_misses']} deadline "
                  "misses under a 10s budget", file=sys.stderr)
            failures += 1
    print("serve smoke:", "FAIL" if failures else "OK")
    return 1 if failures else 0


def demo(n_requests: int, rate_hz: float) -> int:
    index = _build("PQ4x16,IVF32,NProbe4,Rerank32,Scan(xla)")
    engine = ServeEngine(index, ServeConfig(max_batch_queries=32,
                                            default_deadline_ms=50.0))
    engine.warmup(buckets=(8, 16, 32))
    rng = np.random.default_rng(1)
    futures = []
    for r in _trace(rng, index.ntotal, n_requests, ivf=True):
        futures.append(engine.submit(**r))
        time.sleep(1.0 / rate_hz)
    for f in futures:
        f.result(timeout=60)
    engine.close()
    for key, val in engine.metrics.summary().items():
        print(f"  {key}: {val}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="deterministic CI gate: parity + zero-miss")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="demo arrival rate (req/s)")
    args = ap.parse_args(argv)
    return smoke() if args.smoke else demo(args.requests, args.rate)


if __name__ == "__main__":
    sys.exit(main())
