"""repro.serve: deadline-aware batched serving over the streaming index.

The paper's design premise is a deployed retrieval system answering heavy
query traffic cheaply — fast encoding plus LUT-based compressed-domain
distances exist so the *serving* cost per query is small. This package is
that serving layer: an async request queue (`RequestQueue`), a
deadline-aware coalescing scheduler (`Scheduler`), pow2 shape-bucket
batching (`batching`, mirroring the `ENCODE_BUCKETS` ladder so each
bucket compiles once), and a double-buffered dispatch engine
(`ServeEngine`) that overlaps host-side batch assembly for request group
t+1 with the device scan of group t.

Batched execution is bit-identical to searching every request alone —
pad queries are fully masked out and each request's rows are sliced back
by exact-top-k prefix stability — so batching is purely a throughput
knob, never a quality one. `tests/test_serve.py` holds the parity
property suite; `docs/SERVING.md` the architecture tour.
"""
from repro.serve.batching import (QUERY_BUCKETS, Batch, Request, coalesce,
                                  k_bucket, query_bucket)
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.metrics import ServeMetrics, latency_percentiles
from repro.serve.queue import RequestQueue
from repro.serve.scheduler import Scheduler

__all__ = [
    "QUERY_BUCKETS", "Batch", "Request", "RequestQueue", "Scheduler",
    "ServeConfig", "ServeEngine", "ServeMetrics", "coalesce", "k_bucket",
    "latency_percentiles", "query_bucket",
]
