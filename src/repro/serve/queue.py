"""Thread-safe request queue with earliest-deadline-first draining.

The queue is deliberately dumb: it stamps, stores, and pops. All policy
(linger windows, bucket targeting) lives in ``Scheduler``; all shape
work lives in ``batching``. Pops are EDF — pending requests sort by
(has-no-deadline, absolute deadline, submit seq), so deadline-carrying
requests always drain before best-effort ones and FIFO breaks ties —
and take a PREFIX of that order whose summed query rows fit the caller's
budget, so a wide request never starves behind narrow ones forever (it
is at the front of some prefix as soon as its deadline or seq says so).
"""
from __future__ import annotations

import threading
import time

from repro.serve.batching import Request


def _edf_key(r: Request):
    return (r.t_deadline is None, r.t_deadline or 0.0, r.seq)


class RequestQueue:
    def __init__(self):
        self._cond = threading.Condition()
        self._items: list[Request] = []
        self._seq = 0
        self._closed = False

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def submit(self, request: Request) -> Request:
        """Stamp submit time / seq / absolute deadline and enqueue."""
        with self._cond:
            if self._closed:
                raise RuntimeError("submit on a closed RequestQueue")
            request.t_submit = time.perf_counter()
            request.seq = self._seq
            self._seq += 1
            if request.deadline_ms is not None:
                request.t_deadline = request.t_submit \
                    + request.deadline_ms / 1e3
            self._items.append(request)
            self._cond.notify_all()
        return request

    def close(self) -> None:
        """No further submits; pending requests still drain via take."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def drained(self) -> bool:
        """Closed AND empty: the worker's termination condition."""
        with self._cond:
            return self._closed and not self._items

    def take(self, max_queries: int, *, block: bool = True,
             timeout: float | None = None,
             strict_budget: bool = False) -> list[Request]:
        """Pop the EDF prefix totalling at most ``max_queries`` rows.

        Blocks (optionally up to ``timeout`` seconds) for the queue to
        become non-empty; returns [] on timeout, on ``block=False`` with
        nothing pending, or once the queue is closed and drained. By
        default pops at least one request when anything is pending, even
        a head wider than ``max_queries`` — right for a FRESH batch,
        whose caller sizes ``max_queries`` at the full batch budget (the
        engine bounds request width at submit, so such a head always
        fits a batch of its own). A REFILL into a partly-built batch
        must instead pass ``strict_budget=True``: an oversize head is
        then refused (returns [] immediately, head left queued) rather
        than popped past the remaining budget."""
        deadline = None if timeout is None \
            else time.perf_counter() + timeout
        with self._cond:
            while not self._items:
                if self._closed or not block:
                    return []
                remaining = None if deadline is None \
                    else deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    return []
                self._cond.wait(remaining)
            self._items.sort(key=_edf_key)
            taken, used = [], 0
            while self._items:
                head = self._items[0]
                if (taken or strict_budget) \
                        and used + head.num_queries > max_queries:
                    break
                taken.append(self._items.pop(0))
                used += head.num_queries
            return taken
