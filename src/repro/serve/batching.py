"""Request coalescing onto the pow2 shape-bucket ladder.

One jit compile per (query bucket, k bucket) pair, the same
bucket-by-size idiom as ``ENCODE_BUCKETS`` on the encode path: the
scheduler hands a group of requests here, ``coalesce`` pads them up to
the next ``QUERY_BUCKETS`` rung, and ``split_results`` slices each
request's rows back out of the batched result.

Every padding decision below is parity-preserving by construction:

* pad QUERIES are zero vectors whose rows are simply discarded at
  fan-in (and fully masked whenever a filter-mask stream exists, so
  they cannot even cost scan work on the masked path);
* per-request ``k`` batches at the pow2-bucketed max and slices each
  request back to its own ``min(k_r, ntotal)`` prefix — the exact
  sorted top-k is prefix-stable, so the first j columns never depend
  on how many more were computed;
* per-request ``nprobe`` coalesces into a (Q,) vector that
  ``IVFIndex.search`` masks per query (probe at the batch max, excess
  cells never enter that query's pool);
* maskless requests riding a batch that carries masks get all-True
  rows — an all-True row lowers to a zero bias, which can only turn
  -0.0 scores into +0.0, invisible to ranking and to ``array_equal``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import numpy as np

#: Q-padding ladder for coalesced batches (pow2, like ENCODE_BUCKETS):
#: each rung is one jit specialization of the batched search.
QUERY_BUCKETS = (8, 16, 32, 64, 128)


def query_bucket(num_queries: int,
                 buckets: tuple[int, ...] = QUERY_BUCKETS) -> int:
    """Smallest ladder rung holding ``num_queries`` rows."""
    for b in buckets:
        if num_queries <= b:
            return b
    raise ValueError(
        f"batch of {num_queries} queries exceeds the largest query "
        f"bucket {buckets[-1]}; lower max_batch_queries or extend "
        "QUERY_BUCKETS")


def k_bucket(k: int) -> int:
    """Next power of two >= k: batching heterogeneous-k requests at a
    bucketed k_max keeps the compile count per query bucket at
    O(log k_max) instead of one per distinct k."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    return 1 << (k - 1).bit_length()


@dataclasses.dataclass
class Request:
    """One search request: its own query block plus per-request knobs.

    ``deadline_ms`` is a latency budget relative to submission; the
    queue stamps ``t_submit`` and ``seq`` (FIFO tie-break) on submit and
    derives the absolute ``t_deadline``. ``future`` resolves to this
    request's own ``(distances, indices)`` numpy pair."""
    queries: np.ndarray                      # (q, dim) float32
    k: int
    nprobe: Any = None                       # None | int | (q,) int vector
    filter_mask: np.ndarray | None = None    # (q, ntotal) bool
    deadline_ms: float | None = None
    # stamped by RequestQueue.submit
    t_submit: float = 0.0
    t_deadline: float | None = None
    seq: int = -1
    future: Any = None

    @property
    def num_queries(self) -> int:
        return int(self.queries.shape[0])


class Batch(NamedTuple):
    """A coalesced, bucket-padded group of requests ready to execute."""
    requests: tuple                          # the member Requests, in order
    spans: tuple                             # per-request (lo, hi) row spans
    queries: np.ndarray                      # (bucket, dim), pad rows zero
    bucket: int                              # the QUERY_BUCKETS rung used
    k_eff: int                               # batched k (pow2 of max k_r)
    nprobe: Any                              # None | int | (bucket,) vector
    filter_mask: np.ndarray | None           # None | (bucket, ntotal) bool
    deadline: float | None                   # earliest absolute deadline

    @property
    def num_real(self) -> int:
        return int(self.spans[-1][1]) if self.spans else 0

    @property
    def num_pad(self) -> int:
        return self.bucket - self.num_real


def coalesce(requests, *, ntotal: int, default_nprobe: int | None = None,
             pow2_k: bool = True,
             buckets: tuple[int, ...] = QUERY_BUCKETS) -> Batch:
    """Stack a request group into one padded ``Batch``.

    ``default_nprobe`` fills nprobe-less requests when any member pins
    its own width (pass the index's ``nprobe``); with no member pinning
    one, the batch nprobe stays None and the index default applies
    uniformly. ``ntotal`` sizes the combined filter mask."""
    if not requests:
        raise ValueError("coalesce needs at least one request")
    spans, lo = [], 0
    for r in requests:
        spans.append((lo, lo + r.num_queries))
        lo += r.num_queries
    bucket = query_bucket(lo, buckets)
    dim = requests[0].queries.shape[1]
    queries = np.zeros((bucket, dim), dtype=np.float32)
    for r, (a, b) in zip(requests, spans):
        queries[a:b] = r.queries

    k_max = max(r.k for r in requests)
    k_eff = k_bucket(k_max) if pow2_k else k_max

    nprobe = None
    if any(r.nprobe is not None for r in requests):
        if default_nprobe is None:
            raise ValueError(
                "a request pins nprobe but no default_nprobe was given "
                "for the nprobe-less requests (pass the index's nprobe)")
        lens = np.ones(bucket, dtype=np.int32)   # pad rows: cheapest probe
        for r, (a, b) in zip(requests, spans):
            lens[a:b] = default_nprobe if r.nprobe is None else r.nprobe
        if lo == bucket and int(lens.min()) == int(lens.max()):
            nprobe = int(lens[0])
        else:
            nprobe = lens

    filter_mask = None
    if any(r.filter_mask is not None for r in requests):
        # pad rows all-False only BECAUSE a mask stream already exists:
        # on maskless batches the pads just compute-and-discard, which
        # beats shipping a (bucket, ntotal) mask to mask them out.
        filter_mask = np.zeros((bucket, ntotal), dtype=bool)
        for r, (a, b) in zip(requests, spans):
            filter_mask[a:b] = True if r.filter_mask is None \
                else r.filter_mask

    deadlines = [r.t_deadline for r in requests if r.t_deadline is not None]
    return Batch(requests=tuple(requests), spans=tuple(spans),
                 queries=queries, bucket=bucket, k_eff=k_eff,
                 nprobe=nprobe, filter_mask=filter_mask,
                 deadline=min(deadlines) if deadlines else None)


def split_results(batch: Batch, distances: np.ndarray, indices: np.ndarray,
                  ntotal: int):
    """Fan the batched (bucket, W) result back into per-request views.

    Operates on NUMPY arrays on purpose: the engine converts the device
    result to host memory once per batch, and per-request slicing here
    is plain strided views — slicing per-span on device arrays would
    compile one kernel per distinct span shape, breaking the
    one-compile-per-bucket guarantee."""
    out = []
    for r, (a, b) in zip(batch.requests, batch.spans):
        w = min(r.k, ntotal)
        out.append((distances[a:b, :w], indices[a:b, :w]))
    return out
