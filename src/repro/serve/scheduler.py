"""Deadline-aware dynamic batching policy.

The scheduler decides WHEN a batch is cut, trading latency for batch
width: after the first request of a group arrives it lingers up to
``linger_ms`` for followers to coalesce — but never past the point
where the group's earliest deadline could no longer absorb a service
time (tracked as an EWMA of observed batch service, padded by
``deadline_slack_ms``). A request with a tight deadline therefore cuts
its batch almost immediately; best-effort traffic coalesces up to the
full linger window.
"""
from __future__ import annotations

import time

from repro.serve.queue import RequestQueue


class Scheduler:
    def __init__(self, queue: RequestQueue, *, max_batch_queries: int,
                 linger_ms: float = 2.0, deadline_slack_ms: float = 0.0):
        self.queue = queue
        self.max_batch_queries = max_batch_queries
        self.linger_ms = linger_ms
        self.deadline_slack_ms = deadline_slack_ms
        self._service_ewma_ms = 0.0

    @property
    def service_estimate_ms(self) -> float:
        return self._service_ewma_ms

    def observe_service(self, ms: float) -> None:
        """Fold one observed batch service time into the EWMA the linger
        cut uses as its deadline-slack estimate."""
        if self._service_ewma_ms == 0.0:
            self._service_ewma_ms = ms
        else:
            self._service_ewma_ms += 0.25 * (ms - self._service_ewma_ms)

    def _linger_budget_s(self, items) -> float:
        """Seconds the group can still afford to wait for followers."""
        budget = self.linger_ms / 1e3
        now = time.perf_counter()
        reserve = (self._service_ewma_ms + self.deadline_slack_ms) / 1e3
        for r in items:
            if r.t_deadline is not None:
                budget = min(budget, r.t_deadline - now - reserve)
        return max(budget, 0.0)

    def next_items(self, *, block: bool = True):
        """The next request group to coalesce (empty list = nothing
        pending; with ``block=True`` an empty list means the queue is
        closed and drained). Takes the EDF head, then lingers within the
        group's deadline budget to fill toward ``max_batch_queries``."""
        items = self.queue.take(self.max_batch_queries, block=block)
        if not items:
            return items
        used = sum(r.num_queries for r in items)
        cutoff = time.perf_counter() + self._linger_budget_s(items)
        while used < self.max_batch_queries:
            remaining = cutoff - time.perf_counter()
            if remaining <= 0:
                break
            more = self.queue.take(self.max_batch_queries - used,
                                   block=True, timeout=remaining)
            if not more:
                break
            items.extend(more)
            used += sum(r.num_queries for r in more)
            cutoff = min(cutoff, time.perf_counter()
                         + self._linger_budget_s(more))
        return items
