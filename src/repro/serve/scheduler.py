"""Deadline-aware dynamic batching policy.

The scheduler decides WHEN a batch is cut, trading latency for batch
width: after the first request of a group arrives it lingers up to
``linger_ms`` for followers to coalesce — but never past the point
where the group's earliest deadline could no longer absorb a service
time (tracked as an EWMA of observed batch service, padded by
``deadline_slack_ms``). A request with a tight deadline therefore cuts
its batch almost immediately; best-effort traffic coalesces up to the
full linger window.
"""
from __future__ import annotations

import time
from typing import Callable

from repro.serve.queue import RequestQueue

#: linger-wait slice (s) when an ``interrupt`` probe is armed: the
#: engine's "batch t finished on device" signal is checked at this
#: granularity, bounding how long a ready result can sit behind an
#: open linger window.
_INTERRUPT_POLL_S = 5e-4


class Scheduler:
    def __init__(self, queue: RequestQueue, *, max_batch_queries: int,
                 linger_ms: float = 2.0, deadline_slack_ms: float = 0.0):
        self.queue = queue
        self.max_batch_queries = max_batch_queries
        self.linger_ms = linger_ms
        self.deadline_slack_ms = deadline_slack_ms
        self._service_ewma_ms = 0.0

    @property
    def service_estimate_ms(self) -> float:
        return self._service_ewma_ms

    def observe_service(self, ms: float) -> None:
        """Fold one observed batch service time into the EWMA the linger
        cut uses as its deadline-slack estimate."""
        if self._service_ewma_ms == 0.0:
            self._service_ewma_ms = ms
        else:
            self._service_ewma_ms += 0.25 * (ms - self._service_ewma_ms)

    def _linger_budget_s(self, items) -> float:
        """Seconds the group can still afford to wait for followers."""
        budget = self.linger_ms / 1e3
        now = time.perf_counter()
        reserve = (self._service_ewma_ms + self.deadline_slack_ms) / 1e3
        for r in items:
            if r.t_deadline is not None:
                budget = min(budget, r.t_deadline - now - reserve)
        return max(budget, 0.0)

    def next_items(self, *, block: bool = True,
                   interrupt: Callable[[], bool] | None = None):
        """The next request group to coalesce (empty list = nothing
        pending; with ``block=True`` an empty list means the queue is
        closed and drained). Takes the EDF head, then lingers within the
        group's deadline budget to fill toward ``max_batch_queries``.

        Refills are budget-STRICT: a request wider than the remaining
        budget is left queued (it leads the next batch) rather than
        popped past ``max_batch_queries`` — an overfull group would pick
        an un-warmed bucket or, at the top rung, fail the whole group in
        ``coalesce``. An EDF head the budget refuses also ends the
        linger: later arrivals may not legally jump that head.

        ``interrupt`` (optional, engine-armed) is polled during the
        linger wait; when it returns True the group is cut immediately —
        the engine uses it to stop a linger for batch t+1 from delaying
        fan-out of batch t once t's device result is ready."""
        items = self.queue.take(self.max_batch_queries, block=block)
        if not items:
            return items
        used = sum(r.num_queries for r in items)
        cutoff = time.perf_counter() + self._linger_budget_s(items)
        while used < self.max_batch_queries:
            remaining = cutoff - time.perf_counter()
            if remaining <= 0:
                break
            if interrupt is not None:
                if interrupt():
                    break
                remaining = min(remaining, _INTERRUPT_POLL_S)
            more = self.queue.take(self.max_batch_queries - used,
                                   block=True, timeout=remaining,
                                   strict_budget=True)
            if more:
                items.extend(more)
                used += sum(r.num_queries for r in more)
                cutoff = min(cutoff, time.perf_counter()
                             + self._linger_budget_s(more))
            elif interrupt is None or len(self.queue):
                break    # full wait elapsed, or an oversize head refused
        return items
