"""Serving observability: latency percentiles, deadline accounting,
batching efficiency, cold-compile ledger, and the dispatch-overflow
counter (the load-shed events `index.dispatch.OVERFLOWS` rate-limits
out of the warning stream — here they stay exactly countable).
"""
from __future__ import annotations

import threading

import numpy as np

from repro.index import dispatch as _dispatch


def latency_percentiles(latencies_ms) -> dict:
    """p50/p95/p99 over a latency sample (ms). Empty sample -> NaNs, so
    a dry run still emits well-formed rows."""
    if len(latencies_ms) == 0:
        return {"p50_ms": float("nan"), "p95_ms": float("nan"),
                "p99_ms": float("nan")}
    lat = np.asarray(latencies_ms, dtype=np.float64)
    p50, p95, p99 = np.percentile(lat, [50, 95, 99])
    return {"p50_ms": float(p50), "p95_ms": float(p95),
            "p99_ms": float(p99)}


class ServeMetrics:
    """Accumulates per-request and per-batch accounting for one engine.

    ``dispatch_overflows`` reads the process-wide ``OVERFLOWS`` meter as
    a delta from this object's last ``reset()``, so concurrent direct
    index use outside the engine window doesn't pollute the count (two
    engines serving simultaneously would share it — overflow is a
    property of the shared index, not of one queue)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        with self._lock:
            self.latencies_ms: list[float] = []
            self.deadline_misses = 0
            self.deadline_total = 0
            self.batches = 0
            self.padded_queries = 0
            self.real_queries = 0
            self.cold_compile_ms: dict[str, float] = {}
            self._overflow_base = _dispatch.OVERFLOWS.count

    @property
    def dispatch_overflows(self) -> int:
        return _dispatch.OVERFLOWS.count - self._overflow_base

    def record_batch(self, batch) -> None:
        with self._lock:
            self.batches += 1
            self.padded_queries += batch.num_pad
            self.real_queries += batch.num_real

    def record_request(self, request, t_done: float) -> None:
        with self._lock:
            self.latencies_ms.append((t_done - request.t_submit) * 1e3)
            if request.t_deadline is not None:
                self.deadline_total += 1
                if t_done > request.t_deadline:
                    self.deadline_misses += 1

    def record_cold_compile(self, label: str, ms: float) -> None:
        with self._lock:
            self.cold_compile_ms[label] = ms

    def summary(self) -> dict:
        """One flat dict: the BENCH_serve.json row shape."""
        with self._lock:
            lat = list(self.latencies_ms)
            out = {
                "requests": len(lat),
                **latency_percentiles(lat),
                "deadline_misses": self.deadline_misses,
                "deadline_total": self.deadline_total,
                "deadline_miss_rate": (
                    self.deadline_misses / self.deadline_total
                    if self.deadline_total else 0.0),
                "batches": self.batches,
                "padded_queries": self.padded_queries,
                "real_queries": self.real_queries,
                "cold_compile_ms": dict(self.cold_compile_ms),
            }
        out["dispatch_overflows"] = self.dispatch_overflows
        return out
