"""Device-resident distributed stage 1: per-device streaming scan+top-L
under ``shard_map``, merged with an all-gather of (L, 2) candidate tuples.

This is the pod-scale shape of the paper's billion-vector experiments: the
uint8 code matrix (and RVQ-style bias) lives SHARDED across devices — no
device ever holds the full database — each device runs the streaming
scan+top-L engine over its own shard with replicated query LUTs, and the
per-device (Q, L) score/index tuples are all-gathered so the host-side
caller reranks ONE merged pool through the streaming stage-2 engine
(``Index._rerank_topk`` -> ``repro.index.rerank``). Stage 2 deliberately
runs after the merge rather than per shard: bit-parity with the flat
search requires reranking exactly the global top-L pool (a per-shard
local rerank would rank a superset and can disagree on the final top-k),
and the uint8 candidate-code gather is ~100x smaller than shipping
reconstructions between devices. A device-side merged rerank is a
ROADMAP open item.

Merge exactness: device d's global ids are ``local + d * shard_rows`` and
the gathered pools are concatenated device-major, so among equal scores
positions are in ascending-global-index order — the final ``lax.top_k``
therefore reproduces flat-search tie resolution bit-for-bit. Rows added to
pad the database to a device multiple get a +inf bias, so they can never
surface (the same -inf-in-the-negated-domain masking the kernel applies to
its own block padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.utils import compat


@functools.lru_cache(maxsize=16)
def _device_topl_fn(mesh, topl_local: int, shard_rows: int, impl: str):
    """Compiled per-device scan+top-L + all-gather for one mesh/shape."""
    from jax.sharding import PartitionSpec as P

    def per_device(codes, bias, luts):
        scores, idx = ops.adc_scan_topl(codes, luts, topl=topl_local,
                                        bias=bias, impl=impl)
        offset = jax.lax.axis_index("shard").astype(jnp.int32) * shard_rows
        idx = idx + offset
        # all-gather of the per-device (L, 2) candidate tuples -> every
        # device (and the host) sees the full (D, Q, L) pool
        return (jax.lax.all_gather(scores, "shard"),
                jax.lax.all_gather(idx, "shard"))

    f = compat.shard_map(
        per_device, mesh=mesh,
        in_specs=(P("shard"), P("shard"), P()),
        out_specs=(P(), P()),
        check_vma=False)
    return jax.jit(f)


def device_stage1_topl(codes, luts, bias, *, topl: int, impl: str,
                       devices=None):
    """Sharded stage 1 over ``devices`` (default: all local devices).

    codes (N, M) uint8, luts (Q, M, K) f32, bias None | (N,) ->
    (scores, indices), each (Q, min(topl, N)), bit-identical to the flat
    single-device search.
    """
    devices = list(devices if devices is not None else jax.devices())
    d = len(devices)
    n, _ = codes.shape
    q = luts.shape[0]
    topl = min(topl, n)

    shard_rows = -(-n // d)
    pad = shard_rows * d - n
    codes_p = jnp.pad(codes, ((0, pad), (0, 0)))
    bias_full = bias if bias is not None else jnp.zeros((n,), jnp.float32)
    # pad rows masked via +inf bias (uniform across devices, so one SPMD
    # program handles the ragged tail shard)
    bias_p = jnp.pad(bias_full.astype(jnp.float32), (0, pad),
                     constant_values=jnp.inf)

    mesh = jax.sharding.Mesh(np.asarray(devices), ("shard",))
    topl_local = min(topl, shard_rows)
    fn = _device_topl_fn(mesh, topl_local, shard_rows, impl)
    s_all, i_all = fn(codes_p, bias_p, luts.astype(jnp.float32))

    # (D, Q, L) -> (Q, D*L) device-major, then one top-L over the pool
    pool_s = jnp.swapaxes(s_all, 0, 1).reshape(q, d * topl_local)
    pool_i = jnp.swapaxes(i_all, 0, 1).reshape(q, d * topl_local)
    neg, order = jax.lax.top_k(-pool_s, topl)
    return -neg, jnp.take_along_axis(pool_i, order, axis=1)
