"""Device-resident distributed stage 1: per-device streaming scan+top-L
under ``shard_map``, merged with an all-gather of (L, 2) candidate tuples.

This is the pod-scale shape of the paper's billion-vector experiments: the
uint8 code matrix (and RVQ-style bias) lives SHARDED across devices — no
device ever holds the full database — each device runs the streaming
scan+top-L engine over its own shard with replicated query LUTs, and the
per-device (Q, L) score/index tuples are all-gathered so the host-side
caller reranks ONE merged pool through the streaming stage-2 engine
(``Index._rerank_topk`` -> ``repro.index.rerank``). Stage 2 deliberately
runs after the merge rather than per shard: bit-parity with the flat
search requires reranking exactly the global top-L pool (a per-shard
local rerank would rank a superset and can disagree on the final top-k),
and the uint8 candidate-code gather is ~100x smaller than shipping
reconstructions between devices. A device-side merged rerank is a
ROADMAP open item.

Merge exactness: device d's global ids are ``local + d * shard_rows`` and
the gathered pools are concatenated device-major, so among equal scores
positions are in ascending-global-index order — the final ``lax.top_k``
therefore reproduces flat-search tie resolution bit-for-bit. Rows added to
pad the database to a device multiple get a +inf bias, so they can never
surface (the same -inf-in-the-negated-domain masking the kernel applies to
its own block padding).

``device_gather_topl`` is the IVF face: shards are CELL ranges of the
cell-grouped buffer, each device receives only its own ragged probe plan
(slots of cells it owns — "probes only owning shards" by construction),
runs the gathered scan+top-L (``ops.adc_gather_topl``), and the
all-gathered pools merge lexicographically by (score, GLOBAL id) on the
host — cell-grouped shards interleave global ids, so the device-major
positional argument above does not apply and the merge is explicit
(``candidates.merge_topl``).

``device_dispatch_topl`` is the same face over the cell-batched dispatch
engine: the router (``repro.index.dispatch.build_shard_dispatch``) routes
the global probe against each shard's clip-restricted CSR offsets ON
DEVICE — non-owned cells are empty spans, so shards need no probe
masking and the host never builds a ragged plan — each device streams
its owned cells once through ``ops.adc_dispatch_topl``, scatter-merges
its own per-cell partials to a per-query pool (``combine_pools``), and
the all-gathered pools merge lexicographically exactly like the gathered
face. Cell-sharded serving never touches host numpy on the hot path.

The memory/collective shape of these paths is pinned by the
``sharded.stage1.device`` / ``sharded.stage1.dispatch`` contracts in
``repro.analysis.contracts``: no device materializes a (Q, N) or even
(Q, N/D) score matrix, and the only cross-device collective is the
candidate-tuple all-gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.utils import compat

_IMAX = np.iinfo(np.int32).max


@functools.lru_cache(maxsize=16)
def _device_topl_fn(mesh, topl_local: int, shard_rows: int, impl: str,
                    has_qbias: bool):
    """Compiled per-device scan+top-L + all-gather for one mesh/shape."""
    from jax.sharding import PartitionSpec as P

    def per_device(codes, bias, luts, *qbias):
        scores, idx = ops.adc_scan_topl(
            codes, luts, topl=topl_local, bias=bias,
            qbias=qbias[0] if has_qbias else None, impl=impl)
        offset = jax.lax.axis_index("shard").astype(jnp.int32) * shard_rows
        # +inf slots (device pad rows, filtered-out points) keep the _IMAX
        # sentinel instead of a wrapped/out-of-range "global" id
        idx = jnp.where(jnp.isposinf(scores), _IMAX, idx + offset)
        # all-gather of the per-device (L, 2) candidate tuples -> every
        # device (and the host) sees the full (D, Q, L) pool
        return (jax.lax.all_gather(scores, "shard"),
                jax.lax.all_gather(idx, "shard"))

    in_specs = [P("shard"), P("shard"), P()]
    if has_qbias:
        in_specs.append(P(None, "shard"))
    f = compat.shard_map(
        per_device, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(), P()),
        check_vma=False)
    return jax.jit(f)


def device_stage1_topl(codes, luts, bias, *, topl: int, impl: str,
                       qbias=None, devices=None):
    """Sharded stage 1 over ``devices`` (default: all local devices).

    codes (N, M) uint8, luts (Q, M, K) f32, bias None | (N,),
    qbias None | (Q, N) per-(query, point) bias stream (the lowered
    filter mask), sharded along N alongside the codes ->
    (scores, indices), each (Q, min(topl, N)), bit-identical to the flat
    single-device search.
    """
    devices = list(devices if devices is not None else jax.devices())
    d = len(devices)
    n, _ = codes.shape
    q = luts.shape[0]
    topl = min(topl, n)

    shard_rows = -(-n // d)
    pad = shard_rows * d - n
    codes_p = jnp.pad(codes, ((0, pad), (0, 0)))
    bias_full = bias if bias is not None else jnp.zeros((n,), jnp.float32)
    # pad rows masked via +inf bias (uniform across devices, so one SPMD
    # program handles the ragged tail shard)
    bias_p = jnp.pad(bias_full.astype(jnp.float32), (0, pad),
                     constant_values=jnp.inf)
    args = [codes_p, bias_p, luts.astype(jnp.float32)]
    if qbias is not None:
        args.append(jnp.pad(qbias.astype(jnp.float32), ((0, 0), (0, pad))))

    mesh = jax.sharding.Mesh(np.asarray(devices), ("shard",))
    topl_local = min(topl, shard_rows)
    fn = _device_topl_fn(mesh, topl_local, shard_rows, impl,
                         qbias is not None)
    s_all, i_all = fn(*args)

    # (D, Q, L) -> (Q, D*L) device-major, then one top-L over the pool
    pool_s = jnp.swapaxes(s_all, 0, 1).reshape(q, d * topl_local)
    pool_i = jnp.swapaxes(i_all, 0, 1).reshape(q, d * topl_local)
    neg, order = jax.lax.top_k(-pool_s, topl)
    return -neg, jnp.take_along_axis(pool_i, order, axis=1)


@functools.lru_cache(maxsize=16)
def _device_gather_fn(mesh, topl_local: int, impl: str):
    """Compiled per-device gathered scan+top-L + all-gather."""
    from jax.sharding import PartitionSpec as P

    def per_device(codes, rows, gids, rowbias, luts):
        scores, ids = ops.adc_gather_topl(
            codes[0], rows[0], gids[0], luts, rowbias=rowbias[0],
            topl=topl_local, impl=impl)
        return (jax.lax.all_gather(scores, "shard"),
                jax.lax.all_gather(ids, "shard"))

    f = compat.shard_map(
        per_device, mesh=mesh,
        in_specs=(P("shard"), P("shard"), P("shard"), P("shard"), P()),
        out_specs=(P(), P()),
        check_vma=False)
    return jax.jit(f)


def device_gather_topl(codes, bias, plans, luts, rowbias_fn, *, topl: int,
                       impl: str, devices=None):
    """Device-resident IVF stage 1: one cell-range shard per device, each
    probing only the cells it owns.

    codes (N, M) the cell-grouped buffer; bias None | (N,) its per-point
    stream; plans: per shard ``(row_lo, row_hi, rows, gids, cells)`` —
    the shard-local ragged probe plan from ``IVFIndex._probe_plan`` (rows
    already shifted by ``row_lo``; cells are each slot's coarse cell, the
    residual correction's bias key); rowbias_fn(rows, gids, cells,
    shard_bias) -> the (Q, W) slot bias (gathered norms/residual cross
    terms + per-(query, cell) residual correction + lowered filter) or
    None. The slot bias is composed host-side BEFORE the shard plans ship
    to devices, so the per-device kernel contract is unchanged.

    Every shard's buffer slice is padded to a common row count and every
    plan to a common width, so one SPMD program serves the ragged shards;
    pad slots carry gid ``_IMAX`` and can never surface. The all-gathered
    (D, Q, L) pools merge lexicographically by (score, global id) — the
    exact flat-search tie-break over interleaved id ranges.

    Returns (scores, global ids), each (Q, min(topl, pool width)).
    """
    from repro.index.candidates import merge_topl

    devices = list(devices if devices is not None else jax.devices())
    d = len(devices)
    if len(plans) != d:
        raise ValueError(f"{len(plans)} shard plans for {d} devices")
    q = luts.shape[0]
    rmax = max(max(hi - lo for lo, hi, *_ in plans), 1)
    w = max(max(rows.shape[1] for _, _, rows, _, _ in plans), 1)

    codes_sh, rows_sh, gids_sh, rb_sh = [], [], [], []
    for row_lo, row_hi, rows, gids, cells in plans:
        shard_codes = codes[row_lo:row_hi]
        shard_codes = jnp.pad(
            shard_codes, ((0, rmax - shard_codes.shape[0]), (0, 0)))
        shard_bias = None if bias is None else bias[row_lo:row_hi]
        rows_j = jnp.asarray(rows)
        gids_j = jnp.asarray(gids)
        rb = rowbias_fn(rows_j, gids_j, cells, shard_bias)
        if rb is None:
            rb = jnp.zeros(rows_j.shape, jnp.float32)
        pad_w = w - rows.shape[1]
        codes_sh.append(shard_codes)
        rows_sh.append(jnp.pad(rows_j, ((0, 0), (0, pad_w))))
        gids_sh.append(jnp.pad(gids_j, ((0, 0), (0, pad_w)),
                               constant_values=_IMAX))
        rb_sh.append(jnp.pad(rb, ((0, 0), (0, pad_w))))

    mesh = jax.sharding.Mesh(np.asarray(devices), ("shard",))
    topl_local = min(topl, w)
    fn = _device_gather_fn(mesh, topl_local, impl)
    s_all, i_all = fn(jnp.stack(codes_sh), jnp.stack(rows_sh),
                      jnp.stack(gids_sh), jnp.stack(rb_sh),
                      luts.astype(jnp.float32))

    pool_s = jnp.swapaxes(s_all, 0, 1).reshape(q, d * topl_local)
    pool_i = jnp.swapaxes(i_all, 0, 1).reshape(q, d * topl_local)
    return merge_topl(pool_s, pool_i, topl)


@functools.lru_cache(maxsize=16)
def _device_dispatch_fn(mesh, topl_local: int, impl: str, has_qkeep: bool):
    """Compiled per-device routed dispatch + pool combine + all-gather."""
    from jax.sharding import PartitionSpec as P
    from repro.index.dispatch import combine_pools
    from repro.kernels.dispatch_topl import DispatchPlan

    def per_device(codes, ids, rowbias, qidx, te, tb, tf, tlo, thi,
                   comb_e, comb_slot, cellterm, luts, *qkeep):
        plan = DispatchPlan(qidx[0], te[0], tb[0], tf[0], tlo[0], thi[0])
        part_s, part_g = ops.adc_dispatch_topl(
            codes[0], ids[0], rowbias[0], luts, cellterm[0], plan,
            topl=topl_local, qkeep=qkeep[0][0] if has_qkeep else None,
            impl=impl)
        s, g = combine_pools(part_s, part_g, comb_e[0], comb_slot[0],
                             topl=topl_local)
        return (jax.lax.all_gather(s, "shard"),
                jax.lax.all_gather(g, "shard"))

    in_specs = [P("shard")] * 12 + [P()]
    if has_qkeep:
        in_specs.append(P("shard"))
    f = compat.shard_map(
        per_device, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(), P()),
        check_vma=False)
    return jax.jit(f)


def device_dispatch_topl(codes, shards, luts, *, topl: int, impl: str,
                         devices=None):
    """Device-resident IVF stage 1 over the cell-batched dispatch engine:
    one cell-range shard per device, routed on device against its own
    clip-restricted CSR offsets.

    codes (N, M) the cell-grouped buffer; shards: per device
    ``(row_lo, row_hi, routing, ids, rowbias, qkeep, cellterm)`` — the
    shard's buffer row range, its ``repro.index.dispatch.Routing`` from
    ``build_shard_dispatch`` (common shape buckets across shards), and
    the shard-local bias streams from ``IVFIndex._dispatch_streams``
    (ids (n_s,) row -> GLOBAL id; rowbias None | (n_s,) with (N,)
    filters folded to +inf; qkeep None | (Q, n_s); cellterm (E+1, cap)).

    Every shard's buffer slice / id / bias streams pad to a common row
    count so one SPMD program serves the ragged shards; pad rows sit
    beyond every owned cell's ``[lo, hi)`` window and can never surface.
    Each device combines its own partial pools before the all-gather, so
    the collective ships (Q, L) tuples — same shape as the gathered
    face — and the host merge is the same exact lexicographic
    (score, global id) ``merge_topl``.

    Returns (scores, global ids), each (Q, min(topl, pool width)).
    """
    from repro.index.candidates import merge_topl

    devices = list(devices if devices is not None else jax.devices())
    d = len(devices)
    if len(shards) != d:
        raise ValueError(f"{len(shards)} shard specs for {d} devices")
    q = luts.shape[0]
    rmax = max(max(hi - lo for lo, hi, *_ in shards), 1)
    has_qkeep = any(s[5] is not None for s in shards)

    codes_sh, ids_sh, rb_sh, qk_sh, ct_sh = [], [], [], [], []
    plan_sh = {f: [] for f in ("qidx", "tile_e", "tile_block",
                               "tile_first", "tile_lo", "tile_hi")}
    ce_sh, cs_sh = [], []
    for row_lo, row_hi, routing, ids, rowbias, qkeep, cellterm in shards:
        n_s = row_hi - row_lo
        pad = rmax - n_s
        codes_sh.append(jnp.pad(codes[row_lo:row_hi],
                                ((0, pad), (0, 0))))
        ids_sh.append(jnp.pad(ids, (0, pad), constant_values=_IMAX))
        rb = rowbias if rowbias is not None \
            else jnp.zeros((n_s,), jnp.float32)
        rb_sh.append(jnp.pad(rb.astype(jnp.float32), (0, pad)))
        if has_qkeep:
            qk = qkeep if qkeep is not None \
                else jnp.ones((q, n_s), jnp.float32)
            qk_sh.append(jnp.pad(qk.astype(jnp.float32),
                                 ((0, 0), (0, pad))))
        for field in plan_sh:
            plan_sh[field].append(getattr(routing.plan, field))
        ce_sh.append(routing.comb_e)
        cs_sh.append(routing.comb_slot)
        ct_sh.append(cellterm)

    mesh = jax.sharding.Mesh(np.asarray(devices), ("shard",))
    topl_local = min(topl, rmax)
    fn = _device_dispatch_fn(mesh, topl_local, impl, has_qkeep)
    args = [jnp.stack(codes_sh), jnp.stack(ids_sh), jnp.stack(rb_sh)]
    args += [jnp.stack(plan_sh[f]) for f in ("qidx", "tile_e", "tile_block",
                                             "tile_first", "tile_lo",
                                             "tile_hi")]
    args += [jnp.stack(ce_sh), jnp.stack(cs_sh), jnp.stack(ct_sh),
             luts.astype(jnp.float32)]
    if has_qkeep:
        args.append(jnp.stack(qk_sh))
    s_all, i_all = fn(*args)

    l = s_all.shape[-1]
    pool_s = jnp.swapaxes(s_all, 0, 1).reshape(q, d * l)
    pool_i = jnp.swapaxes(i_all, 0, 1).reshape(q, d * l)
    return merge_topl(pool_s, pool_i, topl)
