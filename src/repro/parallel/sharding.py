"""Logical-axis sharding rules (MaxText-style).

Every model family annotates its params with *logical* axes
(``logical_axes(cfg)``); a rules table maps logical -> mesh axes and
``params_pspecs`` materializes ``PartitionSpec`` pytrees for pjit.

Mesh axes:
  pod    — pure data parallelism across pods (cross-DCI gradient reduce)
  data   — FSDP: batch sharding + parameter/optimizer-state sharding
  model  — tensor parallelism: attention heads / FFN hidden / MoE experts /
           vocab; KV-cache sequence axis during decode (sequence
           parallelism for the cache scan)

Rules (single pod):
  embed      -> data    (FSDP shard of the model dimension)
  heads/ffn/
  kv_heads   -> model   (megatron TP)
  experts    -> model   (expert parallelism)
  vocab      -> model   (sharded embedding/logits; softmax reduces over it)
  rnn        -> model   (RG-LRU / rwkv channel dim)
  layers/sub -> None    (scanned)
  batch      -> data (+pod)
  kv_seq     -> model   (decode cache sequence parallelism)
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

RULES_SINGLE_POD: dict[str | None, Any] = {
    "embed": "data",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "rnn": "model",
    "layers": None,
    "sub": None,
    "batch": "data",
    "kv_seq": "model",
    # Megatron-style sequence parallelism for the residual stream at layer
    # boundaries: the per-layer activations saved for backward shard their
    # sequence axis over "model" (16x smaller saved stacks); attention/MLP
    # internals re-gather as needed.
    "seq_act": "model",
    None: None,
}

# multi-pod: identical placement inside each pod; params replicated across
# the pod axis (pure DP), batch additionally split across pods.
RULES_MULTI_POD = dict(RULES_SINGLE_POD)
RULES_MULTI_POD["batch"] = ("pod", "data")


def partition_spec(axes: tuple, rules: dict) -> P:
    """Map one logical-axis tuple to a PartitionSpec.

    A mesh axis may appear at most once per spec; on conflicts the first
    (leftmost) logical axis keeps it (e.g. MoE expert weights
    ("experts","embed","ffn") -> ("model","data",None): the expert axis
    claims "model", so the per-expert ffn dim stays unsharded)."""
    used: set = set()
    out = []
    for a in axes:
        mesh_ax = rules.get(a, None)
        flat = (tuple(mesh_ax) if isinstance(mesh_ax, (tuple, list))
                else (mesh_ax,)) if mesh_ax is not None else ()
        if mesh_ax is None or any(m in used for m in flat):
            out.append(None)
        else:
            used.update(flat)
            out.append(mesh_ax)
    return P(*out)


def params_pspecs(logical: Any, rules: dict) -> Any:
    """Pytree of logical-axis tuples -> pytree of PartitionSpec."""
    return jax.tree.map(lambda ax: partition_spec(ax, rules), logical,
                        is_leaf=lambda x: isinstance(x, tuple))


def _mesh_axis_size(mesh, axis) -> int:
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def params_pspecs_shaped(logical: Any, struct: Any, rules: dict, mesh) -> Any:
    """Shape-aware variant: mesh axes that do not evenly divide the
    corresponding dimension are dropped (e.g. hubert's 504-way vocab head
    on a 16-way model axis stays replicated instead of erroring)."""

    def spec(axes, leaf):
        base = partition_spec(axes, rules)
        out = []
        for i, mesh_ax in enumerate(base):
            if mesh_ax is None or i >= len(leaf.shape):
                out.append(None)
                continue
            if leaf.shape[i] % _mesh_axis_size(mesh, mesh_ax) != 0:
                out.append(None)
            else:
                out.append(mesh_ax)
        return P(*out)

    return jax.tree.map(spec, logical, struct,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_pspec(batch_tree: Any, rules: dict) -> Any:
    """Shard every batch leaf on its leading (batch) axis."""
    def spec(leaf):
        b = rules["batch"]
        return P(b, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(spec, batch_tree)


def shardings(mesh: Mesh, pspecs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
