"""Step builders: pure functions ready for jit/pjit with named shardings.

  * train_step(params, opt_state, batch, step) -> (params, opt_state, metrics)
  * prefill_step(params, batch) -> (last_logits, caches)
  * decode_step(params, caches, tokens, pos) -> (logits, caches)

The builders close over the config + optimizer so the returned functions
are pure pytree->pytree maps that lower identically on 1 device or 512.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import registry
from repro import optim as optim_lib
from repro.optim import compress as compress_lib
from repro.utils.pytree import global_norm


def make_train_step(cfg: ModelConfig, *, opt=None, lr_fn=None,
                    grad_clip: float = 1.0, balance_coef: float = 0.01,
                    grad_compress: str | None = None,
                    microbatches: int = 1,
                    cast_params: bool = False):
    """Build the canonical LM train step (CE + optional MoE balance).

    microbatches > 1 enables gradient accumulation: the global batch is
    split along axis 0 and scanned sequentially with f32 grad accumulation
    (identical math up to summation order; peak activation memory divides
    by the microbatch count — how the train_4k shapes fit 16 GB/chip).

    grad_compress: None | "int8" — error-feedback 8-bit gradient
    quantization applied before the (GSPMD-inserted) gradient reduction;
    the EF accumulator rides in opt_state (see repro/optim/compress.py).
    """
    opt = opt or optim_lib.adamw()
    lr_fn = lr_fn or optim_lib.constant(1e-4)
    if grad_compress:
        opt = compress_lib.with_error_feedback(opt, scheme=grad_compress)

    def loss_f(p, b):
        if cast_params:
            # bf16 compute copy: GSPMD sinks the convert below the FSDP
            # all-gather, halving weight-gather wire traffic; the cast is
            # linear so gradients accumulate back into f32 masters.
            from repro.utils.pytree import tree_cast
            p = tree_cast(p, cfg.compute_dtype)
        return registry.loss_fn(p, cfg, b, balance_coef=balance_coef)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_f, has_aux=True)(params, batch)
        else:
            mb_batch = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)

            def mb_body(acc, mb):
                g_acc, loss_acc, ce_acc, bal_acc = acc
                (l, aux), g = jax.value_and_grad(
                    loss_f, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + l, ce_acc + aux["ce"],
                        bal_acc + aux["balance"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            z = jnp.zeros((), jnp.float32)
            (grads, loss_sum, ce_sum, bal_sum), _ = jax.lax.scan(
                mb_body, (zeros, z, z, z), mb_batch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            aux = {"ce": ce_sum / microbatches,
                   "balance": bal_sum / microbatches}

        grads, gnorm = optim_lib.clip_by_global_norm(grads, grad_clip)
        params, opt_state = opt.apply(params, grads, opt_state, lr_fn(step))
        metrics = {
            "loss": loss,
            "ce": aux["ce"],
            "balance": aux["balance"],
            "grad_norm": gnorm,
            "lr": lr_fn(step),
        }
        return params, opt_state, metrics

    return train_step, opt


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        if cfg.kind == "encoder":
            # encoder "prefill" is just the forward pass (no cache)
            return registry.forward(params, cfg, batch), ()
        return registry.family(cfg).prefill(params, cfg, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, caches, tokens, pos):
        return registry.decode_step(params, cfg, caches, tokens, pos)

    return decode_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, aux = registry.loss_fn(params, cfg, batch)
        return {"loss": loss, **aux}

    return eval_step
