"""Activation sharding hints (with_sharding_constraint anchors).

GSPMD propagates shardings from inputs/outputs, but inside a deep scanned
body it can pick flop-equivalent-but-communication-heavy layouts (e.g.
token-replicated contraction sharding) or pad small head axes up to the
mesh. The model code therefore drops logical-axis *hints* at the canonical
anchor points (embeddings, q/k/v, attention scores, MLP hidden, MoE
buffers, logits), resolved against the active rules + mesh.

Outside a mesh context (unit tests, single-device runs) hints are no-ops.
Axes that do not divide the corresponding mesh axis are dropped from the
hint rather than padded.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(rules: dict, mesh: Mesh):
    token = _CTX.set((rules, mesh))
    try:
        yield
    finally:
        _CTX.reset(token)


@contextlib.contextmanager
def disabled():
    """No-op hints (required inside shard_map bodies, where
    with_sharding_constraint on manual axes is disallowed)."""
    token = _CTX.set(None)
    try:
        yield
    finally:
        _CTX.reset(token)


def current_mesh() -> Mesh | None:
    """The mesh of the active activation_sharding context (None outside)."""
    ctx = _CTX.get()
    return ctx[1] if ctx else None


def current_rules() -> dict | None:
    ctx = _CTX.get()
    return ctx[0] if ctx else None


def _mesh_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def hint(x: jax.Array, *logical_axes) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; no-op without context."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = []
    for i, ax in enumerate(logical_axes):
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            spec.append(None)
            continue
        if x.shape[i] % _mesh_size(mesh, mesh_ax) != 0:
            spec.append(None)         # drop instead of padding
            continue
        spec.append(mesh_ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
