"""Explicit expert-parallel MoE dispatch via shard_map (the §Perf
iteration-6 path; `repro/models/moe.py` is the pjit-auto baseline).

Under pjit, the sort-based dispatch lowers to GSPMD-chosen collectives that
measured 336 s of projected wire time on deepseek-moe train_4k (global
argsort + gathers materialized via all-gather). This path pins the
communication pattern to the textbook EP schedule instead:

  1. tokens are sequence-split across the "model" axis (each of the 16
     model ranks routes a disjoint 1/16 of the local tokens);
  2. local top-k routing + capacity into per-expert buffers (E, C_loc, d);
  3. all-to-all over "model": each rank keeps its E/16 experts and
     receives those experts' rows from all 16 peers;
  4. batched expert FFN on (E/16, 16*C_loc, d);
  5. reverse all-to-all + local combine;
  6. all-gather the token slices to restore the replicated activation.

Wire cost = 2 all-to-alls of the dispatched activations + one activation
all-gather — the information-theoretic minimum for EP + the SP boundary.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import moe as moe_lib


def _local_moe(p_local, cfg: ModelConfig, x_loc, n_model: int):
    """Per-device body. x_loc: (n_loc, d) this rank's token slice;
    p_local: router replicated, expert weights sliced (E/n_model, ...)."""
    n_loc, d = x_loc.shape
    e, k = cfg.num_experts, cfg.top_k
    e_loc = e // n_model
    cap = int(math.ceil(n_loc * k * cfg.capacity_factor / e))
    cap = min(max(cap, cfg.min_capacity), n_loc * k)

    gates, idx, balance = moe_lib.route(p_local, cfg, x_loc)
    flat_e = idx.reshape(-1)
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.arange(n_loc * k, dtype=jnp.int32) // k

    sort_idx = jnp.argsort(flat_e, stable=True)          # local sort only
    sorted_e = flat_e[sort_idx]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    rank = jnp.arange(n_loc * k, dtype=jnp.int32) - seg_start[sorted_e]
    kept = rank < cap
    dest_e = jnp.where(kept, sorted_e, e)
    dest_c = jnp.where(kept, rank, 0)

    buf = jnp.zeros((e + 1, cap, d), cfg.compute_dtype)
    buf = buf.at[dest_e, dest_c].set(x_loc[flat_tok[sort_idx]])
    send = buf[:e].reshape(n_model, e_loc, cap, d)

    # dispatch a2a: axis 0 = destination rank -> axis 0 = source rank
    recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                              tiled=True)                # (n_model*e_loc? ...)
    recv = recv.reshape(n_model, e_loc, cap, d).transpose(1, 0, 2, 3)
    expert_in = recv.reshape(e_loc, n_model * cap, d)

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    w_g, w_u, w_d = p_local["w_gate"], p_local["w_up"], p_local["w_down"]
    h_g = act(jnp.einsum("ecd,edf->ecf", expert_in,
                         w_g.astype(cfg.compute_dtype)))
    h_u = jnp.einsum("ecd,edf->ecf", expert_in,
                     w_u.astype(cfg.compute_dtype))
    out = jnp.einsum("ecf,efd->ecd", h_g * h_u,
                     w_d.astype(cfg.compute_dtype))      # (e_loc, n*cap, d)

    # combine a2a (reverse)
    back = out.reshape(e_loc, n_model, cap, d).transpose(1, 0, 2, 3)
    back = back.reshape(n_model * e_loc, cap, d)
    mine = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0,
                              tiled=True)
    mine = mine.reshape(e, cap, d)

    out_pad = jnp.concatenate(
        [mine, jnp.zeros((1, cap, d), mine.dtype)], axis=0)
    gathered = out_pad[dest_e, dest_c]
    weighted = gathered * flat_gate[sort_idx][:, None].astype(gathered.dtype)
    combined = jnp.zeros((n_loc, d), cfg.compute_dtype).at[
        flat_tok[sort_idx]].add(weighted)

    if cfg.num_shared_experts:
        from repro.models import layers
        combined = combined + layers.mlp_block(p_local["shared"], cfg, x_loc)
    return combined, balance


def moe_block_ep(p, cfg: ModelConfig, x, mesh):
    """shard_map expert-parallel MoE. x: (B, T, d), consumed in the
    sequence-parallel layout P("data","model",None) — each device routes
    its own (B/data, T/model) token slice (the SP residual layout the
    scan body already maintains, so entering EP costs no extra reshard).
    Returns (out, balance)."""
    from jax.sharding import PartitionSpec as P

    n_model = mesh.shape["model"]

    def body(p_local, x_blk):
        bb, t_loc, d = x_blk.shape              # local (B/data, T/model, d)
        x_loc = x_blk.reshape(bb * t_loc, d)
        out_loc, balance = _local_moe(p_local, cfg, x_loc, n_model)
        balance = jax.lax.pmean(jax.lax.pmean(balance, "model"), "data")
        return out_loc.reshape(bb, t_loc, d), balance

    param_specs = {
        "router": P(),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if cfg.num_shared_experts:
        param_specs["shared"] = jax.tree.map(lambda _: P(), p["shared"])

    from repro.parallel import hints
    from repro.utils.compat import shard_map
    with hints.disabled():   # no sharding constraints inside manual bodies
        out, balance = shard_map(
            body, mesh=mesh,
            in_specs=(param_specs, P("data", "model", None)),
            out_specs=(P("data", "model", None), P()),
            check_vma=False,
        )(p, x)
    return out, balance
