from repro.parallel.sharding import (
    RULES_SINGLE_POD,
    RULES_MULTI_POD,
    partition_spec,
    params_pspecs,
    batch_pspec,
)

__all__ = ["RULES_SINGLE_POD", "RULES_MULTI_POD", "partition_spec",
           "params_pspecs", "batch_pspec"]
