"""Shared in-kernel top-L merge: bitonic lexicographic sort networks.

The three streaming kernels (``topl_scan`` / ``gather_topl`` /
``dispatch_topl``) each carry a VMEM-resident (rows, L) heap of
(score, gid) pairs ordered by (score asc, gid asc) and must fold every
streamed candidate block into it. The original merge was an iterative
lexicographic min-select — L passes over the (rows, L + block) candidate
array, O(L * block) compare work per grid step, which dominates at
L = 500+. This module replaces it with a per-block pre-top-L:

  1. ``bitonic_sort_pairs`` — a block-local bitonic sorting network over
     the candidate block (O(block * log^2 block) compare-exchanges built
     ONLY from where/compare ops, so it maps onto the VPU with no
     gathers, no ``lax.sort``, no ``lax.top_k`` — all of which Mosaic
     may reject inside a kernel body);
  2. keep the block's first L columns (its exact top-L);
  3. ``merge_sorted_pairs`` — a single bitonic MERGE (O(L log L)) of the
     sorted heap with the sorted block prefix.

Exactness: the dual-key compare ``(s1, g1) <= (s2, g2)`` is a total
order over all real candidates (gids are distinct within a block and
against the heap), and pad entries are the identical-bit canonical pair
(+inf, INT32_MAX), so sorting-network output is unique — bit-identical
to the iterative select and therefore to ``lax.top_k`` over the full
score matrix (whose positional tie-break is the ascending-gid
tie-break). The heap stays sorted ascending across grid steps: it
initializes to all-pads (trivially sorted) and every merge emits a
sorted prefix.

These helpers are plain jnp over the LAST axis with any leading batch
dims, so they run identically inside Pallas kernel bodies (interpret or
compiled) and in host-level tests (``tests/test_merge.py`` proves them
against a lexsort oracle).
"""
from __future__ import annotations

import jax.numpy as jnp

_IMAX = jnp.iinfo(jnp.int32).max


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def _lex_le(s1, g1, s2, g2):
    """(s1, g1) <= (s2, g2) under (score asc, gid asc) — the tie order of
    ``lax.top_k`` over ascending global ids."""
    return (s1 < s2) | ((s1 == s2) & (g1 <= g2))


def _pad_pairs(s, g, width: int):
    """Right-pad the last axis to ``width`` with the canonical pad pair."""
    extra = width - s.shape[-1]
    if extra <= 0:
        return s, g
    pad = [(0, 0)] * (s.ndim - 1) + [(0, extra)]
    return (jnp.pad(s, pad, constant_values=jnp.inf),
            jnp.pad(g, pad, constant_values=_IMAX))


def _stage(s, g, j: int, k: int):
    """One compare-exchange stage of the bitonic network: element i pairs
    with i ^ j; the pair sorts ascending iff (i & k) == 0. Realized as a
    reshape of the last axis into (pairs, 2, j) — element i = b*2j + h*j
    + t pairs across h — plus a per-pair-group direction mask."""
    lead, w = s.shape[:-1], s.shape[-1]
    s2 = s.reshape(lead + (w // (2 * j), 2, j))
    g2 = g.reshape(lead + (w // (2 * j), 2, j))
    a_s, b_s = s2[..., 0, :], s2[..., 1, :]
    a_g, b_g = g2[..., 0, :], g2[..., 1, :]
    # ascending iff the group's base index has bit k clear
    asc = ((jnp.arange(w // (2 * j)) * 2 * j) & k) == 0
    keep = jnp.where(asc[:, None], _lex_le(a_s, a_g, b_s, b_g),
                     _lex_le(b_s, b_g, a_s, a_g))
    lo_s = jnp.where(keep, a_s, b_s)
    hi_s = jnp.where(keep, b_s, a_s)
    lo_g = jnp.where(keep, a_g, b_g)
    hi_g = jnp.where(keep, b_g, a_g)
    s_out = jnp.stack([lo_s, hi_s], axis=-2).reshape(lead + (w,))
    g_out = jnp.stack([lo_g, hi_g], axis=-2).reshape(lead + (w,))
    return s_out, g_out


def bitonic_sort_pairs(s, g):
    """Sort (score, gid) pairs ascending by (score, gid) along the last
    axis. Any width (padded internally to a power of two); any leading
    batch dims. Returns arrays of the input width."""
    w = s.shape[-1]
    if w <= 1:
        return s, g
    wp = _next_pow2(w)
    s, g = _pad_pairs(s, g, wp)
    k = 2
    while k <= wp:
        j = k // 2
        while j >= 1:
            s, g = _stage(s, g, j, k)
            j //= 2
        k *= 2
    return s[..., :w], g[..., :w]


def merge_sorted_pairs(heap_s, heap_g, sorted_s, sorted_g, topl: int):
    """Merge two ascending-sorted (score, gid) runs into the exact sorted
    top-``topl``. Both runs are padded to a common power-of-two width P,
    the second is reversed (descending), and the concatenation — a
    bitonic sequence of length 2P — is collapsed with the log2(2P)
    merge stages of the bitonic network."""
    p = _next_pow2(max(heap_s.shape[-1], sorted_s.shape[-1]))
    heap_s, heap_g = _pad_pairs(heap_s, heap_g, p)
    sorted_s, sorted_g = _pad_pairs(sorted_s, sorted_g, p)
    s = jnp.concatenate([heap_s, sorted_s[..., ::-1]], axis=-1)
    g = jnp.concatenate([heap_g, sorted_g[..., ::-1]], axis=-1)
    j = p
    while j >= 1:
        s, g = _stage(s, g, j, 2 * p)   # k > width: every group ascending
        j //= 2
    return s[..., :topl], g[..., :topl]


def merge_block_topl(heap_s, heap_g, cand_s, cand_g, topl: int):
    """Fold an UNSORTED candidate block into the sorted (rows, topl) heap:
    block-local bitonic sort, keep the block's top-``topl`` prefix, one
    bitonic merge with the heap. Returns the new sorted heap — the
    drop-in replacement for the iterative lexicographic select in the
    three streaming kernels, bit-identical by the total-order argument in
    the module docstring."""
    cand_s, cand_g = bitonic_sort_pairs(cand_s, cand_g)
    keep = min(topl, cand_s.shape[-1])
    return merge_sorted_pairs(heap_s, heap_g, cand_s[..., :keep],
                              cand_g[..., :keep], topl)
