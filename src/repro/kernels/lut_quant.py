"""Reduced-precision stage-1 LUT quantization (the opt-in fast path).

Stage 1's inner loop is LUT traffic: every scored point gathers M table
entries. Halving (fp16) or quartering (int8) the bytes behind that gather
buys bandwidth at the cost of rounded scores — so the engine treats the
quantized scan as a POOL SELECTOR only: it over-fetches ``L' =
overfetch * L`` candidates under the quantized order, then re-scores the
surviving pool with the exact f32 chain and takes the exact
lexicographic top-L. With ``overfetch = 1`` and ``lut_dtype='float32'``
the quantized machinery is bypassed entirely (bit-identical to the
default path); quantized modes trade a bounded recall loss (measured
>= 0.999 at overfetch 2 in ``tests/test_quantized.py``) for scan speed.

Quantization schemes (per query q, book m — one (scale, zero-point) pair
per (q, m) row of the (Q, M, K) table):

  float16  the table is cast to f16; kernels gather f16 and accumulate
           in f32, so the quantized score is ``sum_m f32(f16(lut))``.
  int8     affine: ``zp = (max + min) / 2``, ``scale`` the smallest POWER
           OF TWO >= ``(max - min) / 254``,
           ``q8 = clip(round((lut - zp) / scale), -127, 127)``; kernels
           gather i8 and accumulate ``sum_m f32(q8) * scale[q, m]`` in
           f32. The per-query offset ``sum_m zp[q, m]`` is deliberately
           DROPPED: it is constant across all candidates of a query, so
           the selected pool is invariant to it, and pool survivors are
           re-scored exactly anyway — dropping it keeps the kernels
           scale-only.

           The power-of-two scale costs at most one quantization bit
           (>= 7 effective bits) and buys bit-exactness across compilers:
           ``f32(q8) * scale`` is then EXACT (no rounding), so XLA's
           mul+add -> FMA contraction — which it applies or skips
           depending on fusion context — cannot change a single bit of
           the accumulation chain (an FMA over an exact product rounds
           in exactly the same place as the separate add). With a
           free-form scale the same chain differs by 1 ulp between the
           eager oracle and the jitted scan.

The quantized ranking semantics are pinned by the ``*_q_ref`` oracles in
``ref.py``; every impl (pallas, xla) must match them bit-for-bit so the
selected pools — and therefore the final exact results — are
implementation-independent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_IMAX = jnp.iinfo(jnp.int32).max

#: lut_dtype values accepted by the search/ops APIs
LUT_DTYPES = ("float32", "float16", "int8")


def check_lut_dtype(lut_dtype: str) -> str:
    if lut_dtype not in LUT_DTYPES:
        raise ValueError(f"unknown lut_dtype {lut_dtype!r} "
                         f"(choose from {LUT_DTYPES})")
    return lut_dtype


def quantize_luts(luts: jax.Array, lut_dtype: str):
    """Quantize f32 (Q, M, K) score tables for the reduced-precision scan.

    Returns ``(qluts, scale)``: for 'float16' ``(f16 tables, None)``; for
    'int8' ``(i8 tables, (Q, M) f32 per-(query, book) scales)`` — the
    affine zero-point is folded away (see module doc). The 'float32'
    passthrough stays eager (no copy); the quantizing branches are
    jitted (tables are small; per-op eager dispatch would dominate).
    """
    check_lut_dtype(lut_dtype)
    if lut_dtype == "float32":
        return luts.astype(jnp.float32), None
    return _quantize_luts_jit(luts, lut_dtype)


@functools.partial(jax.jit, static_argnames=("lut_dtype",))
def _quantize_luts_jit(luts: jax.Array, lut_dtype: str):
    if lut_dtype == "float16":
        return luts.astype(jnp.float16), None
    hi = jnp.max(luts, axis=2)                              # (Q, M)
    lo = jnp.min(luts, axis=2)
    zp = (hi + lo) * 0.5
    raw = jnp.maximum(hi - lo, jnp.float32(1e-30)) / 254.0
    # smallest power of two >= raw: keeps f32(q8) * scale exact (module doc)
    scale = jnp.exp2(jnp.ceil(jnp.log2(raw)))
    q8 = jnp.clip(jnp.round((luts - zp[..., None]) / scale[..., None]),
                  -127, 127).astype(jnp.int8)
    return q8, scale.astype(jnp.float32)


def pool_width(topl: int, overfetch: int, limit: int) -> int:
    """The over-fetched pool width L' = overfetch * L, clamped to the
    scannable population."""
    if overfetch < 1:
        raise ValueError(f"overfetch must be >= 1, got {overfetch}")
    return min(limit, max(topl, int(overfetch) * topl))


def exact_topl(scores: jax.Array, gids: jax.Array, topl: int):
    """Exact lexicographic (score asc, gid asc) top-``topl`` over an
    UNORDERED candidate pool (…, P) — the final selection after the exact
    f32 re-score, tie contract identical to every exact kernel path.

    ``lexsort``'s last key is primary, so sorting by (gid, score) ranks
    equal scores by ascending gid: the tie contract of every exact
    kernel path.

    Perf note (CPU XLA, measured at the (32, 200) pool shape): this
    two-key lexsort costs ~1.2ms/call, which dominates the re-score
    stage — but every exact alternative lands in the same band, because
    the selection PRIMITIVES are the floor, not the algorithm:
    ``lax.top_k`` alone is ~350us at k=L and k-linear (k=P' costs
    ~950us), an O(P^2) vectorized rank-select is ~1.3-1.9ms, and a
    bitcast-keyed gid-presort + positional top_k is ~1.3ms. Keep the
    lexsort: it is the simplest exact formulation and within noise of
    the fastest measured variant."""
    order = jnp.lexsort((gids, scores), axis=-1)[..., :topl]
    return (jnp.take_along_axis(scores, order, axis=-1),
            jnp.take_along_axis(gids, order, axis=-1))
