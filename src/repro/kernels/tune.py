"""Block-config autotuner registry + persistent winner cache.

Every block/chunk parameter a kernel wrapper in ``ops.py`` accepts was
historically a hand-pinned constant, tuned once on one CPU and wrong
everywhere else. This module makes that tuning durable:

  * ``KERNELS`` — the registry of tunable kernels: per (kernel, impl)
    the block parameters, their hand-pinned defaults (the zero-cache
    fallback), the shape dimensions that key a tuning bucket, and the
    candidate ladders the sweep driver (``repro.tune``) explores;
  * ``best_config(kernel, impl, **dims)`` — the lookup ``ops.py``
    resolves EVERY block parameter through: pow2-bucket the shape dims,
    consult the versioned JSON cache for this device kind, fall back to
    the registered defaults when no winner is cached (or tuning is
    disabled via ``REPRO_TUNE_DISABLE=1``);
  * ``align`` / ``clamp_chunk`` — the ONE home of the block-rounding
    heuristics that used to be copy-pasted ad hoc across ``ops.py``;
  * cache I/O with schema validation: ``load_cache`` raises
    ``TuneCacheError`` on any drift (wrong version, unknown kernel,
    unknown parameter, non-integer config), so a stale cache fails
    loudly instead of silently mis-tuning.

Cache document shape (``TUNE_CACHE.json`` at the repo root, or the path
in ``REPRO_TUNE_CACHE``)::

    {"schema_version": 1,
     "entries": {"<device kind>": {"<kernel>.<impl>": {
         "n=65536,q=32,topl=128": {
             "config": {"chunk_n": 8192},
             "us": 101.2, "default_us": 130.4}}}}}

Winners are keyed by (device kind, kernel.impl, shape bucket); a bucket
key is the pow2 ceiling of each registered dim, so any runtime shape
resolves to the bucket the sweep actually timed. The sweep driver only
ever REPLACES the default when a candidate beats the incumbent by a
hysteresis margin, so tuner-resolved configs are never slower than the
hand-pinned defaults (up to timing noise below the margin).

This module is import-light on purpose (no ``ops`` import): ``ops.py``
imports it, the sweep driver imports both.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
from typing import NamedTuple

from repro.kernels.adc_scan import DEFAULT_BLOCK_N, DEFAULT_BLOCK_Q
from repro.kernels.dispatch_topl import DEFAULT_DISPATCH_CHUNK
from repro.kernels.gather_topl import (DEFAULT_CHUNK_W,
                                       DEFAULT_GATHER_BLOCK_Q,
                                       DEFAULT_GATHER_BLOCK_W)
from repro.kernels.rerank_dist import (DEFAULT_RERANK_BLOCK_L,
                                       DEFAULT_RERANK_BLOCK_Q,
                                       DEFAULT_RERANK_CHUNK_L)
from repro.kernels.topl_scan import (DEFAULT_CHUNK_N, DEFAULT_TOPL_BLOCK_N,
                                     DEFAULT_TOPL_BLOCK_Q)
from repro.kernels.unq_encode import DEFAULT_BLOCK_B

SCHEMA_VERSION = 1
CACHE_ENV = "REPRO_TUNE_CACHE"
DISABLE_ENV = "REPRO_TUNE_DISABLE"


class TuneCacheError(ValueError):
    """The tune cache on disk does not match this build's schema."""


class KernelSpec(NamedTuple):
    """One tunable (kernel, impl) entry: parameter defaults (the
    zero-cache fallback), the shape dims that key a bucket, and the
    candidate ladder per parameter (empty = registered for resolution
    but not swept)."""
    params: dict
    dims: tuple
    candidates: dict


#: every (kernel, impl) whose block parameters ``ops.py`` resolves.
#: The four engine kernels carry sweep ladders; the auxiliary kernels
#: are registered defaults-only so EVERY block parameter still resolves
#: through ``best_config`` (and picks up cached winners if a future
#: sweep adds ladders).
KERNELS = {
    "adc_scan_topl.pallas": KernelSpec(
        {"block_n": DEFAULT_TOPL_BLOCK_N, "block_q": DEFAULT_TOPL_BLOCK_Q},
        ("n", "q", "topl"),
        {"block_n": (256, 512, 1024, 2048, 4096), "block_q": (8, 16)}),
    "adc_scan_topl.xla": KernelSpec(
        {"chunk_n": DEFAULT_CHUNK_N},
        ("n", "q", "topl"),
        {"chunk_n": (1024, 2048, 4096, 8192, 16384)}),
    "adc_gather_topl.pallas": KernelSpec(
        {"block_w": DEFAULT_GATHER_BLOCK_W,
         "block_q": DEFAULT_GATHER_BLOCK_Q},
        ("w", "q", "topl"),
        {"block_w": (128, 256, 512, 1024, 2048), "block_q": (8, 16)}),
    "adc_gather_topl.xla": KernelSpec(
        {"chunk_w": DEFAULT_CHUNK_W},
        ("w", "q", "topl"),
        {"chunk_w": (512, 1024, 2048, 4096, 8192)}),
    # one shared entry for both impls: the chunk is baked into the tile
    # plan by the router (index/dispatch.build_dispatch), so the router
    # and the kernel MUST resolve the same value — a single registry key
    # guarantees it
    "adc_dispatch_topl": KernelSpec(
        {"chunk": DEFAULT_DISPATCH_CHUNK},
        ("n", "q"),
        {"chunk": (64, 128, 256, 512)}),
    "rerank_gather_dist.pallas": KernelSpec(
        {"block_l": DEFAULT_RERANK_BLOCK_L,
         "block_q": DEFAULT_RERANK_BLOCK_Q},
        ("l", "q", "d"),
        {"block_l": (64, 128, 256, 512), "block_q": (8, 16)}),
    "rerank_gather_dist.xla": KernelSpec(
        {"chunk_l": DEFAULT_RERANK_CHUNK_L},
        ("l", "q", "d"),
        {"chunk_l": (32, 64, 128, 256, 512)}),
    # auxiliary kernels: defaults-only registration (no sweep ladder yet)
    "adc_scan.pallas": KernelSpec(
        {"block_n": DEFAULT_BLOCK_N}, ("n",), {}),
    "adc_scan_batch.pallas": KernelSpec(
        {"block_n": DEFAULT_BLOCK_N, "block_q": DEFAULT_BLOCK_Q},
        ("n", "q"), {}),
    "unq_encode.pallas": KernelSpec(
        {"block_b": DEFAULT_BLOCK_B}, ("b",), {}),
}

#: the hysteresis margin the sweep applies: a challenger must beat the
#: running best by this factor to replace it — keeps winners stable
#: against timing noise (same machine -> same winners) and guarantees a
#: cached winner is never slower than the default beyond noise. 0.8 is
#: deliberately wide: within-pass interleaved timing noise is a few
#: percent, but candidates hovering a few percent past a narrow bar
#: flip-flop between sweeps, and a durable cache values reproducible
#: winners over the last ~10% of a marginal one.
HYSTERESIS = 0.8


# ---------------------------------------------------------------------------
# shape buckets + the shared rounding helpers (satellite: ONE home for
# the ad-hoc ``min(block, max(8, ceil...))`` heuristics ops.py carried)
# ---------------------------------------------------------------------------

def shape_bucket(value: int, floor: int = 8) -> int:
    """Pow2 ceiling of a shape dim (ENCODE_BUCKETS-style ladder)."""
    b = floor
    while b < value:
        b *= 2
    return b


def bucket_key(spec: KernelSpec, dims: dict) -> str:
    """Canonical cache key for a shape: ``"n=65536,q=32,topl=128"``."""
    missing = [d for d in spec.dims if d not in dims]
    if missing:
        raise KeyError(f"missing bucket dims {missing} (have {list(dims)})")
    return ",".join(f"{d}={shape_bucket(int(dims[d]))}" for d in spec.dims)


def align(dim: int, *, cap: int, multiple: int = 8) -> int:
    """Shrink a block request to a small dim: ``dim`` rounded up to the
    tile ``multiple`` (floor ``multiple``), capped by the requested
    block. The former ``min(block, max(8, -(-d // 8) * 8))`` pattern."""
    return min(cap, max(multiple, -(-dim // multiple) * multiple))


def clamp_chunk(dim: int, *, cap: int, floor: int) -> int:
    """Shrink a streaming chunk request for a small dim: at most the
    request, at least ``floor`` (the heap width), and no wider than
    ~dim/8 so short scans keep a few steps instead of one padded chunk.
    The former ``min(chunk, max(topl, -(-d // 8)))`` pattern."""
    return min(cap, max(floor, -(-dim // 8)))


# ---------------------------------------------------------------------------
# cache I/O + validation
# ---------------------------------------------------------------------------

_default_cache_path: pathlib.Path | None = None


def cache_path() -> pathlib.Path:
    global _default_cache_path
    env = os.environ.get(CACHE_ENV, "")
    if env:
        return pathlib.Path(env)
    if _default_cache_path is None:      # resolve() syscalls once, not
        _default_cache_path = pathlib.Path(            # per dispatch
            __file__).resolve().parents[3] / "TUNE_CACHE.json"
    return _default_cache_path


def validate(doc) -> dict:
    """Check a cache document against this build's schema; returns the
    document. Raises ``TuneCacheError`` on ANY drift."""
    if not isinstance(doc, dict):
        raise TuneCacheError(f"cache root must be an object, got "
                             f"{type(doc).__name__}")
    if doc.get("schema_version") != SCHEMA_VERSION:
        raise TuneCacheError(
            f"schema_version {doc.get('schema_version')!r} != "
            f"{SCHEMA_VERSION} — regenerate with `python -m repro.tune`")
    entries = doc.get("entries")
    if not isinstance(entries, dict):
        raise TuneCacheError("missing/invalid 'entries' object")
    for device, kernels in entries.items():
        if not isinstance(kernels, dict):
            raise TuneCacheError(f"entries[{device!r}] must be an object")
        for key, buckets in kernels.items():
            spec = KERNELS.get(key)
            if spec is None:
                raise TuneCacheError(f"unknown kernel {key!r} in cache")
            if not isinstance(buckets, dict):
                raise TuneCacheError(f"{key!r} buckets must be an object")
            for bkey, entry in buckets.items():
                cfg = entry.get("config") if isinstance(entry, dict) else None
                if not isinstance(cfg, dict):
                    raise TuneCacheError(
                        f"{key!r}[{bkey!r}] missing 'config' object")
                for p, v in cfg.items():
                    if p not in spec.params:
                        raise TuneCacheError(
                            f"{key!r}[{bkey!r}]: unknown param {p!r}")
                    if not isinstance(v, int) or isinstance(v, bool):
                        raise TuneCacheError(
                            f"{key!r}[{bkey!r}].{p}: non-integer {v!r}")
    return doc


_cache_memo: tuple | None = None        # (path, mtime_ns, doc)


def load_cache(path: pathlib.Path | None = None, *,
               refresh: bool = False) -> dict:
    """Load + validate the winner cache (memoized on (path, mtime); a
    missing file is an empty cache, a malformed one raises
    ``TuneCacheError``)."""
    global _cache_memo
    p = pathlib.Path(path) if path is not None else cache_path()
    try:
        mtime = p.stat().st_mtime_ns
    except OSError:
        return {"schema_version": SCHEMA_VERSION, "entries": {}}
    if (not refresh and _cache_memo is not None
            and _cache_memo[0] == p and _cache_memo[1] == mtime):
        return _cache_memo[2]
    try:
        doc = json.loads(p.read_text())
    except ValueError as e:
        raise TuneCacheError(f"unparseable tune cache {p}: {e}") from e
    doc = validate(doc)
    _cache_memo = (p, mtime, doc)
    return doc


def save_cache(doc: dict, path: pathlib.Path | None = None) -> pathlib.Path:
    """Validate + atomically write the cache document."""
    global _cache_memo
    validate(doc)
    p = pathlib.Path(path) if path is not None else cache_path()
    tmp = p.with_suffix(".tmp")
    tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    tmp.replace(p)
    _cache_memo = None
    return p


_device_kind_memo: str | None = None


def device_kind() -> str:
    """Cache key for the current accelerator (e.g. 'cpu',
    'TPU v4' -> 'tpu_v4'). Memoized — the device set is fixed for the
    life of the process, and this sits on the per-call resolve path."""
    global _device_kind_memo
    if _device_kind_memo is None:
        import jax
        _device_kind_memo = \
            jax.devices()[0].device_kind.lower().replace(" ", "_")
    return _device_kind_memo


# ---------------------------------------------------------------------------
# the lookup ops.py resolves every block parameter through
# ---------------------------------------------------------------------------

def registry_key(kernel: str, impl: str | None = None) -> str:
    key = kernel if impl is None else f"{kernel}.{impl}"
    if key not in KERNELS and kernel in KERNELS:
        key = kernel                    # impl-agnostic entry (dispatch)
    if key not in KERNELS:
        raise KeyError(f"unknown tunable kernel {key!r} "
                       f"(registered: {sorted(KERNELS)})")
    return key


_resolve_memo: dict = {}
#: resolution-memo capacity. Eviction is LRU one-at-a-time (dicts are
#: insertion-ordered; a hit reinserts its key at the back), so a serving
#: loop's hot buckets stay resident no matter how much one-off shape
#: churn flows past — a wholesale clear here made steady-state serving
#: repay every resolution after each overflow.
_MEMO_CAP = 4096
#: guards every _resolve_memo access: the serving worker thread and
#: direct index.search callers resolve concurrently, and the unguarded
#: pop-reinsert/evict dance could KeyError mid-eviction (iter one
#: thread, pop another). Held only for dict probes — never across the
#: cache load.
_memo_lock = threading.Lock()


def best_config(kernel: str, impl: str | None = None, **dims) -> dict:
    """Resolve the block parameters for a kernel at a runtime shape:
    the cached winner of this device's (kernel, shape-bucket) sweep, or
    the registered hand-pinned defaults when nothing is cached (or
    ``REPRO_TUNE_DISABLE=1``). Returns ``{param: value}``.

    Resolutions are memoized on (kernel, bucket, cache mtime) in a small
    LRU (capacity ``_MEMO_CAP``), so the steady-state cost is one stat +
    two dict probes — this sits on EVERY kernel dispatch, where a JSON
    reparse per call would cost ~10% of a small rerank call."""
    key = registry_key(kernel, impl)
    spec = KERNELS[key]
    if os.environ.get(DISABLE_ENV, "") not in ("", "0"):
        return dict(spec.params)
    try:
        mtime = cache_path().stat().st_mtime_ns
    except OSError:
        mtime = None
    bkey = bucket_key(spec, dims)
    memo_key = (key, bkey, mtime)
    with _memo_lock:
        hit = _resolve_memo.pop(memo_key, None)
        if hit is not None:
            _resolve_memo[memo_key] = hit   # reinsert: most recently used
    if hit is not None:
        return dict(hit)
    entry = (load_cache().get("entries", {})
             .get(device_kind(), {})
             .get(key, {})
             .get(bkey))
    out = dict(spec.params)
    if entry:
        out.update({p: entry["config"][p]
                    for p in spec.params if p in entry["config"]})
    with _memo_lock:
        while len(_resolve_memo) >= _MEMO_CAP:
            _resolve_memo.pop(next(iter(_resolve_memo)))   # evict oldest
        _resolve_memo[memo_key] = dict(out)
    return out


def cache_fingerprint() -> dict:
    """Small summary for ``Index`` save metadata: where the winners came
    from and how many buckets are tuned for this device."""
    doc = load_cache()
    mine = doc.get("entries", {}).get(device_kind(), {})
    return {"schema_version": doc.get("schema_version", SCHEMA_VERSION),
            "device_kind": device_kind(),
            "tuned_buckets": sum(len(b) for b in mine.values())}
