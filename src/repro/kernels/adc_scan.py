"""Pallas TPU kernel for the compressed-domain ADC scan (paper Eq. 8).

TPU adaptation (see DESIGN.md §3): the CPU algorithm is M scalar table
lookups + adds per database point. Gathers run on the TPU VPU at a fraction
of peak, so the kernel re-expresses the lookup as a one-hot contraction that
runs on the MXU:

    scores_block = sum_m onehot(codes[:, m]) @ lut[m]        # (Bn,K) @ (K,)

The LUT (M*K floats, 16 KB at M=16/K=256) stays resident in VMEM for the
whole scan while uint8 code blocks stream HBM->VMEM; the Pallas grid gives
automatic double-buffering of the code stream, so the scan is purely
HBM-bandwidth-bound — the roofline optimum for this operation (the LUT
gather version is VPU-issue-bound instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 1024
DEFAULT_BLOCK_Q = 128


def _adc_scan_kernel(codes_ref, lut_ref, out_ref, *, block_n: int, num_books: int,
                     book_size: int):
    codes = codes_ref[...].astype(jnp.int32)          # (Bn, M)
    lut = lut_ref[...]                                 # (M, K)
    acc = jnp.zeros((block_n,), jnp.float32)
    # K-dim iota, 2D as required on TPU.
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, book_size), 1)  # (1, K)
    for m in range(num_books):                         # M is static (8 or 16)
        onehot = (codes[:, m:m + 1] == iota_k).astype(jnp.float32)   # (Bn, K)
        # (Bn, K) @ (K,) matvec on the MXU.
        acc = acc + jax.lax.dot_general(
            onehot, lut[m].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def adc_scan_pallas(codes: jax.Array, lut: jax.Array, *,
                    block_n: int = DEFAULT_BLOCK_N,
                    interpret: bool = False) -> jax.Array:
    """scores[n] = sum_m lut[m, codes[n, m]] via a Pallas TPU kernel.

    codes: (N, M) uint8/int32 with N % block_n == 0 (ops.py pads).
    lut:   (M, K) float32.
    Returns (N,) float32.
    """
    n, num_books = codes.shape
    _, book_size = lut.shape
    assert n % block_n == 0, f"N={n} must be padded to a multiple of {block_n}"
    grid = (n // block_n,)
    kernel = functools.partial(
        _adc_scan_kernel, block_n=block_n, num_books=num_books,
        book_size=book_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, num_books), lambda i: (i, 0)),
            pl.BlockSpec((num_books, book_size), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(codes, lut)


def _adc_scan_batch_kernel(codes_ref, luts_ref, out_ref, *, block_n: int,
                           block_q: int, num_books: int, book_size: int):
    codes = codes_ref[...].astype(jnp.int32)          # (Bn, M)
    luts = luts_ref[...]                               # (Bq, M, K)
    acc = jnp.zeros((block_q, block_n), jnp.float32)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, book_size), 1)  # (1, K)
    for m in range(num_books):                         # M is static (8 or 16)
        onehot = (codes[:, m:m + 1] == iota_k).astype(jnp.float32)   # (Bn, K)
        # (Bq, K) x (Bn, K) -> (Bq, Bn) on the MXU: every query's LUT row
        # contracts against the SAME one-hot block, so the uint8 code
        # stream is read from HBM once for all Bq queries.
        acc = acc + jax.lax.dot_general(
            luts[:, m, :].astype(jnp.float32), onehot,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def adc_scan_batch_pallas(codes: jax.Array, luts: jax.Array, *,
                          block_n: int = DEFAULT_BLOCK_N,
                          block_q: int = DEFAULT_BLOCK_Q,
                          interpret: bool = False) -> jax.Array:
    """scores[q, n] = sum_m luts[q, m, codes[n, m]] via one fused TPU kernel.

    The multi-query formulation of the ADC scan: the grid streams each code
    block HBM->VMEM once and contracts it against ALL Q lookup tables
    (grid order is n-outer / q-inner, and the code block index only depends
    on n, so Pallas keeps the block resident across the q sweep). Compared
    with vmapping the single-query kernel this amortizes the HBM code
    stream Q-fold — the scan stays bandwidth-bound at the roofline of ONE
    pass over the compressed database instead of Q passes.

    codes: (N, M) uint8/int32 with N % block_n == 0 (ops.py pads).
    luts:  (Q, M, K) float32 with Q % block_q == 0 (ops.py pads).
    Returns (Q, N) float32.
    """
    n, num_books = codes.shape
    q, _, book_size = luts.shape
    assert n % block_n == 0, f"N={n} must be padded to a multiple of {block_n}"
    assert q % block_q == 0, f"Q={q} must be padded to a multiple of {block_q}"
    grid = (n // block_n, q // block_q)
    kernel = functools.partial(
        _adc_scan_batch_kernel, block_n=block_n, block_q=block_q,
        num_books=num_books, book_size=book_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, num_books), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, num_books, book_size),
                         lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.float32),
        interpret=interpret,
    )(codes, luts)
