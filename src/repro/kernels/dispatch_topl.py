"""Cell-batched dispatch scan + top-L kernels (the MoE-routed IVF stage 1).

The gathered face (``gather_topl.py``) streams a PER-QUERY slot list: every
query re-reads the code rows of every cell it probes, and the padded (Q, W)
plan is built host-side in numpy per batch. Here the roles flip — coarse
cells are the experts, probed queries are the routed tokens
(tensor2tensor-style expert dispatch, cf. ``parallel/ep.py``): the router
(``repro.index.dispatch``) groups the (Q, nprobe) probe matrix BY CELL into
dense per-cell query batches, and each cell's contiguous code range streams
from HBM exactly once for ALL queries probing it.

Work arrives as a static-shape tile plan (``DispatchPlan``): the probed
cells' code ranges are cut into chunk-ALIGNED tiles of the cell-grouped
buffer, so a tile index IS a block index into ``codes`` — the scalar-
prefetched plan arrays drive data-dependent tile DMA without any gather.

Memory model per grid step (grid = (T,), one step per tile, tiles of one
cell consecutive):

  * the (cap, L) score/id heap of the tile's cell lives in the OUTPUT
    blocks, whose index map follows ``tile_e`` — consecutive tiles of one
    cell map to the same block, so the heap stays VMEM-resident across the
    cell's whole code range and is initialized when ``tile_first`` fires;
  * the (chunk, M) uint8 code tile plus its (chunk,) global-id and
    row-bias streams flow HBM->VMEM addressed by ``tile_block`` — the
    codes are read IN PLACE from the cell-grouped buffer (no gathered
    (Q, W, M) batch exists anywhere);
  * the cell's (cap,) query batch gathers its LUT rows in-kernel via an
    exact one-hot matmul (one nonzero per row — a copy, not an
    approximation), so routed LUTs are never duplicated per cell in HBM;
  * scoring reuses the per-m one-hot contraction and the left-to-right m
    accumulation of ``adc_scan_ref``; the bias composition is
    ``chain + (row_bias + cellterm)`` then the (Q, N) keep mask — exactly
    the padded path's ``_plan_rowbias`` order, which is what keeps every
    mixed-stream score bit-identical;
  * rows outside the tile's [lo, hi) validity window, slots with
    ``qidx < 0`` and filtered rows score +inf and are canonicalized to
    gid ``_IMAX`` — identical bits to the gathered kernels' pad handling.

Tie semantics are EXACTLY those of flat search: the in-kernel merge is the
same shared bitonic (score asc, global id asc) pre-top-L merge
(``kernels/merge.py``) as ``gather_topl``, so per-cell partial top-Ls
merged across cells
(``index.dispatch.combine_pools`` -> ``candidates.merge_topl``) reproduce
the padded-plan results bit-for-bit, scores AND ids.

The chunked ``lax.scan`` fallback carries the full (E+1, cap, L) heap and
merges each tile with ``lax.top_k``; exactness relies on the buffer
contract that rows WITHIN a cell are ascending in global id (stable
cell-grouping of add order), so the positional tie-break over
[heap | tile] is the ascending-gid tie-break — the same argument as
``adc_gather_topl_stream_xla``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import merge

DEFAULT_DISPATCH_CHUNK = 128

_IMAX = jnp.iinfo(jnp.int32).max


class DispatchPlan(NamedTuple):
    """The routed work-list the dispatch kernels execute (all int32).

    ``qidx`` (E+1, cap): the query batch of each routed cell (row E is the
    dummy row pad tiles target); -1 marks empty slots. The tile arrays
    (T,) each describe one chunk-aligned tile of the cell-grouped code
    buffer: ``tile_e`` the routed-cell row it scores into (tiles of one
    cell are CONSECUTIVE — the heap-residency contract), ``tile_block``
    its block index (rows [block*chunk, block*chunk + chunk)),
    ``tile_first`` 1 on the first tile of its cell (heap init),
    ``tile_lo``/``tile_hi`` the cell's true row range (rows outside score
    +inf). Pad tiles target the dummy row with lo == hi == 0.
    """
    qidx: jax.Array
    tile_e: jax.Array
    tile_block: jax.Array
    tile_first: jax.Array
    tile_lo: jax.Array
    tile_hi: jax.Array


def _adc_dispatch_topl_kernel(tile_e_ref, tile_block_ref, tile_first_ref,
                              tile_lo_ref, tile_hi_ref, codes_ref, gid_ref,
                              rowb_ref, qidx_ref, cellterm_ref, luts_ref,
                              *rest, topl: int, chunk: int, cap: int,
                              num_q: int, num_books: int, book_size: int,
                              has_qkeep: bool, has_scale: bool):
    rest = list(rest)
    qkeep_ref = rest.pop(0) if has_qkeep else None
    scale_ref = rest.pop(0) if has_scale else None
    scores_ref, idx_ref = rest
    t = pl.program_id(0)

    @pl.when(tile_first_ref[t] == 1)
    def _init():                  # fresh heap at the first tile of each cell
        scores_ref[...] = jnp.full((1, cap, topl), jnp.inf, jnp.float32)
        idx_ref[...] = jnp.full((1, cap, topl), _IMAX, jnp.int32)

    # --- gather the cell's LUT batch: exact one-hot copy (one nonzero per
    # row), so the routed (cap, M, K) tables never materialize in HBM ---
    qidx = qidx_ref[...][0]                                    # (cap,)
    iota_q = jax.lax.broadcasted_iota(jnp.int32, (cap, num_q), 1)
    onehot_q = (qidx[:, None] == iota_q).astype(jnp.float32)   # (cap, Q)
    # quantized tables are f32-cast for the routing dot (an exact copy of
    # the f32-cast entries — one nonzero per row), so scoring below sees
    # exactly f32(qlut); a no-op for the default f32 tables
    luts = luts_ref[...].astype(jnp.float32).reshape(
        num_q, num_books * book_size)
    lut_e = jax.lax.dot(onehot_q, luts,
                        preferred_element_type=jnp.float32)
    lut_e = lut_e.reshape(cap, num_books, book_size)
    scale_e = None
    if has_scale:                      # routed copy of the int8 scales
        scale_e = jax.lax.dot(onehot_q, scale_ref[...],
                              preferred_element_type=jnp.float32)  # (cap, M)

    # --- score the code tile once for the whole query batch: per-m one-hot
    # contraction, left-to-right m accumulation (adc_scan_ref chain); int8
    # scales multiply each per-m part BEFORE the chain (q_ref's order) ---
    codes = codes_ref[...].astype(jnp.int32)                   # (chunk, M)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (book_size, chunk), 0)
    acc = jnp.zeros((cap, chunk), jnp.float32)
    for m in range(num_books):                                 # M is static
        onehot_c = (codes[:, m][None, :] == iota_k).astype(jnp.float32)
        part = jax.lax.dot(lut_e[:, m, :], onehot_c,
                           preferred_element_type=jnp.float32)
        if has_scale:
            part = part * scale_e[:, m][:, None]
        acc = acc + part

    # bias composition order is the padded path's _plan_rowbias order:
    # (row stream + per-(query, cell) term) added as ONE slot value, the
    # (Q, N) keep mask applied after — bit-identical for any stream mix
    rowb = rowb_ref[...][0]                                    # (chunk,)
    cellterm = cellterm_ref[...][0]                            # (cap,)
    acc = acc + (rowb[None, :] + cellterm[:, None])
    if has_qkeep:
        keep = jax.lax.dot(onehot_q, qkeep_ref[...],
                           preferred_element_type=jnp.float32)  # (cap, chunk)
        acc = jnp.where(keep > 0.5, acc, jnp.inf)

    # rows outside the cell's [lo, hi) window and empty batch slots score
    # +inf; +inf entries take the canonical _IMAX gid (identical bits to
    # the gathered kernels' pad handling)
    grow = tile_block_ref[t] * chunk + jax.lax.broadcasted_iota(
        jnp.int32, (1, chunk), 1)
    acc = jnp.where((grow >= tile_lo_ref[t]) & (grow < tile_hi_ref[t]),
                    acc, jnp.inf)
    acc = jnp.where((qidx >= 0)[:, None], acc, jnp.inf)
    gids = jnp.broadcast_to(gid_ref[...][0][None, :], (cap, chunk))
    gids = jnp.where(acc == jnp.inf, _IMAX, gids)

    # --- merge the tile into the cell's running heap: shared bitonic
    # pre-top-L + merge (kernels/merge.py) — same tie semantics as
    # gather_topl, so tie resolution is identical everywhere ---
    out_s, out_g = merge.merge_block_topl(
        scores_ref[...][0], idx_ref[...][0], acc, gids, topl)
    scores_ref[...] = out_s[None]
    idx_ref[...] = out_g[None]


@functools.partial(jax.jit, static_argnames=("topl", "chunk", "interpret"))
def adc_dispatch_topl_pallas(codes: jax.Array, gids_rows: jax.Array,
                             rowbias: jax.Array, luts: jax.Array,
                             cellterm: jax.Array, plan: DispatchPlan,
                             qkeep: jax.Array | None = None,
                             scale: jax.Array | None = None, *, topl: int,
                             chunk: int = DEFAULT_DISPATCH_CHUNK,
                             interpret: bool = False):
    """Fused cell-batched scan+top-L over a routed tile plan.

    codes:     (NP, M) uint8 cell-grouped buffer, NP % chunk == 0
               (ops.py pads; tile blocks index it directly).
    gids_rows: (NP,) int32 buffer row -> global id stream.
    rowbias:   (NP,) float32 per-row additive stream (per-point bias with
               any (N,) filter already folded to +inf).
    luts:      (Q, M, K) float32 per-query tables (whole-array resident).
    cellterm:  (E+1, cap) float32 per-(routed cell, slot) additive term
               (the IVFADC per-(query, cell) residual correction).
    plan:      the DispatchPlan tile work-list (see class doc).
    qkeep:     None | (Q, NP) float32 0/1 keep stream in BUFFER-ROW column
               order (the lowered per-query filter mask).
    scale:     None | (Q, M) float32 int8 affine scales (``luts`` may be
               the float16/int8 quantized tables of ``lut_quant``).

    Returns (scores, ids): ((E+1, cap, topl) f32, (E+1, cap, topl) i32) —
    per-cell partial pools, each slot's top-L sorted by (score asc, global
    id asc). Rows never routed to carry undefined values; ``ops`` masks
    them via the all-invalid ``qidx`` row before anything reads them.
    """
    np_, num_books = codes.shape
    e1, cap = plan.qidx.shape
    num_q, _, book_size = luts.shape
    t_b = plan.tile_e.shape[0]
    assert np_ % chunk == 0, f"N={np_} must be padded to a multiple of {chunk}"
    kernel = functools.partial(
        _adc_dispatch_topl_kernel, topl=topl, chunk=chunk, cap=cap,
        num_q=num_q, num_books=num_books, book_size=book_size,
        has_qkeep=qkeep is not None, has_scale=scale is not None)
    in_specs = [
        pl.BlockSpec((chunk, num_books),
                     lambda t, te, tb, tf, tlo, thi: (tb[t], 0)),
        pl.BlockSpec((1, chunk), lambda t, te, tb, tf, tlo, thi: (0, tb[t])),
        pl.BlockSpec((1, chunk), lambda t, te, tb, tf, tlo, thi: (0, tb[t])),
        pl.BlockSpec((1, cap), lambda t, te, tb, tf, tlo, thi: (te[t], 0)),
        pl.BlockSpec((1, cap), lambda t, te, tb, tf, tlo, thi: (te[t], 0)),
        pl.BlockSpec((num_q, num_books, book_size),
                     lambda t, te, tb, tf, tlo, thi: (0, 0, 0)),
    ]
    args = [codes, gids_rows[None, :], rowbias[None, :], plan.qidx,
            cellterm, luts]
    if qkeep is not None:
        in_specs.append(pl.BlockSpec(
            (num_q, chunk), lambda t, te, tb, tf, tlo, thi: (0, tb[t])))
        args.append(qkeep)
    if scale is not None:
        in_specs.append(pl.BlockSpec(
            (num_q, num_books), lambda t, te, tb, tf, tlo, thi: (0, 0)))
        args.append(scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(t_b,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, cap, topl),
                         lambda t, te, tb, tf, tlo, thi: (te[t], 0, 0)),
            pl.BlockSpec((1, cap, topl),
                         lambda t, te, tb, tf, tlo, thi: (te[t], 0, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((e1, cap, topl), jnp.float32),
            jax.ShapeDtypeStruct((e1, cap, topl), jnp.int32),
        ],
        interpret=interpret,
    )(plan.tile_e, plan.tile_block, plan.tile_first, plan.tile_lo,
      plan.tile_hi, *args)


@functools.partial(jax.jit, static_argnames=("topl", "chunk"))
def adc_dispatch_topl_stream_xla(codes: jax.Array, gids_rows: jax.Array,
                                 rowbias: jax.Array, luts: jax.Array,
                                 cellterm: jax.Array, plan: DispatchPlan,
                                 qkeep: jax.Array | None = None,
                                 scale: jax.Array | None = None, *,
                                 topl: int,
                                 chunk: int = DEFAULT_DISPATCH_CHUNK):
    """XLA fallback with the same streaming semantics: a ``lax.scan`` over
    the tile work-list carrying the full (E+1, cap, L) heap. Each step
    slices one chunk-aligned code tile in place (no gathered batch),
    scores it against the tile's cell batch, and merges that cell's heap
    slice with ``lax.top_k`` — exact because buffer rows within a cell
    ascend in global id (see module doc). Peak working set is
    O(cap * chunk) scores per step plus the output-sized heap carry.
    """
    num_books = codes.shape[1]
    e1, cap = plan.qidx.shape
    num_q = luts.shape[0]
    if luts.dtype != jnp.float32:      # dequantize ONCE, outside the scan
        # bitwise-identical and faster than the narrow gather+convert —
        # same argument as topl_scan.adc_scan_topl_stream_xla
        luts = luts.astype(jnp.float32)
        if scale is not None:
            luts = luts * scale[:, :, None]

    def step(carry, inp):
        hs, hg = carry                                     # (E+1, cap, L)
        te, tb, tlo, thi = inp
        r0 = tb * chunk
        codes_t = jax.lax.dynamic_slice(
            codes, (r0, 0), (chunk, num_books)).astype(jnp.int32)
        gid_t = jax.lax.dynamic_slice(gids_rows, (r0,), (chunk,))
        rowb_t = jax.lax.dynamic_slice(rowbias, (r0,), (chunk,))
        qe = jax.lax.dynamic_slice(plan.qidx, (te, 0), (1, cap))[0]
        ct = jax.lax.dynamic_slice(cellterm, (te, 0), (1, cap))[0]
        safe_q = jnp.clip(qe, 0, num_q - 1)
        lut_e = jnp.take(luts, safe_q, axis=0)             # (cap, M, K)
        picked = jnp.take_along_axis(
            lut_e[:, None, :, :],
            codes_t[None, :, :, None], axis=3)[..., 0]     # (cap, chunk, M)
        s = picked[:, :, 0]
        for m in range(1, num_books):                      # adc_scan_ref chain
            s = s + picked[:, :, m]
        s = s + (rowb_t[None, :] + ct[:, None])
        if qkeep is not None:
            qk = jax.lax.dynamic_slice(qkeep, (0, r0), (num_q, chunk))
            keep = jnp.take(qk, safe_q, axis=0)            # (cap, chunk)
            s = jnp.where(keep > 0.5, s, jnp.inf)
        grow = r0 + jnp.arange(chunk, dtype=jnp.int32)
        s = jnp.where((grow >= tlo) & (grow < thi), s, jnp.inf)
        s = jnp.where((qe >= 0)[:, None], s, jnp.inf)
        g = jnp.where(jnp.isposinf(s), _IMAX,
                      jnp.broadcast_to(gid_t[None, :], (cap, chunk)))
        he_s = jax.lax.dynamic_slice(hs, (te, 0, 0), (1, cap, topl))[0]
        he_g = jax.lax.dynamic_slice(hg, (te, 0, 0), (1, cap, topl))[0]
        neg, pos = jax.lax.top_k(-jnp.concatenate([he_s, s], axis=1), topl)
        ng = jnp.take_along_axis(
            jnp.concatenate([he_g, g], axis=1), pos, axis=1)
        hs = jax.lax.dynamic_update_slice(hs, (-neg)[None], (te, 0, 0))
        hg = jax.lax.dynamic_update_slice(hg, ng[None], (te, 0, 0))
        return (hs, hg), None

    init = (jnp.full((e1, cap, topl), jnp.inf, jnp.float32),
            jnp.full((e1, cap, topl), _IMAX, jnp.int32))
    (hs, hg), _ = jax.lax.scan(
        step, init, (plan.tile_e, plan.tile_block, plan.tile_lo,
                     plan.tile_hi))
    return hs, hg
