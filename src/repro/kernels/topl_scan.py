"""Fused streaming scan + top-L Pallas TPU kernel (the stage-1 engine).

The classic stage 1 materializes the full (Q, N) score matrix and runs
``jax.lax.top_k`` over it. At the billion-vector scale the paper targets
that matrix must never exist: this kernel keeps a running (block_q, L)
top-L heap resident in VMEM while uint8 code blocks stream HBM->VMEM, so
peak memory for stage 1 drops from O(Q*N) to O(Q*L).

Memory model per grid step (grid = (Q/block_q, N/block_n), n innermost):

  * the (block_q, L) score/index heap lives in the OUTPUT blocks, whose
    index map ignores the n axis — Pallas keeps them in VMEM across the
    whole n sweep and writes them back to HBM once per query block;
  * the (block_n, M) uint8 code block and (block_n,) bias block stream in
    (double-buffered by the grid), are scored with the same one-hot MXU
    contraction as ``adc_scan_batch``, and are merged into the heap;
  * rows past ``n_valid`` (the pad the wrapper added to reach a block_n
    multiple) are masked to +inf score so they can never surface.

Tie semantics are EXACTLY those of ``lax.top_k`` over the full matrix:
candidates are ordered by (score asc, global index asc). The merge is the
shared bitonic pre-top-L of ``kernels/merge.py`` — block-local sort under
the total lexicographic order, then one bitonic merge with the sorted
heap — so the streaming result is bit-identical to the materialized oracle
(``ref.adc_scan_topl_ref``), not merely set-equal. The same argument makes
the chunked ``lax.scan`` fallback below exact: within the concatenated
[heap | chunk] array, positions are always in ascending-global-index order
among equal scores, and ``lax.top_k`` breaks ties by position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import merge, ref

DEFAULT_TOPL_BLOCK_N = 1024
DEFAULT_TOPL_BLOCK_Q = 8
DEFAULT_CHUNK_N = 4096

_IMAX = jnp.iinfo(jnp.int32).max


def _adc_scan_topl_kernel(codes_ref, luts_ref, bias_ref, *refs,
                          topl: int, block_n: int, block_q: int,
                          num_books: int, book_size: int, n_valid: int,
                          has_qbias: bool, has_scale: bool):
    refs = list(refs)
    qbias_ref = refs.pop(0) if has_qbias else None
    scale_ref = refs.pop(0) if has_scale else None
    scores_ref, idx_ref = refs
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():                      # fresh heap at the start of each n sweep
        scores_ref[...] = jnp.full((block_q, topl), jnp.inf, jnp.float32)
        idx_ref[...] = jnp.full((block_q, topl), _IMAX, jnp.int32)

    # --- score the streamed block: same one-hot MXU contraction as
    # adc_scan_batch (bit-identical scores, so ties resolve identically).
    # Quantized tables ride the same contraction: the one-hot dot copies
    # the f32-cast entry exactly (one nonzero per column), and the int8
    # per-(query, book) scale multiplies each per-m part BEFORE the
    # chain — the op order of ``ref.adc_scan_batch_q_ref`` ---
    codes = codes_ref[...].astype(jnp.int32)           # (Bn, M)
    luts = luts_ref[...]                               # (Bq, M, K)
    scale = scale_ref[...] if has_scale else None      # (Bq, M)
    acc = jnp.zeros((block_q, block_n), jnp.float32)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, book_size), 1)
    for m in range(num_books):                         # M is static (8 or 16)
        onehot = (codes[:, m:m + 1] == iota_k).astype(jnp.float32)
        part = jax.lax.dot_general(
            luts[:, m, :].astype(jnp.float32), onehot,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        if has_scale:
            part = part * scale[:, m][:, None]
        acc = acc + part
    acc = acc + bias_ref[...][None, :]
    if has_qbias:
        # the per-query bias stream: lowered filter masks (0 = keep,
        # +inf = drop) and any other per-(query, point) additive term
        acc = acc + qbias_ref[...]

    # global ids of this block; pad rows (>= n_valid) masked to +inf score
    gids = ni * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_n), 1)                    # (1, Bn)
    acc = jnp.where(gids < n_valid, acc, jnp.inf)
    gids = jnp.broadcast_to(gids, (block_q, block_n))

    # --- merge block into the running heap: block-local bitonic pre-top-L
    # then one bitonic merge with the sorted heap (kernels/merge.py) —
    # compare/where ops only, bit-identical to the lexicographic
    # (score asc, global id asc) select it replaced ---
    out_s, out_g = merge.merge_block_topl(
        scores_ref[...], idx_ref[...], acc, gids, topl)
    scores_ref[...] = out_s
    idx_ref[...] = out_g


@functools.partial(jax.jit, static_argnames=("topl", "n_valid", "block_n",
                                             "block_q", "interpret"))
def adc_scan_topl_pallas(codes: jax.Array, luts: jax.Array, bias: jax.Array,
                         qbias: jax.Array | None = None,
                         scale: jax.Array | None = None, *, topl: int,
                         n_valid: int,
                         block_n: int = DEFAULT_TOPL_BLOCK_N,
                         block_q: int = DEFAULT_TOPL_BLOCK_Q,
                         interpret: bool = False):
    """Streaming stage 1: per-query top-L without a (Q, N) score matrix.

    codes: (N, M) uint8/int32, N % block_n == 0 (ops.py pads; rows at or
           past ``n_valid`` are the pad and are masked out).
    luts:  (Q, M, K) float32, Q % block_q == 0 (ops.py pads) — or the
           float16/int8 quantized tables of ``lut_quant`` for the
           reduced-precision pool scan.
    bias:  (N,) float32 per-point additive score term (zeros when unused).
    qbias: optional (Q, N) float32 per-(query, point) additive stream —
           the lowering target of the filtered-search API (+inf drops a
           point for one query). Streamed in (block_q, block_n) tiles, so
           the filter rides the fused path with no extra peak memory.
    scale: optional (Q, M) float32 per-(query, book) affine scales —
           REQUIRED with int8 ``luts``, None otherwise.
    Returns (scores, indices): ((Q, topl) f32, (Q, topl) i32), sorted by
    (score asc, index asc) — bit-identical to ``lax.top_k`` over the full
    score matrix (``ref.adc_scan_topl_ref`` for f32 tables,
    ``ref.adc_scan_topl_q_ref`` for quantized ones).
    """
    n, num_books = codes.shape
    q, _, book_size = luts.shape
    assert n % block_n == 0, f"N={n} must be padded to a multiple of {block_n}"
    assert q % block_q == 0, f"Q={q} must be padded to a multiple of {block_q}"
    assert 0 < topl <= n_valid <= n, (topl, n_valid, n)
    grid = (q // block_q, n // block_n)
    kernel = functools.partial(
        _adc_scan_topl_kernel, topl=topl, block_n=block_n, block_q=block_q,
        num_books=num_books, book_size=book_size, n_valid=n_valid,
        has_qbias=qbias is not None, has_scale=scale is not None)
    in_specs = [
        pl.BlockSpec((block_n, num_books), lambda qi, ni: (ni, 0)),
        pl.BlockSpec((block_q, num_books, book_size),
                     lambda qi, ni: (qi, 0, 0)),
        pl.BlockSpec((block_n,), lambda qi, ni: (ni,)),
    ]
    operands = [codes, luts, bias]
    if qbias is not None:
        in_specs.append(pl.BlockSpec((block_q, block_n),
                                     lambda qi, ni: (qi, ni)))
        operands.append(qbias)
    if scale is not None:
        in_specs.append(pl.BlockSpec((block_q, num_books),
                                     lambda qi, ni: (qi, 0)))
        operands.append(scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, topl), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((block_q, topl), lambda qi, ni: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, topl), jnp.float32),
            jax.ShapeDtypeStruct((q, topl), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("topl", "n_valid", "chunk_n"))
def adc_scan_topl_stream_xla(codes: jax.Array, luts: jax.Array,
                             bias: jax.Array,
                             qbias: jax.Array | None = None,
                             scale: jax.Array | None = None, *, topl: int,
                             n_valid: int, chunk_n: int = DEFAULT_CHUNK_N):
    """XLA fallback with the SAME streaming semantics as the Pallas kernel:
    a ``lax.scan`` over (Q, chunk_n) code chunks carrying the (Q, L) heap,
    merged with an incremental ``lax.top_k``. Peak live memory is
    O(Q * (L + chunk_n)) — the (Q, N) matrix is never built (asserted by
    the HLO peak-memory test).

    ``qbias`` is the optional (Q, N) per-(query, point) bias stream (the
    lowered filter mask), consumed in (Q, chunk_n) slices alongside the
    code chunks. Quantized (f16/i8) ``luts`` ride the same scan after a
    one-time up-front dequantization of the (Q, M, K) tables to f32
    (``scale`` is the int8 per-(query, book) scale): per-chunk scoring is
    then EXACTLY the f32 path's, so the fallback pays zero per-row
    quantization cost — CPU XLA's reduced-dtype gather+convert lowering
    is ~2x slower than the f32 gather, and the tables are a few hundred
    KB while the codes stream is the real traffic. Bit-exactness vs
    ``ref.adc_scan_batch_q_ref`` is preserved: f32(f16)[idx] ==
    f32(f16[idx]) (widening is exact), and pre-multiplying the int8
    table entry by its scale is the same IEEE multiply as scaling the
    gathered part. The Pallas kernel, by contrast, keeps the tiles in
    the reduced dtype inside VMEM — there the 2-4x tile shrink is the
    point (see ``_adc_scan_topl_q`` variants).

    Exactness: the carry is sorted by (score, index) and every chunk entry
    has a larger global index than every carried entry, so ``lax.top_k``'s
    positional tie-break IS the ascending-global-index tie-break — the
    result is bit-identical to the materialized oracle.
    """
    n, m = codes.shape
    q = luts.shape[0]
    if luts.dtype != jnp.float32:      # dequantize ONCE, outside the scan
        luts = luts.astype(jnp.float32)
        if scale is not None:
            luts = luts * scale[:, :, None]
    pad = (-n) % chunk_n
    codes_c = jnp.pad(codes, ((0, pad), (0, 0))).reshape(-1, chunk_n, m)
    bias_c = jnp.pad(bias, (0, pad)).reshape(-1, chunk_n)
    starts = (jnp.arange(codes_c.shape[0]) * chunk_n).astype(jnp.int32)
    qbias_c = None if qbias is None else jnp.moveaxis(
        jnp.pad(qbias, ((0, 0), (0, pad))).reshape(q, -1, chunk_n), 1, 0)

    def step(carry, inp):
        vals, idx = carry                       # (Q, L), (Q, L)
        chunk, bias_i, start, qbias_i = inp
        s = ref.adc_scan_batch_ref(chunk, luts) + bias_i[None, :]
        if qbias_i is not None:
            s = s + qbias_i
        gids = start + jnp.arange(chunk_n, dtype=jnp.int32)
        s = jnp.where(gids[None, :] < n_valid, s, jnp.inf)
        cand_s = jnp.concatenate([vals, s], axis=1)
        cand_g = jnp.concatenate(
            [idx, jnp.broadcast_to(gids[None, :], (q, chunk_n))], axis=1)
        neg, pos = jax.lax.top_k(-cand_s, topl)
        return (-neg, jnp.take_along_axis(cand_g, pos, axis=1)), None

    init = (jnp.full((q, topl), jnp.inf, jnp.float32),
            jnp.full((q, topl), _IMAX, jnp.int32))
    (vals, idx), _ = jax.lax.scan(step, init,
                                  (codes_c, bias_c, starts, qbias_c))
    return vals, idx
