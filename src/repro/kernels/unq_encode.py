"""Pallas TPU kernel for fused UNQ codeword assignment (paper Eq. 4).

Computes, for a block of encoder heads, the argmax over codewords of the
dot-product score — fusing the (B, M, d_c) x (M, K, d_c) contraction with the
argmax so the (B, K) score matrix never leaves VMEM. The codebooks
(M*K*d_c floats; 2 MB at M=8, K=256, d_c=256) are VMEM-resident across the
whole batch; head blocks stream in through the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_B = 256


def _unq_encode_kernel(heads_ref, books_ref, out_ref, *, num_books: int):
    heads = heads_ref[...]                        # (Bb, M, d_c)
    books = books_ref[...]                        # (M, K, d_c)
    cols = []
    for m in range(num_books):                    # static M
        # (Bb, d_c) @ (d_c, K) on the MXU; argmax fused in-register.
        scores = jax.lax.dot_general(
            heads[:, m, :], books[m],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)   # (Bb, K)
        cols.append(jnp.argmax(scores, axis=-1).astype(jnp.int32))
    out_ref[...] = jnp.stack(cols, axis=1)        # (Bb, M)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def unq_encode_pallas(heads: jax.Array, codebooks: jax.Array, *,
                      block_b: int = DEFAULT_BLOCK_B,
                      interpret: bool = False) -> jax.Array:
    """codes[b, m] = argmax_k <heads[b, m], codebooks[m, k]>.

    heads: (B, M, d_c) with B % block_b == 0 (ops.py pads); codebooks
    (M, K, d_c). Returns (B, M) int32.
    """
    b, num_books, d_c = heads.shape
    _, book_size, _ = codebooks.shape
    assert b % block_b == 0, f"B={b} must be padded to a multiple of {block_b}"
    grid = (b // block_b,)
    kernel = functools.partial(_unq_encode_kernel, num_books=num_books)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, num_books, d_c), lambda i: (i, 0, 0)),
            pl.BlockSpec((num_books, book_size, d_c), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, num_books), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, num_books), jnp.int32),
        interpret=interpret,
    )(heads, codebooks)
