"""Fused gathered scan + top-L kernels (the IVF stage-1 engine).

IVF search scores a PER-QUERY slot list — the padded ragged batch built by
concatenating the inverted lists of each query's probed cells — instead of
the whole database. The flat streaming kernel (``topl_scan.py``) shares
one (N, M) code block across all queries; here each query block carries
its OWN gathered code tile, so the one-hot scoring contraction becomes a
batched (per-query) MXU dot and everything else — the running (block_q, L)
heap in VMEM, the lexicographic (score, global-id) merge, +inf masking of
pad slots — is inherited unchanged.

Memory model per grid step (grid = (Q/block_q, W/block_w), w innermost):

  * the (block_q, L) score/id heap lives in the OUTPUT blocks, whose index
    map ignores the w axis — VMEM-resident across the whole w sweep;
  * the (block_q, block_w, M) uint8 gathered-code tile, the (block_q,
    block_w) global-id tile and the (block_q, block_w) slot-bias tile
    stream HBM->VMEM (the gather itself happens outside the kernel: the
    gathered batch is Q*W*M BYTES — the d2 score values are what must
    never materialize at (Q, N) scale);
  * slots with gid == _IMAX (the ragged pad) score +inf; slots whose bias
    carries +inf (filtered out) are canonicalized to gid _IMAX, so +inf
    entries are identical bits across every implementation;
  * the (block_q, block_w) slot-bias tile is ONE pre-composed stream
    (``ops.adc_gather_topl`` docstring): per-point biases, the residual
    IVF correction's per-(query, cell) term, and lowered filter masks are
    summed host-side in a fixed order, so the kernel adds exactly one
    value per slot and stays bit-identical to the oracle for any mix.

Tie semantics are EXACTLY those of flat search: the merge selects
lexicographic (score asc, global id asc) minima, so at nprobe == nlist
(every point listed exactly once) the result is bit-identical to
``ref.adc_scan_topl_ref`` over the same database — scores AND ids.

The chunked ``lax.scan`` fallback additionally relies on the plan
CONTRACT (gids ascending within each query row, pads last): every chunk
slot then has a gid >= every carried heap entry, so ``lax.top_k``'s
positional tie-break reproduces the ascending-gid tie-break — the same
argument that makes ``topl_scan.adc_scan_topl_stream_xla`` exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import merge

DEFAULT_GATHER_BLOCK_W = 512
DEFAULT_GATHER_BLOCK_Q = 8
DEFAULT_CHUNK_W = 2048

_IMAX = jnp.iinfo(jnp.int32).max


def _adc_gather_topl_kernel(codes_ref, gids_ref, bias_ref, luts_ref,
                            *refs, topl: int, block_w: int,
                            block_q: int, num_books: int, book_size: int,
                            has_scale: bool):
    refs = list(refs)
    scale_ref = refs.pop(0) if has_scale else None
    scores_ref, idx_ref = refs
    wi = pl.program_id(1)

    @pl.when(wi == 0)
    def _init():                      # fresh heap at the start of each w sweep
        scores_ref[...] = jnp.full((block_q, topl), jnp.inf, jnp.float32)
        idx_ref[...] = jnp.full((block_q, topl), _IMAX, jnp.int32)

    # --- score the gathered tile: per-query one-hot contraction, one
    # batched MXU dot per codebook — the same per-m partial values (and
    # the same left-to-right m accumulation) as the flat kernel, so a
    # slot's score is bit-identical to the same point's flat score ---
    codes = codes_ref[...].astype(jnp.int32)           # (Bq, Bw, M)
    luts = luts_ref[...]                               # (Bq, M, K)
    scale = scale_ref[...] if has_scale else None      # (Bq, M)
    acc = jnp.zeros((block_q, block_w), jnp.float32)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, 1, book_size), 2)
    for m in range(num_books):                         # M is static (8 or 16)
        onehot = (codes[:, :, m:m + 1] == iota_k).astype(jnp.float32)
        part = jax.lax.dot_general(
            luts[:, m, :].astype(jnp.float32), onehot,
            dimension_numbers=(((1,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        if has_scale:                  # int8: per-(query, book) scale on
            part = part * scale[:, m][:, None]   # each part BEFORE the chain
        acc = acc + part
    acc = acc + bias_ref[...]

    # pad slots (gid == _IMAX) score +inf; +inf slots (filtered) get the
    # canonical _IMAX gid so +inf entries are identical across paths
    gids = gids_ref[...]
    acc = jnp.where(gids == _IMAX, jnp.inf, acc)
    gids = jnp.where(acc == jnp.inf, _IMAX, gids)

    # --- merge tile into the running heap: shared bitonic pre-top-L +
    # merge (kernels/merge.py) — identical tie semantics to topl_scan ---
    out_s, out_g = merge.merge_block_topl(
        scores_ref[...], idx_ref[...], acc, gids, topl)
    scores_ref[...] = out_s
    idx_ref[...] = out_g


@functools.partial(jax.jit, static_argnames=("topl", "block_w", "block_q",
                                             "interpret"))
def adc_gather_topl_pallas(gathered_codes: jax.Array, gids: jax.Array,
                           rowbias: jax.Array, luts: jax.Array,
                           scale: jax.Array | None = None, *, topl: int,
                           block_w: int = DEFAULT_GATHER_BLOCK_W,
                           block_q: int = DEFAULT_GATHER_BLOCK_Q,
                           interpret: bool = False):
    """Fused gathered scan+top-L over per-query slot lists.

    gathered_codes: (Q, W, M) uint8/int32, W % block_w == 0 (ops.py pads).
    gids:           (Q, W) int32 global ids; _IMAX marks pad slots.
    rowbias:        (Q, W) float32 additive per-slot term (+inf filters).
    luts:           (Q, M, K) float32, Q % block_q == 0 (ops.py pads) —
                    or the float16/int8 quantized tables of ``lut_quant``.
    scale:          optional (Q, M) float32 int8 affine scales (None for
                    f32/f16 tables).
    Returns (scores, ids): ((Q, topl) f32, (Q, topl) i32), sorted by
    (score asc, global id asc).
    """
    q, w, num_books = gathered_codes.shape
    book_size = luts.shape[-1]
    assert w % block_w == 0, f"W={w} must be padded to a multiple of {block_w}"
    assert q % block_q == 0, f"Q={q} must be padded to a multiple of {block_q}"
    assert 0 < topl <= w, (topl, w)
    grid = (q // block_q, w // block_w)
    kernel = functools.partial(
        _adc_gather_topl_kernel, topl=topl, block_w=block_w, block_q=block_q,
        num_books=num_books, book_size=book_size, has_scale=scale is not None)
    in_specs = [
        pl.BlockSpec((block_q, block_w, num_books),
                     lambda qi, wi: (qi, wi, 0)),
        pl.BlockSpec((block_q, block_w), lambda qi, wi: (qi, wi)),
        pl.BlockSpec((block_q, block_w), lambda qi, wi: (qi, wi)),
        pl.BlockSpec((block_q, num_books, book_size),
                     lambda qi, wi: (qi, 0, 0)),
    ]
    operands = [gathered_codes, gids, rowbias, luts]
    if scale is not None:
        in_specs.append(pl.BlockSpec((block_q, num_books),
                                     lambda qi, wi: (qi, 0)))
        operands.append(scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, topl), lambda qi, wi: (qi, 0)),
            pl.BlockSpec((block_q, topl), lambda qi, wi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, topl), jnp.float32),
            jax.ShapeDtypeStruct((q, topl), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("topl", "chunk_w"))
def adc_gather_topl_stream_xla(codes: jax.Array, rows: jax.Array,
                               gids: jax.Array, rowbias: jax.Array,
                               luts: jax.Array,
                               scale: jax.Array | None = None, *, topl: int,
                               chunk_w: int = DEFAULT_CHUNK_W):
    """XLA fallback with the same streaming semantics: a ``lax.scan`` over
    (Q, chunk_w) slot chunks carrying the (Q, L) heap. The gather happens
    per chunk (``codes[rows_chunk]``), so peak gathered memory is
    O(Q * chunk_w * M) bytes and the (Q, W) score batch never exists.

    Exactness relies on the plan contract (gids ascending per query row,
    pads last): every chunk slot's gid is >= every carried entry's, so the
    incremental ``lax.top_k`` positional tie-break IS the ascending-gid
    tie-break — bit-identical to ``ref.adc_gather_topl_ref``.
    """
    q, w = rows.shape
    num_books = codes.shape[1]
    if luts.dtype != jnp.float32:      # dequantize ONCE, outside the scan
        # bitwise-identical to gathering in the reduced dtype and
        # converting/scaling per part (f32 widening is exact; the int8
        # scale multiply is the same IEEE op either side of the gather),
        # and ~2x faster: CPU XLA's narrow gather+convert lowering loses
        # to the plain f32 gather (see topl_scan.adc_scan_topl_stream_xla)
        luts = luts.astype(jnp.float32)
        if scale is not None:
            luts = luts * scale[:, :, None]
    pad = (-w) % chunk_w
    rows_c = jnp.moveaxis(
        jnp.pad(rows, ((0, 0), (0, pad))).reshape(q, -1, chunk_w), 1, 0)
    gids_c = jnp.moveaxis(
        jnp.pad(gids, ((0, 0), (0, pad)), constant_values=_IMAX)
        .reshape(q, -1, chunk_w), 1, 0)
    bias_c = jnp.moveaxis(
        jnp.pad(rowbias, ((0, 0), (0, pad))).reshape(q, -1, chunk_w), 1, 0)

    def step(carry, inp):
        vals, idx = carry                              # (Q, L) x2
        rows_i, gids_i, bias_i = inp
        chunk = jnp.take(codes, rows_i, axis=0).astype(jnp.int32)
        picked = jnp.take_along_axis(
            luts[:, None, :, :], chunk[:, :, :, None], axis=3)[..., 0]
        s = picked[:, :, 0]
        for m in range(1, num_books):                  # adc_scan_ref chain
            s = s + picked[:, :, m]
        s = s + bias_i
        s = jnp.where(gids_i == _IMAX, jnp.inf, s)
        g = jnp.where(jnp.isposinf(s), _IMAX, gids_i)
        cand_s = jnp.concatenate([vals, s], axis=1)
        cand_g = jnp.concatenate([idx, g], axis=1)
        neg, pos = jax.lax.top_k(-cand_s, topl)
        return (-neg, jnp.take_along_axis(cand_g, pos, axis=1)), None

    init = (jnp.full((q, topl), jnp.inf, jnp.float32),
            jnp.full((q, topl), _IMAX, jnp.int32))
    (vals, idx), _ = jax.lax.scan(step, init, (rows_c, gids_c, bias_c))
    return vals, idx
