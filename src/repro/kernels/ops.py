"""Public entry points for the kernels package.

Each op dispatches between:
  impl="pallas"  — the Pallas TPU kernel (``interpret=True`` automatically on
                   CPU so the kernel body is validated in this container);
  impl="xla"     — the pure-jnp oracle from ``ref.py`` (always available,
                   and what the distributed paths use inside pjit);
  impl="onehot"  — XLA one-hot matmul formulation (the MXU-shaped algorithm
                   without Pallas, useful to A/B the adaptation itself).

All wrappers handle padding to kernel block multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.adc_scan import (adc_scan_pallas, adc_scan_batch_pallas,
                                    DEFAULT_BLOCK_N, DEFAULT_BLOCK_Q)
from repro.kernels.unq_encode import unq_encode_pallas, DEFAULT_BLOCK_B


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, multiple: int, axis: int = 0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def adc_scan(codes: jax.Array, lut: jax.Array, *, impl: str = "pallas",
             block_n: int = DEFAULT_BLOCK_N) -> jax.Array:
    """scores[n] = sum_m lut[m, codes[n, m]].  codes (N, M), lut (M, K) -> (N,)."""
    if impl == "xla":
        return ref.adc_scan_ref(codes, lut)
    if impl == "onehot":
        onehot = jax.nn.one_hot(codes.astype(jnp.int32), lut.shape[1],
                                dtype=lut.dtype)          # (N, M, K)
        return jnp.einsum("nmk,mk->n", onehot, lut)
    if impl == "pallas":
        padded, n = _pad_to(codes, block_n, axis=0)
        out = adc_scan_pallas(padded, lut.astype(jnp.float32),
                              block_n=block_n, interpret=not _on_tpu())
        return out[:n]
    raise ValueError(f"unknown impl: {impl!r}")


def adc_scan_batch(codes: jax.Array, luts: jax.Array, *, impl: str = "pallas",
                   block_n: int = DEFAULT_BLOCK_N,
                   block_q: int = DEFAULT_BLOCK_Q) -> jax.Array:
    """Multi-query scan: scores[q, n] = sum_m luts[q, m, codes[n, m]].

    codes (N, M), luts (Q, M, K) -> (Q, N). The pallas impl streams each
    code block once for all Q queries (Q-fold HBM amortization vs the
    per-query ``adc_scan``); xla/onehot are the oracles.
    """
    if impl == "xla":
        return ref.adc_scan_batch_ref(codes, luts)
    if impl == "onehot":
        onehot = jax.nn.one_hot(codes.astype(jnp.int32), luts.shape[-1],
                                dtype=luts.dtype)      # (N, M, K)
        return jnp.einsum("nmk,qmk->qn", onehot, luts)
    if impl == "pallas":
        q = luts.shape[0]
        # shrink the query block for small batches (8 = f32 sublane tile)
        bq = min(block_q, max(8, -(-q // 8) * 8))
        padded_codes, n = _pad_to(codes, block_n, axis=0)
        padded_luts, _ = _pad_to(luts.astype(jnp.float32), bq, axis=0)
        out = adc_scan_batch_pallas(padded_codes, padded_luts,
                                    block_n=block_n, block_q=bq,
                                    interpret=not _on_tpu())
        return out[:q, :n]
    raise ValueError(f"unknown impl: {impl!r}")


def unq_encode(heads: jax.Array, codebooks: jax.Array, *, impl: str = "pallas",
               block_b: int = DEFAULT_BLOCK_B) -> jax.Array:
    """codes[b, m] = argmax_k <heads[b,m], codebooks[m,k]>.

    heads (B, M, d_c), codebooks (M, K, d_c) -> (B, M) int32.
    """
    if impl == "xla":
        return ref.unq_encode_ref(heads, codebooks)
    if impl == "pallas":
        padded, b = _pad_to(heads, block_b, axis=0)
        out = unq_encode_pallas(padded, codebooks, block_b=block_b,
                                interpret=not _on_tpu())
        return out[:b]
    raise ValueError(f"unknown impl: {impl!r}")


def kv_adc_attention(q, k_codes, v_codes, k_books, v_books, length=None, *,
                     impl: str = "xla"):
    """Compressed-KV decode attention (see ref.kv_adc_attention_ref)."""
    if impl == "xla":
        return ref.kv_adc_attention_ref(q, k_codes, v_codes, k_books, v_books,
                                        length)
    raise ValueError(f"unknown impl: {impl!r}")
