"""Public entry points for the kernels package.

Each op dispatches between:
  impl="pallas"  — the Pallas TPU kernel (``interpret=True`` automatically on
                   CPU so the kernel body is validated in this container);
  impl="xla"     — the pure-jnp oracle from ``ref.py`` (always available,
                   and what the distributed paths use inside pjit);
  impl="onehot"  — XLA one-hot matmul formulation (the MXU-shaped algorithm
                   without Pallas, useful to A/B the adaptation itself).

All wrappers handle padding to kernel block multiples.

Block/chunk parameters default to ``None`` and are resolved through the
autotuner registry (``repro.kernels.tune.best_config``): the cached winner
for (device kind, kernel, shape bucket) when ``python -m repro.tune`` has
run on this machine, the registered hand-pinned defaults otherwise. An
explicit integer argument always wins (tests pin exact block shapes).
Small-dim rounding goes through ``tune.align`` / ``tune.clamp_chunk`` —
the ONE home of those heuristics.

The three top-L ops accept ``lut_dtype`` / ``overfetch`` for the opt-in
reduced-precision stage 1 (``lut_quant.py``): the scan runs on quantized
(f16/i8) tables selecting an over-fetched pool of ``overfetch * topl``
candidates, survivors are re-scored with the exact f32 chain (op-for-op
the exact path's composition), and the exact lexicographic top-L of the
pool is returned. ``lut_dtype='float32', overfetch=1`` — the default —
routes down the literally unchanged bit-exact path.

Off-TPU the Pallas kernels run in interpret mode automatically; CI can pin
the decision with ``REPRO_PALLAS_INTERPRET=1`` (force interpret, e.g. when
the accelerator probe is unreliable) or ``=0`` (force compiled).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import lut_quant, ref, tune
from repro.kernels.adc_scan import (adc_scan_pallas, adc_scan_batch_pallas,
                                    DEFAULT_BLOCK_N, DEFAULT_BLOCK_Q)
from repro.kernels.dispatch_topl import (adc_dispatch_topl_pallas,
                                         adc_dispatch_topl_stream_xla,
                                         DispatchPlan,
                                         DEFAULT_DISPATCH_CHUNK)
from repro.kernels.gather_topl import (adc_gather_topl_pallas,
                                       adc_gather_topl_stream_xla,
                                       DEFAULT_CHUNK_W,
                                       DEFAULT_GATHER_BLOCK_Q,
                                       DEFAULT_GATHER_BLOCK_W)
from repro.kernels.rerank_dist import (rerank_gather_dist_pallas,
                                       rerank_gather_dist_chunked_xla,
                                       DEFAULT_RERANK_BLOCK_L,
                                       DEFAULT_RERANK_BLOCK_Q,
                                       DEFAULT_RERANK_CHUNK_L)
from repro.kernels.topl_scan import (adc_scan_topl_pallas,
                                     adc_scan_topl_stream_xla,
                                     DEFAULT_CHUNK_N, DEFAULT_TOPL_BLOCK_N,
                                     DEFAULT_TOPL_BLOCK_Q)
from repro.kernels.unq_encode import unq_encode_pallas, DEFAULT_BLOCK_B

_IMAX = jnp.iinfo(jnp.int32).max


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    """Pallas interpret-mode decision, overridable for CI via env."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "")
    if env != "":
        return env not in ("0", "false", "False")
    return not _on_tpu()


def _pad_to(x: jax.Array, multiple: int, axis: int = 0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def adc_scan(codes: jax.Array, lut: jax.Array, *, impl: str = "pallas",
             block_n: int | None = None) -> jax.Array:
    """scores[n] = sum_m lut[m, codes[n, m]].  codes (N, M), lut (M, K) -> (N,)."""
    if impl == "xla":
        return ref.adc_scan_ref(codes, lut)
    if impl == "onehot":
        onehot = jax.nn.one_hot(codes.astype(jnp.int32), lut.shape[1],
                                dtype=lut.dtype)          # (N, M, K)
        return jnp.einsum("nmk,mk->n", onehot, lut)
    if impl == "pallas":
        cfg = tune.best_config("adc_scan", "pallas", n=codes.shape[0])
        bn = cfg["block_n"] if block_n is None else block_n
        padded, n = _pad_to(codes, bn, axis=0)
        out = adc_scan_pallas(padded, lut.astype(jnp.float32),
                              block_n=bn, interpret=_interpret())
        return out[:n]
    raise ValueError(f"unknown impl: {impl!r}")


def adc_scan_batch(codes: jax.Array, luts: jax.Array, *, impl: str = "pallas",
                   block_n: int | None = None,
                   block_q: int | None = None) -> jax.Array:
    """Multi-query scan: scores[q, n] = sum_m luts[q, m, codes[n, m]].

    codes (N, M), luts (Q, M, K) -> (Q, N). The pallas impl streams each
    code block once for all Q queries (Q-fold HBM amortization vs the
    per-query ``adc_scan``); xla/onehot are the oracles.
    """
    if impl == "xla":
        return ref.adc_scan_batch_ref(codes, luts)
    if impl == "onehot":
        onehot = jax.nn.one_hot(codes.astype(jnp.int32), luts.shape[-1],
                                dtype=luts.dtype)      # (N, M, K)
        return jnp.einsum("nmk,qmk->qn", onehot, luts)
    if impl == "pallas":
        q = luts.shape[0]
        cfg = tune.best_config("adc_scan_batch", "pallas",
                               n=codes.shape[0], q=q)
        bn = cfg["block_n"] if block_n is None else block_n
        # shrink the query block for small batches (8 = f32 sublane tile)
        bq = tune.align(q, cap=cfg["block_q"] if block_q is None else block_q)
        padded_codes, n = _pad_to(codes, bn, axis=0)
        padded_luts, _ = _pad_to(luts.astype(jnp.float32), bq, axis=0)
        out = adc_scan_batch_pallas(padded_codes, padded_luts,
                                    block_n=bn, block_q=bq,
                                    interpret=_interpret())
        return out[:q, :n]
    raise ValueError(f"unknown impl: {impl!r}")


def _scan_topl_run(codes, luts, scale, bias, qbias, *, topl: int, impl: str,
                   block_n, block_q, chunk_n):
    """One streaming scan+top-L pass at the given table precision (the
    shared engine behind the exact path and the quantized pool scan)."""
    n = codes.shape[0]
    q = luts.shape[0]
    if impl == "xla":
        cfg = tune.best_config("adc_scan_topl", "xla", n=n, q=q, topl=topl)
        cn = cfg["chunk_n"] if chunk_n is None else chunk_n
        return adc_scan_topl_stream_xla(
            codes, luts, bias, qbias, scale, topl=topl, n_valid=n,
            chunk_n=tune.clamp_chunk(n, cap=cn, floor=topl))
    if impl == "pallas":
        cfg = tune.best_config("adc_scan_topl", "pallas", n=n, q=q, topl=topl)
        bn = cfg["block_n"] if block_n is None else block_n
        bq = tune.align(q, cap=cfg["block_q"] if block_q is None else block_q)
        padded_codes, _ = _pad_to(codes, bn, axis=0)
        padded_luts, _ = _pad_to(luts, bq, axis=0)
        padded_bias, _ = _pad_to(bias.astype(jnp.float32), bn, axis=0)
        padded_qbias = None
        if qbias is not None:
            padded_qbias, _ = _pad_to(qbias.astype(jnp.float32), bq, axis=0)
            padded_qbias, _ = _pad_to(padded_qbias, bn, axis=1)
        padded_scale = None
        if scale is not None:
            padded_scale, _ = _pad_to(scale, bq, axis=0)
        scores, idx = adc_scan_topl_pallas(
            padded_codes, padded_luts, padded_bias, padded_qbias,
            padded_scale, topl=topl, n_valid=n, block_n=bn, block_q=bq,
            interpret=_interpret())
        return scores[:q], idx[:q]
    raise ValueError(
        f"unknown impl for adc_scan_topl: {impl!r} (streaming top-L has "
        "'pallas' and 'xla' paths; 'onehot' materializes the score matrix "
        "and is routed through the MaterializedTopL generator instead)")


@functools.partial(jax.jit, static_argnames=("topl",))
def _rescore_flat(codes, luts, bias, qbias, pool_g, topl: int):
    """Exact f32 re-score of a flat-scan candidate pool: the exact path's
    op-for-op score composition (left-to-right chain + bias + qbias) at
    the pool's rows, then the exact lexicographic top-L. Jitted: the
    pool is small (Q, L') but the ~15 eager op dispatches otherwise cost
    more than the compiled work on CPU."""
    n, num_books = codes.shape
    luts_f = luts.astype(jnp.float32)
    rows = jnp.minimum(pool_g, n - 1)
    c = jnp.take(codes, rows, axis=0).astype(jnp.int32)       # (Q, P, M)
    picked = jnp.take_along_axis(
        luts_f[:, None, :, :], c[..., None], axis=3)[..., 0]  # (Q, P, M)
    s = picked[..., 0]
    for m in range(1, num_books):                             # adc_scan_ref
        s = s + picked[..., m]                                # association
    s = s + jnp.take(bias, rows)
    if qbias is not None:
        s = s + jnp.take_along_axis(qbias, rows, axis=1)
    # scan-pad rows (gid >= n, incl. the _IMAX heap pad) can never surface
    s = jnp.where(pool_g >= n, jnp.inf, s)
    return lut_quant.exact_topl(s, pool_g, topl)


def adc_scan_topl(codes: jax.Array, luts: jax.Array, *, topl: int,
                  bias: jax.Array | None = None,
                  qbias: jax.Array | None = None, impl: str = "pallas",
                  block_n: int | None = None,
                  block_q: int | None = None,
                  chunk_n: int | None = None,
                  lut_dtype: str = "float32", overfetch: int = 1):
    """Streaming stage 1: per-query top-L over the compressed database
    WITHOUT materializing the (Q, N) score matrix.

    codes (N, M), luts (Q, M, K), optional bias (N,) ->
    ((Q, L), (Q, L) int32) with L = min(topl, N), sorted by
    (score asc, index asc) — bit-identical to ``lax.top_k`` over the full
    matrix (``ref.adc_scan_topl_ref``), tie resolution included.

      impl="pallas"  the fused scan+top-L kernel: a running (block_q, L)
                     heap in VMEM while code blocks stream from HBM.
      impl="xla"     chunked ``lax.scan`` + incremental top-L merge; the
                     always-available fallback with the same O(Q*L) peak.

    Both paths mask the internal N-padding rows to +inf so a pad entry can
    never surface as a candidate. ``bias`` carries per-point terms that do
    not fit the LUT decomposition (RVQ's stored ||decode(code)||^2);
    ``qbias`` is the optional (Q, N) per-(query, point) bias stream — the
    lowering target of the filtered-search API (+inf drops one point for
    one query) — consumed in tiles/chunks by both paths.

    ``lut_dtype`` in {'float16', 'int8'} switches the scan to quantized
    tables selecting an over-fetched pool of ``overfetch * topl``
    candidates, exactly re-scored in f32 before the final top-L (see
    ``lut_quant``); the default ('float32', overfetch 1) is the bit-exact
    path above, unchanged.
    """
    n = codes.shape[0]
    topl = min(topl, n)
    if bias is None:
        bias = jnp.zeros((n,), jnp.float32)
    lut_quant.check_lut_dtype(lut_dtype)
    if lut_dtype != "float32" or overfetch != 1:
        pool_l = lut_quant.pool_width(topl, overfetch, n)
        qluts, scale = lut_quant.quantize_luts(luts, lut_dtype)
        _, pool_g = _scan_topl_run(
            codes, qluts, scale, bias, qbias, topl=pool_l, impl=impl,
            block_n=block_n, block_q=block_q, chunk_n=chunk_n)
        return _rescore_flat(codes, luts, bias, qbias, pool_g, topl)
    return _scan_topl_run(
        codes, luts.astype(jnp.float32), None, bias, qbias, topl=topl,
        impl=impl, block_n=block_n, block_q=block_q, chunk_n=chunk_n)


def _gather_topl_run(codes, rows, gids, luts, scale, rowbias, *, topl: int,
                     impl: str, block_w, block_q, chunk_w):
    """One gathered scan+top-L pass at the given table precision."""
    q, w = rows.shape
    if impl == "xla":
        cfg = tune.best_config("adc_gather_topl", "xla", w=w, q=q, topl=topl)
        cw = cfg["chunk_w"] if chunk_w is None else chunk_w
        return adc_gather_topl_stream_xla(
            codes, rows, gids, rowbias.astype(jnp.float32), luts, scale,
            topl=topl, chunk_w=tune.clamp_chunk(w, cap=cw, floor=topl))
    if impl == "pallas":
        cfg = tune.best_config("adc_gather_topl", "pallas",
                               w=w, q=q, topl=topl)
        bq = tune.align(q, cap=cfg["block_q"] if block_q is None else block_q)
        bw = tune.align(w, cap=cfg["block_w"] if block_w is None else block_w)
        gathered = jnp.take(codes, rows, axis=0)           # (Q, W, M) u8
        gathered, _ = _pad_to(gathered, bq, axis=0)
        gathered, _ = _pad_to(gathered, bw, axis=1)
        padded_gids = jnp.pad(
            gids, ((0, gathered.shape[0] - q), (0, gathered.shape[1] - w)),
            constant_values=_IMAX)
        padded_bias = jnp.pad(
            rowbias.astype(jnp.float32),
            ((0, gathered.shape[0] - q), (0, gathered.shape[1] - w)))
        padded_luts, _ = _pad_to(luts, bq, axis=0)
        padded_scale = None
        if scale is not None:
            padded_scale, _ = _pad_to(scale, bq, axis=0)
        scores, idx = adc_gather_topl_pallas(
            gathered, padded_gids, padded_bias, padded_luts, padded_scale,
            topl=topl, block_w=bw, block_q=bq, interpret=_interpret())
        return scores[:q], idx[:q]
    raise ValueError(
        f"unknown impl for adc_gather_topl: {impl!r} (the gathered top-L "
        "has 'pallas' and 'xla' paths; 'onehot' routes through the "
        "materialized generator)")


@functools.partial(jax.jit, static_argnames=("topl",))
def _rescore_gather(codes, rows, gids, luts, rowbias, pool_g, topl: int):
    """Exact f32 re-score of a gathered-scan pool: pool gids map back to
    their slots via the ascending-gids plan contract (searchsorted), the
    exact chain + rowbias composition is reproduced op-for-op, +inf
    entries take the canonical _IMAX gid (gathered-path semantics)."""
    q, w = rows.shape
    num_books = luts.shape[1]
    luts_f = luts.astype(jnp.float32)
    slot = jax.vmap(jnp.searchsorted)(gids, pool_g)           # (Q, P)
    slot = jnp.minimum(slot, w - 1).astype(jnp.int32)
    hit = jnp.take_along_axis(gids, slot, axis=1) == pool_g
    rows_p = jnp.take_along_axis(rows, slot, axis=1)
    c = jnp.take(codes, rows_p, axis=0).astype(jnp.int32)     # (Q, P, M)
    picked = jnp.take_along_axis(
        luts_f[:, None, :, :], c[..., None], axis=3)[..., 0]
    s = picked[..., 0]
    for m in range(1, num_books):                             # adc_scan_ref
        s = s + picked[..., m]                                # association
    s = s + jnp.take_along_axis(rowbias.astype(jnp.float32), slot, axis=1)
    s = jnp.where(hit & (pool_g != _IMAX), s, jnp.inf)
    pool_g = jnp.where(jnp.isposinf(s), _IMAX, pool_g)
    return lut_quant.exact_topl(s, pool_g, topl)


def adc_gather_topl(codes: jax.Array, rows: jax.Array, gids: jax.Array,
                    luts: jax.Array, *, topl: int,
                    rowbias: jax.Array | None = None, impl: str = "pallas",
                    block_w: int | None = None,
                    block_q: int | None = None,
                    chunk_w: int | None = None,
                    lut_dtype: str = "float32", overfetch: int = 1):
    """Gathered stage 1 (IVF probing): per-query top-L over per-query slot
    lists instead of the whole database.

    codes (N, M) code buffer, rows (Q, W) buffer rows to score per query,
    gids (Q, W) the global id behind each slot (``_IMAX`` marks ragged
    pads), luts (Q, M, K), optional rowbias (Q, W) additive per-slot
    stream -> ((Q, L), (Q, L) int32) with L = min(topl, W), sorted by
    (score asc, global id asc).

    ``rowbias`` is the single additive slot stream every per-slot term
    composes onto HOST-SIDE before the kernel runs: gathered per-point
    biases (RVQ norms, residual-IVF cross terms ``2<centroid, decode>``),
    the residual correction's per-(query, cell) term
    ``||centroid||^2 - 2<q, centroid>`` gathered at each slot's cell, and
    the lowered filter mask (+inf drops a slot). Keeping the composition
    outside the kernel fixes one addition order, which is what makes all
    paths bit-identical for any mix of streams.

    CONTRACT: gids must be ascending within each query row (pads last) —
    IVF plan builders sort their probe lists by global id, which is what
    makes every path bit-identical to ``ref.adc_gather_topl_ref`` AND to
    flat search at nprobe == nlist (see gather_topl.py). The quantized
    path leans on the same contract to map pool gids back to slots for
    the exact re-score.

      impl="pallas"  the fused kernel: gathered uint8 code tiles stream
                     HBM->VMEM against a VMEM-resident (block_q, L) heap.
      impl="xla"     chunked ``lax.scan`` gathering O(Q*chunk_w) slots at
                     a time; the always-available fallback.

    (The materialized 'onehot' formulation routes through
    ``MaterializedTopL.gather_topl`` instead, scoring the full buffer.)

    ``lut_dtype`` / ``overfetch``: the reduced-precision pool scan + exact
    re-score, as in ``adc_scan_topl``.
    """
    q, w = rows.shape
    topl = min(topl, w)
    if rowbias is None:
        rowbias = jnp.zeros((q, w), jnp.float32)
    lut_quant.check_lut_dtype(lut_dtype)
    if lut_dtype != "float32" or overfetch != 1:
        pool_l = lut_quant.pool_width(topl, overfetch, w)
        qluts, scale = lut_quant.quantize_luts(luts, lut_dtype)
        _, pool_g = _gather_topl_run(
            codes, rows, gids, qluts, scale, rowbias, topl=pool_l,
            impl=impl, block_w=block_w, block_q=block_q, chunk_w=chunk_w)
        return _rescore_gather(codes, rows, gids, luts, rowbias, pool_g,
                               topl)
    return _gather_topl_run(
        codes, rows, gids, luts.astype(jnp.float32), None, rowbias,
        topl=topl, impl=impl, block_w=block_w, block_q=block_q,
        chunk_w=chunk_w)


def _dispatch_topl_run(codes, gids_rows, rowbias, luts, scale, cellterm,
                       plan, qkeep, *, topl: int, impl: str, chunk: int):
    """One dispatch scan+top-L pass at the given table precision."""
    n = codes.shape[0]
    padded_codes, _ = _pad_to(codes, chunk, axis=0)
    n_pad = padded_codes.shape[0] - n
    gids_p = jnp.pad(gids_rows, (0, n_pad), constant_values=_IMAX)
    rowb_p = jnp.pad(rowbias.astype(jnp.float32), (0, n_pad))
    qkeep_p = None
    if qkeep is not None:
        qkeep_p = jnp.pad(qkeep.astype(jnp.float32), ((0, 0), (0, n_pad)))
    if impl == "xla":
        scores, ids = adc_dispatch_topl_stream_xla(
            padded_codes, gids_p, rowb_p, luts, cellterm, plan, qkeep_p,
            scale, topl=topl, chunk=chunk)
    elif impl == "pallas":
        luts_p, _ = _pad_to(luts, 8, axis=0)
        scale_p = None
        if scale is not None:
            scale_p, _ = _pad_to(scale, 8, axis=0)
        if qkeep_p is not None:
            qkeep_p, _ = _pad_to(qkeep_p, 8, axis=0)
        scores, ids = adc_dispatch_topl_pallas(
            padded_codes, gids_p, rowb_p, luts_p, cellterm, plan, qkeep_p,
            scale_p, topl=topl, chunk=chunk, interpret=_interpret())
    else:
        raise ValueError(
            f"unknown impl for adc_dispatch_topl: {impl!r} (the dispatch "
            "face has 'pallas' and 'xla' paths; backends without the "
            "dispatch_topl capability use the padded gathered path)")
    # rows the router never routed (bucket padding past the active cells)
    # hold whatever the kernel left there — mask them to the canonical
    # (+inf, _IMAX) empty pool so partials are deterministic end to end
    routed = jnp.any(plan.qidx >= 0, axis=1)[:, None, None]
    scores = jnp.where(routed, scores, jnp.inf)
    ids = jnp.where(routed, ids, _IMAX)
    return scores, ids


@functools.partial(jax.jit, static_argnames=("topl",))
def _rescore_dispatch(codes, rowbias, luts, cellterm, plan, qkeep, pos,
                      part_g, topl: int):
    """Exact f32 re-score of per-cell dispatch pools: pool gids map to
    buffer rows via ``pos`` (the index's global id -> row inverse), the
    exact ``chain + (rowbias + cellterm)`` composition and mask order are
    reproduced op-for-op, +inf entries take the canonical _IMAX gid."""
    num_books = codes.shape[1]
    num_q = luts.shape[0]
    luts_f = luts.astype(jnp.float32)
    valid = part_g != _IMAX
    safe_g = jnp.clip(part_g, 0, pos.shape[0] - 1)
    rows_p = jnp.take(pos, safe_g)                        # (E+1, cap, P)
    c = jnp.take(codes, rows_p, axis=0).astype(jnp.int32)
    safe_q = jnp.clip(plan.qidx, 0, num_q - 1)            # (E+1, cap)
    lut_e = jnp.take(luts_f, safe_q, axis=0)              # (E+1, cap, M, K)
    picked = jnp.take_along_axis(
        lut_e[:, :, None, :, :], c[..., None], axis=4)[..., 0]
    s = picked[..., 0]
    for m in range(1, num_books):                         # adc_scan_ref
        s = s + picked[..., m]                            # association
    s = s + (jnp.take(rowbias.astype(jnp.float32), rows_p)
             + cellterm[:, :, None])
    if qkeep is not None:
        keep = qkeep[safe_q[..., None], rows_p]           # (E+1, cap, P)
        s = jnp.where(keep > 0.5, s, jnp.inf)
    s = jnp.where(valid, s, jnp.inf)
    s = jnp.where((plan.qidx >= 0)[:, :, None], s, jnp.inf)
    part_g = jnp.where(jnp.isposinf(s), _IMAX, part_g)
    return lut_quant.exact_topl(s, part_g, topl)


def adc_dispatch_topl(codes: jax.Array, gids_rows: jax.Array,
                      rowbias: jax.Array | None, luts: jax.Array,
                      cellterm: jax.Array, plan: DispatchPlan, *, topl: int,
                      qkeep: jax.Array | None = None, impl: str = "pallas",
                      chunk: int | None = None,
                      pos: jax.Array | None = None,
                      lut_dtype: str = "float32", overfetch: int = 1):
    """Cell-batched dispatch stage 1 (MoE-routed IVF probing): each routed
    cell's contiguous code range is scored ONCE for the dense batch of
    queries probing it, against a per-cell VMEM top-L heap.

    codes (N, M) the cell-grouped buffer, gids_rows (N,) buffer row ->
    global id, rowbias None | (N,) per-row additive stream (per-point
    bias with any (N,) filter already folded to +inf), luts (Q, M, K),
    cellterm (E+1, cap) per-(routed cell, slot) additive term, plan the
    ``DispatchPlan`` from ``repro.index.dispatch``, qkeep None | (Q, N)
    0/1 keep stream in buffer-row column order.

    ``chunk`` must be the tile width the plan was built with
    (``Routing.chunk``); ``None`` resolves the same shared registry entry
    the router uses, so router and kernel agree by construction.

    Returns per-cell partial pools ((E+1, cap, L) f32, (E+1, cap, L) i32)
    with L = min(topl, N), each slot sorted by (score asc, global id
    asc); rows the router never filled are masked to (+inf, _IMAX), so
    partials are fully deterministic. ``index.dispatch.combine_pools``
    scatters them back to per-query pools — bit-identical to the padded
    gathered path, tie semantics included.

      impl="pallas"  fused kernel: scalar-prefetched tile plan drives the
                     HBM code stream, heaps stay VMEM-resident per cell.
      impl="xla"     chunked ``lax.scan`` over the same tile plan; the
                     always-available fallback.

    ``lut_dtype`` / ``overfetch``: the reduced-precision pool scan + exact
    re-score (as in ``adc_scan_topl``) — requires ``pos``, the (n_ids,)
    global id -> buffer row inverse, to locate pool survivors' codes.
    """
    n = codes.shape[0]
    topl = min(topl, n)
    if rowbias is None:
        rowbias = jnp.zeros((n,), jnp.float32)
    if chunk is None:
        chunk = tune.best_config("adc_dispatch_topl",
                                 n=n, q=luts.shape[0])["chunk"]
    lut_quant.check_lut_dtype(lut_dtype)
    if lut_dtype != "float32" or overfetch != 1:
        if pos is None:
            raise ValueError(
                "quantized adc_dispatch_topl needs pos (global id -> "
                "buffer row) to re-score pool survivors exactly")
        pool_l = lut_quant.pool_width(topl, overfetch, n)
        qluts, scale = lut_quant.quantize_luts(luts, lut_dtype)
        _, part_g = _dispatch_topl_run(
            codes, gids_rows, rowbias, qluts, scale, cellterm, plan, qkeep,
            topl=pool_l, impl=impl, chunk=chunk)
        return _rescore_dispatch(codes, rowbias, luts, cellterm, plan,
                                 qkeep, pos, part_g, topl)
    return _dispatch_topl_run(
        codes, gids_rows, rowbias, luts.astype(jnp.float32), None, cellterm,
        plan, qkeep, topl=topl, impl=impl, chunk=chunk)


def rerank_gather_dist(cand_codes: jax.Array, queries: jax.Array,
                       table: jax.Array, *, impl: str = "pallas",
                       block_l: int | None = None,
                       block_q: int | None = None,
                       chunk_l: int | None = None) -> jax.Array:
    """Streaming stage 2 for table-decodable quantizers: exact d1
    reconstruction distances over per-query candidate lists WITHOUT
    materializing the (Q, L, D) reconstruction tensor.

    cand_codes (Q, L, M) integer candidate codes, queries (Q, D) f32,
    table (M, K, D) f32 with ``recon = sum_m table[m, code_m]``
    (``ref.decode_with_table``) -> d1 (Q, L) f32, bit-identical to the
    materialized oracle ``ref.rerank_gather_dist_ref``.

      impl="pallas"  the fused gather-decode-distance kernel: code tiles
                     stream HBM->VMEM, sub-codewords gathered from the
                     VMEM-resident table, ||q - recon||^2 reduced per
                     (query, candidate) tile.
      impl="xla"     chunked ``lax.scan`` over L; the always-available
                     fallback with O(Q * chunk_l * D) peak.
    """
    q, l, _ = cand_codes.shape
    d = queries.shape[1]
    if impl == "xla":
        cfg = tune.best_config("rerank_gather_dist", "xla", l=l, q=q, d=d)
        cl = cfg["chunk_l"] if chunk_l is None else chunk_l
        return rerank_gather_dist_chunked_xla(
            cand_codes, queries.astype(jnp.float32),
            table.astype(jnp.float32), chunk_l=cl)
    if impl == "pallas":
        cfg = tune.best_config("rerank_gather_dist", "pallas", l=l, q=q, d=d)
        bq = tune.align(q, cap=cfg["block_q"] if block_q is None else block_q)
        bl = tune.align(l, cap=cfg["block_l"] if block_l is None else block_l)
        padded_codes, _ = _pad_to(cand_codes, bq, axis=0)
        padded_codes, _ = _pad_to(padded_codes, bl, axis=1)
        padded_queries, _ = _pad_to(queries.astype(jnp.float32), bq, axis=0)
        out = rerank_gather_dist_pallas(
            padded_codes, padded_queries, table.astype(jnp.float32),
            block_l=bl, block_q=bq, interpret=_interpret())
        return out[:q, :l]
    raise ValueError(
        f"unknown impl for rerank_gather_dist: {impl!r} (the streaming "
        "stage 2 has 'pallas' and 'xla' paths; backends without the "
        "streaming capabilities use the materialized vmap reranker)")


def unq_encode(heads: jax.Array, codebooks: jax.Array, *, impl: str = "pallas",
               block_b: int | None = None) -> jax.Array:
    """codes[b, m] = argmax_k <heads[b,m], codebooks[m,k]>.

    heads (B, M, d_c), codebooks (M, K, d_c) -> (B, M) int32.
    """
    if impl == "xla":
        return ref.unq_encode_ref(heads, codebooks)
    if impl == "pallas":
        cfg = tune.best_config("unq_encode", "pallas", b=heads.shape[0])
        bb = cfg["block_b"] if block_b is None else block_b
        padded, b = _pad_to(heads, bb, axis=0)
        out = unq_encode_pallas(padded, codebooks, block_b=bb,
                                interpret=_interpret())
        return out[:b]
    raise ValueError(f"unknown impl: {impl!r}")


def kv_adc_attention(q, k_codes, v_codes, k_books, v_books, length=None, *,
                     impl: str = "xla"):
    """Compressed-KV decode attention (see ref.kv_adc_attention_ref)."""
    if impl == "xla":
        return ref.kv_adc_attention_ref(q, k_codes, v_codes, k_books, v_books,
                                        length)
    raise ValueError(f"unknown impl: {impl!r}")
