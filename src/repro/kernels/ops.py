"""Public entry points for the kernels package.

Each op dispatches between:
  impl="pallas"  — the Pallas TPU kernel (``interpret=True`` automatically on
                   CPU so the kernel body is validated in this container);
  impl="xla"     — the pure-jnp oracle from ``ref.py`` (always available,
                   and what the distributed paths use inside pjit);
  impl="onehot"  — XLA one-hot matmul formulation (the MXU-shaped algorithm
                   without Pallas, useful to A/B the adaptation itself).

All wrappers handle padding to kernel block multiples.

Off-TPU the Pallas kernels run in interpret mode automatically; CI can pin
the decision with ``REPRO_PALLAS_INTERPRET=1`` (force interpret, e.g. when
the accelerator probe is unreliable) or ``=0`` (force compiled).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.adc_scan import (adc_scan_pallas, adc_scan_batch_pallas,
                                    DEFAULT_BLOCK_N, DEFAULT_BLOCK_Q)
from repro.kernels.dispatch_topl import (adc_dispatch_topl_pallas,
                                         adc_dispatch_topl_stream_xla,
                                         DispatchPlan,
                                         DEFAULT_DISPATCH_CHUNK)
from repro.kernels.gather_topl import (adc_gather_topl_pallas,
                                       adc_gather_topl_stream_xla,
                                       DEFAULT_CHUNK_W,
                                       DEFAULT_GATHER_BLOCK_Q,
                                       DEFAULT_GATHER_BLOCK_W)
from repro.kernels.rerank_dist import (rerank_gather_dist_pallas,
                                       rerank_gather_dist_chunked_xla,
                                       DEFAULT_RERANK_BLOCK_L,
                                       DEFAULT_RERANK_BLOCK_Q,
                                       DEFAULT_RERANK_CHUNK_L)
from repro.kernels.topl_scan import (adc_scan_topl_pallas,
                                     adc_scan_topl_stream_xla,
                                     DEFAULT_CHUNK_N, DEFAULT_TOPL_BLOCK_N,
                                     DEFAULT_TOPL_BLOCK_Q)
from repro.kernels.unq_encode import unq_encode_pallas, DEFAULT_BLOCK_B


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    """Pallas interpret-mode decision, overridable for CI via env."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "")
    if env != "":
        return env not in ("0", "false", "False")
    return not _on_tpu()


def _pad_to(x: jax.Array, multiple: int, axis: int = 0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def adc_scan(codes: jax.Array, lut: jax.Array, *, impl: str = "pallas",
             block_n: int = DEFAULT_BLOCK_N) -> jax.Array:
    """scores[n] = sum_m lut[m, codes[n, m]].  codes (N, M), lut (M, K) -> (N,)."""
    if impl == "xla":
        return ref.adc_scan_ref(codes, lut)
    if impl == "onehot":
        onehot = jax.nn.one_hot(codes.astype(jnp.int32), lut.shape[1],
                                dtype=lut.dtype)          # (N, M, K)
        return jnp.einsum("nmk,mk->n", onehot, lut)
    if impl == "pallas":
        padded, n = _pad_to(codes, block_n, axis=0)
        out = adc_scan_pallas(padded, lut.astype(jnp.float32),
                              block_n=block_n, interpret=_interpret())
        return out[:n]
    raise ValueError(f"unknown impl: {impl!r}")


def adc_scan_batch(codes: jax.Array, luts: jax.Array, *, impl: str = "pallas",
                   block_n: int = DEFAULT_BLOCK_N,
                   block_q: int = DEFAULT_BLOCK_Q) -> jax.Array:
    """Multi-query scan: scores[q, n] = sum_m luts[q, m, codes[n, m]].

    codes (N, M), luts (Q, M, K) -> (Q, N). The pallas impl streams each
    code block once for all Q queries (Q-fold HBM amortization vs the
    per-query ``adc_scan``); xla/onehot are the oracles.
    """
    if impl == "xla":
        return ref.adc_scan_batch_ref(codes, luts)
    if impl == "onehot":
        onehot = jax.nn.one_hot(codes.astype(jnp.int32), luts.shape[-1],
                                dtype=luts.dtype)      # (N, M, K)
        return jnp.einsum("nmk,qmk->qn", onehot, luts)
    if impl == "pallas":
        q = luts.shape[0]
        # shrink the query block for small batches (8 = f32 sublane tile)
        bq = min(block_q, max(8, -(-q // 8) * 8))
        padded_codes, n = _pad_to(codes, block_n, axis=0)
        padded_luts, _ = _pad_to(luts.astype(jnp.float32), bq, axis=0)
        out = adc_scan_batch_pallas(padded_codes, padded_luts,
                                    block_n=block_n, block_q=bq,
                                    interpret=_interpret())
        return out[:q, :n]
    raise ValueError(f"unknown impl: {impl!r}")


def adc_scan_topl(codes: jax.Array, luts: jax.Array, *, topl: int,
                  bias: jax.Array | None = None,
                  qbias: jax.Array | None = None, impl: str = "pallas",
                  block_n: int = DEFAULT_TOPL_BLOCK_N,
                  block_q: int = DEFAULT_TOPL_BLOCK_Q,
                  chunk_n: int = DEFAULT_CHUNK_N):
    """Streaming stage 1: per-query top-L over the compressed database
    WITHOUT materializing the (Q, N) score matrix.

    codes (N, M), luts (Q, M, K), optional bias (N,) ->
    ((Q, L), (Q, L) int32) with L = min(topl, N), sorted by
    (score asc, index asc) — bit-identical to ``lax.top_k`` over the full
    matrix (``ref.adc_scan_topl_ref``), tie resolution included.

      impl="pallas"  the fused scan+top-L kernel: a running (block_q, L)
                     heap in VMEM while code blocks stream from HBM.
      impl="xla"     chunked ``lax.scan`` + incremental top-L merge; the
                     always-available fallback with the same O(Q*L) peak.

    Both paths mask the internal N-padding rows to +inf so a pad entry can
    never surface as a candidate. ``bias`` carries per-point terms that do
    not fit the LUT decomposition (RVQ's stored ||decode(code)||^2);
    ``qbias`` is the optional (Q, N) per-(query, point) bias stream — the
    lowering target of the filtered-search API (+inf drops one point for
    one query) — consumed in tiles/chunks by both paths.
    """
    n = codes.shape[0]
    q = luts.shape[0]
    topl = min(topl, n)
    if bias is None:
        bias = jnp.zeros((n,), jnp.float32)
    if impl == "xla":
        return adc_scan_topl_stream_xla(
            codes, luts, bias, qbias, topl=topl, n_valid=n,
            chunk_n=min(chunk_n, max(topl, -(-n // 8))))
    if impl == "pallas":
        bq = min(block_q, max(8, -(-q // 8) * 8))
        padded_codes, _ = _pad_to(codes, block_n, axis=0)
        padded_luts, _ = _pad_to(luts.astype(jnp.float32), bq, axis=0)
        padded_bias, _ = _pad_to(bias.astype(jnp.float32), block_n, axis=0)
        padded_qbias = None
        if qbias is not None:
            padded_qbias, _ = _pad_to(qbias.astype(jnp.float32), bq, axis=0)
            padded_qbias, _ = _pad_to(padded_qbias, block_n, axis=1)
        scores, idx = adc_scan_topl_pallas(
            padded_codes, padded_luts, padded_bias, padded_qbias, topl=topl,
            n_valid=n, block_n=block_n, block_q=bq, interpret=_interpret())
        return scores[:q], idx[:q]
    raise ValueError(
        f"unknown impl for adc_scan_topl: {impl!r} (streaming top-L has "
        "'pallas' and 'xla' paths; 'onehot' materializes the score matrix "
        "and is routed through the MaterializedTopL generator instead)")


def adc_gather_topl(codes: jax.Array, rows: jax.Array, gids: jax.Array,
                    luts: jax.Array, *, topl: int,
                    rowbias: jax.Array | None = None, impl: str = "pallas",
                    block_w: int = DEFAULT_GATHER_BLOCK_W,
                    block_q: int = DEFAULT_GATHER_BLOCK_Q,
                    chunk_w: int = DEFAULT_CHUNK_W):
    """Gathered stage 1 (IVF probing): per-query top-L over per-query slot
    lists instead of the whole database.

    codes (N, M) code buffer, rows (Q, W) buffer rows to score per query,
    gids (Q, W) the global id behind each slot (``_IMAX`` marks ragged
    pads), luts (Q, M, K), optional rowbias (Q, W) additive per-slot
    stream -> ((Q, L), (Q, L) int32) with L = min(topl, W), sorted by
    (score asc, global id asc).

    ``rowbias`` is the single additive slot stream every per-slot term
    composes onto HOST-SIDE before the kernel runs: gathered per-point
    biases (RVQ norms, residual-IVF cross terms ``2<centroid, decode>``),
    the residual correction's per-(query, cell) term
    ``||centroid||^2 - 2<q, centroid>`` gathered at each slot's cell, and
    the lowered filter mask (+inf drops a slot). Keeping the composition
    outside the kernel fixes one addition order, which is what makes all
    paths bit-identical for any mix of streams.

    CONTRACT: gids must be ascending within each query row (pads last) —
    IVF plan builders sort their probe lists by global id, which is what
    makes every path bit-identical to ``ref.adc_gather_topl_ref`` AND to
    flat search at nprobe == nlist (see gather_topl.py).

      impl="pallas"  the fused kernel: gathered uint8 code tiles stream
                     HBM->VMEM against a VMEM-resident (block_q, L) heap.
      impl="xla"     chunked ``lax.scan`` gathering O(Q*chunk_w) slots at
                     a time; the always-available fallback.

    (The materialized 'onehot' formulation routes through
    ``MaterializedTopL.gather_topl`` instead, scoring the full buffer.)
    """
    q, w = rows.shape
    topl = min(topl, w)
    if rowbias is None:
        rowbias = jnp.zeros((q, w), jnp.float32)
    if impl == "xla":
        return adc_gather_topl_stream_xla(
            codes, rows, gids, rowbias.astype(jnp.float32),
            luts.astype(jnp.float32), topl=topl,
            chunk_w=min(chunk_w, max(topl, -(-w // 8))))
    if impl == "pallas":
        bq = min(block_q, max(8, -(-q // 8) * 8))
        bw = min(block_w, max(8, -(-w // 8) * 8))
        gathered = jnp.take(codes, rows, axis=0)           # (Q, W, M) u8
        gathered, _ = _pad_to(gathered, bq, axis=0)
        gathered, _ = _pad_to(gathered, bw, axis=1)
        padded_gids = jnp.pad(
            gids, ((0, gathered.shape[0] - q), (0, gathered.shape[1] - w)),
            constant_values=jnp.iinfo(jnp.int32).max)
        padded_bias = jnp.pad(
            rowbias.astype(jnp.float32),
            ((0, gathered.shape[0] - q), (0, gathered.shape[1] - w)))
        padded_luts, _ = _pad_to(luts.astype(jnp.float32), bq, axis=0)
        scores, idx = adc_gather_topl_pallas(
            gathered, padded_gids, padded_bias, padded_luts, topl=topl,
            block_w=bw, block_q=bq, interpret=_interpret())
        return scores[:q], idx[:q]
    raise ValueError(
        f"unknown impl for adc_gather_topl: {impl!r} (the gathered top-L "
        "has 'pallas' and 'xla' paths; 'onehot' routes through the "
        "materialized generator)")


def adc_dispatch_topl(codes: jax.Array, gids_rows: jax.Array,
                      rowbias: jax.Array | None, luts: jax.Array,
                      cellterm: jax.Array, plan: DispatchPlan, *, topl: int,
                      qkeep: jax.Array | None = None, impl: str = "pallas",
                      chunk: int = DEFAULT_DISPATCH_CHUNK):
    """Cell-batched dispatch stage 1 (MoE-routed IVF probing): each routed
    cell's contiguous code range is scored ONCE for the dense batch of
    queries probing it, against a per-cell VMEM top-L heap.

    codes (N, M) the cell-grouped buffer, gids_rows (N,) buffer row ->
    global id, rowbias None | (N,) per-row additive stream (per-point
    bias with any (N,) filter already folded to +inf), luts (Q, M, K),
    cellterm (E+1, cap) per-(routed cell, slot) additive term, plan the
    ``DispatchPlan`` from ``repro.index.dispatch``, qkeep None | (Q, N)
    0/1 keep stream in buffer-row column order.

    Returns per-cell partial pools ((E+1, cap, L) f32, (E+1, cap, L) i32)
    with L = min(topl, N), each slot sorted by (score asc, global id
    asc); rows the router never filled are masked to (+inf, _IMAX), so
    partials are fully deterministic. ``index.dispatch.combine_pools``
    scatters them back to per-query pools — bit-identical to the padded
    gathered path, tie semantics included.

      impl="pallas"  fused kernel: scalar-prefetched tile plan drives the
                     HBM code stream, heaps stay VMEM-resident per cell.
      impl="xla"     chunked ``lax.scan`` over the same tile plan; the
                     always-available fallback.
    """
    n = codes.shape[0]
    topl = min(topl, n)
    if rowbias is None:
        rowbias = jnp.zeros((n,), jnp.float32)
    padded_codes, _ = _pad_to(codes, chunk, axis=0)
    n_pad = padded_codes.shape[0] - n
    gids_p = jnp.pad(gids_rows, (0, n_pad),
                     constant_values=jnp.iinfo(jnp.int32).max)
    rowb_p = jnp.pad(rowbias.astype(jnp.float32), (0, n_pad))
    luts_f = luts.astype(jnp.float32)
    qkeep_p = None
    if qkeep is not None:
        qkeep_p = jnp.pad(qkeep.astype(jnp.float32), ((0, 0), (0, n_pad)))
    if impl == "xla":
        scores, ids = adc_dispatch_topl_stream_xla(
            padded_codes, gids_p, rowb_p, luts_f, cellterm, plan, qkeep_p,
            topl=topl, chunk=chunk)
    elif impl == "pallas":
        luts_p, _ = _pad_to(luts_f, 8, axis=0)
        if qkeep_p is not None:
            qkeep_p, _ = _pad_to(qkeep_p, 8, axis=0)
        scores, ids = adc_dispatch_topl_pallas(
            padded_codes, gids_p, rowb_p, luts_p, cellterm, plan, qkeep_p,
            topl=topl, chunk=chunk, interpret=_interpret())
    else:
        raise ValueError(
            f"unknown impl for adc_dispatch_topl: {impl!r} (the dispatch "
            "face has 'pallas' and 'xla' paths; backends without the "
            "dispatch_topl capability use the padded gathered path)")
    # rows the router never routed (bucket padding past the active cells)
    # hold whatever the kernel left there — mask them to the canonical
    # (+inf, _IMAX) empty pool so partials are deterministic end to end
    routed = jnp.any(plan.qidx >= 0, axis=1)[:, None, None]
    scores = jnp.where(routed, scores, jnp.inf)
    ids = jnp.where(routed, ids, jnp.iinfo(jnp.int32).max)
    return scores, ids


def rerank_gather_dist(cand_codes: jax.Array, queries: jax.Array,
                       table: jax.Array, *, impl: str = "pallas",
                       block_l: int = DEFAULT_RERANK_BLOCK_L,
                       block_q: int = DEFAULT_RERANK_BLOCK_Q,
                       chunk_l: int = DEFAULT_RERANK_CHUNK_L) -> jax.Array:
    """Streaming stage 2 for table-decodable quantizers: exact d1
    reconstruction distances over per-query candidate lists WITHOUT
    materializing the (Q, L, D) reconstruction tensor.

    cand_codes (Q, L, M) integer candidate codes, queries (Q, D) f32,
    table (M, K, D) f32 with ``recon = sum_m table[m, code_m]``
    (``ref.decode_with_table``) -> d1 (Q, L) f32, bit-identical to the
    materialized oracle ``ref.rerank_gather_dist_ref``.

      impl="pallas"  the fused gather-decode-distance kernel: code tiles
                     stream HBM->VMEM, sub-codewords gathered from the
                     VMEM-resident table, ||q - recon||^2 reduced per
                     (query, candidate) tile.
      impl="xla"     chunked ``lax.scan`` over L; the always-available
                     fallback with O(Q * chunk_l * D) peak.
    """
    if impl == "xla":
        return rerank_gather_dist_chunked_xla(
            cand_codes, queries.astype(jnp.float32),
            table.astype(jnp.float32), chunk_l=chunk_l)
    if impl == "pallas":
        q, l, _ = cand_codes.shape
        bq = min(block_q, max(8, -(-q // 8) * 8))
        bl = min(block_l, max(8, -(-l // 8) * 8))
        padded_codes, _ = _pad_to(cand_codes, bq, axis=0)
        padded_codes, _ = _pad_to(padded_codes, bl, axis=1)
        padded_queries, _ = _pad_to(queries.astype(jnp.float32), bq, axis=0)
        out = rerank_gather_dist_pallas(
            padded_codes, padded_queries, table.astype(jnp.float32),
            block_l=bl, block_q=bq, interpret=_interpret())
        return out[:q, :l]
    raise ValueError(
        f"unknown impl for rerank_gather_dist: {impl!r} (the streaming "
        "stage 2 has 'pallas' and 'xla' paths; backends without the "
        "streaming capabilities use the materialized vmap reranker)")


def unq_encode(heads: jax.Array, codebooks: jax.Array, *, impl: str = "pallas",
               block_b: int = DEFAULT_BLOCK_B) -> jax.Array:
    """codes[b, m] = argmax_k <heads[b,m], codebooks[m,k]>.

    heads (B, M, d_c), codebooks (M, K, d_c) -> (B, M) int32.
    """
    if impl == "xla":
        return ref.unq_encode_ref(heads, codebooks)
    if impl == "pallas":
        padded, b = _pad_to(heads, block_b, axis=0)
        out = unq_encode_pallas(padded, codebooks, block_b=block_b,
                                interpret=_interpret())
        return out[:b]
    raise ValueError(f"unknown impl: {impl!r}")


def kv_adc_attention(q, k_codes, v_codes, k_books, v_books, length=None, *,
                     impl: str = "xla"):
    """Compressed-KV decode attention (see ref.kv_adc_attention_ref)."""
    if impl == "xla":
        return ref.kv_adc_attention_ref(q, k_codes, v_codes, k_books, v_books,
                                        length)
    raise ValueError(f"unknown impl: {impl!r}")
