"""Fused gather-decode-distance Pallas TPU kernel (the stage-2 engine).

The classic stage 2 gathers each query's candidate codes, decodes them to
full-dimensional reconstructions and reduces — materializing a (Q, L, D)
float tensor (~200 MB at Q=1024, L=500, D=96) that exists only to be
summed over D immediately. For table-decodable quantizers (PQ / OPQ /
RVQ: ``recon = sum_m table[m, code_m]``) this kernel streams (block_q,
block_l, M) uint8 candidate-code tiles HBM->VMEM, gathers sub-codewords
from the VMEM-resident (M, K, D) decode table via the same one-hot MXU
contraction the stage-1 scan uses, and reduces ``||q - recon||^2``
per (query, candidate) in place — the only reconstruction that ever
exists is the (block_q, block_l, D) VMEM tile.

Memory model per grid step (grid = (Q/block_q, L/block_l)):

  * the (M, K, D) decode table is replicated to every step and stays
    VMEM-resident (e.g. 8x256x96 f32 = 786 KB);
  * the (block_q, block_l, M) uint8 code tile and the (block_q, D) query
    block stream in (double-buffered by the grid);
  * output is the dense (block_q, block_l) distance tile — no top-k in
    the kernel, so no masking is needed: the wrapper slices padding off.

Exactness: the one-hot contraction sums exactly one non-zero term per
(candidate, m), so each partial equals the gathered table row bit-for-bit,
and the per-m accumulation is the same left-to-right chain as
``ref.decode_with_table`` — the kernel, the chunked ``lax.scan`` fallback
below, and the materialized oracle (``ref.rerank_gather_dist_ref``) are
bit-identical, not merely allclose.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

DEFAULT_RERANK_BLOCK_L = 128
DEFAULT_RERANK_BLOCK_Q = 8
# 64 beat 128/256/512 on CPU at Q=32, L=500, D=96 (BENCH_stage2.json);
# re-tune on real TPU hardware alongside the stage-1 blocks
DEFAULT_RERANK_CHUNK_L = 64


def _rerank_gather_dist_kernel(codes_ref, queries_ref, table_ref, out_ref,
                               *, block_l: int, block_q: int,
                               num_books: int, book_size: int):
    codes = codes_ref[...].astype(jnp.int32)           # (Bq, Bl, M)
    table = table_ref[...]                             # (M, K, D)
    dim = table.shape[-1]

    # --- decode: per-m one-hot MXU contraction against the resident
    # table. Exactly one non-zero per (q, l, k) row, so each partial is
    # bit-identical to the gather table[m][code] and the chained adds
    # reproduce ref.decode_with_table exactly. ---
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, 1, book_size), 2)
    acc = jnp.zeros((block_q, block_l, dim), jnp.float32)
    for m in range(num_books):                         # M is static (8 or 16)
        onehot = (codes[:, :, m:m + 1] == iota_k).astype(jnp.float32)
        acc = acc + jax.lax.dot_general(
            onehot, table[m].astype(jnp.float32),
            dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (Bq, Bl, D)

    # --- distance: reduce over D in VMEM; the (Bq, Bl, D) recon tile is
    # the only reconstruction that ever exists. ---
    diff = acc - queries_ref[...][:, None, :]
    out_ref[...] = jnp.sum(jnp.square(diff), axis=-1)


@functools.partial(jax.jit, static_argnames=("block_l", "block_q",
                                             "interpret"))
def rerank_gather_dist_pallas(cand_codes: jax.Array, queries: jax.Array,
                              table: jax.Array, *,
                              block_l: int = DEFAULT_RERANK_BLOCK_L,
                              block_q: int = DEFAULT_RERANK_BLOCK_Q,
                              interpret: bool = False) -> jax.Array:
    """Fused stage 2: d1 distances without a (Q, L, D) reconstruction.

    cand_codes: (Q, L, M) uint8/int32, Q % block_q == 0 and
                L % block_l == 0 (ops.py pads; pad rows/cols produce
                garbage distances the wrapper slices off).
    queries:    (Q, D) float32.
    table:      (M, K, D) float32 additive decode table
                (``ref.decode_with_table`` semantics).
    Returns d1 (Q, L) float32, bit-identical to
    ``ref.rerank_gather_dist_ref``.
    """
    q, l, num_books = cand_codes.shape
    _, book_size, dim = table.shape
    assert q % block_q == 0, f"Q={q} must be padded to a multiple of {block_q}"
    assert l % block_l == 0, f"L={l} must be padded to a multiple of {block_l}"
    grid = (q // block_q, l // block_l)
    kernel = functools.partial(
        _rerank_gather_dist_kernel, block_l=block_l, block_q=block_q,
        num_books=num_books, book_size=book_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_l, num_books),
                         lambda qi, li: (qi, li, 0)),
            pl.BlockSpec((block_q, dim), lambda qi, li: (qi, 0)),
            pl.BlockSpec((num_books, book_size, dim),
                         lambda qi, li: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_l), lambda qi, li: (qi, li)),
        out_shape=jax.ShapeDtypeStruct((q, l), jnp.float32),
        interpret=interpret,
    )(cand_codes, queries, table)


@functools.partial(jax.jit, static_argnames=("chunk_l",))
def rerank_gather_dist_chunked_xla(cand_codes: jax.Array, queries: jax.Array,
                                   table: jax.Array, *,
                                   chunk_l: int = DEFAULT_RERANK_CHUNK_L
                                   ) -> jax.Array:
    """XLA fallback with the SAME streaming semantics as the Pallas
    kernel: a ``lax.scan`` over (Q, chunk_l) candidate-code chunks, each
    decoded and reduced before the next chunk's reconstruction exists.
    Peak live reconstruction is O(Q * chunk_l * D) — the (Q, L, D) tensor
    is never built (asserted by the HLO test in tests/test_rerank.py).

    Exactness: distances are independent per (query, candidate) — the
    chunk split changes no reduction order inside any element — so the
    result is bit-identical to the materialized oracle.
    """
    q, l, m = cand_codes.shape
    pad = (-l) % chunk_l
    cc = jnp.pad(cand_codes, ((0, 0), (0, pad), (0, 0)))
    cc = jnp.moveaxis(cc.reshape(q, -1, chunk_l, m), 1, 0)  # (nc, Q, c, M)

    def step(_, chunk):
        recon = ref.decode_with_table(chunk, table)         # (Q, c, D)
        d = jnp.sum(jnp.square(recon - queries[:, None, :]), axis=-1)
        return None, d

    _, ds = jax.lax.scan(step, None, cc)                    # (nc, Q, c)
    return jnp.moveaxis(ds, 0, 1).reshape(q, -1)[:, :l]
