"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (tests sweep
shapes/dtypes and assert_allclose kernel-vs-oracle). They are also the
fallback implementation used on backends without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_scan_ref(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """Compressed-domain distance scan (paper Eq. 8, the ADC hot loop).

    codes: (N, M) integer codes (uint8/int32), lut: (M, K) float table with
    ``lut[m, k] = -<net(q)_m, c_mk>`` (or any per-codebook score table).
    Returns scores (N,): ``scores[n] = sum_m lut[m, codes[n, m]]``.

    The M accumulation is an explicit left-to-right chain (M is 8/16, so
    this unrolls to M-1 adds) — the same association the Pallas kernels
    use, which makes kernel-vs-oracle comparisons bit-exact instead of
    association-dependent.
    """
    m_idx = jnp.arange(lut.shape[0])[None, :]            # (1, M)
    gathered = lut[m_idx, codes.astype(jnp.int32)]       # (N, M)
    acc = gathered[:, 0]
    for m in range(1, lut.shape[0]):
        acc = acc + gathered[:, m]
    return acc


def adc_scan_batch_ref(codes: jax.Array, luts: jax.Array) -> jax.Array:
    """Multi-query ADC scan: codes (N, M), luts (Q, M, K) -> scores (Q, N).

    Defined as the vmap of the single-query oracle over the LUT axis, so
    per-query rows are bit-identical to ``adc_scan_ref`` — the batched
    kernel is validated against exactly this.
    """
    return jax.vmap(adc_scan_ref, in_axes=(None, 0))(codes, luts)


def adc_scan_topl_ref(codes: jax.Array, luts: jax.Array,
                      bias: jax.Array | None, topl: int):
    """Materialized oracle for the streaming scan+top-L: the full (Q, N)
    score matrix followed by ``lax.top_k``. Ground truth for the fused
    Pallas kernel and the chunked xla fallback — both must match this
    bit-for-bit in (score, index), including tie resolution (top_k breaks
    ties toward the smaller database index).

    codes (N, M), luts (Q, M, K), bias None | (N,) -> ((Q, L), (Q, L))
    with L = min(topl, N), sorted by (score asc, index asc).
    """
    scores = adc_scan_batch_ref(codes, luts)            # (Q, N)
    if bias is not None:
        scores = scores + bias[None, :]
    neg, idx = jax.lax.top_k(-scores, min(topl, codes.shape[0]))
    return -neg, idx


_IMAX = jnp.iinfo(jnp.int32).max


def adc_gather_topl_ref(codes: jax.Array, rows: jax.Array, gids: jax.Array,
                        luts: jax.Array, rowbias: jax.Array | None,
                        topl: int):
    """Materialized oracle for the gathered (IVF-style) scan+top-L.

    Instead of scanning the whole database, each query scans its own
    PER-QUERY slot list — the padded ragged batch an IVF index builds by
    concatenating the inverted lists of its probed cells:

      codes   (N, M)  the contiguous code buffer (cell-grouped for IVF);
      rows    (Q, W)  buffer rows to score for each query (pad slots may
                      repeat any valid row — they are masked via gids);
      gids    (Q, W)  the GLOBAL id each slot stands for (what search
                      returns); ``_IMAX`` marks pad slots, which score
                      +inf and can never surface as real candidates;
      rowbias (Q, W)  additive per-slot score term or None: the gathered
                      per-point bias (RVQ norms) and the lowered
                      filter-mask stream (+inf = filtered out);
      luts    (Q, M, K) per-query score tables.

    Per-slot scores use the same left-to-right M chain as ``adc_scan_ref``
    on the same code row, so a gathered slot is bit-identical to the same
    point's score in the flat scan — the whole IVF==flat-at-full-probe
    guarantee reduces to tie handling.

    CONTRACT: within each query row, ``gids`` must be ascending (pads
    last). Then ``lax.top_k``'s positional tie-break IS the
    ascending-global-id tie-break of the flat oracle, and the result is
    bit-identical to flat search restricted to the listed slots.

    Slots whose score is +inf (pads, filtered) are canonicalized to
    gid ``_IMAX`` so every implementation returns identical bits even
    when +inf entries surface (pool smaller than L); the index layer maps
    them to id -1.

    Returns (scores, gids), each (Q, min(topl, W)), sorted by
    (score asc, gid asc).
    """
    q, w = rows.shape
    m_idx = jnp.arange(luts.shape[1])[None, None, :]          # (1, 1, M)
    gathered_codes = jnp.take(codes, rows, axis=0).astype(jnp.int32)
    picked = jnp.take_along_axis(
        luts[:, None, :, :],                                  # (Q, 1, M, K)
        gathered_codes[:, :, :, None], axis=3)[..., 0]        # (Q, W, M)
    acc = picked[:, :, 0]
    for m in range(1, luts.shape[1]):                         # adc_scan_ref
        acc = acc + picked[:, :, m]                           # association
    if rowbias is not None:
        acc = acc + rowbias
    acc = jnp.where(gids == _IMAX, jnp.inf, acc)
    gids = jnp.where(jnp.isposinf(acc), _IMAX, gids)
    neg, pos = jax.lax.top_k(-acc, min(topl, w))
    return -neg, jnp.take_along_axis(gids, pos, axis=1)


def adc_dispatch_topl_ref(codes: jax.Array, gids_rows: jax.Array,
                          rowbias: jax.Array, luts: jax.Array,
                          cellterm: jax.Array, qidx: jax.Array,
                          cell_lo: jax.Array, cell_hi: jax.Array,
                          topl: int, qkeep: jax.Array | None = None):
    """Materialized oracle for the cell-batched dispatch scan+top-L.

    The MoE-routed IVF stage 1 flips the gathered face's roles: instead of
    each query gathering the rows of its probed cells, each probed CELL
    scores its contiguous code range once for the dense batch of queries
    routed to it:

      codes    (N, M)     the cell-grouped code buffer;
      gids_rows (N,)      buffer row -> global id;
      rowbias  (N,)       per-row additive stream (per-point bias, with
                          any (N,) filter mask already folded to +inf);
      luts     (Q, M, K)  per-query score tables;
      cellterm (E, cap)   per-(routed cell, slot) additive term (the
                          IVFADC per-(query, cell) residual correction);
      qidx     (E, cap)   each routed cell's query batch, -1 = empty slot;
      cell_lo/cell_hi (E,) each routed cell's buffer row range;
      qkeep    None | (Q, N) 0/1 keep stream in buffer-row column order
                          (the lowered per-query filter mask).

    Scores use the same left-to-right M chain as ``adc_scan_ref`` and the
    same bias-composition order as the padded plan
    (``chain + (rowbias + cellterm)``, keep mask applied after), so a
    routed slot is bit-identical to the same (query, point) score on the
    gathered path. Rows outside [lo, hi), empty slots and filtered rows
    score +inf with the canonical ``_IMAX`` gid.

    Deliberately materializes the (E, cap, N) score tensor — ground truth
    only. Returns (scores, gids), each (E, cap, min(topl, N)), every slot
    sorted by (score asc, global id asc): ``lax.top_k`` over ascending
    buffer rows IS that order, because rows within a cell ascend in
    global id (stable cell-grouping of add order).
    """
    n = codes.shape[0]
    num_q, num_books = luts.shape[0], luts.shape[1]
    safe_q = jnp.clip(qidx, 0, num_q - 1)
    lut_e = luts[safe_q]                                     # (E, cap, M, K)
    m_idx = jnp.arange(num_books)[None, None, None, :]
    picked = lut_e[
        jnp.arange(qidx.shape[0])[:, None, None, None],
        jnp.arange(qidx.shape[1])[None, :, None, None],
        m_idx, codes.astype(jnp.int32)[None, None, :, :]]    # (E, cap, N, M)
    acc = picked[..., 0]
    for m in range(1, num_books):                            # adc_scan_ref
        acc = acc + picked[..., m]                           # association
    acc = acc + (rowbias[None, None, :] + cellterm[..., None])
    if qkeep is not None:
        keep = jnp.take(qkeep, safe_q, axis=0)               # (E, cap, N)
        acc = jnp.where(keep > 0.5, acc, jnp.inf)
    rows = jnp.arange(n, dtype=jnp.int32)
    window = (rows[None, None, :] >= cell_lo[:, None, None]) & \
        (rows[None, None, :] < cell_hi[:, None, None])
    acc = jnp.where(window, acc, jnp.inf)
    acc = jnp.where((qidx >= 0)[..., None], acc, jnp.inf)
    gids = jnp.broadcast_to(gids_rows[None, None, :], acc.shape)
    gids = jnp.where(jnp.isposinf(acc), _IMAX, gids)
    neg, pos = jax.lax.top_k(-acc, min(topl, n))
    return -neg, jnp.take_along_axis(gids, pos, axis=-1)


def adc_scan_batch_q_ref(codes: jax.Array, qluts: jax.Array,
                         scale: jax.Array | None = None) -> jax.Array:
    """Quantized-LUT multi-query scan oracle (the reduced-precision pool
    selector of ``kernels/lut_quant.py``).

    codes (N, M) integer; qluts (Q, M, K) float16 (scale None) or int8
    with scale (Q, M) f32 per-(query, book) affine scales -> (Q, N) f32.

    The quantized score is ``sum_m f32(qlut[m, code_m])`` (fp16) or
    ``sum_m f32(q8[m, code_m]) * scale[m]`` (int8), accumulated with the
    same left-to-right chain as ``adc_scan_ref`` — each per-m part is
    converted/scaled elementwise BEFORE the chain, which is the exact op
    order of both kernel impls, so pools match bit-for-bit. The int8
    zero-point offset is per-query constant and deliberately omitted
    (rank-invariant; see lut_quant module doc).
    """
    m_idx = jnp.arange(qluts.shape[1])[None, :]              # (1, M)

    def one(lut_q, sc_q):
        g = lut_q[m_idx, codes.astype(jnp.int32)].astype(jnp.float32)
        parts = g if sc_q is None else g * sc_q[None, :]     # (N, M)
        acc = parts[:, 0]
        for m in range(1, qluts.shape[1]):
            acc = acc + parts[:, m]
        return acc

    if scale is None:
        return jax.vmap(lambda l: one(l, None))(qluts)
    return jax.vmap(one)(qluts, scale)


def adc_scan_topl_q_ref(codes: jax.Array, qluts: jax.Array,
                        scale: jax.Array | None,
                        bias: jax.Array | None, topl: int,
                        qbias: jax.Array | None = None):
    """Materialized oracle for the quantized streaming scan+top-L': the
    full quantized (Q, N) matrix (``adc_scan_batch_q_ref``), the SAME f32
    bias streams as the exact path, then ``lax.top_k``. Defines the pool
    the quantized kernels must select bit-for-bit."""
    s = adc_scan_batch_q_ref(codes, qluts, scale)
    if bias is not None:
        s = s + bias[None, :]
    if qbias is not None:
        s = s + qbias
    neg, idx = jax.lax.top_k(-s, min(topl, codes.shape[0]))
    return -neg, idx


def adc_gather_topl_q_ref(codes: jax.Array, rows: jax.Array,
                          gids: jax.Array, qluts: jax.Array,
                          scale: jax.Array | None,
                          rowbias: jax.Array | None, topl: int):
    """Materialized oracle for the quantized gathered scan+top-L': the
    quantized per-slot chain (fp16 gather->f32 or i8 gather->f32*scale,
    parts converted before the chain), the exact f32 rowbias stream, pad
    and +inf canonicalization exactly as ``adc_gather_topl_ref``."""
    q, w = rows.shape
    gathered_codes = jnp.take(codes, rows, axis=0).astype(jnp.int32)
    picked = jnp.take_along_axis(
        qluts[:, None, :, :],
        gathered_codes[:, :, :, None], axis=3)[..., 0]       # (Q, W, M)
    picked = picked.astype(jnp.float32)
    if scale is not None:
        picked = picked * scale[:, None, :]
    acc = picked[:, :, 0]
    for m in range(1, qluts.shape[1]):
        acc = acc + picked[:, :, m]
    if rowbias is not None:
        acc = acc + rowbias
    acc = jnp.where(gids == _IMAX, jnp.inf, acc)
    gids = jnp.where(jnp.isposinf(acc), _IMAX, gids)
    neg, pos = jax.lax.top_k(-acc, min(topl, w))
    return -neg, jnp.take_along_axis(gids, pos, axis=1)


def adc_dispatch_topl_q_ref(codes: jax.Array, gids_rows: jax.Array,
                            rowbias: jax.Array, qluts: jax.Array,
                            scale: jax.Array | None, cellterm: jax.Array,
                            qidx: jax.Array, cell_lo: jax.Array,
                            cell_hi: jax.Array, topl: int,
                            qkeep: jax.Array | None = None):
    """Materialized oracle for the quantized dispatch scan+top-L': the
    quantized chain per routed slot with the exact f32 bias composition
    ``chain + (rowbias + cellterm)`` and masks of
    ``adc_dispatch_topl_ref``."""
    n = codes.shape[0]
    num_q, num_books = qluts.shape[0], qluts.shape[1]
    safe_q = jnp.clip(qidx, 0, num_q - 1)
    lut_e = qluts[safe_q]                                    # (E, cap, M, K)
    m_idx = jnp.arange(num_books)[None, None, None, :]
    picked = lut_e[
        jnp.arange(qidx.shape[0])[:, None, None, None],
        jnp.arange(qidx.shape[1])[None, :, None, None],
        m_idx, codes.astype(jnp.int32)[None, None, :, :]]    # (E, cap, N, M)
    picked = picked.astype(jnp.float32)
    if scale is not None:
        picked = picked * scale[safe_q][:, :, None, :]
    acc = picked[..., 0]
    for m in range(1, num_books):
        acc = acc + picked[..., m]
    acc = acc + (rowbias[None, None, :] + cellterm[..., None])
    if qkeep is not None:
        keep = jnp.take(qkeep, safe_q, axis=0)               # (E, cap, N)
        acc = jnp.where(keep > 0.5, acc, jnp.inf)
    rows = jnp.arange(n, dtype=jnp.int32)
    window = (rows[None, None, :] >= cell_lo[:, None, None]) & \
        (rows[None, None, :] < cell_hi[:, None, None])
    acc = jnp.where(window, acc, jnp.inf)
    acc = jnp.where((qidx >= 0)[..., None], acc, jnp.inf)
    gids = jnp.broadcast_to(gids_rows[None, None, :], acc.shape)
    gids = jnp.where(jnp.isposinf(acc), _IMAX, gids)
    neg, pos = jax.lax.top_k(-acc, min(topl, n))
    return -neg, jnp.take_along_axis(gids, pos, axis=-1)


def decode_with_table(codes: jax.Array, table: jax.Array) -> jax.Array:
    """Additive table decode: ``recon = sum_m table[m, codes[..., m]]``.

    codes (..., M) integer, table (M, K, D) float32 -> (..., D).

    This is THE reconstruction the stage-2 rerank engine is defined over:
    PQ embeds each sub-codebook into its D-slice (zero elsewhere), OPQ
    additionally rotates each embedded sub-codeword, RVQ's codebooks are
    already full-dimensional. The M accumulation is an explicit
    left-to-right chain (like ``adc_scan_ref``) so the fused kernel, the
    chunked fallback, and the vmap oracle are bit-identical instead of
    association-dependent.
    """
    c = codes.astype(jnp.int32)
    acc = table[0][c[..., 0]]
    for m in range(1, table.shape[0]):
        acc = acc + table[m][c[..., m]]
    return acc


def rerank_gather_dist_ref(cand_codes: jax.Array, queries: jax.Array,
                           table: jax.Array) -> jax.Array:
    """Materialized oracle for the fused gather-decode-distance kernel
    (stage 2, paper Eq. 7 over a table-decodable quantizer).

    cand_codes (Q, L, M) integer candidate codes (already gathered from
    the database by candidate id), queries (Q, D), table (M, K, D) ->
    d1 distances (Q, L): ``||q - sum_m table[m, code_m]||^2``.

    Deliberately materializes the (Q, L, D) reconstruction — it is the
    ground truth the streaming paths are validated against bit-for-bit.
    """
    recon = decode_with_table(cand_codes, table)         # (Q, L, D)
    return jnp.sum(jnp.square(recon - queries[:, None, :]), axis=-1)


def unq_encode_ref(heads: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Codeword assignment (paper Eq. 4).

    heads: (B, M, d_c) = net(x); codebooks: (M, K, d_c).
    Returns codes (B, M) int32: argmax_k <heads[b, m], codebooks[m, k]>.
    """
    scores = jnp.einsum("bmd,mkd->bmk", heads, codebooks)
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


def kv_adc_attention_ref(q: jax.Array, k_codes: jax.Array, v_codes: jax.Array,
                         k_books: jax.Array, v_books: jax.Array,
                         length: jax.Array | int | None = None) -> jax.Array:
    """Beyond-paper: single-step decode attention over an MCQ-compressed KV
    cache, entirely in the compressed domain.

    The attention logit against a compressed key IS the paper's d2 scan:
        q . k_s  ~=  sum_m <q_m, cK_{m, i_{s,m}}>
    and the value aggregation folds the softmax weights into a per-codeword
    histogram before a single (M*K, d) matmul:
        sum_s w_s v_s ~= sum_m sum_k (sum_{s: code=k} w_s) cV_{m,k}
    so the per-token work is O(M) adds instead of O(d) MACs.

    q:        (H, d)         query for one new token (per kv-head group or head)
    k_codes:  (S, H, M) int  compressed keys
    v_codes:  (S, H, M) int  compressed values
    k_books:  (H, M, K, d/M) key codebooks (PQ-style subspace split)
    v_books:  (H, M, K, d/M) value codebooks
    length:   optional valid prefix length (<= S) for masking.
    Returns attention output (H, d).
    """
    H, d = q.shape
    S, _, M = k_codes.shape
    K = k_books.shape[2]
    d_sub = d // M
    q_sub = q.reshape(H, M, d_sub)

    # LUT build: one pass, O(H*M*K*d_sub) — independent of S.
    lut = jnp.einsum("hms,hmks->hmk", q_sub, k_books)            # (H, M, K)

    # ADC scan over the cache: O(S*H*M) lookups.
    m_idx = jnp.arange(M)[None, None, :]
    h_idx = jnp.arange(H)[None, :, None]
    logits = jnp.sum(lut[h_idx, m_idx, k_codes.astype(jnp.int32)], axis=-1)  # (S, H)

    if length is not None:
        mask = jnp.arange(S)[:, None] < length
        logits = jnp.where(mask, logits, -jnp.inf)

    w = jax.nn.softmax(logits / jnp.sqrt(d).astype(logits.dtype), axis=0)  # (S, H)

    # Compressed-domain value aggregation: scatter weights into (H, M, K).
    onehot = jax.nn.one_hot(v_codes.astype(jnp.int32), K, dtype=w.dtype)  # (S,H,M,K)
    hist = jnp.einsum("sh,shmk->hmk", w, onehot)                           # (H, M, K)
    out_sub = jnp.einsum("hmk,hmks->hms", hist, v_books)                   # (H, M, d_sub)
    return out_sub.reshape(H, d)
