"""Sweep driver for the kernel block-config autotuner.

``repro.kernels.tune`` owns the registry (tunable kernels, parameter
ladders, shape buckets) and the persistent winner cache that
``ops.py`` resolves every block parameter through. This package is the
part that actually RUNS: for each registered (kernel, impl) and each
shape bucket it builds representative device-resident inputs once,
times the hand-pinned default and every candidate ladder point
(min-of-repeats wall time around a ``block_until_ready`` boundary),
and records the winner.

Sweep discipline (what makes cached winners trustworthy):

  * the DEFAULT config is always timed first and is the initial
    incumbent, so a recorded winner is never slower than the
    hand-pinned fallback beyond timing noise;
  * a challenger must beat the incumbent by ``tune.HYSTERESIS`` to
    replace it — re-sweeping on the same machine reproduces the same
    winners (the determinism assertion ``--quick`` enforces);
  * an existing cache entry is re-timed as the incumbent before the
    grid, so re-sweeps refine rather than thrash;
  * Pallas impls are skipped when the kernels would run in interpret
    mode (off-TPU default): interpret wall time says nothing about the
    compiled kernel, and a winner measured there would poison the
    cache for the real device.

``python -m repro.tune`` is the CLI (see ``__main__``): ``--quick``
sweeps one bucket per kernel and is the ci.sh smoke, the default mode
sweeps the full bucket ladder, ``--validate`` checks an existing cache
against the schema.
"""
from __future__ import annotations

import itertools
import math
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, tune

_M, _K = 8, 256           # codebook geometry shared by every builder
_D = 64                   # rerank reconstruction dim
_TOPL = 128               # dispatch sweeps (no topl dim in its bucket key)
_SEED = 0

#: one bucket per kernel — the ci.sh smoke ladder, aligned with the
#: quick-scale bench shapes so bench rows exercise the swept bucket
QUICK_BUCKETS = {
    "adc_scan_topl.pallas": ({"n": 65536, "q": 32, "topl": 128},),
    "adc_scan_topl.xla": ({"n": 65536, "q": 32, "topl": 128},),
    "adc_gather_topl.pallas": ({"w": 8192, "q": 32, "topl": 128},),
    "adc_gather_topl.xla": ({"w": 8192, "q": 32, "topl": 128},),
    "adc_dispatch_topl": ({"n": 65536, "q": 32},),
    "rerank_gather_dist.pallas": ({"l": 1024, "q": 32, "d": _D},),
    "rerank_gather_dist.xla": ({"l": 1024, "q": 32, "d": _D},),
}

#: the full ladder: quick's buckets plus one size step up per kernel
FULL_BUCKETS = {
    key: buckets + extra for key, buckets, extra in (
        ("adc_scan_topl.pallas", QUICK_BUCKETS["adc_scan_topl.pallas"],
         ({"n": 262144, "q": 32, "topl": 128},)),
        ("adc_scan_topl.xla", QUICK_BUCKETS["adc_scan_topl.xla"],
         ({"n": 262144, "q": 32, "topl": 128},)),
        ("adc_gather_topl.pallas", QUICK_BUCKETS["adc_gather_topl.pallas"],
         ({"w": 32768, "q": 32, "topl": 128},)),
        ("adc_gather_topl.xla", QUICK_BUCKETS["adc_gather_topl.xla"],
         ({"w": 32768, "q": 32, "topl": 128},)),
        ("adc_dispatch_topl", QUICK_BUCKETS["adc_dispatch_topl"],
         ({"n": 262144, "q": 32},)),
        ("rerank_gather_dist.pallas",
         QUICK_BUCKETS["rerank_gather_dist.pallas"],
         ({"l": 4096, "q": 32, "d": _D},)),
        ("rerank_gather_dist.xla", QUICK_BUCKETS["rerank_gather_dist.xla"],
         ({"l": 4096, "q": 32, "d": _D},)),
    )
}


# ---------------------------------------------------------------------------
# per-kernel input builders + runner factories
# ---------------------------------------------------------------------------

def _build_scan(dims):
    rng = np.random.default_rng(_SEED)
    n, q = dims["n"], dims["q"]
    return {
        "codes": jnp.asarray(rng.integers(0, _K, (n, _M), dtype=np.uint8)),
        "luts": jnp.asarray(
            rng.standard_normal((q, _M, _K), dtype=np.float32)),
        "bias": jnp.asarray(rng.standard_normal((n,), dtype=np.float32)),
    }


def _make_scan(impl):
    def make(inputs, dims, config):
        def fn():
            jax.block_until_ready(ops.adc_scan_topl(
                inputs["codes"], inputs["luts"], topl=dims["topl"],
                bias=inputs["bias"], impl=impl,
                block_n=config.get("block_n"),
                block_q=config.get("block_q"),
                chunk_n=config.get("chunk_n")))
        return fn
    return make


def _build_gather(dims):
    rng = np.random.default_rng(_SEED)
    w, q = dims["w"], dims["q"]
    nbuf = 2 * w
    return {
        "codes": jnp.asarray(rng.integers(0, _K, (nbuf, _M),
                                          dtype=np.uint8)),
        "rows": jnp.asarray(rng.integers(0, nbuf, (q, w), dtype=np.int32)),
        # ascending within each row — the gathered-path plan contract
        "gids": jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32), (q, w)),
        "luts": jnp.asarray(
            rng.standard_normal((q, _M, _K), dtype=np.float32)),
    }


def _make_gather(impl):
    def make(inputs, dims, config):
        def fn():
            jax.block_until_ready(ops.adc_gather_topl(
                inputs["codes"], inputs["rows"], inputs["gids"],
                inputs["luts"], topl=dims["topl"], impl=impl,
                block_w=config.get("block_w"),
                block_q=config.get("block_q"),
                chunk_w=config.get("chunk_w")))
        return fn
    return make


def _build_dispatch(dims):
    rng = np.random.default_rng(_SEED)
    n, q = dims["n"], dims["q"]
    nlist, nprobe = 64, 8
    assert n % nlist == 0
    offsets = np.arange(nlist + 1, dtype=np.int32) * (n // nlist)
    probe = np.sort(np.stack([
        rng.choice(nlist, size=nprobe, replace=False)
        for _ in range(q)]).astype(np.int32), axis=1)
    return {
        "codes": jnp.asarray(rng.integers(0, _K, (n, _M), dtype=np.uint8)),
        "gids_rows": jnp.arange(n, dtype=jnp.int32),
        "luts": jnp.asarray(
            rng.standard_normal((q, _M, _K), dtype=np.float32)),
        "probe": probe,
        "offsets": offsets,
    }


def _make_dispatch(impl):
    def make(inputs, dims, config):
        # the plan bakes the tile width in, so routing is rebuilt per
        # candidate — host-side, outside the timed region
        from repro.index.dispatch import build_dispatch
        routing, _ = build_dispatch(inputs["probe"], inputs["offsets"],
                                    chunk=config["chunk"])
        cellterm = jnp.zeros(routing.plan.qidx.shape, jnp.float32)

        def fn():
            jax.block_until_ready(ops.adc_dispatch_topl(
                inputs["codes"], inputs["gids_rows"], None, inputs["luts"],
                cellterm, routing.plan, topl=_TOPL, impl=impl,
                chunk=routing.chunk))
        return fn
    return make


def _build_rerank(dims):
    rng = np.random.default_rng(_SEED)
    l, q, d = dims["l"], dims["q"], dims["d"]
    return {
        "cand_codes": jnp.asarray(
            rng.integers(0, _K, (q, l, _M), dtype=np.int32)),
        "queries": jnp.asarray(
            rng.standard_normal((q, d), dtype=np.float32)),
        "table": jnp.asarray(
            rng.standard_normal((_M, _K, d), dtype=np.float32)),
    }


def _make_rerank(impl):
    def make(inputs, dims, config):
        def fn():
            jax.block_until_ready(ops.rerank_gather_dist(
                inputs["cand_codes"], inputs["queries"], inputs["table"],
                impl=impl,
                block_l=config.get("block_l"),
                block_q=config.get("block_q"),
                chunk_l=config.get("chunk_l")))
        return fn
    return make


def _dispatch_impl() -> str:
    """The impl the dispatch sweep times: the compiled Pallas kernel on
    TPU, the xla stream everywhere interpret mode would apply."""
    return "pallas" if (ops._on_tpu() and not ops._interpret()) else "xla"


#: registry key -> (input builder, runner factory); the runner factory
#: returns ``make(inputs, dims, config) -> zero-arg timed callable``
RUNNERS = {
    "adc_scan_topl.pallas": (_build_scan, _make_scan("pallas")),
    "adc_scan_topl.xla": (_build_scan, _make_scan("xla")),
    "adc_gather_topl.pallas": (_build_gather, _make_gather("pallas")),
    "adc_gather_topl.xla": (_build_gather, _make_gather("xla")),
    "adc_dispatch_topl": (_build_dispatch, None),   # impl picked at run time
    "rerank_gather_dist.pallas": (_build_rerank, _make_rerank("pallas")),
    "rerank_gather_dist.xla": (_build_rerank, _make_rerank("xla")),
}


# ---------------------------------------------------------------------------
# timing + the sweep proper
# ---------------------------------------------------------------------------

def _time_round_robin(fns: list, repeats: int) -> list[float]:
    """Interleaved min-of-rounds wall times in microseconds: one untimed
    call per fn absorbs compilation, then ``repeats`` rounds visit every
    fn, SHUFFLED each round under a fixed seed. Interleaving is what
    makes winners reproducible on the same machine — ambient drift (CPU
    frequency, cache pressure, VM steal) hits all candidates equally
    instead of biasing whichever one happened to run during a quiet
    window. The shuffle matters too: a fixed cyclic order gives every
    candidate a FIXED predecessor (warm or cold caches), and inserting
    the cached incumbent into the list — as a re-sweep does — would
    shift every candidate's predecessor, enough to flip near-tied
    configs between a sweep and its determinism re-check."""
    for fn in fns:
        fn()
    best = [math.inf] * len(fns)
    order = list(range(len(fns)))
    shuffle = random.Random(0x5eed).shuffle
    for _ in range(max(repeats, 1)):
        shuffle(order)
        for i in order:
            t0 = time.perf_counter()
            fns[i]()
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e6 for b in best]


def _skip(key: str) -> str | None:
    """Reason this registry key cannot be meaningfully swept here."""
    if key.endswith(".pallas") and ops._interpret():
        return "pallas interpret mode — compiled timings unavailable"
    return None


def sweep_bucket(key: str, dims: dict, *, repeats: int,
                 incumbent: dict | None = None, log=print) -> dict:
    """Sweep one (kernel, shape bucket): returns the cache entry
    ``{"config", "us", "default_us"}`` with the winner config covering
    every registered parameter.

    All configs (default, cached incumbent, ladder candidates) are timed
    round-robin in ONE interleaved pass; the incumbent then only needs to
    be merely fastest to stay (it already cleared the hysteresis bar when
    first cached), while a challenger must beat the incumbent (or, fresh,
    the default) by the ``tune.HYSTERESIS`` margin to replace it. The bar
    is fixed at the baseline — among challengers that clear it the plain
    argmin wins, so the candidate ladder's ORDER never decides: a bar
    re-anchored at each successive winner would make a config sitting
    right at ``HYSTERESIS x`` its neighbor a fresh-sweep coin flip that
    the determinism self-check then catches as an incumbent flip."""
    spec = tune.KERNELS[key]
    build, make = RUNNERS[key]
    if make is None:
        make = _make_dispatch(_dispatch_impl())
    inputs = build(dims)

    default_cfg = dict(spec.params)
    incumbent_cfg = {**default_cfg, **incumbent} if incumbent else None
    if incumbent_cfg == default_cfg:
        incumbent_cfg = None
    configs = [default_cfg] + ([incumbent_cfg] if incumbent_cfg else [])
    names = sorted(spec.candidates)
    for values in itertools.product(*(spec.candidates[n] for n in names)):
        cfg = {**default_cfg, **dict(zip(names, values))}
        if cfg not in configs:
            configs.append(cfg)

    times = _time_round_robin(
        [make(inputs, dims, cfg) for cfg in configs], repeats)
    default_us = times[0]
    best_cfg, best_us = default_cfg, default_us
    log(f"    default {default_cfg} -> {default_us:.1f}us")
    if incumbent_cfg:
        us = times[1]
        log(f"    cached  {incumbent_cfg} -> {us:.1f}us")
        if us < best_us:
            best_cfg, best_us = incumbent_cfg, us
    bar = best_us * tune.HYSTERESIS
    for cfg, us in zip(configs, times):
        if cfg in (default_cfg, incumbent_cfg):
            continue
        if us < bar and us < best_us:
            log(f"    winner  {cfg} -> {us:.1f}us")
            best_cfg, best_us = cfg, us
    return {"config": best_cfg, "us": round(best_us, 1),
            "default_us": round(default_us, 1)}


def run_sweep(buckets: dict, *, repeats: int, doc: dict | None = None,
              log=print) -> dict:
    """Sweep every (key, bucket) in ``buckets`` and fold the winners into
    a cache document (existing entries become incumbents). Returns the
    updated document; the caller saves it."""
    if doc is None:
        doc = {"schema_version": tune.SCHEMA_VERSION, "entries": {}}
    dk = tune.device_kind()
    mine = doc.setdefault("entries", {}).setdefault(dk, {})
    for key, bucket_list in buckets.items():
        reason = _skip(key)
        if reason:
            log(f"  SKIP {key}: {reason}")
            continue
        spec = tune.KERNELS[key]
        if not spec.candidates:
            log(f"  SKIP {key}: defaults-only registration (no ladder)")
            continue
        for dims in bucket_list:
            bkey = tune.bucket_key(spec, dims)
            log(f"  {key} [{bkey}]")
            cached = mine.get(key, {}).get(bkey)
            entry = sweep_bucket(
                key, dims, repeats=repeats,
                incumbent=cached["config"] if cached else None, log=log)
            mine.setdefault(key, {})[bkey] = entry
    tune.validate(doc)
    return doc
