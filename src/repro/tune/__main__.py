"""``python -m repro.tune`` — run the kernel block-config sweep.

Modes:

  (default)     sweep the full bucket ladder (``FULL_BUCKETS``) and fold
                the winners into the cache (``TUNE_CACHE.json`` at the
                repo root, or ``--out`` / ``REPRO_TUNE_CACHE``);
  --quick       the ci.sh smoke: one bucket per kernel at the quick-scale
                bench shapes, then three self-checks —
                  roundtrip     save -> reload reproduces the document,
                  determinism   an immediate re-sweep (winners seeded as
                                incumbents behind the hysteresis margin)
                                reproduces the same configs,
                  schema drift  a cache with a foreign schema_version
                                MUST raise ``TuneCacheError``;
                any failed self-check exits non-zero;
  --validate    load + schema-check an existing cache, print the
                fingerprint, exit non-zero on drift.

The sweep never runs Pallas impls in interpret mode (winners measured
there would poison the cache for the real device) — those entries are
skipped with a visible reason.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile

from repro.kernels import tune
from repro.tune import FULL_BUCKETS, QUICK_BUCKETS, run_sweep


def _check_roundtrip(doc: dict, path: pathlib.Path) -> list[str]:
    reloaded = tune.load_cache(path, refresh=True)
    if reloaded != doc:
        return [f"roundtrip: reloaded cache differs from swept document "
                f"({path})"]
    return []


def _check_determinism(doc: dict, *, repeats: int) -> list[str]:
    """Re-sweep with the winners as incumbents: hysteresis must keep
    every config stable on the same machine."""
    before = json.loads(json.dumps(doc))    # deep copy
    after = run_sweep(QUICK_BUCKETS, repeats=repeats, doc=doc,
                      log=lambda *_: None)
    errors = []
    for dk, kernels in before.get("entries", {}).items():
        for key, buckets in kernels.items():
            for bkey, entry in buckets.items():
                got = after["entries"][dk][key][bkey]["config"]
                if got != entry["config"]:
                    errors.append(
                        f"determinism: {key}[{bkey}] flipped "
                        f"{entry['config']} -> {got}")
    return errors


def _check_schema_drift() -> list[str]:
    """A cache written by a different build MUST fail loudly."""
    drifted = {"schema_version": tune.SCHEMA_VERSION + 1, "entries": {}}
    with tempfile.NamedTemporaryFile("w", suffix=".json",
                                     delete=False) as f:
        json.dump(drifted, f)
        p = pathlib.Path(f.name)
    try:
        tune.load_cache(p, refresh=True)
        return ["schema drift: foreign schema_version was ACCEPTED "
                "(load_cache must raise TuneCacheError)"]
    except tune.TuneCacheError:
        return []
    finally:
        p.unlink(missing_ok=True)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="kernel block-config sweep: time candidate ladders "
                    "per shape bucket, persist winners for "
                    "tune.best_config")
    parser.add_argument("--quick", action="store_true",
                        help="one bucket per kernel + self-checks "
                             "(the ci.sh smoke)")
    parser.add_argument("--validate", action="store_true",
                        help="schema-check an existing cache and exit")
    parser.add_argument("--out", default=None,
                        help="cache path (default: repo TUNE_CACHE.json "
                             "or $REPRO_TUNE_CACHE)")
    parser.add_argument("--repeats", type=int, default=None,
                        help="timing repeats per candidate "
                             "(min-of-repeats; default 5)")
    args = parser.parse_args(argv)
    path = pathlib.Path(args.out) if args.out else tune.cache_path()

    if args.validate:
        try:
            doc = tune.load_cache(path, refresh=True)
        except tune.TuneCacheError as e:
            print(f"INVALID {path}: {e}")
            return 1
        print(f"ok {path}")
        n = sum(len(b) for k in doc.get("entries", {}).values()
                for b in k.values())
        print(f"  schema_version: {doc.get('schema_version')}")
        print(f"  tuned buckets (all devices): {n}")
        return 0

    repeats = args.repeats or 5
    buckets = QUICK_BUCKETS if args.quick else FULL_BUCKETS
    try:
        doc = tune.load_cache(path, refresh=True)
    except tune.TuneCacheError as e:
        print(f"existing cache invalid, starting fresh: {e}")
        doc = None

    print(f"== sweep ({'quick' if args.quick else 'full'}, "
          f"repeats={repeats}, device={tune.device_kind()}) ==")
    doc = run_sweep(buckets, repeats=repeats, doc=doc)
    tune.save_cache(doc, path)
    print(f"saved {path}")

    if not args.quick:
        return 0

    print("== self-checks ==")
    errors = []
    errors += _check_roundtrip(doc, path)
    errors += _check_determinism(doc, repeats=repeats)
    errors += _check_schema_drift()
    # determinism may legitimately re-time entries; persist the final doc
    tune.save_cache(doc, path)
    for name in ("roundtrip", "determinism", "schema drift"):
        status = ("FAIL" if any(e.startswith(name.split()[0]) for e in errors)
                  else "ok")
        print(f"  {status:4s} {name}")
    for e in errors:
        print(f"  {e}")
    if errors:
        print(f"quick sweep: {len(errors)} self-check failure(s)")
        return 1
    mine = doc.get("entries", {}).get(tune.device_kind(), {})
    print(f"  tuned buckets for {tune.device_kind()}: "
          f"{sum(len(b) for b in mine.values())}")
    print("quick sweep: all self-checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
