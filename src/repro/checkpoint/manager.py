"""Fault-tolerant checkpointing (orbax is not available offline; this is a
self-contained equivalent with the properties that matter at scale):

  * atomic: writes go to ``step_<N>.tmp`` and are renamed only after fsync —
    a job killed mid-save can never leave a corrupt "latest" checkpoint.
  * self-describing: a manifest carries the pytree structure, shapes,
    dtypes, step and user metadata (data-pipeline state rides along, so
    restarts resume the stream exactly).
  * elastic: ``restore(..., shardings=...)`` re-device_puts every leaf onto
    the *current* mesh, which may have a different device count than the
    mesh that saved it (the host roundtrip is the reshard).
  * bounded: keeps the newest ``keep`` checkpoints.
  * async: ``save(..., blocking=False)`` snapshots to host then writes on a
    background thread so the train loop overlaps I/O with compute.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np

from repro.utils.pytree import tree_flatten_with_names


def _to_host(tree: Any) -> list[tuple[str, np.ndarray, str]]:
    """Returns (name, storable array, original dtype str) per leaf.

    np.savez cannot serialize ml_dtypes (bfloat16/f8); those are widened to
    f32 losslessly and cast back on load via the manifest dtype."""
    named = tree_flatten_with_names(tree)
    out = []
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        orig = str(arr.dtype)
        if arr.dtype.kind == "V" or orig in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            orig = str(jax.numpy.asarray(leaf).dtype)
            arr = arr.astype(np.float32)
        out.append((name, arr, orig))
    return out


def save_pytree(path: pathlib.Path, tree: Any, *, step: int = 0,
                metadata: dict | None = None) -> None:
    """Atomic single-checkpoint save to ``path`` (a directory)."""
    path = pathlib.Path(path)
    tmp = path.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    named = _to_host(tree)
    arrays = {f"a{i}": arr for i, (_, arr, _) in enumerate(named)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "names": [n for n, _, _ in named],
        "shapes": [list(a.shape) for _, a, _ in named],
        "dtypes": [dt for _, _, dt in named],
        "metadata": metadata or {},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if path.exists():
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: pathlib.Path, like: Any, *, shardings: Any = None):
    """Restore into the structure of ``like``; optionally reshard.

    ``like`` may be a pytree of arrays or ShapeDtypeStructs (its leaves are
    only used for structure). Leaf order is validated against the manifest
    names, so structural drift fails loudly instead of silently permuting.
    """
    path = pathlib.Path(path)
    with open(path / "manifest.json") as f:
        manifest = json.load(f)
    data = np.load(path / "arrays.npz")
    arrays = [data[f"a{i}"] for i in range(len(manifest["names"]))]

    named = tree_flatten_with_names(like)
    if [n for n, _ in named] != manifest["names"]:
        raise ValueError(
            "checkpoint structure mismatch:\n"
            f"  saved:   {manifest['names'][:5]}...\n"
            f"  current: {[n for n, _ in named][:5]}...")
    # restore original dtypes (bf16/f8 were widened to f32 for npz)
    arrays = [a if str(a.dtype) == dt else a.astype(jax.numpy.dtype(dt))
              for a, dt in zip(arrays, manifest["dtypes"])]
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))
        restored = [
            jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
            for a, s in zip(arrays, shard_leaves)
        ]
    else:
        restored = [jax.numpy.asarray(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, restored), manifest


class CheckpointManager:
    """Step-numbered checkpoint directory with auto-resume + retention."""

    def __init__(self, root: str | pathlib.Path, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def _step_dir(self, step: int) -> pathlib.Path:
        return self.root / f"step_{step:010d}"

    def all_steps(self) -> list[int]:
        steps = []
        for p in self.root.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                steps.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, *, metadata: dict | None = None,
             blocking: bool = True) -> None:
        self.wait()
        if blocking:
            save_pytree(self._step_dir(step), tree, step=step,
                        metadata=metadata)
            self._gc()
            return
        # snapshot to host synchronously (cheap), write asynchronously
        host_tree = jax.tree.map(lambda x: jax.device_get(x), tree)

        def _write():
            save_pytree(self._step_dir(step), host_tree, step=step,
                        metadata=metadata)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def restore_latest(self, like: Any, *, shardings: Any = None):
        """Returns (tree, manifest) from the newest checkpoint or None."""
        self.wait()
        step = self.latest_step()
        if step is None:
            return None
        return load_pytree(self._step_dir(step), like, shardings=shardings)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
