"""Unified architecture config consumed by every model family."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "transformer"   # transformer | rwkv6 | griffin
    kind: str = "decoder"         # decoder | encoder

    # --- common dims ---
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4         # GQA; == num_heads -> MHA, 1 -> MQA
    head_dim: int | None = None   # default d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    act: str = "silu"             # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    rope_theta: float = 1e4
    qk_norm: bool = False         # chameleon-style QK layernorm
    tie_embeddings: bool = False
    use_post_norm: bool = False   # gemma-style post-block norms
    embed_scale: bool = False     # gemma-style sqrt(d) embedding scaling

    # --- attention pattern ---
    window: int | None = None          # sliding window for "local" layers
    local_global_ratio: int = 0        # gemma3: N local layers per 1 global
    attn_chunk: int = 512              # kv-chunk for flash-chunked attention

    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    min_capacity: int = 4          # floor so tiny (decode) batches never drop
    router_balance: str = "cv2"        # cv2 (paper-lineage) | switch
    moe_ep: bool = False               # explicit shard_map expert-parallel
                                       # dispatch (perf path; needs a mesh)
    first_dense: int = 1               # leading dense layers (deepseek-moe)
    moe_d_ff: int = 0                  # routed-expert hidden (fine-grained)

    # --- recurrent (rwkv6 / griffin) ---
    rwkv_chunk: int = 0                # 0 = sequential wkv scan (paper-
                                       # faithful baseline); >0 = chunked
                                       # parallel formulation (perf path)
    rnn_width: int = 0                 # RG-LRU width (griffin)
    conv_width: int = 4                # griffin temporal conv
    attn_every: int = 3                # griffin: 1 attention per this many

    # --- audio/vlm frontend stubs ---
    input_mode: str = "tokens"         # tokens | frames (hubert stub)
    frame_dim: int = 0                 # stub frame embedding dim

    # --- numerics / memory ---
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "layer"               # none | layer (checkpoint each block)

    # --- paper technique integration ---
    kvq: bool = False                  # UNQ/MCQ-compressed KV cache (decode)
    kvq_books: int = 8                 # M per head-vector
    kvq_book_size: int = 256           # K

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def q_rep(self) -> int:
        return self.num_heads // self.num_kv_heads
