"""Griffin / RecurrentGemma (arXiv:2402.19427) — hybrid RG-LRU + local
attention, 1 attention layer per 2 recurrent layers.

Layer pattern for 26 layers: 8 scanned groups of (recurrent, recurrent,
local-attention) + 2 trailing recurrent layers. The RG-LRU recurrence

    a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t + b_a))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is elementwise, so training uses ``lax.associative_scan`` (log-depth
parallel scan) rather than a sequential loop. The temporal block is
input-proj -> causal depthwise conv (width 4) -> RG-LRU -> gated output.

Decode state is O(1) per recurrent layer (LRU state + conv tail) plus a
bounded ring-buffer KV cache (window 2048) per attention layer — which is
why this arch runs the long_500k cell natively.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers
from repro.parallel import hints

LRU_C = 8.0  # the fixed "c" constant from the paper


def _rnn_width(cfg: ModelConfig) -> int:
    return cfg.rnn_width or cfg.d_model


def _pattern(cfg: ModelConfig) -> tuple[int, int]:
    """Returns (num_groups, num_trailing_recurrent)."""
    group = cfg.attn_every                       # rec, rec, attn
    ng = cfg.num_layers // group
    return ng, cfg.num_layers - ng * group


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_recurrent(key, cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    w = _rnn_width(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_x": layers.dense_init(ks[0], (d, w), dt),
        "w_gate": layers.dense_init(ks[1], (d, w), dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        # RG-LRU gates (dense projections) + per-channel Lambda
        "w_a": layers.dense_init(ks[3], (w, w), dt),
        "b_a": jnp.zeros((w,), dt),
        "w_i": layers.dense_init(ks[4], (w, w), dt),
        "b_i": jnp.zeros((w,), dt),
        # softplus(lambda_p) ~ 0.7 -> decay ~ exp(-8*0.7*0.5) at mid-gate
        "lambda_p": jnp.full((w,), 0.15, dt),
        "w_out": layers.dense_init(ks[5], (w, d), dt),
    }


def _recurrent_axes(cfg: ModelConfig):
    return {
        "w_x": ("embed", "rnn"), "w_gate": ("embed", "rnn"),
        "conv_w": (None, "rnn"), "conv_b": ("rnn",),
        "w_a": ("embed", "rnn"), "b_a": ("rnn",),
        "w_i": ("embed", "rnn"), "b_i": ("rnn",),
        "lambda_p": ("rnn",), "w_out": ("rnn", "embed"),
    }


def _init_block(key, cfg: ModelConfig, kind: str):
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
         "ln2": jnp.zeros((cfg.d_model,), cfg.param_dtype)}
    if kind == "rec":
        p["rec"] = _init_recurrent(k1, cfg)
    else:
        p["attn"] = layers.init_attn(k1, cfg)
    p["mlp"] = layers.init_mlp(k2, cfg)
    return p


def init(key, cfg: ModelConfig):
    ng, trailing = _pattern(cfg)
    k_emb, kg, kt = jax.random.split(key, 3)
    gkeys = jax.random.split(kg, ng * 3).reshape(ng, 3, 2)

    def group_init(keys3):
        return {
            "rec0": _init_block(keys3[0], cfg, "rec"),
            "rec1": _init_block(keys3[1], cfg, "rec"),
            "attn": _init_block(keys3[2], cfg, "attn"),
        }

    params = {
        "embed": layers.embed_init(k_emb, cfg.vocab_size, cfg.d_model,
                                   cfg.param_dtype),
        "groups": jax.vmap(group_init)(gkeys),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    if trailing:
        tkeys = jax.random.split(kt, trailing)
        params["trailing"] = jax.vmap(
            lambda k: _init_block(k, cfg, "rec"))(tkeys)
    return params


def logical_axes(cfg: ModelConfig):
    ng, trailing = _pattern(cfg)
    rec_block = {"ln1": (None,), "ln2": (None,),
                 "rec": _recurrent_axes(cfg), "mlp": layers.mlp_axes(cfg)}
    attn_block = {"ln1": (None,), "ln2": (None,),
                  "attn": layers.attn_axes(cfg), "mlp": layers.mlp_axes(cfg)}
    group = {"rec0": rec_block, "rec1": rec_block, "attn": attn_block}
    stack = lambda tree: jax.tree.map(
        lambda ax: ("layers",) + tuple(ax), tree,
        is_leaf=lambda x: isinstance(x, tuple))
    axes = {"embed": ("vocab", "embed"), "groups": stack(group),
            "final_norm": (None,)}
    if trailing:
        axes["trailing"] = stack(rec_block)
    return axes


# ---------------------------------------------------------------------------
# RG-LRU + temporal block
# ---------------------------------------------------------------------------

def _causal_conv(p, x, tail=None):
    """Depthwise causal conv width W. x: (B, T, w). tail: (B, W-1, w) state.

    Returns (y (B, T, w), new_tail)."""
    wconv = p["conv_w"].astype(x.dtype)
    width = wconv.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        tail = tail.astype(x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)         # (B, T+W-1, w)
    y = sum(xp[:, i:i + x.shape[1]] * wconv[i] for i in range(width))
    return y + p["conv_b"].astype(x.dtype), xp[:, -(width - 1):]


def _rg_lru(p, x, h0):
    """x: (B, T, w) post-conv; h0: (B, w) initial state.

    Parallel associative scan over h_t = a_t h_{t-1} + b_t."""
    r = jax.nn.sigmoid((x @ p["w_a"] + p["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"] + p["b_i"]).astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["lambda_p"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)                               # (B, T, w)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-9)) * i * x.astype(jnp.float32)
    # fold initial state into the first b
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def _temporal_block(p, cfg: ModelConfig, x, state):
    """Griffin recurrent branch. x: (B,T,d); state: {"h": (B,w), "conv": ...}."""
    gate = jax.nn.gelu(x @ p["w_gate"].astype(cfg.compute_dtype))
    y = x @ p["w_x"].astype(cfg.compute_dtype)
    y, new_conv = _causal_conv(p, y, state["conv"] if state else None)
    h, h_last = _rg_lru(p, y, state["h"] if state else jnp.zeros(
        (x.shape[0], y.shape[-1]), jnp.float32))
    out = (h * gate) @ p["w_out"].astype(cfg.compute_dtype)
    return out, {"h": h_last, "conv": new_conv}


def _apply_block(p, cfg: ModelConfig, x, positions, kind: str, state=None):
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "rec":
        out, new_state = _temporal_block(p["rec"], cfg, h, state)
    else:
        out = layers.attn_block(p["attn"], cfg, h, positions, causal=True,
                                window=cfg.window)
        new_state = None
    x = x + out
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.mlp_block(p["mlp"], cfg, h)
    return x, new_state


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward_with_aux(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = x * jnp.sqrt(jnp.asarray(cfg.d_model, cfg.compute_dtype))
    positions = jnp.arange(t)

    def scan_body(x, p_group):
        x = hints.hint(x, "batch", "seq_act", None)   # seq-sharded carry
        x, _ = _apply_block(p_group["rec0"], cfg, x, positions, "rec")
        x, _ = _apply_block(p_group["rec1"], cfg, x, positions, "rec")
        x, _ = _apply_block(p_group["attn"], cfg, x, positions, "attn")
        return hints.hint(x, "batch", "seq_act", None), None

    if cfg.remat == "layer":
        scan_body = jax.checkpoint(scan_body,
                                   policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(scan_body, x, params["groups"])

    if "trailing" in params:
        def trail_body(x, p_layer):
            x, _ = _apply_block(p_layer, cfg, x, positions, "rec")
            return x, None
        x, _ = jax.lax.scan(trail_body, x, params["trailing"])

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T.astype(cfg.compute_dtype)   # tied head
    return logits, {"balance": jnp.zeros((), jnp.float32)}


def forward(params, cfg: ModelConfig, batch):
    return forward_with_aux(params, cfg, batch)[0]


def loss_fn(params, cfg: ModelConfig, batch, **_):
    tokens = batch["tokens"]
    logits, aux = forward_with_aux(params, cfg, {"tokens": tokens[:, :-1]})
    loss = layers.softmax_cross_entropy(logits, tokens[:, 1:])
    return loss, {"ce": loss, "balance": aux["balance"]}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    ng, trailing = _pattern(cfg)
    w = _rnn_width(cfg)
    win = min(cfg.window or max_len, max_len)
    dh = cfg.dh

    def rec_state(n):
        return {"h": jnp.zeros((n, batch_size, w), jnp.float32),
                "conv": jnp.zeros((n, batch_size, cfg.conv_width - 1, w),
                                  cfg.compute_dtype)}

    cache = {
        "rec0": rec_state(ng), "rec1": rec_state(ng),
        "attn": {"k": jnp.zeros((ng, batch_size, win, cfg.num_kv_heads, dh),
                                dtype),
                 "v": jnp.zeros((ng, batch_size, win, cfg.num_kv_heads, dh),
                                dtype)},
    }
    if trailing:
        cache["trailing"] = rec_state(trailing)
    return cache


def cache_logical_axes(cfg: ModelConfig, cache):
    def annotate(leaf):
        if leaf.ndim == 5:   # attention kv: (ng, B, S, Hkv, dh)
            return ("layers", "batch", "kv_seq", None, None)
        return ("layers", "batch") + (None,) * (leaf.ndim - 2)
    return jax.tree.map(annotate, cache)


def _decode_rec(p, cfg: ModelConfig, x, state):
    """Single-token recurrent block. x: (B, d)."""
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)[:, None, :]
    out, new_state = _temporal_block(p["rec"], cfg, h, state)
    x = x + out[:, 0]
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + layers.mlp_block(p["mlp"], cfg, h2), new_state


def _decode_attn(p, cfg: ModelConfig, x, kv, pos):
    b = x.shape[0]
    dh = cfg.dh
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)[:, None, :]
    q, k_new, v_new = layers.qkv_project(p["attn"], cfg, h,
                                         jnp.full((1,), pos))
    s = kv["k"].shape[1]
    slot = pos % s
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        kv["k"], k_new.astype(kv["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        kv["v"], v_new.astype(kv["v"].dtype), slot, axis=1)
    out = layers.decode_attention(q[:, 0], k_cache, v_cache,
                                  jnp.minimum(pos, s - 1), dh)
    x = x + out.reshape(b, -1) @ p["attn"]["wo"].astype(cfg.compute_dtype)
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + layers.mlp_block(p["mlp"], cfg, h2), {"k": k_cache, "v": v_cache}


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = x * jnp.sqrt(jnp.asarray(cfg.d_model, cfg.compute_dtype))

    def scan_body(x, xs):
        p_group, rec0, rec1, kv = xs
        x, s0 = _decode_rec(p_group["rec0"], cfg, x, rec0)
        x, s1 = _decode_rec(p_group["rec1"], cfg, x, rec1)
        x, kv2 = _decode_attn(p_group["attn"], cfg, x, kv, pos)
        return x, (s0, s1, kv2)

    x, (rec0, rec1, kv) = jax.lax.scan(
        scan_body, x,
        (params["groups"], cache["rec0"], cache["rec1"], cache["attn"]))
    new_cache = {"rec0": rec0, "rec1": rec1, "attn": kv}

    if "trailing" in params:
        def trail_body(x, xs):
            p_layer, st = xs
            x, s = _decode_rec(p_layer, cfg, x, st)
            return x, s
        x, ts = jax.lax.scan(trail_body, x,
                             (params["trailing"], cache["trailing"]))
        new_cache["trailing"] = ts

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T.astype(cfg.compute_dtype)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch: dict):
    """Process the prompt; return (last_logits, decode cache): RG-LRU
    states + conv tails (O(1)) and window-sliced attention KV rings."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = x * jnp.sqrt(jnp.asarray(cfg.d_model, cfg.compute_dtype))
    positions = jnp.arange(t)
    win = min(cfg.window or t, t)

    def rec_with_state(p_block, x):
        h = layers.rms_norm(x, p_block["ln1"], cfg.norm_eps)
        out, st = _temporal_block(p_block["rec"], cfg, h, None)
        x = x + out
        h2 = layers.rms_norm(x, p_block["ln2"], cfg.norm_eps)
        return x + layers.mlp_block(p_block["mlp"], cfg, h2), st

    def attn_with_kv(p_block, x):
        h = layers.rms_norm(x, p_block["ln1"], cfg.norm_eps)
        q, k, v = layers.qkv_project(p_block["attn"], cfg, h, positions)
        a = layers.attention(q, k, v, positions, positions, cfg, causal=True,
                             window=cfg.window)
        x = x + a.reshape(b, t, -1) @ p_block["attn"]["wo"].astype(
            cfg.compute_dtype)
        h2 = layers.rms_norm(x, p_block["ln2"], cfg.norm_eps)
        x = x + layers.mlp_block(p_block["mlp"], cfg, h2)
        return x, {"k": k[:, -win:], "v": v[:, -win:]}

    def scan_body(x, p_group):
        x, s0 = rec_with_state(p_group["rec0"], x)
        x, s1 = rec_with_state(p_group["rec1"], x)
        x, kv = attn_with_kv(p_group["attn"], x)
        return x, (s0, s1, kv)

    x, (rec0, rec1, kv) = jax.lax.scan(scan_body, x, params["groups"])
    cache = {"rec0": rec0, "rec1": rec1, "attn": kv}

    if "trailing" in params:
        def trail_body(x, p_layer):
            return rec_with_state(p_layer, x)
        x, ts = jax.lax.scan(trail_body, x, params["trailing"])
        cache["trailing"] = ts

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last_logits = x[:, -1] @ params["embed"].T.astype(cfg.compute_dtype)
    return last_logits, cache
