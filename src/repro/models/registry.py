"""Family dispatch: uniform functional surface over the three model families."""
from __future__ import annotations

from repro.models.config import ModelConfig
from repro.models import transformer, rwkv6, griffin

_FAMILIES = {
    "transformer": transformer,
    "rwkv6": rwkv6,
    "griffin": griffin,
}


def family(cfg: ModelConfig):
    return _FAMILIES[cfg.family]


def init(key, cfg: ModelConfig):
    return family(cfg).init(key, cfg)


def forward(params, cfg: ModelConfig, batch):
    return family(cfg).forward(params, cfg, batch)


def loss_fn(params, cfg: ModelConfig, batch, **kw):
    return family(cfg).loss_fn(params, cfg, batch, **kw)


def logical_axes(cfg: ModelConfig):
    return family(cfg).logical_axes(cfg)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, **kw):
    return family(cfg).init_cache(cfg, batch_size, max_len, **kw)


def cache_logical_axes(cfg: ModelConfig, cache):
    return family(cfg).cache_logical_axes(cfg, cache)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    return family(cfg).decode_step(params, cfg, cache, tokens, pos)


def supports_decode(cfg: ModelConfig) -> bool:
    return cfg.kind == "decoder"
