"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free SSM with
data-dependent decay.

Per layer: a time-mixing block (the wkv recurrence over a per-head
(dk x dv) outer-product state with *input-conditioned* per-channel decay —
the Finch novelty) and a channel-mixing block, both with token-shift
interpolation. Data-dependent quantities (the five token-shift mixes and
the decay) use the official low-rank "ddlerp" parameterization.

Training runs the recurrence as a ``lax.scan`` over time (compact HLO, the
sequential-scan baseline); a chunked parallel formulation is the documented
perf upgrade path. Decode carries O(1) state per layer: the wkv state
(B, H, dk, dv) plus the last token for the shifts — there is NO KV cache,
which is why rwkv6 runs the long_500k cell natively and why the paper's
KV-compression integration is inapplicable here (DESIGN.md §Arch-
applicability).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers
from repro.parallel import hints
from repro.utils.pytree import tree_cast

TM_EXTRA = 32      # ddlerp low-rank dim (official TIME_MIX_EXTRA_DIM)
DECAY_EXTRA = 64   # decay lora dim (official TIME_DECAY_EXTRA_DIM)
HEAD_DIM = 64      # rwkv6 head size


def _num_heads(cfg: ModelConfig) -> int:
    assert cfg.d_model % HEAD_DIM == 0
    return cfg.d_model // HEAD_DIM


def layer_norm(x, p, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = ((x - mean) * jax.lax.rsqrt(var + eps)
         * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32))
    return y.astype(dtype)


def _ln_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def _init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    dt = cfg.param_dtype
    ks = jax.random.split(key, 10)
    h = _num_heads(cfg)
    return {
        # token-shift base mixes (x, then per-branch w/k/v/r/g)
        "mu_x": jnp.full((d,), 0.5, dt),
        "mu_wkvrg": jnp.full((5, d), 0.5, dt),
        # ddlerp lora: (d, 5*TM) and (5, TM, d)
        "maa_w1": layers.dense_init(ks[0], (d, 5 * TM_EXTRA), dt),
        "maa_w2": (jax.random.normal(ks[1], (5, TM_EXTRA, d))
                   * (1.0 / jnp.sqrt(TM_EXTRA))).astype(dt),
        # decay: w0 + tanh(x @ d1) @ d2
        "decay_w0": jnp.full((d,), -6.0, dt),   # slow decay at init
        "decay_w1": layers.dense_init(ks[2], (d, DECAY_EXTRA), dt),
        "decay_w2": (jax.random.normal(ks[3], (DECAY_EXTRA, d))
                     * (1.0 / jnp.sqrt(DECAY_EXTRA))).astype(dt),
        "bonus_u": jnp.zeros((h, HEAD_DIM), dt),      # first-token bonus
        "w_r": layers.dense_init(ks[4], (d, d), dt),
        "w_k": layers.dense_init(ks[5], (d, d), dt),
        "w_v": layers.dense_init(ks[6], (d, d), dt),
        "w_g": layers.dense_init(ks[7], (d, d), dt),
        "w_o": layers.dense_init(ks[8], (d, d), dt),
        "ln_x": _ln_init(HEAD_DIM, dt),               # per-head group norm
    }


def _init_channel_mix(key, cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "w_k": layers.dense_init(k1, (d, cfg.d_ff), dt),
        "w_v": layers.dense_init(k2, (cfg.d_ff, d), dt),
        "w_r": layers.dense_init(k3, (d, d), dt),
    }


def _init_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": _ln_init(cfg.d_model, cfg.param_dtype),
        "ln2": _ln_init(cfg.d_model, cfg.param_dtype),
        "tm": _init_time_mix(k1, cfg),
        "cm": _init_channel_mix(k2, cfg),
    }


def init(key, cfg: ModelConfig):
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.num_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(block_keys)
    params = {
        "embed": layers.embed_init(k_emb, cfg.vocab_size, cfg.d_model,
                                   cfg.param_dtype),
        "ln_in": _ln_init(cfg.d_model, cfg.param_dtype),
        "blocks": stacked,
        "ln_out": _ln_init(cfg.d_model, cfg.param_dtype),
        "lm_head": layers.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                     cfg.param_dtype),
    }
    return params


def logical_axes(cfg: ModelConfig):
    d2 = ("embed", "ffn")
    tm = {
        "mu_x": (None,), "mu_wkvrg": (None, None),
        "maa_w1": ("embed", None), "maa_w2": (None, None, "embed"),
        "decay_w0": (None,), "decay_w1": ("embed", None),
        "decay_w2": (None, "embed"), "bonus_u": ("heads", None),
        "w_r": ("embed", "heads"), "w_k": ("embed", "heads"),
        "w_v": ("embed", "heads"), "w_g": ("embed", "heads"),
        "w_o": ("heads", "embed"),
        "ln_x": {"scale": (None,), "bias": (None,)},
    }
    cm = {"mu_k": (None,), "mu_r": (None,),
          "w_k": d2, "w_v": ("ffn", "embed"), "w_r": ("embed", "heads")}
    ln = {"scale": (None,), "bias": (None,)}
    block = {"ln1": ln, "ln2": ln, "tm": tm, "cm": cm}
    stacked = jax.tree.map(lambda ax: ("layers",) + tuple(ax), block,
                           is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": ("vocab", "embed"), "ln_in": ln, "blocks": stacked,
        "ln_out": ln, "lm_head": ("embed", "vocab"),
    }


# ---------------------------------------------------------------------------
# time mixing
# ---------------------------------------------------------------------------

def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift: returns the 5 mixed inputs (w,k,v,r,g).

    x, x_prev: (B, T, d). Official RWKV6 formulation."""
    xx = x_prev - x
    x_base = x + xx * p["mu_x"]
    lo = jnp.tanh(x_base @ p["maa_w1"])                    # (B,T,5*TM)
    b, t, _ = x.shape
    lo = lo.reshape(b, t, 5, TM_EXTRA)
    deltas = jnp.einsum("btfe,fed->btfd", lo, p["maa_w2"])  # (B,T,5,d)
    mixed = x[:, :, None, :] + xx[:, :, None, :] * (
        p["mu_wkvrg"][None, None] + deltas)
    return [mixed[:, :, i, :] for i in range(5)]            # w,k,v,r,g inputs


def _wkv_scan(r, k, v, w, u, state):
    """The wkv6 recurrence over time.

    r,k,v: (B,T,H,dh); w: (B,T,H,dh) decay in (0,1); u: (H,dh) bonus.
    state: (B,H,dh,dh) carry (key-dim x value-dim).
    Returns (out (B,T,H,dh), final state)."""

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                 # (B,H,dh) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None] [..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, out

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    state, outs = jax.lax.scan(step, state, xs)
    return outs.transpose(1, 0, 2, 3), state


def _wkv_chunked(r, k, v, w, u, state, chunk: int):
    """Chunked-parallel wkv6 (the hillclimb path for train/prefill; see
    EXPERIMENTS.md §Perf iteration 5).

    Within a chunk of C steps the recurrence unrolls to matmuls by
    factoring the cumulative decay: with la_t = sum_{s<=t} log w_s,

        scores[t,s] = <r_t * e^{la_{t-1}}, k_s * e^{-la_s}>   (s < t)
        S_C         = diag(e^{la_C}) S_0 + (k * e^{la_C - la})^T v

    Both exponents are row/column-separable, so intra-chunk work is three
    (C x d) matmuls on the MXU instead of C sequential rank-1 updates, and
    the (dk x dv) state is read/written once per chunk instead of once per
    step — T/C x less state traffic (the memory-roofline win). e^{-la} is
    clamped (decay ~0.99+ at init; |la| within a chunk stays small).

    r,k,v,w: (B,T,H,dh); u: (H,dh); state: (B,H,dh,dh) f32.
    """
    b, t, h, dh = r.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    rs = r.reshape(b, nc, chunk, h, dh)
    ks = k.reshape(b, nc, chunk, h, dh)
    vs = v.reshape(b, nc, chunk, h, dh)
    # per-chunk cumulative log-decay (restarts each chunk so every exponent
    # below is bounded by the chunk length)
    logw = jnp.log(jnp.maximum(w, 1e-12)).reshape(b, nc, chunk, h, dh)
    la = jnp.cumsum(logw, axis=2)

    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)

    def chunk_body(s0, inp):
        r_c, k_c, v_c, la_c = inp                  # (B,C,H,dh) each
        la_prev = jnp.concatenate(
            [jnp.zeros_like(la_c[:, :1]), la_c[:, :-1]], axis=1)
        r_decayed = r_c * jnp.exp(la_prev)                   # <= |r|
        k_grown = k_c * jnp.exp(jnp.minimum(-la_c, 30.0))
        # intra-chunk attention (strictly causal) + bonus diagonal
        scores = jnp.einsum("bthd,bshd->bhts", r_decayed, k_grown)
        scores = scores * mask[None, None]
        intra = jnp.einsum("bhts,bshd->bthd", scores, v_c)
        bonus = jnp.einsum("bthd,bthd->bth", r_c * u[None, None], k_c)
        intra = intra + bonus[..., None] * v_c
        # inter-chunk: contribution of the carried state
        inter = jnp.einsum("bthd,bhde->bthe", r_decayed, s0)
        # state update
        la_end = la_c[:, -1:]                                # (B,1,H,dh)
        k_decayed = k_c * jnp.exp(la_end - la_c)             # <= |k|
        s_new = (jnp.exp(la_end[:, 0])[..., None] * s0
                 + jnp.einsum("bthd,bthe->bhde", k_decayed, v_c))
        return s_new, intra + inter

    xs = tuple(a.transpose(1, 0, 2, 3, 4) for a in
               (rs, ks, vs, la))
    state, outs = jax.lax.scan(chunk_body, state, xs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, t, h, dh)
    return out, state


def time_mix(p, cfg: ModelConfig, x, x_prev, state):
    """x: (B,T,d); x_prev: (B,T,d) shifted-by-one inputs; state: wkv carry.

    Returns (out (B,T,d), new_state)."""
    b, t, d = x.shape
    h = _num_heads(cfg)
    xw, xk, xv, xr, xg = _ddlerp(p["tm"], x, x_prev)
    tm = p["tm"]
    r = (xr @ tm["w_r"]).reshape(b, t, h, HEAD_DIM)
    k = (xk @ tm["w_k"]).reshape(b, t, h, HEAD_DIM)
    v = (xv @ tm["w_v"]).reshape(b, t, h, HEAD_DIM)
    g = xg @ tm["w_g"]
    decay_logit = tm["decay_w0"] + jnp.tanh(xw @ tm["decay_w1"]) @ tm["decay_w2"]
    w = jnp.exp(-jnp.exp(decay_logit.astype(jnp.float32)))   # (B,T,d) in (0,1)
    w = w.reshape(b, t, h, HEAD_DIM)

    if cfg.rwkv_chunk and t > 1 and t % cfg.rwkv_chunk == 0:
        out, new_state = _wkv_chunked(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w,
            tm["bonus_u"].astype(jnp.float32), state,
            chunk=cfg.rwkv_chunk)
    else:
        out, new_state = _wkv_scan(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w,
            tm["bonus_u"].astype(jnp.float32), state)
    # per-head group norm, then silu(g) gate and output projection
    out = layer_norm(out, tm["ln_x"])
    out = out.reshape(b, t, d).astype(x.dtype) * jax.nn.silu(g)
    return out @ tm["w_o"], new_state


def channel_mix(p, x, x_prev):
    cm = p["cm"]
    xx = x_prev - x
    xk = x + xx * cm["mu_k"]
    xr = x + xx * cm["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ cm["w_k"]))
    return jax.nn.sigmoid(xr @ cm["w_r"]) * (k @ cm["w_v"])


def _shift(x, last=None):
    """Token shift: x_prev[t] = x[t-1]; position 0 gets ``last`` (or 0)."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([first, prev[:, 1:]], axis=1)


def _block(p, cfg: ModelConfig, x, state):
    h = layer_norm(x, p["ln1"])
    tm_out, new_state = time_mix(p, cfg, h, _shift(h), state)
    x = x + tm_out
    h2 = layer_norm(x, p["ln2"])
    x = x + channel_mix(p, h2, _shift(h2))
    return x, new_state


def forward_with_aux(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    b, t = tokens.shape
    h = _num_heads(cfg)
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = layer_norm(x, params["ln_in"])
    blocks = tree_cast(params["blocks"], cfg.compute_dtype)

    def scan_body(x, p_layer):
        x = hints.hint(x, "batch", "seq_act", None)   # seq-sharded carry
        s0 = jnp.zeros((b, h, HEAD_DIM, HEAD_DIM), jnp.float32)
        x, _ = _block(p_layer, cfg, x, s0)
        return hints.hint(x, "batch", "seq_act", None), None

    if cfg.remat == "layer":
        scan_body = jax.checkpoint(scan_body,
                                   policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(scan_body, x, blocks)
    x = layer_norm(x, params["ln_out"])
    logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    return logits, {"balance": jnp.zeros((), jnp.float32)}


def forward(params, cfg: ModelConfig, batch):
    return forward_with_aux(params, cfg, batch)[0]


def loss_fn(params, cfg: ModelConfig, batch, **_):
    tokens = batch["tokens"]
    logits, aux = forward_with_aux(params, cfg, {"tokens": tokens[:, :-1]})
    loss = layers.softmax_cross_entropy(logits, tokens[:, 1:])
    return loss, {"ce": loss, "balance": aux["balance"]}


# ---------------------------------------------------------------------------
# decode: O(1) state, no KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    """State per layer: wkv (B,H,dh,dh) + last-token activations for the two
    token shifts. Size is independent of max_len (the whole point)."""
    h = _num_heads(cfg)
    l = cfg.num_layers
    return {
        "wkv": jnp.zeros((l, batch_size, h, HEAD_DIM, HEAD_DIM), jnp.float32),
        "tm_prev": jnp.zeros((l, batch_size, cfg.d_model), cfg.compute_dtype),
        "cm_prev": jnp.zeros((l, batch_size, cfg.d_model), cfg.compute_dtype),
    }


def cache_logical_axes(cfg: ModelConfig, cache):
    return jax.tree.map(lambda x: ("layers", "batch") + (None,) * (x.ndim - 2),
                        cache)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """One-token step: tokens (B,) -> (logits (B,V), new cache)."""
    x = params["embed"][tokens].astype(cfg.compute_dtype)     # (B, d)
    x = layer_norm(x, params["ln_in"])
    blocks = tree_cast(params["blocks"], cfg.compute_dtype)

    def scan_body(x, xs):
        p_layer, wkv, tm_prev, cm_prev = xs
        h = layer_norm(x, p_layer["ln1"])
        tm_out, new_wkv = time_mix(p_layer, cfg, h[:, None, :],
                                   tm_prev[:, None, :].astype(h.dtype), wkv)
        x = x + tm_out[:, 0]
        h2 = layer_norm(x, p_layer["ln2"])
        cm_out = channel_mix(p_layer, h2[:, None, :],
                             cm_prev[:, None, :].astype(h2.dtype))
        x = x + cm_out[:, 0]
        return x, (new_wkv, h.astype(tm_prev.dtype), h2.astype(cm_prev.dtype))

    x, (wkv, tm_prev, cm_prev) = jax.lax.scan(
        scan_body, x,
        (blocks, cache["wkv"], cache["tm_prev"], cache["cm_prev"]))
    x = layer_norm(x, params["ln_out"])
    logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    return logits, {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": cm_prev}


def prefill(params, cfg: ModelConfig, batch: dict):
    """Process the prompt; return (last_logits, decode cache). The cache is
    the stacked per-layer wkv state + last normed activations — O(1) in T."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    h = _num_heads(cfg)
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = layer_norm(x, params["ln_in"])
    blocks = tree_cast(params["blocks"], cfg.compute_dtype)

    def scan_body(x, p_layer):
        hh = layer_norm(x, p_layer["ln1"])
        s0 = jnp.zeros((b, h, HEAD_DIM, HEAD_DIM), jnp.float32)
        tm_out, state = time_mix(p_layer, cfg, hh, _shift(hh), s0)
        x = x + tm_out
        h2 = layer_norm(x, p_layer["ln2"])
        x = x + channel_mix(p_layer, h2, _shift(h2))
        return x, (state, hh[:, -1], h2[:, -1])

    x, (wkv, tm_prev, cm_prev) = jax.lax.scan(scan_body, x, blocks)
    x = layer_norm(x, params["ln_out"])
    last_logits = x[:, -1] @ params["lm_head"].astype(cfg.compute_dtype)
    cache = {"wkv": wkv, "tm_prev": tm_prev.astype(cfg.compute_dtype),
             "cm_prev": cm_prev.astype(cfg.compute_dtype)}
    return last_logits, cache
