"""Transformer family: dense decoder LMs, MoE decoder LMs, gemma3-style
local:global attention patterns, and encoder-only (HuBERT) models.

Layers are stacked along a leading "scan group" axis and executed with
``lax.scan`` so the HLO stays compact at 88 layers (critical for the
512-device dry-run compiles). A scan group is:

  * 1 layer for uniform archs (yi, minitron, mistral-large, chameleon, MoE);
  * ``local_global_ratio + 1`` layers for gemma3 (5 sliding-window + 1
    global), unrolled inside the scan body with static window choices.

Decode uses per-layer KV caches scanned alongside the layer params; local
layers keep a ring buffer of ``window`` entries, so long-context decode
memory is bounded for the sliding-window portion of the stack. With
``cfg.kvq`` the global-attention cache is stored as MCQ codes and scored in
the compressed domain (the paper's technique — see repro/models/kvq.py).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers, moe as moe_lib, kvq as kvq_lib
from repro.parallel import hints

Params = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _group_size(cfg: ModelConfig) -> int:
    return cfg.local_global_ratio + 1 if cfg.local_global_ratio else 1


def _num_groups(cfg: ModelConfig) -> int:
    gs = _group_size(cfg)
    assert cfg.num_layers % gs == 0, (cfg.num_layers, gs)
    return cfg.num_layers // gs


def _layer_window(cfg: ModelConfig, idx_in_group: int) -> int | None:
    """Static window for sub-layer ``idx_in_group`` of a scan group.

    gemma3 pattern: [local]*ratio + [global]; uniform archs use cfg.window
    for every layer (None -> full attention)."""
    if cfg.local_global_ratio:
        return cfg.window if idx_in_group < cfg.local_global_ratio else None
    return cfg.window


def _init_layer(key, cfg: ModelConfig):
    k_attn, k_ffn = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "attn": layers.init_attn(k_attn, cfg),
    }
    if cfg.moe:
        p["moe"] = moe_lib.init_moe(k_ffn, cfg)
    else:
        p["mlp"] = layers.init_mlp(k_ffn, cfg)
    if cfg.use_post_norm:
        p["post_ln1"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
        p["post_ln2"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
    return p


def _layer_axes(cfg: ModelConfig):
    p = {
        "ln1": (None,),
        "ln2": (None,),
        "attn": layers.attn_axes(cfg),
    }
    if cfg.moe:
        p["moe"] = moe_lib.moe_axes(cfg)
    else:
        p["mlp"] = layers.mlp_axes(cfg)
    if cfg.use_post_norm:
        p["post_ln1"] = (None,)
        p["post_ln2"] = (None,)
    return p


def init(key, cfg: ModelConfig) -> Params:
    gs, ng = _group_size(cfg), _num_groups(cfg)
    k_emb, k_blocks, k_head, k_front = jax.random.split(key, 4)

    # stacked (ng, gs, ...) block params via double-vmapped init
    block_keys = jax.random.split(k_blocks, ng * gs).reshape(ng, gs, 2)
    stacked = jax.vmap(jax.vmap(lambda k: _init_layer(k, cfg)))(block_keys)

    params = {
        "blocks": stacked,
        "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    if cfg.input_mode == "frames":
        params["frontend"] = {
            "proj": layers.dense_init(k_front, (cfg.frame_dim, cfg.d_model),
                                      cfg.param_dtype),
            "mask_embed": (jax.random.normal(k_emb, (cfg.frame_dim,))
                           * 0.02).astype(cfg.param_dtype),
        }
    else:
        params["embed"] = layers.embed_init(k_emb, cfg.vocab_size,
                                            cfg.d_model, cfg.param_dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            k_head, (cfg.d_model, cfg.vocab_size), cfg.param_dtype)
    return params


def logical_axes(cfg: ModelConfig):
    la = _layer_axes(cfg)
    stacked = jax.tree.map(lambda ax: ("layers", "sub") + tuple(ax), la,
                           is_leaf=lambda x: isinstance(x, tuple))
    axes = {"blocks": stacked, "final_norm": (None,)}
    if cfg.input_mode == "frames":
        axes["frontend"] = {"proj": (None, "embed"), "mask_embed": (None,)}
    else:
        axes["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed", "vocab")
    return axes


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_apply(p, cfg: ModelConfig, x, positions, *, causal: bool,
                 window: int | None, collect_kv: bool = False):
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    if collect_kv:
        q, k, v = layers.qkv_project(p["attn"], cfg, h, positions)
        a_heads = layers.attention(q, k, v, positions, positions, cfg,
                                   causal=causal, window=window)
        b, t = x.shape[:2]
        a = a_heads.reshape(b, t, -1) @ p["attn"]["wo"].astype(cfg.compute_dtype)
        kv = (k, v)
    else:
        a = layers.attn_block(p["attn"], cfg, h, positions, causal=causal,
                              window=window)
        kv = None
    if cfg.use_post_norm:
        a = layers.rms_norm(a, p["post_ln1"], cfg.norm_eps)
    x = x + a
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe:
        mesh = hints.current_mesh()
        if (cfg.moe_ep and mesh is not None and h.ndim == 3
                and h.shape[1] % mesh.shape["model"] == 0
                and cfg.num_experts % mesh.shape["model"] == 0):
            from repro.parallel import ep
            f, balance = ep.moe_block_ep(p["moe"], cfg, h, mesh)
        else:
            f, balance = moe_lib.moe_block(p["moe"], cfg, h)
    else:
        f, balance = layers.mlp_block(p["mlp"], cfg, h), jnp.zeros((), jnp.float32)
    if cfg.use_post_norm:
        f = layers.rms_norm(f, p["post_ln2"], cfg.norm_eps)
    return x + f, balance, kv


def _group_apply(p_group, cfg: ModelConfig, x, positions, *, causal: bool,
                 collect_kv: bool = False):
    """Apply one scan group (gs sub-layers, static windows)."""
    gs = _group_size(cfg)
    balance = jnp.zeros((), jnp.float32)
    kvs = []
    for i in range(gs):
        p_i = jax.tree.map(lambda a: a[i], p_group)
        x, b, kv = _block_apply(p_i, cfg, x, positions, causal=causal,
                                window=_layer_window(cfg, i),
                                collect_kv=collect_kv)
        balance = balance + b
        kvs.append(kv)
    return x, balance, kvs


def embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, cfg.compute_dtype))
    return hints.hint(x, "batch", *([None] * (x.ndim - 1)))


def embed_frames(params, cfg: ModelConfig, frames, mask=None):
    """HuBERT frontend stub: precomputed frame embeddings + learned mask
    token at masked positions, projected to d_model."""
    if mask is not None:
        me = params["frontend"]["mask_embed"].astype(frames.dtype)
        frames = jnp.where(mask[..., None], me[None, None, :], frames)
    return (frames @ params["frontend"]["proj"].astype(cfg.compute_dtype))


def forward_with_aux(params, cfg: ModelConfig, batch: dict):
    """Full-sequence forward -> (logits (B, T, V), {"balance": scalar}).

    batch: {"tokens": (B, T)} for decoders / chameleon; {"frames": (B,T,F),
    "mask": (B,T)} for hubert.
    """
    if cfg.input_mode == "frames":
        x = embed_frames(params, cfg, batch["frames"].astype(cfg.compute_dtype),
                         batch.get("mask"))
        t = x.shape[1]
    else:
        x = embed_tokens(params, cfg, batch["tokens"])
        t = batch["tokens"].shape[1]
    positions = jnp.arange(t)
    causal = cfg.kind == "decoder"

    body = functools.partial(_group_apply, cfg=cfg, positions=positions,
                             causal=causal)

    def scan_body(carry, p_group):
        x, bal = carry
        # sequence-sharded at the layer boundary: this is the tensor the
        # scan saves per layer for backward (Megatron SP — DESIGN.md §5)
        x = hints.hint(x, "batch", "seq_act", None)
        x, b, _ = body(p_group, x=x)
        x = hints.hint(x, "batch", "seq_act", None)
        return (x, bal + b), None

    if cfg.remat == "layer":
        scan_body = jax.checkpoint(scan_body,
                                   policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.checkpoint_dots)

    (x, balance), _ = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), params["blocks"])

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits, {"balance": balance}


def unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(cfg.compute_dtype)
    else:
        logits = x @ params["lm_head"].astype(cfg.compute_dtype)
    return hints.hint(logits, "batch", *([None] * (x.ndim - 2)), "vocab")


def forward(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    return forward_with_aux(params, cfg, batch)[0]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch: dict, *,
            balance_coef: float = 0.01):
    """Next-token CE for decoders; masked-frame CE for encoders."""
    if cfg.kind == "encoder":
        logits, aux = forward_with_aux(params, cfg, batch)
        loss = layers.softmax_cross_entropy(
            logits, batch["targets"], mask=batch["mask"])
    else:
        tokens = batch["tokens"]
        logits, aux = forward_with_aux(
            params, cfg, {**batch, "tokens": tokens[:, :-1]})
        loss = layers.softmax_cross_entropy(logits, tokens[:, 1:])
    total = loss + balance_coef * aux["balance"]
    return total, {"ce": loss, "balance": aux["balance"]}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    """Per-layer KV caches, stacked (ng, gs, ...) to scan with the params.

    Local (sliding-window) layers allocate a ring buffer of ``window``
    slots; global layers allocate ``max_len`` (or MCQ code storage under
    cfg.kvq)."""
    gs, ng = _group_size(cfg), _num_groups(cfg)
    dh = cfg.dh
    caches = []
    for i in range(gs):
        w = _layer_window(cfg, i)
        s = min(w, max_len) if w else max_len
        if cfg.kvq and w is None:
            caches.append(kvq_lib.init_kvq_cache(cfg, ng, batch_size, s))
        else:
            shape = (ng, batch_size, s, cfg.num_kv_heads, dh)
            caches.append({"k": jnp.zeros(shape, dtype),
                           "v": jnp.zeros(shape, dtype)})
    # stack over sub-layer axis -> pytree leaves (ng, gs_variant...) kept as
    # a per-sub-layer list because shapes differ between local/global.
    return caches


def cache_logical_axes(cfg: ModelConfig, caches):
    """Sharding annotation for the cache: sequence axis over 'model';
    kvq codebooks ((ng, Hkv, M, K, d_sub)) are replicated serving constants."""
    def annotate(path, leaf):
        name = str(path[-1].key) if path else ""
        if "books" in name:
            return ("layers",) + (None,) * (leaf.ndim - 1)
        # (ng, B, S, Hkv, dh) or kvq codes (ng, B, S, Hkv, M)
        return ("layers", "batch", "kv_seq", None, None)[: leaf.ndim]
    return jax.tree_util.tree_map_with_path(annotate, caches)


def _decode_layer(p, cfg: ModelConfig, cache_i, x, pos, window):
    """One layer of single-token decode. x: (B, d). Returns (x, new_cache)."""
    b = x.shape[0]
    dh = cfg.dh
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)[:, None, :]   # (B, 1, d)
    positions = jnp.full((1,), pos)
    q, k_new, v_new = layers.qkv_project(p["attn"], cfg, h, positions)
    q = q[:, 0]                                                  # (B, H, dh)

    if cfg.kvq and window is None:
        out, new_cache = kvq_lib.decode_attention_kvq(
            cfg, cache_i, q, k_new[:, 0], v_new[:, 0], pos)
    else:
        s = cache_i["k"].shape[1]
        slot = pos % s if window else pos
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_i["k"], k_new.astype(cache_i["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_i["v"], v_new.astype(cache_i["v"].dtype), slot, axis=1)
        # ring buffer: every slot < min(pos+1, S) is valid
        valid_upto = jnp.minimum(pos, s - 1)
        out = layers.decode_attention(q, k_cache, v_cache, valid_upto, dh)
        new_cache = {"k": k_cache, "v": v_cache}

    a = out.reshape(b, -1) @ p["attn"]["wo"].astype(cfg.compute_dtype)
    if cfg.use_post_norm:
        a = layers.rms_norm(a, p["post_ln1"], cfg.norm_eps)
    x = x + a
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe:
        f, _ = moe_lib.moe_block(p["moe"], cfg, h[:, None, :])
        f = f[:, 0]
    else:
        f = layers.mlp_block(p["mlp"], cfg, h)
    if cfg.use_post_norm:
        f = layers.rms_norm(f, p["post_ln2"], cfg.norm_eps)
    return x + f, new_cache


def decode_step(params, cfg: ModelConfig, caches, tokens, pos):
    """serve_step: one new token per sequence.

    tokens: (B,) int32; pos: scalar int32 (current position, 0-based).
    Returns (logits (B, V), new_caches).
    """
    x = embed_tokens(params, cfg, tokens[:, None])[:, 0]        # (B, d)
    gs = _group_size(cfg)

    def scan_body(x, xs):
        p_group = xs[0]
        cache_group = xs[1:]
        new_caches = []
        for i in range(gs):
            p_i = jax.tree.map(lambda a: a[i], p_group)
            x, nc = _decode_layer(p_i, cfg, cache_group[i], x, pos,
                                  _layer_window(cfg, i))
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(scan_body, x, (params["blocks"], *caches))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits, list(new_caches)


# ---------------------------------------------------------------------------
# prefill (serve: process the prompt, emit last-token logits + decode cache)
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch: dict):
    """Run the prompt through the stack, returning (last_logits (B, V),
    caches) where caches match ``init_cache``'s layout (local layers keep
    only the trailing ``window`` ring — aligned when T % window == 0)."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    positions = jnp.arange(t)
    gs = _group_size(cfg)

    def scan_body(x, p_group):
        x, _, kvs = _group_apply(p_group, cfg, x, positions, causal=True,
                                 collect_kv=True)
        return x, tuple(kvs)

    if cfg.remat == "layer":
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, kv_stacks = jax.lax.scan(scan_body, x, params["blocks"])

    caches = []
    for i in range(gs):
        k, v = kv_stacks[i]                     # (ng, B, T, Hkv, dh)
        w = _layer_window(cfg, i)
        if w and w < t:
            k, v = k[:, :, -w:], v[:, :, -w:]   # ring-aligned iff t % w == 0
        caches.append({"k": k, "v": v})
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last_logits = unembed(params, cfg, x[:, -1])
    return last_logits, caches
