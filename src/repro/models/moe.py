"""Mixture-of-Experts FFN (DeepSeekMoE-style fine-grained experts).

Dispatch is sort-based with a fixed per-expert capacity: tokens are ranked
within their chosen expert via a stable argsort, tokens past capacity are
dropped (routed to a zero "overflow expert"), expert FFNs run as one batched
einsum over (E, C, d) buffers, and outputs are combined with the (top-k
normalized) router gates. No (T, E, C) one-hot tensor is ever materialized,
which is what makes 64-expert/top-6 routing tractable at 1M tokens.

The router balance loss defaults to the squared coefficient of variation —
the same CV² regularizer the UNQ paper borrows from the MoE literature for
codeword balancing (the lineage runs both ways here).

Expert-parallel execution: the (E, ...) expert tensors carry the "experts"
logical axis, sharded over the "model" mesh axis; under pjit the dispatch
buffers (E, C, d) shard the same way. An explicit shard_map all-to-all
variant lives in repro/parallel/ep.py (perf path).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers
from repro.parallel import hints


def init_moe(key, cfg: ModelConfig):
    e = cfg.num_experts
    d_ff = cfg.moe_d_ff or cfg.d_ff
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(k_r, (cfg.d_model, e), cfg.param_dtype),
        "w_gate": layers.dense_init(k_g, (e, cfg.d_model, d_ff),
                                    cfg.param_dtype, fan_in=cfg.d_model),
        "w_up": layers.dense_init(k_u, (e, cfg.d_model, d_ff),
                                  cfg.param_dtype, fan_in=cfg.d_model),
        "w_down": layers.dense_init(k_d, (e, d_ff, cfg.d_model),
                                    cfg.param_dtype, fan_in=d_ff),
    }
    if cfg.num_shared_experts:
        # shared experts fused into one wider gated MLP (mathematically
        # identical to summing num_shared_experts parallel MLPs).
        shared_ff = d_ff * cfg.num_shared_experts
        p["shared"] = layers.init_mlp(k_s, cfg, d_ff=shared_ff)
    return p


def moe_axes(cfg: ModelConfig):
    p = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "ffn"),
        "w_up": ("experts", "embed", "ffn"),
        "w_down": ("experts", "ffn", "embed"),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.mlp_axes(cfg)
    return p


def route(p, cfg: ModelConfig, x_flat):
    """Router: (N, d) -> (gates (N, k), expert ids (N, k), balance loss)."""
    logits = (x_flat @ p["router"].astype(cfg.compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (N, E)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)                # (N, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    mean_probs = jnp.mean(probs, axis=0)                        # (E,)
    if cfg.router_balance == "cv2":
        # CV^2 balance (same statistic as UNQ's codeword regularizer, Eq. 11)
        balance = jnp.var(mean_probs) / (jnp.square(jnp.mean(mean_probs)) + 1e-10)
    else:  # switch-style
        frac = jnp.mean(
            jnp.sum(jax.nn.one_hot(idx, cfg.num_experts), axis=1), axis=0)
        balance = cfg.num_experts * jnp.sum(frac * mean_probs)
    return gates, idx, balance


def moe_block(p, cfg: ModelConfig, x):
    """x: (B, T, d) -> (out (B, T, d), balance_loss scalar)."""
    b, t, d = x.shape
    n = b * t
    e, k = cfg.num_experts, cfg.top_k
    # capacity floor keeps tiny (decode-step) batches dropless; cap at n*k
    # since an expert can never receive more than every slot.
    cap = int(math.ceil(n * k * cfg.capacity_factor / e))
    cap = min(max(cap, cfg.min_capacity), n * k)
    x_flat = x.reshape(n, d)

    gates, idx, balance = route(p, cfg, x_flat)

    flat_e = idx.reshape(-1)                                    # (N*k,)
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.arange(n * k, dtype=jnp.int32) // k

    # stable sort by expert; rank within expert = position - segment start
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))       # (E,)
    rank = jnp.arange(n * k, dtype=jnp.int32) - seg_start[sorted_e]
    kept = rank < cap
    dest_e = jnp.where(kept, sorted_e, e)                       # overflow -> E
    dest_c = jnp.where(kept, rank, 0)

    # dispatch: (E+1, C, d) buffers; overflow rows collide into [E, 0]
    # (dropped). The gathered token matrix is hinted onto the data axis —
    # without it GSPMD replicates the (N*k, d) gather per device (verified
    # 100+ GB/device at 1M tokens).
    dispatched = hints.hint(x_flat[flat_tok[sort_idx]], "batch", None)
    buf = jnp.zeros((e + 1, cap, d), cfg.compute_dtype)
    buf = buf.at[dest_e, dest_c].set(dispatched)

    # batched expert FFN on the real experts. Buffers shard over BOTH the
    # expert-parallel axis (experts -> "model") and the capacity axis
    # (slots -> "data"): without the capacity sharding each model-group
    # would process the full global slot count (verified 16x flops waste).
    # The scatter above / gather below are the dispatch+combine all-to-alls.
    expert_in = hints.hint(buf[:e], "experts", "batch", None)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h_g = act(hints.hint(
        jnp.einsum("ecd,edf->ecf", expert_in,
                   p["w_gate"].astype(cfg.compute_dtype)),
        "experts", "batch", None))
    h_u = jnp.einsum("ecd,edf->ecf", expert_in,
                     p["w_up"].astype(cfg.compute_dtype))
    out_buf = hints.hint(
        jnp.einsum("ecf,efd->ecd", h_g * h_u,
                   p["w_down"].astype(cfg.compute_dtype)),
        "experts", "batch", None)                                # (E, C, d)

    # combine: gather back (overflow reads the zero expert), unsort, weight
    out_pad = jnp.concatenate(
        [out_buf, jnp.zeros((1, cap, d), out_buf.dtype)], axis=0)
    gathered = hints.hint(out_pad[dest_e, dest_c], "batch", None)  # (N*k, d)
    weighted = gathered * flat_gate[sort_idx][:, None].astype(gathered.dtype)
    combined = jnp.zeros((n, d), cfg.compute_dtype).at[
        flat_tok[sort_idx]].add(weighted)
    combined = hints.hint(combined, "batch", None)

    if cfg.num_shared_experts:
        combined = combined + layers.mlp_block(p["shared"], cfg, x_flat)
    return combined.reshape(b, t, d), balance
