"""KV-cache compression with multi-codebook quantization — the paper's
technique integrated into the LM zoo (DESIGN.md §4).

A decode-attention logit against a compressed key IS the paper's d2 (Eq. 8):

    q . k_s  ~=  sum_m <q_m, cK_{m, i_{s,m}}>

so scoring a 500k-token cache costs M table adds per cached token (plus one
M*K LUT build per query), and the value aggregation folds softmax weights
into a per-codeword histogram before a single (M*K, d) matmul — O(S*M)
scatter-adds instead of O(S*d) MACs, exactly the paper's compressed-domain
scan transplanted into attention.

Storage per cached token per kv-head: 2*M bytes (keys+values) instead of
2*dh*2 bytes bf16 — 32x smaller at M=8, dh=128. This is what makes the
gemma3 long_500k bonus cell fit (see EXPERIMENTS.md §Dry-run).

Codebooks are per-(layer-group, kv-head, subspace) and are calibrated with
k-means on sampled K/V vectors (``calibrate_kvq``) — the PQ member of the
paper's MCQ family; the UNQ nonlinear encoder/decoder can be swapped in for
the codebook-learning step without changing this scoring path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel import hints


def _dims(cfg: ModelConfig):
    m = cfg.kvq_books
    dh = cfg.dh
    assert dh % m == 0, (dh, m)
    return m, cfg.kvq_book_size, dh // m


def init_kvq_cache(cfg: ModelConfig, ng: int, batch: int, s: int):
    """Compressed cache for one (global-attention) sub-layer slot.

    Codebooks ride along in the cache pytree (they are per-layer serving
    constants, calibrated offline; random-init here stands in for the
    dry-run and is overwritten by ``calibrate_kvq`` in serving)."""
    m, k, d_sub = _dims(cfg)
    hkv = cfg.num_kv_heads
    key = jax.random.PRNGKey(0)
    books = jax.random.normal(key, (ng, hkv, m, k, d_sub)) * 0.02
    return {
        "k_codes": jnp.zeros((ng, batch, s, hkv, m), jnp.uint8),
        "v_codes": jnp.zeros((ng, batch, s, hkv, m), jnp.uint8),
        "k_books": books.astype(jnp.float32),
        "v_books": books.astype(jnp.float32),
    }


def quantize_vectors(x, books):
    """PQ-encode: x (..., dh), books (M, K, d_sub) -> codes (..., M) uint8.

    Nearest codeword per subspace by L2 (reconstruction-optimal for ADC)."""
    m, k, d_sub = books.shape
    xs = x.reshape(*x.shape[:-1], m, d_sub)
    d = (jnp.sum(xs * xs, axis=-1)[..., None]
         - 2.0 * jnp.einsum("...ms,mks->...mk", xs, books)
         + jnp.sum(books * books, axis=-1))
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


def dequantize_codes(codes, books):
    """codes (..., M) -> (..., dh)."""
    m, k, d_sub = books.shape
    m_idx = jnp.arange(m)
    cw = books[m_idx, codes.astype(jnp.int32)]       # (..., M, d_sub)
    return cw.reshape(*codes.shape[:-1], m * d_sub)


def calibrate_kvq(key, samples, m: int, book_size: int, iters: int = 15):
    """k-means codebooks from sampled cache vectors: (N, dh) -> (M, K, d_sub)."""
    from repro.core.baselines import kmeans
    n, dh = samples.shape
    d_sub = dh // m
    xs = samples.reshape(n, m, d_sub)
    keys = jax.random.split(key, m)
    return jnp.stack([kmeans(keys[i], xs[:, i, :], book_size, iters)
                      for i in range(m)])


def decode_attention_kvq_sharded(cfg: ModelConfig, cache, q, k_new, v_new,
                                 pos, mesh, seq_axes):
    """Explicit shard_map schedule for single-stream long-context decode
    (§Perf iteration 7): each shard ADC-scans its local slice of the code
    cache, the softmax reduces via (pmax, psum), and value aggregation
    psums per-shard partial histograms — the same shard/merge pattern as
    the paper's distributed billion-scale search. No sequence gather.
    """
    from jax.sharding import PartitionSpec as P

    m, kk, d_sub = _dims(cfg)
    b, h, dh = q.shape
    hkv = cfg.num_kv_heads
    rep = h // hkv
    s = cache["k_codes"].shape[1]
    axes = tuple(a for a in (seq_axes if isinstance(seq_axes, (tuple, list))
                             else (seq_axes,)) if a)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    s_loc = s // n_shards

    def body(k_codes, v_codes, k_books, v_books, q_, k_new_, v_new_, pos_):
        # shard offset along the flattened seq axes
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        off = idx * s_loc

        kc_new = quantize_vectors_per_head(k_new_, k_books)
        vc_new = quantize_vectors_per_head(v_new_, v_books)
        # write only on the owning shard
        local_pos = jnp.clip(pos_ - off, 0, s_loc - 1)
        own = (pos_ >= off) & (pos_ < off + s_loc)
        k_upd = jax.lax.dynamic_update_slice_in_dim(
            k_codes, kc_new[:, None], local_pos, axis=1)
        v_upd = jax.lax.dynamic_update_slice_in_dim(
            v_codes, vc_new[:, None], local_pos, axis=1)
        k_codes = jnp.where(own, k_upd, k_codes)
        v_codes = jnp.where(own, v_upd, v_codes)

        qg = q_.reshape(b, hkv, rep, m, d_sub)
        lut = jnp.einsum("bhrms,hmks->bhrmk", qg.astype(jnp.float32),
                         k_books)

        codes = k_codes.astype(jnp.int32)                    # (B,S_loc,Hkv,M)

        def scan_one(lut_bhr, codes_bh):                     # (M,K), (S,M)
            mi = jnp.arange(m)[None, :]
            return jnp.sum(lut_bhr[mi, codes_bh], axis=1)    # (S_loc,)

        logits = jax.vmap(jax.vmap(jax.vmap(
            scan_one, in_axes=(0, None)), in_axes=(0, 1)), in_axes=(0, 0))(
            lut, codes) / jnp.sqrt(dh)                       # (B,Hkv,rep,S_loc)
        gpos = off + jnp.arange(s_loc)
        logits = jnp.where((gpos <= pos_)[None, None, None, :], logits,
                           -jnp.inf)
        # global softmax via pmax/psum
        mx = logits.max(-1, keepdims=True)
        for a in axes:
            mx = jax.lax.pmax(mx, a)
        p = jnp.exp(logits - mx)
        denom = p.sum(-1, keepdims=True)
        for a in axes:
            denom = jax.lax.psum(denom, a)
        w = p / jnp.maximum(denom, 1e-30)

        onehot = jax.nn.one_hot(v_codes.astype(jnp.int32), kk,
                                dtype=jnp.float32)           # (B,S,Hkv,M,K)
        hist = jnp.einsum("bhrs,bshmk->bhrmk", w, onehot)
        for a in axes:
            hist = jax.lax.psum(hist, a)
        out = jnp.einsum("bhrmk,hmks->bhrms", hist, v_books)
        return out.reshape(b, h, dh).astype(q_.dtype), k_codes, v_codes

    seq_spec = seq_axes if not isinstance(seq_axes, (tuple, list)) else \
        tuple(seq_axes)
    codes_spec = P(None, seq_spec, None, None)
    from repro.parallel import hints as _hints
    from repro.utils.compat import shard_map as _shard_map
    with _hints.disabled():
        out, k_codes, v_codes = _shard_map(
            body, mesh=mesh,
            in_specs=(codes_spec, codes_spec, P(), P(), P(), P(), P(), P()),
            out_specs=(P(), codes_spec, codes_spec),
            check_vma=False,
        )(cache["k_codes"], cache["v_codes"], cache["k_books"],
          cache["v_books"], q, k_new, v_new, pos)
    return out, {**cache, "k_codes": k_codes, "v_codes": v_codes}


def decode_attention_kvq(cfg: ModelConfig, cache, q, k_new, v_new, pos):
    """One decode step against the compressed cache (single layer).

    cache: {"k_codes"/"v_codes" (B, S, Hkv, M), "k_books"/"v_books"
            (Hkv, M, K, d_sub)}  — the per-layer slice (scan strips ng).
    q:     (B, H, dh) current query;  k_new/v_new: (B, Hkv, dh).
    Returns (attention output (B, H, dh), updated cache).

    Routes to the explicit shard_map schedule for single-stream
    long-context serving (batch unsharded, sequence spread over the mesh).
    """
    mesh = hints.current_mesh()
    rules = hints.current_rules()
    if mesh is not None and rules is not None and rules.get("batch") is None:
        seq_axes = rules.get("kv_seq")
        if seq_axes:
            n = 1
            for a in (seq_axes if isinstance(seq_axes, (tuple, list))
                      else (seq_axes,)):
                n *= mesh.shape[a]
            if cache["k_codes"].shape[1] % n == 0:
                return decode_attention_kvq_sharded(
                    cfg, cache, q, k_new, v_new, pos, mesh, seq_axes)
    m, kk, d_sub = _dims(cfg)
    b, h, dh = q.shape
    hkv = cfg.num_kv_heads
    rep = h // hkv
    s = cache["k_codes"].shape[1]

    # --- encode the new K/V token and write its codes at `pos` ---
    k_codes_new = quantize_vectors_per_head(k_new, cache["k_books"])  # (B,Hkv,M)
    v_codes_new = quantize_vectors_per_head(v_new, cache["v_books"])
    k_codes = jax.lax.dynamic_update_slice_in_dim(
        cache["k_codes"], k_codes_new[:, None], pos, axis=1)
    v_codes = jax.lax.dynamic_update_slice_in_dim(
        cache["v_codes"], v_codes_new[:, None], pos, axis=1)

    # --- LUT build: O(H*M*K*d_sub), independent of S ---
    qg = q.reshape(b, hkv, rep, m, d_sub)
    lut = jnp.einsum("bhrms,hmks->bhrmk", qg.astype(jnp.float32),
                     cache["k_books"])                       # (B,Hkv,rep,M,K)

    # --- ADC scan over the cache: gather-sum, O(S*M) per head ---
    # logits[b,h,r,s] = sum_m lut[b,h,r,m, k_codes[b,s,h,m]]
    codes = k_codes.astype(jnp.int32)                        # (B,S,Hkv,M)

    def scan_one(lut_bhr, codes_bh):                         # (M,K), (S,M)
        m_idx = jnp.arange(m)[None, :]
        return jnp.sum(lut_bhr[m_idx, codes_bh], axis=1)     # (S,)

    logits = jax.vmap(  # over B
        jax.vmap(       # over Hkv
            jax.vmap(scan_one, in_axes=(0, None)),           # over rep
            in_axes=(0, 1)),
        in_axes=(0, 0))(lut, codes)                          # (B,Hkv,rep,S)
    logits = hints.hint(logits, "batch", None, None, "kv_seq")
    logits = logits / jnp.sqrt(dh)
    valid = (jnp.arange(s) <= pos)[None, None, None, :]
    w = jax.nn.softmax(jnp.where(valid, logits, -jnp.inf), axis=-1)
    w = hints.hint(w, "batch", None, None, "kv_seq")

    # --- compressed-domain value aggregation: weight histogram + matmul ---
    # One-hot einsum (not scatter-add): under pjit the contraction over the
    # SHARDED sequence axis stays local per shard and reduces with one tiny
    # (B,Hkv,rep,M,K) all-reduce; the scatter formulation forced GSPMD to
    # all-gather the full-length softmax weights (§Perf iteration 7).
    onehot = jax.nn.one_hot(v_codes.astype(jnp.int32), kk,
                            dtype=jnp.float32)               # (B,S,Hkv,M,K)
    onehot = hints.hint(onehot, "batch", "kv_seq", None, None, None)
    hist = jnp.einsum("bhrs,bshmk->bhrmk", w, onehot)        # (B,Hkv,rep,M,K)
    out = jnp.einsum("bhrmk,hmks->bhrms", hist, cache["v_books"])
    out = out.reshape(b, h, dh).astype(q.dtype)

    new_cache = {**cache, "k_codes": k_codes, "v_codes": v_codes}
    return out, new_cache


def quantize_vectors_per_head(x, books):
    """x (B, Hkv, dh), books (Hkv, M, K, d_sub) -> (B, Hkv, M) uint8."""
    return jax.vmap(quantize_vectors, in_axes=(1, 0), out_axes=1)(x, books)
