"""Model zoo: the 10 assigned architectures as pure-JAX scanned-layer models.

Families:
  * transformer — dense decoder LMs (yi-6b, minitron-8b, mistral-large-123b,
    gemma3-12b incl. 5:1 local:global, chameleon-34b), MoE decoder LMs
    (deepseek-moe-16b, moonshot-v1-16b-a3b) and the encoder-only
    hubert-xlarge (bidirectional + masked-frame objective).
  * rwkv6 — attention-free SSM (Finch, data-dependent decay).
  * griffin — RecurrentGemma hybrid (RG-LRU + local attention, 1:2).

Every family exposes the same functional surface:
  init(key, cfg) -> params                    (or jax.eval_shape-able)
  forward(params, cfg, batch) -> logits
  init_cache(cfg, batch, max_len) -> cache    (decoder families)
  decode_step(params, cfg, cache, tok, pos) -> (logits, cache)
  logical_axes(cfg) -> pytree of logical-axis tuples (for sharding rules)
"""
from repro.models.config import ModelConfig

__all__ = ["ModelConfig"]
