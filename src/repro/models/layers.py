"""Shared layer substrate for the model zoo.

Everything is a pure function over plain dict pytrees. Parameters carry a
parallel "logical axes" pytree (built by each family's ``logical_axes``)
that the sharding rules in ``repro.parallel`` map to mesh axes.

Attention comes in three execution strategies:
  * full      — one (Tq, Tk) score matrix; used for short sequences.
  * chunked   — flash-style: ``lax.scan`` over KV chunks with a running
                (max, denom, acc) triple, outer ``lax.scan`` over Q chunks.
                O(Tq * Ck) live memory; used for long prefill.
  * decode    — single-token query against a cache (optionally
                MCQ-compressed — see repro/models/kvq.py).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel import hints


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, *, scale: float | None = None,
               fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[0]
    s = scale if scale is not None else 1.0 / jnp.sqrt(fan)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., T, H, dh), positions: (..., T). Rotates pairs (even, odd)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., :, None, :]                        # (..., T, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: int | None,
               k_valid=None):
    """Additive mask bias (0 or -inf): q_pos (Tq,), k_pos (Tk,) -> (Tq, Tk)."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    if k_valid is not None:
        ok &= k_valid[None, :]
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def repeat_kv(k, rep: int):
    """GQA -> MHA expansion: (B, T, Hkv, dh) -> (B, T, Hkv*rep, dh).

    TP-friendly formulation: the kv projections stay replicated across the
    model axis (small), queries shard by head, and the repeated kv shards
    by head too — avoids GSPMD padding a 4-8-way kv-head axis up to a
    16-way mesh axis (verified 3x flops blowup without this)."""
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def full_attention(q, k, v, q_pos, k_pos, *, causal: bool,
                   window: int | None = None):
    """q: (B, Tq, H, dh), k/v: (B, Tk, Hkv, dh). Returns (B, Tq, H, dh)."""
    b, tq, h, dh = q.shape
    k = repeat_kv(k, h // k.shape[2])
    v = repeat_kv(v, h // v.shape[2])
    k = hints.hint(k, "batch", None, "heads", None)
    v = hints.hint(v, "batch", None, "heads", None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(dh)
    scores = hints.hint(scores, "batch", "heads", None, None)
    bias = _mask_bias(q_pos, k_pos, causal=causal, window=window)
    w = jax.nn.softmax(scores + bias[None, None], axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention(q, k, v, q_pos, k_pos, *, causal: bool,
                      window: int | None = None, q_chunk: int = 512,
                      kv_chunk: int = 512):
    """Flash-style memory-efficient attention (pure JAX).

    Outer scan over Q chunks, inner scan over KV chunks with a running
    (row-max, denominator, accumulator). Live memory O(q_chunk * kv_chunk)
    per (batch, head) instead of O(Tq * Tk).
    """
    b, tq, h, dh = q.shape
    k = repeat_kv(k, h // k.shape[2])
    v = repeat_kv(v, h // v.shape[2])
    tk = k.shape[1]
    q_chunk = min(q_chunk, tq)
    kv_chunk = min(kv_chunk, tk)
    assert tq % q_chunk == 0 and tk % kv_chunk == 0
    nq, nk = tq // q_chunk, tk // kv_chunk

    qc = q.reshape(b, nq, q_chunk, h, dh)
    qp = q_pos.reshape(nq, q_chunk)
    kc = k.reshape(b, nk, kv_chunk, h, dh)
    vc = v.reshape(b, nk, kv_chunk, h, dh)
    kp = k_pos.reshape(nk, kv_chunk)
    scale = 1.0 / jnp.sqrt(dh)

    def q_body(_, qi):
        q_blk, qp_blk = qi                       # (B, Cq, H, dh), (Cq,)

        def kv_body(carry, ki):
            m, l, acc = carry
            k_blk, v_blk, kp_blk = ki
            s = jnp.einsum("bqhd,bkhd->bhqk",
                           q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            s = hints.hint(s, "batch", "heads", None, None)
            s = s + _mask_bias(qp_blk, kp_blk, causal=causal,
                               window=window)[None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new = -inf): exp(-inf - -inf)
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,H,Cq,dh)
        return None, out.transpose(0, 2, 1, 3)         # (B,Cq,H,dh)

    _, outs = jax.lax.scan(q_body, None,
                           (qc.transpose(1, 0, 2, 3, 4), qp))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, tq, h, dh)
    return out.astype(q.dtype)


def attention(q, k, v, q_pos, k_pos, cfg: ModelConfig, *, causal: bool,
              window: int | None = None):
    """Strategy dispatch: full matrix for short sequences, chunked for long."""
    tq, tk = q.shape[1], k.shape[1]
    if tq * tk <= 2048 * 2048 or tq % min(cfg.attn_chunk, tq) != 0:
        return full_attention(q, k, v, q_pos, k_pos, causal=causal,
                              window=window)
    return chunked_attention(q, k, v, q_pos, k_pos, causal=causal,
                             window=window, q_chunk=cfg.attn_chunk,
                             kv_chunk=cfg.attn_chunk)


def decode_attention(q, k_cache, v_cache, pos, dh: int):
    """Single-step decode: q (B, H, dh) vs cache (B, S, Hkv, dh); positions
    >= ``pos`` are masked (cache not yet filled). Returns (B, H, dh).

    The natural decode sharding is the cache SEQUENCE axis (kv_seq rule):
    each shard scores its slice and the softmax reduces across shards, so
    the (small) kv-head axis never has to divide the mesh."""
    b, s, hkv, _ = k_cache.shape
    h = q.shape[1]
    rep = h // hkv
    qg = q.reshape(b, hkv, rep, dh)
    scores = jnp.einsum("bhrd,bshd->bhrs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / jnp.sqrt(dh)
    scores = hints.hint(scores, "batch", None, None, "kv_seq")
    valid = (jnp.arange(s) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrs,bshd->bhrd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (projections + norms)
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig):
    dh = cfg.dh
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (cfg.d_model, cfg.num_heads * dh), cfg.param_dtype),
        "wk": dense_init(k2, (cfg.d_model, cfg.num_kv_heads * dh), cfg.param_dtype),
        "wv": dense_init(k3, (cfg.d_model, cfg.num_kv_heads * dh), cfg.param_dtype),
        "wo": dense_init(k4, (cfg.num_heads * dh, cfg.d_model), cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), cfg.param_dtype)
        p["k_norm"] = jnp.zeros((dh,), cfg.param_dtype)
    return p


def attn_axes(cfg: ModelConfig):
    p = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = (None,)
        p["k_norm"] = (None,)
    return p


def qkv_project(p, cfg: ModelConfig, x, positions):
    """x (B, T, d) -> q (B, T, H, dh), k/v (B, T, Hkv, dh) with RoPE."""
    b, t, _ = x.shape
    dh = cfg.dh
    q = (x @ p["wq"].astype(cfg.compute_dtype)).reshape(b, t, cfg.num_heads, dh)
    k = (x @ p["wk"].astype(cfg.compute_dtype)).reshape(b, t, cfg.num_kv_heads, dh)
    v = (x @ p["wv"].astype(cfg.compute_dtype)).reshape(b, t, cfg.num_kv_heads, dh)
    q = hints.hint(q, "batch", None, "heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(p, cfg: ModelConfig, x, positions, *, causal: bool,
               window: int | None = None):
    q, k, v = qkv_project(p, cfg, x, positions)
    out = attention(q, k, v, positions, positions, cfg, causal=causal,
                    window=window)
    b, t = x.shape[:2]
    return out.reshape(b, t, -1) @ p["wo"].astype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (cfg.d_model, d_ff), cfg.param_dtype),
        "w_up": dense_init(k2, (cfg.d_model, d_ff), cfg.param_dtype),
        "w_down": dense_init(k3, (d_ff, cfg.d_model), cfg.param_dtype),
    }


def mlp_axes(cfg: ModelConfig):
    return {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
            "w_down": ("ffn", "embed")}


def mlp_block(p, cfg: ModelConfig, x):
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    ffn_axes = ("batch",) + (None,) * (x.ndim - 2) + ("ffn",)
    g = act(hints.hint(x @ p["w_gate"].astype(cfg.compute_dtype), *ffn_axes))
    u = hints.hint(x @ p["w_up"].astype(cfg.compute_dtype), *ffn_axes)
    out = (g * u) @ p["w_down"].astype(cfg.compute_dtype)
    return hints.hint(out, "batch", *([None] * (x.ndim - 1)))


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits, labels, mask=None, *, z_loss: float = 0.0):
    """Mean CE over valid positions. logits (..., V) f32-upcast; labels int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)
