from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    qhadam,
    sgd,
    clip_by_global_norm,
    chain_clip,
)
from repro.optim.schedules import (
    one_cycle,
    cosine_decay,
    linear_warmup_cosine,
    constant,
    linear_anneal,
)

__all__ = [
    "Optimizer", "adam", "adamw", "qhadam", "sgd",
    "clip_by_global_norm", "chain_clip",
    "one_cycle", "cosine_decay", "linear_warmup_cosine", "constant",
    "linear_anneal",
]
