"""Learning-rate / coefficient schedules as pure ``step -> value`` functions.

Includes One-Cycle (Smith & Topin 2017), which the UNQ paper uses for fast
convergence (§3.4), and the linear anneal used for the paper's beta
coefficient (1.0 -> 0.05 over training).
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_anneal(start: float, end: float, total_steps: int):
    """Paper's beta schedule: linear from ``start`` to ``end``."""

    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return jnp.asarray(start + (end - start) * frac, jnp.float32)

    return fn


def one_cycle(max_lr: float, total_steps: int, pct_start: float = 0.3,
              div_factor: float = 25.0, final_div_factor: float = 1e4):
    """One-Cycle LR: cosine ramp lr0 -> max_lr over ``pct_start`` of training,
    then cosine anneal max_lr -> max_lr / final_div_factor."""
    lr0 = max_lr / div_factor
    lr_end = max_lr / final_div_factor
    up = max(int(total_steps * pct_start), 1)
    down = max(total_steps - up, 1)

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        frac_up = jnp.clip(step / up, 0.0, 1.0)
        lr_up = lr0 + (max_lr - lr0) * 0.5 * (1 - jnp.cos(jnp.pi * frac_up))
        frac_dn = jnp.clip((step - up) / down, 0.0, 1.0)
        lr_dn = lr_end + (max_lr - lr_end) * 0.5 * (1 + jnp.cos(jnp.pi * frac_dn))
        return jnp.where(step < up, lr_up, lr_dn).astype(jnp.float32)

    return fn


def cosine_decay(max_lr: float, total_steps: int, warmup: int = 0,
                 min_lr: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = max_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_lr + (max_lr - min_lr) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos).astype(jnp.float32)

    return fn


def linear_warmup_cosine(max_lr: float, total_steps: int, warmup: int):
    return cosine_decay(max_lr, total_steps, warmup=warmup)
