"""Pure-JAX pytree optimizers (optax is not available in this environment).

An ``Optimizer`` is a pair of pure functions over arbitrary parameter
pytrees, mirroring the optax GradientTransformation contract so the training
loops compose with pjit (optimizer state shards exactly like the params):

    state  = opt.init(params)
    params, state = opt.apply(params, grads, state, lr)

Implemented: SGD(+momentum), Adam, AdamW, and QHAdam (Quasi-Hyperbolic Adam,
Ma & Yarats 2018) — the optimizer the UNQ paper trains with (§3.4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import global_norm

Params = Any
Grads = Any
OptState = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    apply: Callable[[Params, Grads, OptState, jax.Array], tuple[Params, OptState]]
    name: str = "optimizer"


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": _zeros_like_f32(params), "count": jnp.zeros((), jnp.int32)}

    def apply(params, grads, state, lr):
        def upd(p, g, mu):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            mu = momentum * mu + g
            return (p.astype(jnp.float32) - lr * mu).astype(p.dtype), mu

        flat = jax.tree.map(upd, params, grads, state["mu"])
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"mu": new_mu, "count": state["count"] + 1}

    return Optimizer(init, apply, "sgd")


def _adam_family(b1: float, b2: float, eps: float, weight_decay: float,
                 nu1: float | None, nu2: float | None, name: str,
                 decay_mask: Callable[[str], bool] | None = None) -> Optimizer:
    """Shared Adam/AdamW/QHAdam machinery.

    nu1/nu2 None -> plain Adam update; otherwise the quasi-hyperbolic
    interpolation between the raw gradient and the EMA (QHAdam):
        num = (1 - nu1) * g + nu1 * m_hat
        den = sqrt((1 - nu2) * g^2 + nu2 * v_hat) + eps
    """

    def init(params):
        return {
            "m": _zeros_like_f32(params),
            "v": _zeros_like_f32(params),
            "count": jnp.zeros((), jnp.int32),
        }

    def apply(params, grads, state, lr):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            m_hat = m / c1
            v_hat = v / c2
            if nu1 is None:
                num, den = m_hat, jnp.sqrt(v_hat) + eps
            else:
                num = (1 - nu1) * g + nu1 * m_hat
                den = jnp.sqrt((1 - nu2) * jnp.square(g) + nu2 * v_hat) + eps
            step = num / den
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        is_t = lambda t: isinstance(t, tuple)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_t)
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_t)
        return new_params, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init, apply, name)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return _adam_family(b1, b2, eps, 0.0, None, None, "adam")


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    return _adam_family(b1, b2, eps, weight_decay, None, None, "adamw")


def qhadam(nu1: float = 0.7, nu2: float = 1.0, b1: float = 0.995,
           b2: float = 0.999, eps: float = 1e-8,
           weight_decay: float = 0.0) -> Optimizer:
    """Quasi-Hyperbolic Adam with the recommended defaults from the paper."""
    return _adam_family(b1, b2, eps, weight_decay, nu1, nu2, "qhadam")


def clip_by_global_norm(grads: Grads, max_norm: float) -> tuple[Grads, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""

    def apply(params, grads, state, lr):
        grads, _ = clip_by_global_norm(grads, max_norm)
        return opt.apply(params, grads, state, lr)

    return Optimizer(opt.init, apply, f"{opt.name}+clip{max_norm}")
