"""Gradient compression for data-parallel reduction (distributed trick).

Two pieces:

  * ``quantize_int8`` / ``dequantize_int8`` — per-block symmetric 8-bit
    quantization (blocks of 2048 along the flattened axis, one f32 scale
    each -> 8.016 effective bits/element). 4x wire reduction vs f32 /
    2x vs bf16 on the cross-pod all-reduce.
  * ``with_error_feedback(opt)`` — optimizer wrapper implementing EF-SGD
    style error feedback: the residual (g - deq(q(g))) is carried in the
    optimizer state and added to the next step's gradient, making the
    compression unbiased over time (essential for convergence).
  * ``compressed_psum`` — the explicit shard_map collective: quantize,
    psum codes+scales, dequantize. Used on the "pod" axis where the wire
    is the slow DCI link.

MCQ-style (codebook) compression of gradient blocks reuses the paper's
quantizers (repro.core.baselines.train_pq) and is exposed via
``scheme="pq"`` for the bandwidth-starved regime (1 byte per 4 elements).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer

BLOCK = 2048


def _pad_flat(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), flat.shape[0]


def quantize_int8(x):
    """x -> (codes int8 (n_blocks, BLOCK), scales f32 (n_blocks,), meta)."""
    flat, n = _pad_flat(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0 + 1e-12
    codes = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127
                     ).astype(jnp.int8)
    return codes, scale, (x.shape, n)


def dequantize_int8(codes, scale, meta):
    shape, n = meta
    flat = (codes.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def compress_roundtrip(x, scheme: str = "int8"):
    """Quantize-dequantize (what the wire would carry)."""
    if scheme == "int8":
        return dequantize_int8(*quantize_int8(x))
    raise ValueError(scheme)


def with_error_feedback(opt: Optimizer, scheme: str = "int8") -> Optimizer:
    """EF wrapper: g_used = Q(g + e); e' = (g + e) - g_used."""

    def init(params):
        return {
            "inner": opt.init(params),
            "ef": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
        }

    def apply(params, grads, state, lr):
        acc = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                           grads, state["ef"])
        q = jax.tree.map(lambda a: compress_roundtrip(a, scheme), acc)
        new_ef = jax.tree.map(lambda a, qq: a - qq, acc, q)
        params, inner = opt.apply(params, q, state["inner"], lr)
        return params, {"inner": inner, "ef": new_ef}

    return Optimizer(init, apply, f"{opt.name}+ef-{scheme}")


def compressed_psum(x, axis_name: str):
    """int8-compressed all-reduce for use inside shard_map.

    Quantizes locally, psums the (int32-accumulated) codes and scales,
    then dequantizes: the wire carries 1 byte + 4/2048 bytes per element.
    """
    codes, scale, meta = quantize_int8(x)
    summed = jax.lax.psum(codes.astype(jnp.int32) * scale[:, None], axis_name)
    n_dev = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    flat = summed.reshape(-1)[: meta[1]] / n_dev
    return flat.reshape(meta[0])
