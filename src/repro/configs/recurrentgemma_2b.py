"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention 1:2
[arXiv:2402.19427].

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000,
rnn width 2560, local window 2048. Pattern: 8 groups of (rec, rec, attn)
+ 2 trailing recurrent layers. Decode state is O(1) + bounded window,
so long_500k runs natively.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b",
    family="griffin",
    kind="decoder",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    act="gelu",
    window=2048,
    rnn_width=2560,
    conv_width=4,
    attn_every=3,
)

SMOKE = FULL.with_(
    name="recurrentgemma-2b-smoke",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=160, vocab_size=256, window=8, rnn_width=64,
    compute_dtype=jnp.float32, remat="none",
)
