"""gemma3-12b — dense GQA with 5:1 local:global attention, 128k context
[hf:google/gemma-3-12b-pt].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
Layer pattern: 8 scanned groups of [5 x sliding-window(1024), 1 x global].
The long_500k bonus cell runs with cfg.kvq=True: the 8 global layers decode
against an MCQ-compressed KV cache (the paper's technique), bounding
global-KV memory (see DESIGN.md §4).
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma3-12b",
    family="transformer",
    kind="decoder",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    act="gelu",
    local_global_ratio=5,
    window=1024,
    qk_norm=True,
    use_post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=1e6,
)

# long-context serving variant: global layers hold MCQ-compressed KV
FULL_KVQ = FULL.with_(name="gemma3-12b-kvq", kvq=True, kvq_books=8,
                      kvq_book_size=256)

SMOKE = FULL.with_(
    name="gemma3-12b-smoke",
    num_layers=6, local_global_ratio=5, window=8,
    d_model=64, num_heads=4, num_kv_heads=2, d_ff=160,
    vocab_size=512, compute_dtype=jnp.float32, remat="none",
)
