"""rwkv6-1.6b — "Finch", attention-free SSM with data-dependent decay
[arXiv:2404.05892].

24L d_model=2048 d_ff=7168 vocab=65536 (head dim 64 -> 32 heads). O(1)
decode state, so long_500k runs natively. The paper's KV-compression
technique is inapplicable (no KV cache) — DESIGN.md §Arch-applicability.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv6",
    kind="decoder",
    num_layers=24,
    d_model=2048,
    num_heads=32,          # d_model / 64 (rwkv6 head size)
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
)

SMOKE = FULL.with_(
    name="rwkv6-1.6b-smoke",
    num_layers=2, d_model=128, num_heads=2, num_kv_heads=2, d_ff=256,
    vocab_size=256, compute_dtype=jnp.float32, remat="none",
)
