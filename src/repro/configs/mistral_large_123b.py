"""mistral-large-123b — dense GQA decoder
[hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mistral-large-123b",
    family="transformer",
    kind="decoder",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    act="silu",
)

SMOKE = FULL.with_(
    name="mistral-large-123b-smoke",
    num_layers=4, d_model=96, num_heads=6, num_kv_heads=2, d_ff=224,
    vocab_size=256, compute_dtype=jnp.float32, remat="none",
)
