"""The paper's own experimental configs (§4.1).

Deep1M: 96-d deep descriptors; BigANN1M: 128-d SIFT. Both at 8 and 16
bytes/vector (M codebooks of K=256), encoder/decoder with two 1024-unit
hidden layers, 256-d codewords, rerank top-500 (top-1000 at 1B scale).
"""
from repro.core.unq import UNQConfig
from repro.core.search import SearchConfig
from repro.core.training import TrainConfig

DEEP_8B = UNQConfig(dim=96, num_codebooks=8, codebook_size=256,
                    code_dim=256, hidden_dim=1024, num_hidden_layers=2)
DEEP_16B = DEEP_8B.with_(num_codebooks=16)
BIGANN_8B = DEEP_8B.with_(dim=128)
BIGANN_16B = BIGANN_8B.with_(num_codebooks=16)

SEARCH = SearchConfig(rerank=500, topk=100)
SEARCH_1B = SearchConfig(rerank=1000, topk=100)

TRAIN = TrainConfig(epochs=30, batch_size=256, lr=1e-3, alpha=0.01,
                    beta_start=1.0, beta_end=0.05)

# CPU-scale smoke variant (same code path, small model)
SMOKE = UNQConfig(dim=32, num_codebooks=4, codebook_size=64, code_dim=32,
                  hidden_dim=64, num_hidden_layers=2)
SMOKE_TRAIN = TrainConfig(epochs=2, batch_size=128, lr=1e-3)
