"""Architecture registry (``--arch <id>``), shape matrix, and input specs.

The 10 assigned architectures plus the paper's own UNQ configs. Each arch
module exports FULL (the exact published config, dry-run only) and SMOKE
(a reduced same-code-path config that runs a real step on CPU).

SHAPES defines the 4 assigned input shapes; CELLS enumerates the 40
(arch x shape) cells with skip annotations (encoder-only archs have no
decode; long_500k requires sub-quadratic decode state). The gemma3 KVQ
long-context variant is a bonus cell exercising the paper's technique.
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

_ARCH_MODULES = {
    "yi-6b": "repro.configs.yi_6b",
    "minitron-8b": "repro.configs.minitron_8b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}

ARCH_IDS = list(_ARCH_MODULES)


def get(arch: str, *, smoke: bool = False, variant: str | None = None) -> ModelConfig:
    """Look up an architecture config by id (``--arch``)."""
    mod = importlib.import_module(_ARCH_MODULES[arch])
    if variant:
        return getattr(mod, variant)
    return mod.SMOKE if smoke else mod.FULL


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    step: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# subquadratic-decode archs eligible for long_500k
_LONG_OK = {"rwkv6-1.6b", "recurrentgemma-2b"}
_ENCODER_ONLY = {"hubert-xlarge"}


def cell_status(arch: str, shape: str) -> str:
    """"run" or a skip reason for the (arch, shape) cell."""
    if arch in _ENCODER_ONLY and SHAPES[shape].step == "decode":
        return "skip: encoder-only arch has no decode step"
    if shape == "long_500k" and arch not in _LONG_OK:
        return ("skip: full-attention decode at 500k KV; run via the "
                "gemma3-12b-kvq bonus cell instead"
                if arch == "gemma3-12b"
                else "skip: pure full-attention arch (quadratic/unbounded KV)")
    return "run"


def all_cells():
    """All 40 (arch, shape) cells + the gemma3 KVQ bonus cell."""
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            cells.append((arch, shape, cell_status(arch, shape)))
    cells.append(("gemma3-12b-kvq", "long_500k", "run"))
    return cells


def input_specs(cfg: ModelConfig, shape: Shape, *, for_smoke: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of a step.

    train/prefill: the token (or stub-frame) batch. decode: the per-step
    token batch + position. Cache/params specs are built separately via
    jax.eval_shape in the dry-run driver.
    """
    b = shape.global_batch
    t = shape.seq_len
    if for_smoke:
        b, t = min(b, 2), min(t, 64)
    if cfg.input_mode == "frames":
        return {
            "frames": jax.ShapeDtypeStruct((b, t, cfg.frame_dim), jnp.float32),
            "targets": jax.ShapeDtypeStruct((b, t), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, t), jnp.bool_),
        }
    if shape.step == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}
    n = t + 1 if shape.step == "train" else t
    return {"tokens": jax.ShapeDtypeStruct((b, n), jnp.int32)}
