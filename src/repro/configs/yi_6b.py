"""yi-6b — dense llama-arch GQA decoder [arXiv:2403.04652].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="yi-6b",
    family="transformer",
    kind="decoder",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5e6,
    act="silu",
)

SMOKE = FULL.with_(
    name="yi-6b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=160,
    vocab_size=256, compute_dtype=jnp.float32, remat="none",
)
