"""deepseek-moe-16b — fine-grained MoE [arXiv:2401.06066].

28L d_model=2048 16H (GQA kv=16 = MHA) vocab=102400;
MoE: 64 routed experts (d_ff=1408 each), top-6, + 2 shared experts
(fused into one 2816-wide gated MLP). All layers MoE per the assignment
spec (the public checkpoint's first dense layer is noted in DESIGN.md).
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-moe-16b",
    family="transformer",
    kind="decoder",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    act="silu",
    moe=True,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    capacity_factor=1.25,
    router_balance="cv2",
)

SMOKE = FULL.with_(
    name="deepseek-moe-16b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=96,
    moe_d_ff=96, num_experts=8, top_k=2, num_shared_experts=2,
    vocab_size=256, compute_dtype=jnp.float32, remat="none",
)
