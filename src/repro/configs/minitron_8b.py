"""minitron-8b — width/depth-pruned Nemotron dense GQA [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minitron-8b",
    family="transformer",
    kind="decoder",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    act="silu",
)

SMOKE = FULL.with_(
    name="minitron-8b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=192,
    vocab_size=512, compute_dtype=jnp.float32, remat="none",
)
