"""chameleon-34b — early-fusion VLM [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. Image VQ tokens
live in the same 65536 vocabulary (early fusion), so the backbone is a
dense decoder with QK-norm (chameleon's stability fix); the VQ-VAE image
tokenizer frontend is a STUB producing token ids.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="chameleon-34b",
    family="transformer",
    kind="decoder",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    act="silu",
    qk_norm=True,
)

SMOKE = FULL.with_(
    name="chameleon-34b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=160,
    vocab_size=256, compute_dtype=jnp.float32, remat="none",
)
