"""moonshot-v1-16b-a3b — Moonlight-style MoE
[hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) vocab=163840; MoE 64 routed (d_ff=1408)
top-6 + 2 shared experts.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="transformer",
    kind="decoder",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    act="silu",
    moe=True,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    capacity_factor=1.25,
    router_balance="cv2",
)

SMOKE = FULL.with_(
    name="moonshot-v1-16b-a3b-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=96,
    moe_d_ff=96, num_experts=8, top_k=2, num_shared_experts=2,
    vocab_size=256, compute_dtype=jnp.float32, remat="none",
)
