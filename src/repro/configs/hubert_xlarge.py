"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

48L d_model=1280 16H d_ff=5120, 504 k-means targets. The conv waveform
frontend is a STUB per the assignment: input_specs() feeds precomputed
frame embeddings (B, T, 1280); training is masked-frame cluster prediction.
Encoder-only -> no decode shapes (see DESIGN.md skips).
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="hubert-xlarge",
    family="transformer",
    kind="encoder",
    input_mode="frames",
    frame_dim=1280,
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    act="gelu",
)

SMOKE = FULL.with_(
    name="hubert-xlarge-smoke",
    num_layers=2, d_model=64, frame_dim=64, num_heads=4, num_kv_heads=4,
    d_ff=160, vocab_size=64, compute_dtype=jnp.float32, remat="none",
)
