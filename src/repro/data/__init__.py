from repro.data.descriptors import (
    DescriptorDataset,
    make_synthetic_dataset,
    exact_knn,
    sample_triplets,
)
from repro.data.tokens import TokenStream, masked_frame_batch

__all__ = [
    "DescriptorDataset",
    "make_synthetic_dataset",
    "exact_knn",
    "sample_triplets",
    "TokenStream",
    "masked_frame_batch",
]
