"""Synthetic token / frame pipelines for the LM architecture zoo.

Deterministic, shardable streams:
  * ``TokenStream`` — zipfian token-id batches for decoder LMs (each
    data-parallel rank draws a disjoint substream; state = (step, rank) so
    the pipeline is exactly resumable from a checkpoint).
  * ``masked_frame_batch`` — HuBERT-style masked-prediction batches:
    precomputed frame embeddings (the conv frontend is a stub per the
    assignment) + k-means-style cluster targets + a mask.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int          # per-rank batch
    rank: int = 0
    world: int = 1
    seed: int = 0
    step: int = 0            # checkpointable pipeline position

    def next_batch(self) -> dict:
        """Returns {"tokens": (B, S+1) int32}; caller shifts for inputs/labels."""
        rng = np.random.default_rng(
            (self.seed, self.rank, self.step))
        # Zipf-ish marginal with short-range repetition structure so the
        # loss is learnable (pure uniform tokens give a flat loss surface).
        base = rng.zipf(1.3, size=(self.batch_size, self.seq_len + 1))
        tokens = (base % self.vocab_size).astype(np.int32)
        rep = rng.random((self.batch_size, self.seq_len + 1)) < 0.3
        shifted = np.roll(tokens, 1, axis=1)
        tokens = np.where(rep, shifted, tokens)
        self.step += 1
        return {"tokens": tokens}

    def state_dict(self) -> dict:
        return {"step": self.step, "rank": self.rank, "seed": self.seed}

    def load_state_dict(self, s: dict) -> None:
        self.step = int(s["step"])
        self.seed = int(s["seed"])


def masked_frame_batch(seed: int, batch: int, frames: int, dim: int,
                       num_targets: int, mask_prob: float = 0.2) -> dict:
    """HuBERT-style batch: frame embeddings + cluster targets + span mask."""
    rng = np.random.default_rng(seed)
    emb = rng.normal(0, 1, (batch, frames, dim)).astype(np.float32)
    targets = rng.integers(0, num_targets, (batch, frames)).astype(np.int32)
    mask = (rng.random((batch, frames)) < mask_prob)
    return {"frames": emb, "targets": targets, "mask": mask}
