"""Descriptor datasets + exact ground-truth nearest neighbors.

The paper evaluates on Deep1M/Deep1B (96-d CNN descriptors) and
BigANN1M/1B (128-d SIFT). Those datasets are not available offline, so the
pipeline provides statistically similar synthetic stand-ins:

  * ``deep``-style: L2-normalized activations of a random deep feature map
    (a random MLP applied to latent gaussians — correlated, low intrinsic
    dimension, unit norm, like the Deep1B descriptors of [3]).
  * ``sift``-style: non-negative, heavy-tailed histogram features with
    block-sparse structure, like SIFT.

Both are generated from a clustered latent mixture so nearest-neighbor
structure is non-trivial (pure i.i.d. gaussians make ANN meaninglessly hard
and flat). Everything is deterministic in the seed.

Exact k-NN (used for triplet sampling and for recall ground truth) is a
chunked brute-force scan in JAX — the same computation FAISS does on GPU in
the paper's setup.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DescriptorDataset:
    train: np.ndarray     # (n_train, D) learning set
    base: np.ndarray      # (n_base, D)  database to compress
    queries: np.ndarray   # (n_query, D) held-out queries
    gt_nn: np.ndarray     # (n_query,)   true NN of each query in `base`
    name: str = "synthetic"

    @property
    def dim(self) -> int:
        return self.train.shape[1]


# Calibrated so 8-byte quantizer distortion is a realistic 20-40% of the
# data variance (real Deep1M/SIFT behave this way): the latent mixture
# overlaps heavily (sigma 0.9 vs unit center spread) and a full-dimensional
# "texture" component is added in descriptor space — real descriptors carry
# high-entropy content that 64 bits cannot capture, which is exactly what a
# tight synthetic manifold lacks (RVQ was near-lossless without it).
_NOISE_SIGMA = 0.9
_TEXTURE_SIGMA = 0.55     # relative to the unit-norm descriptor


def _deep_like(rng: np.random.Generator, n: int, dim: int, latent: int,
               centers: np.ndarray, w1: np.ndarray, w2: np.ndarray) -> np.ndarray:
    z = centers[rng.integers(0, len(centers), n)] + rng.normal(
        0, _NOISE_SIGMA, (n, latent))
    h = np.maximum(z @ w1, 0.0)
    x = h @ w2
    x = x / (np.linalg.norm(x, axis=1, keepdims=True) + 1e-9)
    x = x + rng.normal(0, _TEXTURE_SIGMA / np.sqrt(dim), (n, dim))
    x = x / (np.linalg.norm(x, axis=1, keepdims=True) + 1e-9)
    return x.astype(np.float32)


def _sift_like(rng: np.random.Generator, n: int, dim: int, latent: int,
               centers: np.ndarray, w1: np.ndarray, w2: np.ndarray) -> np.ndarray:
    z = centers[rng.integers(0, len(centers), n)] + rng.normal(
        0, _NOISE_SIGMA, (n, latent))
    h = np.maximum(z @ w1, 0.0)
    x = np.abs(h @ w2)
    scale = np.mean(x)
    x = np.abs(x + rng.normal(0, _TEXTURE_SIGMA * scale, (n, dim)))
    # heavy-tailed histogram-ish counts, clipped like root-SIFT pipelines
    x = np.minimum(x ** 1.5 * 25.0, 255.0)
    return x.astype(np.float32)


def make_synthetic_dataset(kind: str = "deep", *, dim: int | None = None,
                           n_train: int = 20_000, n_base: int = 50_000,
                           n_query: int = 1_000, n_centers: int = 512,
                           latent: int = 24, seed: int = 0,
                           compute_gt: bool = True) -> DescriptorDataset:
    """Build a Deep1M/BigANN1M-like synthetic dataset (sizes configurable —
    the paper's 500k-train/1M-base protocol is the default in benchmarks,
    scaled down for CPU in tests)."""
    if dim is None:
        dim = 96 if kind == "deep" else 128
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1.0, (n_centers, latent))
    w1 = rng.normal(0, 1.0 / np.sqrt(latent), (latent, 4 * latent))
    w2 = rng.normal(0, 1.0 / np.sqrt(4 * latent), (4 * latent, dim))
    gen = _deep_like if kind == "deep" else _sift_like
    train = gen(rng, n_train, dim, latent, centers, w1, w2)
    base = gen(rng, n_base, dim, latent, centers, w1, w2)
    queries = gen(rng, n_query, dim, latent, centers, w1, w2)
    gt = exact_knn(queries, base, k=1)[:, 0] if compute_gt else np.zeros(
        (n_query,), np.int64)
    return DescriptorDataset(train, base, queries, gt,
                             name=f"{kind}{n_base // 1000}k")


def exact_knn(queries: np.ndarray, base: np.ndarray, k: int,
              batch: int = 256) -> np.ndarray:
    """Exact top-k neighbors by L2, chunked over queries: (Q, k) indices."""
    base_j = jnp.asarray(base)
    base_sq = jnp.sum(base_j * base_j, axis=1)

    @jax.jit
    def _knn(qb):
        d = (jnp.sum(qb * qb, axis=1)[:, None] - 2.0 * qb @ base_j.T
             + base_sq[None, :])
        _, idx = jax.lax.top_k(-d, k)
        return idx

    outs = []
    for s in range(0, queries.shape[0], batch):
        outs.append(np.asarray(_knn(jnp.asarray(queries[s:s + batch]))))
    return np.concatenate(outs, axis=0)


def sample_triplets(rng: np.random.Generator, train: np.ndarray,
                    neighbors: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-epoch positive/negative sampling (paper §3.4).

    neighbors: (n, >=200) each row = indices of the true NNs of train[i]
    (excluding i itself). Positives ~ top-3 NNs; negatives ~ ranks 100..200.
    Returns (pos_idx, neg_idx), each (n,).
    """
    n = train.shape[0]
    pos = neighbors[np.arange(n), rng.integers(0, 3, n)]
    hi = min(200, neighbors.shape[1])
    lo = min(100, hi - 1)
    neg = neighbors[np.arange(n), rng.integers(lo, hi, n)]
    return pos, neg


def epoch_neighbors(train: np.ndarray, k: int = 201, batch: int = 256) -> np.ndarray:
    """Top-k true NNs of every training point within the train set,
    excluding the point itself (column 0 of exact_knn is the point)."""
    nn = exact_knn(train, train, k=k, batch=batch)
    return nn[:, 1:]
