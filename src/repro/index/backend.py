"""Scan-backend registry: resolves which ADC-scan implementation an index
uses on the current device, instead of threading ``impl=`` strings through
every call site.

Backends are the kernel dispatch targets of ``repro.kernels.ops``:

  * ``xla``    — pure-jnp gather oracle; always available, and what the
                 distributed paths use inside pjit.
  * ``onehot`` — the MXU-shaped one-hot matmul formulation in plain XLA.
  * ``pallas`` — the fused Pallas TPU kernel (interpret mode off-TPU, so it
                 stays exercisable in CI but is never auto-selected there).

``resolve_scan_backend("auto")`` picks the highest-priority backend whose
``auto_select`` predicate holds on the current device (pallas on TPU, xla
elsewhere). Explicitly naming a registered backend always works — e.g.
benchmarks A/B all three on one host.

Backends also declare **capabilities** — feature flags the index layer
resolves against instead of branching on backend names:

  * ``streaming_topl`` — the backend has a stage-1 path that produces
    per-query top-L candidates WITHOUT materializing the (Q, N) score
    matrix (``ops.adc_scan_topl``). Backends without it fall back to the
    materialized full-matrix scan + ``lax.top_k``. Stage 2 keys off the
    same flag: streaming backends get the streaming rerank engine
    (chunked table decode / cross-query dedup), the rest the
    materialized vmap reranker.
  * ``fused_topl``     — the streaming stage-1 path is a single fused
    kernel (scan + running top-L heap in VMEM), not a chunked
    composition; ``candidate_generator_for`` resolves the streaming
    engine's kernel flavor off this flag.
  * ``fused_rerank``   — the backend runs stage 2 for table-decodable
    quantizers as the single fused gather-decode-distance kernel
    (``ops.rerank_gather_dist``): candidate-code tiles stream HBM->VMEM
    and ||q - recon||^2 reduces in place, so the (Q, L, D)
    reconstruction never exists. Streaming backends without it use the
    chunked ``lax.scan`` rerank with the same guarantee.
  * ``dispatch_topl``  — the backend has a cell-batched IVF stage-1 face
    (``ops.adc_dispatch_topl``): probed cells are routed MoE-style into
    dense per-cell query batches on device and each cell's contiguous
    code range is streamed once for all co-probing queries, replacing
    the host-built padded plan. Backends without it (onehot — its IVF
    formulation IS the materialized full scan) keep the gathered path.
  * ``tuned``          — the backend's kernel block/chunk parameters
    resolve through the autotuner registry (``repro.kernels.tune``):
    per-(device kind, kernel, shape bucket) winners from a persisted
    sweep cache, hand-pinned defaults as the zero-cache fallback.
    ``Index.save`` records the active tuning fingerprint for such
    backends so saved-index provenance includes how it was timed.
  * ``quantized_lut``  — the backend's stage-1 faces accept reduced-
    precision score tables (``lut_dtype='float16' | 'int8'``): the scan
    selects an over-fetched candidate pool under quantized scores and
    the pool is re-scored with the exact f32 chain before the final
    top-L (``repro.kernels.lut_quant``). ``Index.search`` gates its
    ``lut_dtype=`` argument on this flag — backends without it (onehot's
    materialized matrix) reject quantized requests loudly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

import jax


@dataclasses.dataclass(frozen=True)
class ScanBackend:
    name: str
    priority: int                       # higher wins for "auto"
    auto_select: Callable[[], bool]     # eligible for auto-resolution?
    description: str = ""
    capabilities: frozenset = frozenset()


_REGISTRY: dict[str, ScanBackend] = {}


def register_scan_backend(name: str, *, priority: int,
                          auto_select: Callable[[], bool] = lambda: True,
                          description: str = "",
                          capabilities: Iterable[str] = ()) -> None:
    """Register (or override) a scan backend for auto-resolution."""
    _REGISTRY[name] = ScanBackend(name, priority, auto_select, description,
                                  frozenset(capabilities))


def backend_capabilities(name: str) -> frozenset:
    """Declared capability flags of a registered backend."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown scan backend {name!r}; registered: "
            f"{available_scan_backends()}")
    return _REGISTRY[name].capabilities


def backend_supports(name: str, capability: str) -> bool:
    """True iff ``name`` is registered and declares ``capability``."""
    return name in _REGISTRY and capability in _REGISTRY[name].capabilities


def available_scan_backends() -> list[str]:
    """All registered backend names, highest priority first."""
    return [b.name for b in
            sorted(_REGISTRY.values(), key=lambda b: -b.priority)]


def resolve_scan_backend(name: str | None = "auto") -> str:
    """Map a backend request to a concrete ``impl`` string for kernels.ops.

    ``"auto"``/None picks per-device; a concrete registered name is passed
    through (letting callers pin a backend for A/B runs); anything else is
    an error listing the registry.
    """
    if name is None or name == "auto":
        eligible = [b for b in _REGISTRY.values() if b.auto_select()]
        if not eligible:
            return "xla"
        return max(eligible, key=lambda b: b.priority).name
    if name in _REGISTRY:
        return name
    raise ValueError(
        f"unknown scan backend {name!r}; registered: "
        f"{available_scan_backends()} (or 'auto')")


def encode_impl_for(backend: str) -> str:
    """The encode-kernel impl paired with a scan backend (``unq_encode``
    has no one-hot variant, so ``onehot`` scans encode via xla)."""
    return "pallas" if backend == "pallas" else "xla"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


register_scan_backend(
    "xla", priority=0,
    description="pure-jnp gather oracle (always available)",
    capabilities=("streaming_topl", "dispatch_topl", "tuned",
                  "quantized_lut"))
register_scan_backend(
    "onehot", priority=10, auto_select=lambda: False,
    description="one-hot matmul formulation in plain XLA (A/B target)")
register_scan_backend(
    "pallas", priority=100, auto_select=_on_tpu,
    description="fused Pallas TPU kernel (interpret mode off-TPU)",
    capabilities=("streaming_topl", "fused_topl", "fused_rerank",
                  "dispatch_topl", "tuned", "quantized_lut"))
