"""Pluggable stage-1 candidate generation (the streaming engine's index
face).

``Index.search`` and ``ShardedIndex`` delegate stage 1 — d2 scores over
the compressed database plus per-query top-L — to a ``CandidateGenerator``
resolved through the scan-backend registry, instead of hardcoding one
"full (Q, N) matrix + lax.top_k" implementation:

  * ``StreamingTopL``     backends with the ``streaming_topl`` capability
                          (pallas: fused scan+top-L kernel; xla: chunked
                          scan + incremental merge). Peak memory O(Q*L);
                          the (Q, N) score matrix is never materialized.
  * ``MaterializedTopL``  the classic full-matrix scan for backends
                          without a streaming path (onehot), kept as the
                          A/B reference.

Both produce bit-identical (score, index) results — the streaming paths
reproduce ``lax.top_k`` tie semantics exactly — so generator selection is
purely a memory/performance decision, never a quality one. Per-point score
biases (RVQ's ||decode(code)||^2) flow through either path.
"""
from __future__ import annotations

import abc
import functools

import jax
import jax.numpy as jnp

from repro.index.backend import backend_supports, resolve_scan_backend
from repro.kernels import ops


class CandidateGenerator(abc.ABC):
    """Stage 1 strategy: codes + per-query LUTs -> top-L candidates."""

    #: whether this generator allocates the full (Q, N) score matrix
    materializes_scores: bool

    def __init__(self, impl: str):
        self.impl = impl                # concrete kernels.ops impl string

    @abc.abstractmethod
    def topl(self, codes, luts, bias, *, topl: int):
        """codes (N, M), luts (Q, M, K), bias None | (N,) ->
        (scores, indices), each (Q, min(topl, N)), sorted closest-first
        with ties broken toward the smaller database index."""

    def __repr__(self):
        return f"{type(self).__name__}(impl={self.impl!r})"


@functools.partial(jax.jit, static_argnames=("topl", "impl"))
def _materialized_topl(codes, luts, bias, *, topl: int, impl: str):
    scores = ops.adc_scan_batch(codes, luts, impl=impl)    # (Q, N)
    if bias is not None:
        scores = scores + bias[None, :]
    neg, idx = jax.lax.top_k(-scores, topl)
    return -neg, idx


class MaterializedTopL(CandidateGenerator):
    """Full (Q, N) score matrix + ``lax.top_k`` (the pre-streaming stage 1;
    reference semantics, O(Q*N) peak memory)."""

    materializes_scores = True

    def topl(self, codes, luts, bias, *, topl: int):
        return _materialized_topl(codes, luts, bias,
                                  topl=min(topl, codes.shape[0]),
                                  impl=self.impl)


class StreamingTopL(CandidateGenerator):
    """Streaming scan+top-L (``ops.adc_scan_topl``): O(Q*L) peak memory,
    bit-identical to ``MaterializedTopL`` including tie resolution."""

    materializes_scores = False

    def topl(self, codes, luts, bias, *, topl: int):
        return ops.adc_scan_topl(codes, luts, topl=topl, bias=bias,
                                 impl=self.impl)


def candidate_generator_for(backend: str | None = "auto") -> CandidateGenerator:
    """Resolve an index's backend request to a stage-1 generator.

    The backend name resolves through the scan registry; backends that
    declare the ``streaming_topl`` capability get the streaming engine,
    everything else the materialized fallback.
    """
    impl = resolve_scan_backend(backend)
    if backend_supports(impl, "streaming_topl"):
        return StreamingTopL(impl)
    return MaterializedTopL(impl)
