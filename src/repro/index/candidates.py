"""Pluggable stage-1 candidate generation (the streaming engine's index
face).

``Index.search`` and ``ShardedIndex`` delegate stage 1 — d2 scores over
the compressed database plus per-query top-L — to a ``CandidateGenerator``
resolved through the scan-backend registry, instead of hardcoding one
"full (Q, N) matrix + lax.top_k" implementation:

  * ``StreamingTopL``     backends with the ``streaming_topl`` capability
                          (pallas: fused scan+top-L kernel; xla: chunked
                          scan + incremental merge). Peak memory O(Q*L);
                          the (Q, N) score matrix is never materialized.
  * ``MaterializedTopL``  the classic full-matrix scan for backends
                          without a streaming path (onehot), kept as the
                          A/B reference.

Both produce bit-identical (score, index) results — the streaming paths
reproduce ``lax.top_k`` tie semantics exactly — so generator selection is
purely a memory/performance decision, never a quality one.

Two bias streams flow through every path:

  * ``bias``  (N,)   per-point terms (RVQ's ||decode(code)||^2);
  * ``qbias`` (Q, N) per-(query, point) terms — the lowering target of
    the filtered-search API (``filter_mask`` becomes 0 / +inf).

``gather_topl`` is the IVF face of the same engines: each query scores a
per-query slot list (a padded ragged concatenation of inverted lists)
instead of the whole database. Streaming backends ride
``ops.adc_gather_topl`` (fused kernel / chunked gather-scan); the
materialized path scores the full buffer with its own formulation and
gathers the slots — which keeps IVF-at-full-probe bit-identical to flat
search PER BACKEND, reassociated onehot reductions included.

``dispatch_topl`` is the cell-batched face of the same IVF stage 1
(backends with the ``dispatch_topl`` capability): instead of per-query
slot lists, the device router (``repro.index.dispatch``) batches the
queries probing each cell and ``ops.adc_dispatch_topl`` streams every
probed cell's contiguous code range exactly once — same scores, same tie
semantics, no host-side plan. ``supports_dispatch`` is the capability
gate ``IVFIndex.search`` resolves its default against.
"""
from __future__ import annotations

import abc
import functools

import jax
import jax.numpy as jnp

from repro.index.backend import backend_supports, resolve_scan_backend
from repro.kernels import ops

_IMAX = jnp.iinfo(jnp.int32).max


class CandidateGenerator(abc.ABC):
    """Stage 1 strategy: codes + per-query LUTs -> top-L candidates."""

    #: whether this generator allocates the full (Q, N) score matrix
    materializes_scores: bool

    def __init__(self, impl: str):
        self.impl = impl                # concrete kernels.ops impl string

    @abc.abstractmethod
    def topl(self, codes, luts, bias, *, topl: int, qbias=None,
             lut_dtype: str = "float32", overfetch: int = 1):
        """codes (N, M), luts (Q, M, K), bias None | (N,), qbias
        None | (Q, N) -> (scores, indices), each (Q, min(topl, N)),
        sorted closest-first with ties broken toward the smaller
        database index. ``lut_dtype``/``overfetch`` select the
        reduced-precision pool scan + exact re-score (streaming engines
        only — gate on the backend's ``quantized_lut`` capability)."""

    @abc.abstractmethod
    def gather_topl(self, codes, rows, gids, luts, rowbias, *, topl: int,
                    lut_dtype: str = "float32", overfetch: int = 1):
        """Gathered (IVF) stage 1: codes (N, M) buffer, rows/gids (Q, W)
        per-query slot plan (gids ascending per row, ``_IMAX`` pads),
        rowbias None | (Q, W) -> (scores, global ids), each
        (Q, min(topl, W)), sorted by (score asc, gid asc); +inf entries
        carry the canonical ``_IMAX`` id."""

    def dispatch_topl(self, codes, gids_rows, rowbias, luts, cellterm,
                      plan, *, topl: int, qkeep=None, chunk=None, pos=None,
                      lut_dtype: str = "float32", overfetch: int = 1):
        """Cell-batched (MoE-routed) IVF stage 1: codes (N, M)
        cell-grouped buffer, gids_rows (N,) row -> global id, rowbias
        None | (N,) per-row bias, luts (Q, M, K), cellterm (E+1, cap)
        per-(routed cell, slot) bias, plan a
        ``repro.index.dispatch.DispatchPlan``, qkeep None | (Q, N) keep
        stream -> per-cell partial pools ((E+1, cap, L) scores / global
        ids) for ``dispatch.combine_pools``. Only backends declaring the
        ``dispatch_topl`` capability implement it. ``chunk`` must be the
        tile width the plan was routed with (``Routing.chunk``; None
        re-resolves the shared tuner entry); ``pos`` is the (n_ids,)
        global id -> buffer row inverse the quantized re-score needs."""
        raise NotImplementedError(
            f"{type(self).__name__} has no cell-batched dispatch face; "
            "gate callers on supports_dispatch(backend)")

    def __repr__(self):
        return f"{type(self).__name__}(impl={self.impl!r})"


@functools.partial(jax.jit, static_argnames=("topl", "impl"))
def _materialized_topl(codes, luts, bias, qbias, *, topl: int, impl: str):
    scores = ops.adc_scan_batch(codes, luts, impl=impl)    # (Q, N)
    if bias is not None:
        scores = scores + bias[None, :]
    if qbias is not None:
        scores = scores + qbias
    neg, idx = jax.lax.top_k(-scores, topl)
    return -neg, idx


@functools.partial(jax.jit, static_argnames=("topl", "impl"))
def _materialized_gather_topl(codes, rows, gids, luts, rowbias, *,
                              topl: int, impl: str):
    """Full-buffer scan (this backend's own formulation — identical bits
    to its flat scan) + slot gather + top-L. The (Q, N) matrix exists, as
    it does on every materialized path."""
    scores = ops.adc_scan_batch(codes, luts, impl=impl)    # (Q, N)
    picked = jnp.take_along_axis(scores, rows, axis=1)     # (Q, W)
    if rowbias is not None:
        picked = picked + rowbias
    picked = jnp.where(gids == _IMAX, jnp.inf, picked)
    gids = jnp.where(jnp.isposinf(picked), _IMAX, gids)
    neg, pos = jax.lax.top_k(-picked, topl)
    return -neg, jnp.take_along_axis(gids, pos, axis=1)


class MaterializedTopL(CandidateGenerator):
    """Full (Q, N) score matrix + ``lax.top_k`` (the pre-streaming stage 1;
    reference semantics, O(Q*N) peak memory)."""

    materializes_scores = True

    def _check_exact(self, lut_dtype: str, overfetch: int):
        if lut_dtype != "float32" or overfetch != 1:
            raise ValueError(
                f"{type(self).__name__} ({self.impl!r}) has no quantized-"
                "LUT path — its formulation IS the materialized f32 "
                "matrix; gate callers on the 'quantized_lut' capability")

    def topl(self, codes, luts, bias, *, topl: int, qbias=None,
             lut_dtype: str = "float32", overfetch: int = 1):
        self._check_exact(lut_dtype, overfetch)
        return _materialized_topl(codes, luts, bias, qbias,
                                  topl=min(topl, codes.shape[0]),
                                  impl=self.impl)

    def gather_topl(self, codes, rows, gids, luts, rowbias, *, topl: int,
                    lut_dtype: str = "float32", overfetch: int = 1):
        self._check_exact(lut_dtype, overfetch)
        return _materialized_gather_topl(
            codes, rows, gids, luts, rowbias,
            topl=min(topl, rows.shape[1]), impl=self.impl)


class StreamingTopL(CandidateGenerator):
    """Streaming scan+top-L (``ops.adc_scan_topl``): O(Q*L) peak memory,
    bit-identical to ``MaterializedTopL`` including tie resolution."""

    materializes_scores = False

    def topl(self, codes, luts, bias, *, topl: int, qbias=None,
             lut_dtype: str = "float32", overfetch: int = 1):
        return ops.adc_scan_topl(codes, luts, topl=topl, bias=bias,
                                 qbias=qbias, impl=self.impl,
                                 lut_dtype=lut_dtype, overfetch=overfetch)

    def gather_topl(self, codes, rows, gids, luts, rowbias, *, topl: int,
                    lut_dtype: str = "float32", overfetch: int = 1):
        return ops.adc_gather_topl(codes, rows, gids, luts, topl=topl,
                                   rowbias=rowbias, impl=self.impl,
                                   lut_dtype=lut_dtype, overfetch=overfetch)

    def dispatch_topl(self, codes, gids_rows, rowbias, luts, cellterm,
                      plan, *, topl: int, qkeep=None, chunk=None, pos=None,
                      lut_dtype: str = "float32", overfetch: int = 1):
        return ops.adc_dispatch_topl(codes, gids_rows, rowbias, luts,
                                     cellterm, plan, topl=topl,
                                     qkeep=qkeep, impl=self.impl,
                                     chunk=chunk, pos=pos,
                                     lut_dtype=lut_dtype,
                                     overfetch=overfetch)


def candidate_generator_for(backend: str | None = "auto") -> CandidateGenerator:
    """Resolve an index's backend request to a stage-1 generator.

    The backend name resolves through the scan registry; backends that
    declare the ``streaming_topl`` capability get the streaming engine,
    everything else the materialized fallback. Within the streaming
    engine, ``fused_topl`` selects the kernel flavor: backends declaring
    it run the single fused scan+top-L kernel (the ``pallas`` dispatch
    target), the rest the chunked ``lax.scan`` composition (``xla``).
    """
    impl = resolve_scan_backend(backend)
    if backend_supports(impl, "streaming_topl"):
        return StreamingTopL(
            "pallas" if backend_supports(impl, "fused_topl") else "xla")
    return MaterializedTopL(impl)


def supports_dispatch(backend: str | None = "auto") -> bool:
    """True when the resolved backend has the cell-batched dispatch face
    (``dispatch_topl`` capability) — what ``IVFIndex.search`` keys its
    dispatch-vs-padded default on."""
    return backend_supports(resolve_scan_backend(backend), "dispatch_topl")


def merge_topl(scores, ids, topl: int):
    """Exact lexicographic (score asc, id asc) top-L over an UNSORTED
    candidate pool (Q, P) — the cross-shard merge for IVF pools, whose
    per-shard global-id ranges interleave (cell-grouped shards), so the
    positional tie-break of a plain ``lax.top_k`` would be wrong.

    Two stable argsorts: ascending id first, then stable-by-score — among
    equal scores the id order survives, which is exactly the flat-search
    tie-break. +inf entries are canonicalized to id ``_IMAX`` first.
    """
    ids = jnp.where(jnp.isposinf(scores), _IMAX, ids)
    order1 = jnp.argsort(ids, axis=1, stable=True)
    s = jnp.take_along_axis(scores, order1, axis=1)
    g = jnp.take_along_axis(ids, order1, axis=1)
    order2 = jnp.argsort(s, axis=1, stable=True)
    topl = min(topl, scores.shape[1])
    return (jnp.take_along_axis(s, order2, axis=1)[:, :topl],
            jnp.take_along_axis(g, order2, axis=1)[:, :topl])
