"""The ``Index`` protocol: a FAISS-style object that owns a compressed
database you can query (paper §3.3 generalized over quantizers).

Lifecycle::

    index = index_factory("UNQ8x256,Rerank500", dim=96)
    index.train(train_vectors)        # fit the quantizer
    index.add(base_vectors)           # compress + append to the database
    D, I = index.search(queries, k)   # two-stage compressed-domain search
    index.save(path); index = Index.load(path)

Every implementation reduces to four primitives (train / encode / LUT
build / reconstruct); the two-stage search itself — batched multi-query
ADC scan (d2, Eq. 8), top-L candidates, decoder rerank (d1, Eq. 7) — is
implemented ONCE here and shared by UNQ and every shallow baseline, which
is what makes paper-style method comparisons a single loop.

Stage 1 is delegated to a ``CandidateGenerator`` resolved through the
scan-backend registry (``repro.index.candidates``): backends declaring the
``streaming_topl`` capability run the streaming scan+top-L engine — the
(Q, N) score matrix is never materialized — and the rest fall back to the
classic full-matrix scan. Every Index subclass gets the right path with no
per-class branching, and per-point score biases flow through either.

Stage 2 is delegated the same way to a ``Reranker``
(``repro.index.rerank``): table-decodable quantizers stream through the
fused gather-decode-distance kernel (``fused_rerank`` capability) or its
chunked fallback, decoder quantizers (UNQ) go through cross-query
candidate dedup, and the ``use_d2=False`` exhaustive-rerank ablation
chunks over the database — the (Q, L, D) / (Q, N, D) reconstruction
tensors of the classic paths never exist, and every path is bit-identical
to the materialized vmap oracle kept as the A/B reference.
"""
from __future__ import annotations

import abc
import functools
import json
import pathlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import load_pytree, save_pytree
from repro.index.backend import backend_supports, resolve_scan_backend
from repro.index.candidates import candidate_generator_for
from repro.kernels import tune

# kind -> Index subclass, populated by __init_subclass__
_KINDS: dict[str, type["Index"]] = {}


class Index(abc.ABC):
    """Abstract compressed-database index (see module docstring)."""

    kind: str = "abstract"

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        if cls.kind != "abstract":
            _KINDS[cls.kind] = cls

    #: encode-batch ladder: ``add`` pads inputs up to the next bucket so
    #: differently-sized chunks reuse one encoder compilation (the ladder
    #: then continues in 8192-row multiples)
    ENCODE_BUCKETS = (256, 1024, 4096, 8192)

    def __init__(self, dim: int, *, rerank: int = 0, backend: str = "auto"):
        self.dim = dim
        self.rerank = rerank          # L: stage-2 candidates (0 = ADC only)
        self.backend = backend        # scan backend name or "auto"
        self._codes: jax.Array | None = None     # (N, M) uint8
        self._bias: jax.Array | None = None      # (N,) f32 or None
        self._rerank_fn = None                   # cached jitted vmap stage 2
        self._decode_fn = None                   # cached jitted chunk decode
        self._exhaustive_fn = None               # cached jitted use_d2=False
        self._table_cache = None                 # cached decode table

    # -- database state ----------------------------------------------------

    @property
    def ntotal(self) -> int:
        return 0 if self._codes is None else int(self._codes.shape[0])

    @property
    def codes(self) -> jax.Array | None:
        """The compressed database, (ntotal, M) uint8."""
        return self._codes

    def result_width(self, k: int) -> int:
        """Number of result columns ``search(queries, k)`` returns:
        ``min(k, ntotal)``. The serving fan-in slices a coalesced
        k_max-wide batch back to each request's own width with this, so
        a request's rows are bit-identical to searching it alone — the
        exact sorted top-k is prefix-stable (its first j columns never
        depend on how many more were asked for)."""
        return min(k, self.ntotal)

    @property
    def bias(self) -> jax.Array | None:
        """Per-point additive d2 score term, (ntotal,) f32, or None.

        Additive quantizers (RVQ) store ||decode(code)||^2 here — the
        standard extra-4-bytes trick. Public so wrappers (``ShardedIndex``,
        custom shard stores) never reach into private attributes."""
        return self._bias

    @property
    @abc.abstractmethod
    def is_trained(self) -> bool:
        ...

    def reset(self) -> None:
        """Drop the database (the trained quantizer is kept)."""
        self._codes = None
        self._bias = None

    def with_codes(self, codes, bias=None) -> "Index":
        """A shallow view over the same trained quantizer with a different
        code matrix (shard construction, external code stores)."""
        import copy
        clone = copy.copy(self)
        clone._codes = None if codes is None else jnp.asarray(codes)
        clone._bias = bias
        return clone

    def subset(self, n: int) -> "Index":
        """View over the first ``n`` database entries (nested-subset
        scaling studies, paper Tables 3/4)."""
        return self.with_codes(
            self._codes[:n],
            None if self._bias is None else self._bias[:n])

    # -- quantizer primitives (implementation-specific) --------------------

    def train(self, xs, **kw) -> "Index":
        """Fit the index on (n, dim) training vectors. Returns self.

        Training is an ORDERED pipeline of ``TrainStage``s
        (``core.training.run_train_pipeline``): plain quantizers declare
        the single ``_fit_quantizer`` stage, composite indexes sequence
        theirs — ``IVFIndex`` fits its coarse k-means first and, in
        residual mode, hands ``x - centroid(x)`` to the wrapped
        quantizer's stage. Keyword arguments are shared across the whole
        pipeline; each stage picks the ones it declares and ignores the
        rest (so ``train(xs, coarse_iters=5, iters=10)`` configures both
        IVF stages in one call).

        ``xs`` is handed to the first stage as given — each stage
        coerces to the array type it needs (UNQ trains host-side from
        numpy; the shallow quantizers convert to jnp themselves), so a
        large numpy training set is not round-tripped through the
        device before training starts.
        """
        from repro.core.training import run_train_pipeline
        run_train_pipeline(self._train_stages(), xs, kw)
        self._invalidate_caches()
        return self

    def _train_stages(self):
        """The ordered ``TrainStage`` list ``train`` runs. Default: the
        single quantizer-fitting stage."""
        from repro.core.training import TrainStage
        return [TrainStage(self.kind, self._fit_quantizer)]

    def _fit_quantizer(self, xs, **kw) -> jax.Array | None:
        """Fit this index's own quantizer (the default single pipeline
        stage). Return None, or transformed vectors for later stages."""
        raise NotImplementedError(
            f"{type(self).__name__} must implement _fit_quantizer or "
            "override _train_stages")

    @abc.abstractmethod
    def _encode(self, xs) -> jax.Array:
        """(n, dim) -> (n, M) uint8 codes."""

    @abc.abstractmethod
    def _build_luts(self, queries) -> jax.Array:
        """(Q, dim) -> (Q, M, K) float32 additive score tables (lower=closer
        after summation, up to a per-query constant)."""

    @abc.abstractmethod
    def _reconstruct(self, codes) -> jax.Array:
        """(n, M) codes -> (n, dim) reconstructions for stage-2 rerank."""

    def _encode_bias(self, codes) -> jax.Array | None:
        """Per-point additive score term for new codes (None for most)."""
        return None

    def _build_decode_table(self) -> jax.Array | None:
        """(M, K, D) f32 additive decode table with ``recon = sum_m
        table[m, code_m]`` (``ref.decode_with_table`` semantics), or None
        when reconstruction needs a learned decoder (UNQ) — the stage-2
        engine then uses cross-query dedup instead of the fused kernel."""
        return None

    def _decode_table(self) -> jax.Array | None:
        """Cached ``_build_decode_table`` (dropped by _invalidate_caches).

        Built under ``ensure_compile_time_eval`` so a first call from
        inside a jit trace (``_reconstruct`` is traced by the vmap oracle
        and the chunked decoders) still caches a concrete table instead
        of leaking a tracer."""
        if self._table_cache is None:
            with jax.ensure_compile_time_eval():
                self._table_cache = self._build_decode_table()
        return self._table_cache

    # -- add / search ------------------------------------------------------

    @classmethod
    def _encode_bucket(cls, n: int) -> int:
        """Smallest encode-batch bucket >= n (see ENCODE_BUCKETS)."""
        for b in cls.ENCODE_BUCKETS:
            if n <= b:
                return b
        step = cls.ENCODE_BUCKETS[-1]
        return -(-n // step) * step

    def add(self, xs) -> "Index":
        """Compress (n, dim) vectors and append them to the database.

        Inputs are zero-padded up to the next ``ENCODE_BUCKETS`` size
        before encoding (pad rows sliced off after), so adding
        differently-sized chunks hits one compiled encoder instead of
        re-jitting per (n, dim) shape. Encoders are row-stable, so the
        codes are identical to encoding unpadded.
        """
        if not self.is_trained:
            raise RuntimeError(f"{type(self).__name__}.add before train()")
        xs = jnp.asarray(xs)
        n = xs.shape[0]
        bucket = self._encode_bucket(n)
        if bucket != n:
            xs = jnp.pad(xs, ((0, bucket - n), (0, 0)))
        codes = self._encode(xs)[:n]
        bias = self._encode_bias(codes)
        if self._codes is None:
            self._codes, self._bias = codes, bias
        else:
            self._codes = jnp.concatenate([self._codes, codes], axis=0)
            if bias is not None:
                self._bias = jnp.concatenate([self._bias, bias], axis=0)
        return self

    def _lower_filter(self, filter_mask, num_queries: int):
        """Lower a boolean keep-mask to the two stage-1 bias streams.

        filter_mask: None | (ntotal,) | (Q, ntotal) bool (True = keep).
        Returns (bias, qbias): the per-point (N,) stream — the index's own
        bias with filtered points forced to +inf for a shared mask — and
        the per-(query, point) (Q, N) stream for per-query masks. Uses
        ``where`` rather than addition so kept points' scores are
        bit-identical to an index built over only the kept points.
        """
        if filter_mask is None:
            return self._bias, None
        mask = jnp.asarray(filter_mask, bool)
        if mask.ndim == 1:
            if mask.shape != (self.ntotal,):
                raise ValueError(
                    f"filter_mask shape {mask.shape} != ({self.ntotal},)")
            base_bias = self._bias if self._bias is not None \
                else jnp.zeros((self.ntotal,), jnp.float32)
            return jnp.where(mask, base_bias, jnp.inf), None
        if mask.shape != (num_queries, self.ntotal):
            raise ValueError(
                f"filter_mask shape {mask.shape} != "
                f"({num_queries}, {self.ntotal})")
        return self._bias, jnp.where(mask, 0.0, jnp.inf).astype(jnp.float32)

    def _check_quantized_request(self, lut_dtype: str, overfetch: int):
        """Gate a ``lut_dtype``/``overfetch`` request on the resolved
        backend's ``quantized_lut`` capability (loud, not silent f32)."""
        if lut_dtype == "float32" and overfetch == 1:
            return
        impl = resolve_scan_backend(self.backend)
        if not backend_supports(impl, "quantized_lut"):
            raise ValueError(
                f"backend {impl!r} does not declare the 'quantized_lut' "
                f"capability; lut_dtype={lut_dtype!r} / "
                f"overfetch={overfetch} need a streaming backend")

    def search(self, queries, k: int, *, use_rerank: bool | None = None,
               use_d2: bool = True, filter_mask=None,
               lut_dtype: str = "float32", overfetch: int = 1):
        """Two-stage search: (Q, dim) queries -> (distances, indices), each
        (Q, k), sorted closest-first.

        ``use_rerank=None`` reranks iff the index has a rerank budget;
        ``use_rerank=False`` returns raw d2 ranking ("No reranking"
        ablation); ``use_d2=False`` reranks the ENTIRE database with exact
        reconstruction distances ("Exhaustive reranking" ablation),
        chunked over N — the (Q, N, D) reconstruction never exists.

        ``filter_mask`` — (ntotal,) or (Q, ntotal) bool, True = eligible —
        is the public filtered-search API: it lowers to a ±inf additive
        bias stream that rides every stage-1 path (fused kernel included),
        so a filtered point can never enter the candidate pool. Results
        over the kept points are bit-identical to searching an index that
        only contains them; when fewer than k points survive, the tail is
        reported as (distance=+inf, index=-1).

        ``lut_dtype`` in {'float16', 'int8'} (with ``overfetch`` >= 1)
        opts stage 1 into the reduced-precision fast path: the scan
        selects ``overfetch * L`` candidates under quantized tables and
        re-scores the pool with the exact f32 chain before the final
        top-L (``repro.kernels.lut_quant``). Only backends with the
        ``quantized_lut`` capability accept it; the default is the
        bit-exact f32 path, unchanged.
        """
        if self.ntotal == 0:
            raise RuntimeError("search on an empty index (call add first)")
        self._check_quantized_request(lut_dtype, overfetch)
        queries = jnp.asarray(queries)
        if use_rerank is None:
            use_rerank = self.rerank > 0
        if use_rerank and self.rerank <= 0:
            raise ValueError(
                f"{type(self).__name__} has no rerank budget (rerank=0); "
                "set index.rerank or pass use_rerank=False")
        if not use_d2:
            if filter_mask is not None:
                raise ValueError(
                    "filter_mask is not supported with use_d2=False "
                    "(the exhaustive-rerank ablation scans every point)")
            return self._exhaustive_rerank_topk(queries, k)
        topl = min(self.rerank if use_rerank else k, self.ntotal)
        luts = self._build_luts(queries)
        gen = candidate_generator_for(self.backend)
        bias, qbias = self._lower_filter(filter_mask, queries.shape[0])
        d2, cand = gen.topl(self._codes, luts, bias, topl=topl, qbias=qbias,
                            lut_dtype=lut_dtype, overfetch=overfetch)
        if not use_rerank:
            d, i = d2[:, :k], cand[:, :k]
            if filter_mask is not None:
                i = jnp.where(jnp.isposinf(d), -1, i)
            return d, i
        valid = jnp.isfinite(d2) if filter_mask is not None else None
        return self._rerank_topk(queries, cand, k, valid=valid)

    def _rerank_topk(self, queries, cand, k: int, *, valid=None):
        """Shared stage-2 tail: d1 rerank of the candidate pool + final
        top-k. Also used by ShardedIndex on the merged pool.

        ``valid`` (Q, L) bool marks pool entries that are real candidates
        (filtered search can underfill the pool): invalid slots are
        clamped to row 0 for the gather, forced to d1=+inf so they can
        never outrank a real candidate, and reported as index -1."""
        if valid is not None:
            cand = jnp.where(valid, cand, 0)
        d1 = self._rerank_distances(queries, cand)         # (Q, L)
        if valid is not None:
            d1 = jnp.where(valid, d1, jnp.inf)
        kk = min(k, d1.shape[1])
        neg, order = jax.lax.top_k(-d1, kk)
        d = -neg
        i = jnp.take_along_axis(cand, order, axis=1)
        if valid is not None:
            i = jnp.where(jnp.isposinf(d), -1, i)
        return d, i

    def _rerank_distances(self, queries, cand) -> jax.Array:
        """Stage 2: exact reconstruction distances d1 = ||q - recon||^2
        over each query's candidate list. queries (Q, D), cand (Q, L).

        Delegates to the ``Reranker`` resolved through the scan-backend
        registry (``repro.index.rerank``): fused/chunked table decode,
        cross-query dedup, or the materialized vmap oracle — all
        bit-identical, chosen purely on memory/perf grounds.
        """
        from repro.index.rerank import reranker_for
        return reranker_for(self).distances(self, queries, cand)

    def _rerank_distances_vmap(self, queries, cand) -> jax.Array:
        """The materialized stage-2 oracle: per-query gather + decode +
        reduce under vmap, building the (Q, L, D) reconstruction. Ground
        truth for every streaming reranker, and the path backends without
        streaming capabilities use.

        The jitted kernel is cached on the instance (codes passed as an
        argument, so ``add``/``with_codes`` don't invalidate it); anything
        that swaps quantizer parameters must call ``_invalidate_caches``.
        """
        if self._rerank_fn is None:
            def _one(codes, q, c_idx):
                recon = self._reconstruct(codes[c_idx])    # (L, D)
                return jnp.sum(jnp.square(recon - q[None, :]), axis=-1)

            self._rerank_fn = jax.jit(jax.vmap(_one, in_axes=(None, 0, 0)))
        return self._rerank_fn(self._codes, queries, cand)

    def _chunk_decode_fn(self):
        """Jitted fixed-shape ``codes -> reconstructions`` used by the
        dedup reranker's batched unique-row decode (cached; dropped by
        ``_invalidate_caches``)."""
        if self._decode_fn is None:
            self._decode_fn = jax.jit(self._reconstruct)
        return self._decode_fn

    def _exhaustive_rerank_topk(self, queries, k: int):
        """``use_d2=False``: exact-d1 top-k over ALL codes, chunked over N
        (``rerank.exhaustive_topk``) — each chunk decoded once for every
        query, merged into a running (Q, k) heap with ``lax.top_k`` tie
        semantics."""
        from repro.index.rerank import exhaustive_topk
        if self._exhaustive_fn is None:
            self._exhaustive_fn = jax.jit(
                functools.partial(exhaustive_topk, self._reconstruct),
                static_argnames=("k",))
        return self._exhaustive_fn(self._codes, queries, k=min(k, self.ntotal))

    def _invalidate_caches(self) -> None:
        """Drop compiled closures over quantizer params (after train/load)."""
        self._rerank_fn = None
        self._decode_fn = None
        self._exhaustive_fn = None
        self._table_cache = None

    # -- persistence (checkpoint/manager: atomic, self-describing) ---------

    @abc.abstractmethod
    def _tree(self) -> Any:
        """Pytree of everything save/load roundtrips (params + codes)."""

    @abc.abstractmethod
    def _metadata(self) -> dict:
        """JSON-serializable config sufficient to rebuild ``_tree`` shapes."""

    @classmethod
    @abc.abstractmethod
    def _empty_from_metadata(cls, meta: dict) -> "Index":
        """Rebuild an index whose ``_tree`` has the saved structure/shapes
        (leaf values are placeholders until ``_set_tree``)."""

    @abc.abstractmethod
    def _set_tree(self, tree: Any) -> None:
        """Install a restored ``_tree``."""

    def save(self, path) -> None:
        """Atomic save to a checkpoint directory (manager.save_pytree).

        For backends with the ``tuned`` capability the manifest also
        records the active autotuner fingerprint (schema version, device
        kind, tuned bucket count) — provenance for any timing attached to
        the checkpoint; ``load`` ignores it.
        """
        metadata = {"index_kind": self.kind,
                    "index_meta": self._metadata()}
        if backend_supports(resolve_scan_backend(self.backend), "tuned"):
            metadata["tuning"] = tune.cache_fingerprint()
        save_pytree(pathlib.Path(path), self._tree(), metadata=metadata)

    @staticmethod
    def load(path) -> "Index":
        """Load any saved index, dispatching on the manifest's kind tag."""
        path = pathlib.Path(path)
        with open(path / "manifest.json") as f:
            manifest = json.load(f)
        meta = manifest["metadata"]
        kind = meta.get("index_kind")
        if kind not in _KINDS:
            raise ValueError(
                f"{path} is not a saved index (kind={kind!r}; "
                f"known: {sorted(_KINDS)})")
        index = _KINDS[kind]._empty_from_metadata(meta["index_meta"])
        tree, _ = load_pytree(path, index._tree())
        index._set_tree(tree)
        return index

    def __repr__(self):
        return (f"{type(self).__name__}(dim={self.dim}, "
                f"ntotal={self.ntotal}, rerank={self.rerank}, "
                f"backend={self.backend!r}, trained={self.is_trained})")
