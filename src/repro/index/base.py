"""The ``Index`` protocol: a FAISS-style object that owns a compressed
database you can query (paper §3.3 generalized over quantizers).

Lifecycle::

    index = index_factory("UNQ8x256,Rerank500", dim=96)
    index.train(train_vectors)        # fit the quantizer
    index.add(base_vectors)           # compress + append to the database
    D, I = index.search(queries, k)   # two-stage compressed-domain search
    index.save(path); index = Index.load(path)

Every implementation reduces to four primitives (train / encode / LUT
build / reconstruct); the two-stage search itself — batched multi-query
ADC scan (d2, Eq. 8), top-L candidates, decoder rerank (d1, Eq. 7) — is
implemented ONCE here and shared by UNQ and every shallow baseline, which
is what makes paper-style method comparisons a single loop.

Stage 1 is delegated to a ``CandidateGenerator`` resolved through the
scan-backend registry (``repro.index.candidates``): backends declaring the
``streaming_topl`` capability run the streaming scan+top-L engine — the
(Q, N) score matrix is never materialized — and the rest fall back to the
classic full-matrix scan. Every Index subclass gets the right path with no
per-class branching, and per-point score biases flow through either.
"""
from __future__ import annotations

import abc
import json
import pathlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import load_pytree, save_pytree
from repro.index.candidates import candidate_generator_for

# kind -> Index subclass, populated by __init_subclass__
_KINDS: dict[str, type["Index"]] = {}


class Index(abc.ABC):
    """Abstract compressed-database index (see module docstring)."""

    kind: str = "abstract"

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        if cls.kind != "abstract":
            _KINDS[cls.kind] = cls

    def __init__(self, dim: int, *, rerank: int = 0, backend: str = "auto"):
        self.dim = dim
        self.rerank = rerank          # L: stage-2 candidates (0 = ADC only)
        self.backend = backend        # scan backend name or "auto"
        self._codes: jax.Array | None = None     # (N, M) uint8
        self._bias: jax.Array | None = None      # (N,) f32 or None
        self._rerank_fn = None                   # cached jitted stage 2

    # -- database state ----------------------------------------------------

    @property
    def ntotal(self) -> int:
        return 0 if self._codes is None else int(self._codes.shape[0])

    @property
    def codes(self) -> jax.Array | None:
        """The compressed database, (ntotal, M) uint8."""
        return self._codes

    @property
    def bias(self) -> jax.Array | None:
        """Per-point additive d2 score term, (ntotal,) f32, or None.

        Additive quantizers (RVQ) store ||decode(code)||^2 here — the
        standard extra-4-bytes trick. Public so wrappers (``ShardedIndex``,
        custom shard stores) never reach into private attributes."""
        return self._bias

    @property
    @abc.abstractmethod
    def is_trained(self) -> bool:
        ...

    def reset(self) -> None:
        """Drop the database (the trained quantizer is kept)."""
        self._codes = None
        self._bias = None

    def with_codes(self, codes, bias=None) -> "Index":
        """A shallow view over the same trained quantizer with a different
        code matrix (shard construction, external code stores)."""
        import copy
        clone = copy.copy(self)
        clone._codes = None if codes is None else jnp.asarray(codes)
        clone._bias = bias
        return clone

    def subset(self, n: int) -> "Index":
        """View over the first ``n`` database entries (nested-subset
        scaling studies, paper Tables 3/4)."""
        return self.with_codes(
            self._codes[:n],
            None if self._bias is None else self._bias[:n])

    # -- quantizer primitives (implementation-specific) --------------------

    @abc.abstractmethod
    def train(self, xs, **kw) -> "Index":
        """Fit the quantizer on (n, dim) training vectors. Returns self."""

    @abc.abstractmethod
    def _encode(self, xs) -> jax.Array:
        """(n, dim) -> (n, M) uint8 codes."""

    @abc.abstractmethod
    def _build_luts(self, queries) -> jax.Array:
        """(Q, dim) -> (Q, M, K) float32 additive score tables (lower=closer
        after summation, up to a per-query constant)."""

    @abc.abstractmethod
    def _reconstruct(self, codes) -> jax.Array:
        """(n, M) codes -> (n, dim) reconstructions for stage-2 rerank."""

    def _encode_bias(self, codes) -> jax.Array | None:
        """Per-point additive score term for new codes (None for most)."""
        return None

    # -- add / search ------------------------------------------------------

    def add(self, xs) -> "Index":
        """Compress (n, dim) vectors and append them to the database."""
        if not self.is_trained:
            raise RuntimeError(f"{type(self).__name__}.add before train()")
        codes = self._encode(jnp.asarray(xs))
        bias = self._encode_bias(codes)
        if self._codes is None:
            self._codes, self._bias = codes, bias
        else:
            self._codes = jnp.concatenate([self._codes, codes], axis=0)
            if bias is not None:
                self._bias = jnp.concatenate([self._bias, bias], axis=0)
        return self

    def search(self, queries, k: int, *, use_rerank: bool | None = None,
               use_d2: bool = True):
        """Two-stage search: (Q, dim) queries -> (distances, indices), each
        (Q, k), sorted closest-first.

        ``use_rerank=None`` reranks iff the index has a rerank budget;
        ``use_rerank=False`` returns raw d2 ranking ("No reranking"
        ablation); ``use_d2=False`` reranks the ENTIRE database with exact
        reconstruction distances ("Exhaustive reranking" ablation).
        """
        if self.ntotal == 0:
            raise RuntimeError("search on an empty index (call add first)")
        queries = jnp.asarray(queries)
        if use_rerank is None:
            use_rerank = self.rerank > 0
        if use_rerank and self.rerank <= 0:
            raise ValueError(
                f"{type(self).__name__} has no rerank budget (rerank=0); "
                "set index.rerank or pass use_rerank=False")
        if use_d2:
            topl = min(self.rerank if use_rerank else k, self.ntotal)
            luts = self._build_luts(queries)
            gen = candidate_generator_for(self.backend)
            d2, cand = gen.topl(self._codes, luts, self._bias, topl=topl)
            if not use_rerank:
                return d2[:, :k], cand[:, :k]
        else:
            cand = jnp.broadcast_to(jnp.arange(self.ntotal),
                                    (queries.shape[0], self.ntotal))

        return self._rerank_topk(queries, cand, k)

    def _rerank_topk(self, queries, cand, k: int):
        """Shared stage-2 tail: d1 rerank of the candidate pool + final
        top-k. Also used by ShardedIndex on the merged pool."""
        d1 = self._rerank_distances(queries, cand)         # (Q, L)
        kk = min(k, d1.shape[1])
        neg, order = jax.lax.top_k(-d1, kk)
        return -neg, jnp.take_along_axis(cand, order, axis=1)

    def _rerank_distances(self, queries, cand) -> jax.Array:
        """Stage 2: exact reconstruction distances d1 = ||q - recon||^2
        over each query's candidate list. queries (Q, D), cand (Q, L).

        The jitted kernel is cached on the instance (codes passed as an
        argument, so ``add``/``with_codes`` don't invalidate it); anything
        that swaps quantizer parameters must call ``_invalidate_caches``.
        """
        if self._rerank_fn is None:
            def _one(codes, q, c_idx):
                recon = self._reconstruct(codes[c_idx])    # (L, D)
                return jnp.sum(jnp.square(recon - q[None, :]), axis=-1)

            self._rerank_fn = jax.jit(jax.vmap(_one, in_axes=(None, 0, 0)))
        return self._rerank_fn(self._codes, queries, cand)

    def _invalidate_caches(self) -> None:
        """Drop compiled closures over quantizer params (after train/load)."""
        self._rerank_fn = None

    # -- persistence (checkpoint/manager: atomic, self-describing) ---------

    @abc.abstractmethod
    def _tree(self) -> Any:
        """Pytree of everything save/load roundtrips (params + codes)."""

    @abc.abstractmethod
    def _metadata(self) -> dict:
        """JSON-serializable config sufficient to rebuild ``_tree`` shapes."""

    @classmethod
    @abc.abstractmethod
    def _empty_from_metadata(cls, meta: dict) -> "Index":
        """Rebuild an index whose ``_tree`` has the saved structure/shapes
        (leaf values are placeholders until ``_set_tree``)."""

    @abc.abstractmethod
    def _set_tree(self, tree: Any) -> None:
        """Install a restored ``_tree``."""

    def save(self, path) -> None:
        """Atomic save to a checkpoint directory (manager.save_pytree)."""
        save_pytree(pathlib.Path(path), self._tree(),
                    metadata={"index_kind": self.kind,
                              "index_meta": self._metadata()})

    @staticmethod
    def load(path) -> "Index":
        """Load any saved index, dispatching on the manifest's kind tag."""
        path = pathlib.Path(path)
        with open(path / "manifest.json") as f:
            manifest = json.load(f)
        meta = manifest["metadata"]
        kind = meta.get("index_kind")
        if kind not in _KINDS:
            raise ValueError(
                f"{path} is not a saved index (kind={kind!r}; "
                f"known: {sorted(_KINDS)})")
        index = _KINDS[kind]._empty_from_metadata(meta["index_meta"])
        tree, _ = load_pytree(path, index._tree())
        index._set_tree(tree)
        return index

    def __repr__(self):
        return (f"{type(self).__name__}(dim={self.dim}, "
                f"ntotal={self.ntotal}, rerank={self.rerank}, "
                f"backend={self.backend!r}, trained={self.is_trained})")
