"""Pluggable stage-2 reranking (the streaming engine's index face).

``Index._rerank_distances`` — exact reconstruction distances d1 (paper
Eq. 7) over each query's stage-1 candidate list — delegates to a
``Reranker`` resolved through the scan-backend registry, mirroring how
stage 1 resolves a ``CandidateGenerator``:

  * ``TableRerank``  table-decodable quantizers (PQ / OPQ / RVQ:
                     ``recon = sum_m table[m, code_m]``) on streaming
                     backends. Backends declaring ``fused_rerank`` run
                     the fused gather-decode-distance Pallas kernel;
                     the rest the chunked ``lax.scan`` fallback. Peak
                     reconstruction memory O(Q * block * D) — the
                     (Q, L, D) tensor is never materialized.
  * ``DedupRerank``  decoder quantizers (UNQ's neural decoder) on
                     streaming backends: cross-query candidate dedup.
                     Candidate pools overlap heavily across queries, so
                     the (Q*L) pool is flattened, each UNIQUE code row is
                     decoded once in fixed-size batches, and distances
                     are gathered back per (query, candidate) in chunks —
                     decoder FLOPs and activation memory are bounded by
                     the decode chunk, and the held reconstruction shrinks
                     from (Q*L, D) to (U, D), U = #unique <= min(Q*L, N).
  * ``VmapRerank``   the classic per-query gather + decode + reduce vmap,
                     materializing (Q, L, D). Kept as the A/B oracle and
                     used by backends without streaming capabilities
                     (onehot).
  * ``ResidualRerank`` wraps any of the three for residual IVF indexes
                     (IVFADC): candidates reconstruct as
                     ``centroid + decode(code)`` — an extra centroid face
                     on the decode table for the table engine, centroid
                     adds on the deduped unique rows for decoder
                     quantizers.

All paths produce bit-identical d1 (and therefore identical final
(distance, index) rankings) — verified by tests/test_rerank.py and
tests/test_residual.py — so reranker selection is purely a
memory/performance decision, never a quality one.

``exhaustive_rerank_topk`` is the ``use_d2=False`` ablation re-shaped the
same way: a ``lax.scan`` over database chunks, each decoded ONCE for all
queries (the decode is query-independent), merged into a running (Q, k)
heap with the same lexicographic tie semantics as the stage-1 streaming
engine — the (Q, N, D) reconstruction of the old path never exists.
"""
from __future__ import annotations

import abc
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.backend import backend_supports, resolve_scan_backend
from repro.kernels import ops

_IMAX = jnp.iinfo(jnp.int32).max

#: decode-batch ladder for DedupRerank (fixed shapes -> one compile per
#: bucket, smallest bucket >= the unique count serves small pools)
DEDUP_DECODE_CHUNK = 2048
#: L-chunk for the gathered-distance scan (shared with the table path)
DEDUP_DIST_CHUNK = ops.DEFAULT_RERANK_CHUNK_L


class Reranker(abc.ABC):
    """Stage-2 strategy: queries + candidate ids -> exact d1 distances."""

    #: whether this reranker materializes the (Q, L, D) reconstruction
    materializes_recon: bool

    @abc.abstractmethod
    def distances(self, index, queries, cand) -> jax.Array:
        """queries (Q, D), cand (Q, L) int32 rows of ``index.codes`` ->
        d1 (Q, L) f32 with d1[q, l] = ||queries[q] - recon(cand[q, l])||^2."""

    def __repr__(self):
        return f"{type(self).__name__}()"


class VmapRerank(Reranker):
    """Per-query ``codes[c_idx]`` gather + decode + reduce under ``vmap``
    (the pre-streaming stage 2; reference semantics, O(Q*L*D) peak)."""

    materializes_recon = True

    def distances(self, index, queries, cand):
        return index._rerank_distances_vmap(queries, cand)


class TableRerank(Reranker):
    """Streaming stage 2 for table-decodable quantizers
    (``ops.rerank_gather_dist``): candidate codes are gathered as uint8
    (L*M bytes per query, ~100x smaller than the float reconstruction)
    and the decode+distance runs tile-by-tile — fused Pallas kernel or
    chunked xla, bit-identical to ``VmapRerank``."""

    materializes_recon = False

    def __init__(self, impl: str):
        self.impl = impl                # concrete kernels.ops impl string

    def distances(self, index, queries, cand):
        cand_codes = jnp.take(index.codes, cand, axis=0)     # (Q, L, M) u8
        return ops.rerank_gather_dist(
            cand_codes, jnp.asarray(queries, jnp.float32),
            index._decode_table(), impl=self.impl)

    def __repr__(self):
        return f"TableRerank(impl={self.impl!r})"


@functools.partial(jax.jit, static_argnames=("chunk_l",))
def _gathered_dist_chunked(recon_u, queries, inv, *, chunk_l: int):
    """d[q, l] = ||queries[q] - recon_u[inv[q, l]]||^2 via a ``lax.scan``
    over (Q, chunk_l) column chunks — peak gathered-reconstruction memory
    O(Q * chunk_l * D) instead of O(Q * L * D)."""
    q, l = inv.shape
    pad = (-l) % chunk_l
    inv_c = jnp.moveaxis(
        jnp.pad(inv, ((0, 0), (0, pad))).reshape(q, -1, chunk_l), 1, 0)

    def step(_, idx):
        recon = recon_u[idx]                                 # (Q, c, D)
        return None, jnp.sum(jnp.square(recon - queries[:, None, :]),
                             axis=-1)

    _, ds = jax.lax.scan(step, None, inv_c)                  # (nc, Q, c)
    return jnp.moveaxis(ds, 0, 1).reshape(q, -1)[:, :l]


class DedupRerank(Reranker):
    """Cross-query candidate dedup for decoder quantizers (UNQ).

    Stage-1 pools overlap heavily across queries (popular database points
    appear in many top-L lists), so decoding ``codes[cand]`` per query
    repeats the expensive neural decode for every duplicate. This path
    runs host-side dedup on the concrete candidate matrix (search is
    eager), decodes each unique code row ONCE in fixed-size batches, and
    gathers the decoded rows back per (query, candidate) in chunks.

    Memory: decoder activations are bounded by ``decode_chunk`` and the
    gathered distance tiles by ``dist_chunk``; the held reconstruction is
    the deduped (U, D) matrix, U = #unique <= min(Q*L, ntotal) — the
    savings over the vmap path's (Q, L, D) scale exactly with the pool
    overlap (worst case, fully disjoint pools, they are the same size).

    Exactness: the decoder is row-stable (per-row results are independent
    of batch composition for batch > 1), so gathered unique rows are
    bit-identical to the per-query decode — d1 matches ``VmapRerank``
    bit-for-bit.

    ``add_centroid=True`` is the residual-IVF variant (resolved through
    ``ResidualRerank``): dedup runs over unique BUFFER ROWS — a row pins
    both its code and its coarse cell — and each unique reconstruction
    gains its row's centroid, so d1 is computed against
    ``decode(code) + centroid`` exactly.
    """

    materializes_recon = False

    def __init__(self, decode_chunk: int = DEDUP_DECODE_CHUNK,
                 dist_chunk: int = DEDUP_DIST_CHUNK,
                 add_centroid: bool = False):
        self.decode_chunk = decode_chunk
        self.dist_chunk = dist_chunk
        self.add_centroid = add_centroid

    def distances(self, index, queries, cand):
        cand = jnp.asarray(cand)
        q, l = cand.shape
        uniq, inv = np.unique(np.asarray(cand), return_inverse=True)
        # smallest ladder bucket >= n_unique (>= 8 keeps the decoder's
        # matmuls off degenerate single-row shapes)
        chunk = self.decode_chunk
        while chunk // 2 >= max(uniq.size, 8) and chunk > 8:
            chunk //= 2
        pad = (-uniq.size) % chunk
        rows_u = jnp.asarray(np.pad(uniq, (0, pad)), jnp.int32)
        codes_u = jnp.take(index.codes, rows_u, axis=0)      # (U_pad, M)
        cells_u = jnp.take(index._cells_dev, rows_u) \
            if self.add_centroid else None
        decode = index._chunk_decode_fn()
        parts = []
        for s in range(0, codes_u.shape[0], chunk):
            r = decode(codes_u[s:s + chunk])
            if cells_u is not None:
                r = r + jnp.take(index.coarse, cells_u[s:s + chunk], axis=0)
            parts.append(r)
        recon_u = jnp.concatenate(parts, axis=0)
        return _gathered_dist_chunked(
            recon_u, jnp.asarray(queries, jnp.float32),
            jnp.asarray(inv.reshape(q, l), jnp.int32),
            chunk_l=self.dist_chunk)


class ResidualRerank(Reranker):
    """Stage 2 for residual IVF indexes (IVFADC): every candidate's
    implied reconstruction is ``centroid + decode(code)``, so d1 must be
    computed against it — the wrapped reranker's ``||q - decode(code)||^2``
    would rank residual decodes as if they were points.

    Wraps whichever reranker the backend would resolve for the wrapped
    quantizer and reroutes it:

      * ``TableRerank`` — candidate code rows are EXTENDED with their
        coarse cell id and scored against the index's residual decode
        table (``IVFIndex._residual_table``: the inner table plus one
        centroid face), so the UNCHANGED fused/chunked table engine
        reconstructs ``decode(code) + centroid`` bit-exactly — the
        centroid face is simply the last chained add;
      * ``DedupRerank`` — cross-query dedup over unique buffer rows with
        ``add_centroid=True`` (a row pins code AND cell);
      * ``VmapRerank`` — the materialized per-query oracle with the
        centroid added to each gathered reconstruction (the A/B ground
        truth of the two above, used by the onehot backend).

    All three produce bit-identical d1 (``decode`` is shared and the
    centroid add is a single exact fp add per row), extending the
    engine's "reranker selection is never a quality decision" contract
    to residual indexes.
    """

    def __init__(self, inner: Reranker):
        self.inner = inner
        self.materializes_recon = inner.materializes_recon
        if isinstance(inner, DedupRerank):
            # a residual wrap ALWAYS adds centroids — enforced here so the
            # natural composition ResidualRerank(DedupRerank()) cannot
            # silently rank against bare residual decodes
            inner.add_centroid = True

    def distances(self, index, queries, cand):
        if isinstance(self.inner, TableRerank) and index.nlist <= 256:
            # this route only resolves when nlist <= K <= 256 (uint8
            # codes), so the cell column fits uint8 too — the extended
            # tensor keeps the table engine's uint8 streaming footprint
            # (a direct construction with nlist > 256 falls through to
            # the materialized residual oracle instead of wrapping)
            cand_codes = jnp.take(index.codes, cand, axis=0)  # (Q, L, M)
            cand_cells = jnp.take(index._cells_dev,
                                  cand)[..., None].astype(cand_codes.dtype)
            codes_ext = jnp.concatenate([cand_codes, cand_cells], axis=-1)
            return ops.rerank_gather_dist(
                codes_ext, jnp.asarray(queries, jnp.float32),
                index._residual_table(), impl=self.inner.impl)
        if isinstance(self.inner, DedupRerank):
            return self.inner.distances(index, queries, cand)
        return self._vmap_residual(index, queries, cand)

    @staticmethod
    def _vmap_residual(index, queries, cand):
        """Materialized residual oracle: per-query gather + decode +
        centroid add + reduce under vmap (cached on the index; dropped by
        ``_invalidate_caches``)."""
        if index._res_rerank_fn is None:
            def _one(codes, cells, coarse, q, c_idx):
                recon = index._reconstruct(codes[c_idx]) \
                    + coarse[cells[c_idx]]                   # (L, D)
                return jnp.sum(jnp.square(recon - q[None, :]), axis=-1)

            index._res_rerank_fn = jax.jit(
                jax.vmap(_one, in_axes=(None, None, None, 0, 0)))
        return index._res_rerank_fn(index.codes, index._cells_dev,
                                    index.coarse, queries, cand)

    def __repr__(self):
        return f"ResidualRerank({self.inner!r})"


def reranker_for(index) -> Reranker:
    """Resolve an index's backend request to a stage-2 reranker.

    Streaming-capable backends (``streaming_topl``) get the streaming
    engine — the fused kernel where the backend declares ``fused_rerank``
    and the index is table-decodable, the chunked xla path otherwise for
    tables, cross-query dedup for decoder quantizers. Backends without a
    streaming path (onehot) keep the materialized vmap reference.
    Residual IVF indexes get their resolved reranker wrapped in
    ``ResidualRerank`` so candidates reconstruct as centroid + decode.
    One residual-specific override: the extended-table route pads every
    decode-table face to max(K, nlist), so when ``nlist > K`` (large IVF
    over small codebooks) it would inflate the resident table and the
    per-face contraction work — those indexes rerank through the dedup
    route instead (bit-identical d1, per the engine contract).
    """
    residual = bool(getattr(index, "residual", False))
    impl = resolve_scan_backend(index.backend)
    table = index._decode_table()
    if not backend_supports(impl, "streaming_topl"):
        inner: Reranker = VmapRerank()
    elif table is not None and not (residual and
                                    index.nlist > table.shape[1]):
        inner = TableRerank(
            "pallas" if backend_supports(impl, "fused_rerank") else "xla")
    else:
        inner = DedupRerank()
    return ResidualRerank(inner) if residual else inner


# ---------------------------------------------------------------------------
# use_d2=False: chunked exhaustive rerank over the whole database
# ---------------------------------------------------------------------------

def exhaustive_topk(reconstruct_fn, payload, queries, *, k: int,
                    chunk_n: int = 2048):
    """Exact-d1 top-k over ALL codes without a (Q, N, D) reconstruction:
    a ``lax.scan`` over chunk_n-row payload chunks, each decoded ONCE for
    every query, carrying a (Q, k) heap merged with ``lax.top_k``.

    ``payload`` is whatever ``reconstruct_fn`` needs per point: the
    (N, M) code matrix for plain quantizers, or any pytree of N-leading
    arrays — residual IVF threads ``(codes, cells)`` so each chunk can
    reconstruct ``decode(code) + centroid``. The scan chunks every leaf
    along the leading axis together.

    Tie semantics are exactly ``lax.top_k`` over the full (Q, N) d1
    matrix: the carry is sorted by (distance, index) and every chunk
    entry has a larger global index than every carried entry, so top_k's
    positional tie-break IS the ascending-index tie-break.

    Trace-time function: callers jit it (with ``reconstruct_fn`` closed
    over) so the decode+distance fuse per chunk.
    """
    n = jax.tree_util.tree_leaves(payload)[0].shape[0]
    q = queries.shape[0]
    k = min(k, n)
    pad = (-n) % chunk_n

    def chunked(a):
        a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        return a.reshape((-1, chunk_n) + a.shape[1:])

    payload_c = jax.tree_util.tree_map(chunked, payload)
    num_chunks = (n + pad) // chunk_n
    starts = (jnp.arange(num_chunks) * chunk_n).astype(jnp.int32)

    def step(carry, inp):
        vals, idx = carry                                    # (Q, k) x2
        chunk, start = inp
        recon = reconstruct_fn(chunk)                        # (c, D), once
        d = jnp.sum(jnp.square(recon[None, :, :] - queries[:, None, :]),
                    axis=-1)                                 # (Q, c)
        gids = start + jnp.arange(chunk_n, dtype=jnp.int32)
        d = jnp.where(gids[None, :] < n, d, jnp.inf)
        cand_s = jnp.concatenate([vals, d], axis=1)
        cand_g = jnp.concatenate(
            [idx, jnp.broadcast_to(gids[None, :], (q, chunk_n))], axis=1)
        neg, pos = jax.lax.top_k(-cand_s, k)
        return (-neg, jnp.take_along_axis(cand_g, pos, axis=1)), None

    init = (jnp.full((q, k), jnp.inf, jnp.float32),
            jnp.full((q, k), _IMAX, jnp.int32))
    (vals, idx), _ = jax.lax.scan(step, init, (payload_c, starts))
    return vals, idx
