"""Pluggable stage-2 reranking (the streaming engine's index face).

``Index._rerank_distances`` — exact reconstruction distances d1 (paper
Eq. 7) over each query's stage-1 candidate list — delegates to a
``Reranker`` resolved through the scan-backend registry, mirroring how
stage 1 resolves a ``CandidateGenerator``:

  * ``TableRerank``  table-decodable quantizers (PQ / OPQ / RVQ:
                     ``recon = sum_m table[m, code_m]``) on streaming
                     backends. Backends declaring ``fused_rerank`` run
                     the fused gather-decode-distance Pallas kernel;
                     the rest the chunked ``lax.scan`` fallback. Peak
                     reconstruction memory O(Q * block * D) — the
                     (Q, L, D) tensor is never materialized.
  * ``DedupRerank``  decoder quantizers (UNQ's neural decoder) on
                     streaming backends: cross-query candidate dedup.
                     Candidate pools overlap heavily across queries, so
                     the (Q*L) pool is flattened, each UNIQUE code row is
                     decoded once in fixed-size batches, and distances
                     are gathered back per (query, candidate) in chunks —
                     decoder FLOPs and activation memory are bounded by
                     the decode chunk, and the held reconstruction shrinks
                     from (Q*L, D) to (U, D), U = #unique <= min(Q*L, N).
  * ``VmapRerank``   the classic per-query gather + decode + reduce vmap,
                     materializing (Q, L, D). Kept as the A/B oracle and
                     used by backends without streaming capabilities
                     (onehot).

All three produce bit-identical d1 (and therefore identical final
(distance, index) rankings) — verified by tests/test_rerank.py — so
reranker selection is purely a memory/performance decision, never a
quality one.

``exhaustive_rerank_topk`` is the ``use_d2=False`` ablation re-shaped the
same way: a ``lax.scan`` over database chunks, each decoded ONCE for all
queries (the decode is query-independent), merged into a running (Q, k)
heap with the same lexicographic tie semantics as the stage-1 streaming
engine — the (Q, N, D) reconstruction of the old path never exists.
"""
from __future__ import annotations

import abc
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.backend import backend_supports, resolve_scan_backend
from repro.kernels import ops

_IMAX = jnp.iinfo(jnp.int32).max

#: decode-batch ladder for DedupRerank (fixed shapes -> one compile per
#: bucket, smallest bucket >= the unique count serves small pools)
DEDUP_DECODE_CHUNK = 2048
#: L-chunk for the gathered-distance scan (shared with the table path)
DEDUP_DIST_CHUNK = ops.DEFAULT_RERANK_CHUNK_L


class Reranker(abc.ABC):
    """Stage-2 strategy: queries + candidate ids -> exact d1 distances."""

    #: whether this reranker materializes the (Q, L, D) reconstruction
    materializes_recon: bool

    @abc.abstractmethod
    def distances(self, index, queries, cand) -> jax.Array:
        """queries (Q, D), cand (Q, L) int32 rows of ``index.codes`` ->
        d1 (Q, L) f32 with d1[q, l] = ||queries[q] - recon(cand[q, l])||^2."""

    def __repr__(self):
        return f"{type(self).__name__}()"


class VmapRerank(Reranker):
    """Per-query ``codes[c_idx]`` gather + decode + reduce under ``vmap``
    (the pre-streaming stage 2; reference semantics, O(Q*L*D) peak)."""

    materializes_recon = True

    def distances(self, index, queries, cand):
        return index._rerank_distances_vmap(queries, cand)


class TableRerank(Reranker):
    """Streaming stage 2 for table-decodable quantizers
    (``ops.rerank_gather_dist``): candidate codes are gathered as uint8
    (L*M bytes per query, ~100x smaller than the float reconstruction)
    and the decode+distance runs tile-by-tile — fused Pallas kernel or
    chunked xla, bit-identical to ``VmapRerank``."""

    materializes_recon = False

    def __init__(self, impl: str):
        self.impl = impl                # concrete kernels.ops impl string

    def distances(self, index, queries, cand):
        cand_codes = jnp.take(index.codes, cand, axis=0)     # (Q, L, M) u8
        return ops.rerank_gather_dist(
            cand_codes, jnp.asarray(queries, jnp.float32),
            index._decode_table(), impl=self.impl)

    def __repr__(self):
        return f"TableRerank(impl={self.impl!r})"


@functools.partial(jax.jit, static_argnames=("chunk_l",))
def _gathered_dist_chunked(recon_u, queries, inv, *, chunk_l: int):
    """d[q, l] = ||queries[q] - recon_u[inv[q, l]]||^2 via a ``lax.scan``
    over (Q, chunk_l) column chunks — peak gathered-reconstruction memory
    O(Q * chunk_l * D) instead of O(Q * L * D)."""
    q, l = inv.shape
    pad = (-l) % chunk_l
    inv_c = jnp.moveaxis(
        jnp.pad(inv, ((0, 0), (0, pad))).reshape(q, -1, chunk_l), 1, 0)

    def step(_, idx):
        recon = recon_u[idx]                                 # (Q, c, D)
        return None, jnp.sum(jnp.square(recon - queries[:, None, :]),
                             axis=-1)

    _, ds = jax.lax.scan(step, None, inv_c)                  # (nc, Q, c)
    return jnp.moveaxis(ds, 0, 1).reshape(q, -1)[:, :l]


class DedupRerank(Reranker):
    """Cross-query candidate dedup for decoder quantizers (UNQ).

    Stage-1 pools overlap heavily across queries (popular database points
    appear in many top-L lists), so decoding ``codes[cand]`` per query
    repeats the expensive neural decode for every duplicate. This path
    runs host-side dedup on the concrete candidate matrix (search is
    eager), decodes each unique code row ONCE in fixed-size batches, and
    gathers the decoded rows back per (query, candidate) in chunks.

    Memory: decoder activations are bounded by ``decode_chunk`` and the
    gathered distance tiles by ``dist_chunk``; the held reconstruction is
    the deduped (U, D) matrix, U = #unique <= min(Q*L, ntotal) — the
    savings over the vmap path's (Q, L, D) scale exactly with the pool
    overlap (worst case, fully disjoint pools, they are the same size).

    Exactness: the decoder is row-stable (per-row results are independent
    of batch composition for batch > 1), so gathered unique rows are
    bit-identical to the per-query decode — d1 matches ``VmapRerank``
    bit-for-bit.
    """

    materializes_recon = False

    def __init__(self, decode_chunk: int = DEDUP_DECODE_CHUNK,
                 dist_chunk: int = DEDUP_DIST_CHUNK):
        self.decode_chunk = decode_chunk
        self.dist_chunk = dist_chunk

    def distances(self, index, queries, cand):
        cand = jnp.asarray(cand)
        q, l = cand.shape
        uniq, inv = np.unique(np.asarray(cand), return_inverse=True)
        # smallest ladder bucket >= n_unique (>= 8 keeps the decoder's
        # matmuls off degenerate single-row shapes)
        chunk = self.decode_chunk
        while chunk // 2 >= max(uniq.size, 8) and chunk > 8:
            chunk //= 2
        pad = (-uniq.size) % chunk
        codes_u = jnp.take(index.codes, jnp.asarray(
            np.pad(uniq, (0, pad)), jnp.int32), axis=0)      # (U_pad, M)
        decode = index._chunk_decode_fn()
        recon_u = jnp.concatenate(
            [decode(codes_u[s:s + chunk])
             for s in range(0, codes_u.shape[0], chunk)], axis=0)
        return _gathered_dist_chunked(
            recon_u, jnp.asarray(queries, jnp.float32),
            jnp.asarray(inv.reshape(q, l), jnp.int32),
            chunk_l=self.dist_chunk)


def reranker_for(index) -> Reranker:
    """Resolve an index's backend request to a stage-2 reranker.

    Streaming-capable backends (``streaming_topl``) get the streaming
    engine — the fused kernel where the backend declares ``fused_rerank``
    and the index is table-decodable, the chunked xla path otherwise for
    tables, cross-query dedup for decoder quantizers. Backends without a
    streaming path (onehot) keep the materialized vmap reference.
    """
    impl = resolve_scan_backend(index.backend)
    if not backend_supports(impl, "streaming_topl"):
        return VmapRerank()
    if index._decode_table() is not None:
        return TableRerank(
            "pallas" if backend_supports(impl, "fused_rerank") else "xla")
    return DedupRerank()


# ---------------------------------------------------------------------------
# use_d2=False: chunked exhaustive rerank over the whole database
# ---------------------------------------------------------------------------

def exhaustive_topk(reconstruct_fn, codes, queries, *, k: int,
                    chunk_n: int = 2048):
    """Exact-d1 top-k over ALL codes without a (Q, N, D) reconstruction:
    a ``lax.scan`` over (chunk_n, M) code chunks, each decoded ONCE for
    every query, carrying a (Q, k) heap merged with ``lax.top_k``.

    Tie semantics are exactly ``lax.top_k`` over the full (Q, N) d1
    matrix: the carry is sorted by (distance, index) and every chunk
    entry has a larger global index than every carried entry, so top_k's
    positional tie-break IS the ascending-index tie-break.

    Trace-time function: callers jit it (with ``reconstruct_fn`` closed
    over) so the decode+distance fuse per chunk.
    """
    n, m = codes.shape
    q = queries.shape[0]
    k = min(k, n)
    pad = (-n) % chunk_n
    codes_c = jnp.pad(codes, ((0, pad), (0, 0))).reshape(-1, chunk_n, m)
    starts = (jnp.arange(codes_c.shape[0]) * chunk_n).astype(jnp.int32)

    def step(carry, inp):
        vals, idx = carry                                    # (Q, k) x2
        chunk, start = inp
        recon = reconstruct_fn(chunk)                        # (c, D), once
        d = jnp.sum(jnp.square(recon[None, :, :] - queries[:, None, :]),
                    axis=-1)                                 # (Q, c)
        gids = start + jnp.arange(chunk_n, dtype=jnp.int32)
        d = jnp.where(gids[None, :] < n, d, jnp.inf)
        cand_s = jnp.concatenate([vals, d], axis=1)
        cand_g = jnp.concatenate(
            [idx, jnp.broadcast_to(gids[None, :], (q, chunk_n))], axis=1)
        neg, pos = jax.lax.top_k(-cand_s, k)
        return (-neg, jnp.take_along_axis(cand_g, pos, axis=1)), None

    init = (jnp.full((q, k), jnp.inf, jnp.float32),
            jnp.full((q, k), _IMAX, jnp.int32))
    (vals, idx), _ = jax.lax.scan(step, init, (codes_c, starts))
    return vals, idx
