"""FAISS-style string factory for compressed-domain indexes.

    index = index_factory("UNQ8x256,Rerank500", dim=96)

Grammar — comma-separated components, exactly one quantizer:

  quantizers                         modifiers
  ----------------------------       ---------------------------------
  UNQ{M}x{K}   neural (the paper)    Rerank{L}   stage-2 budget (d1)
  PQ{M}[x{K}]  product quant.        Scan(name)  pin a scan backend
  OPQ{M}[x{K}] rotated PQ                        (xla|onehot|pallas|auto)
  RVQ{M}[x{K}] residual/additive

M = codebooks (bytes/vector at K<=256), K = codebook size (default 256).
Without ``Rerank``, UNQ keeps its paper default (L=500) and the shallow
quantizers are ADC-only — the classic FAISS IndexPQ behavior.
"""
from __future__ import annotations

import re

from repro.index.base import Index
from repro.index.pq_index import OPQIndex, PQIndex, RVQIndex
from repro.index.unq_index import UNQIndex

_QUANT_RE = re.compile(r"^(UNQ|PQ|OPQ|RVQ)(\d+)(?:x(\d+))?$")
_RERANK_RE = re.compile(r"^Rerank(\d+)$")
_SCAN_RE = re.compile(r"^Scan\((\w+)\)$")

_QUANTIZERS = {"UNQ": UNQIndex, "PQ": PQIndex, "OPQ": OPQIndex,
               "RVQ": RVQIndex}


def index_factory(spec: str, dim: int, *, backend: str = "auto") -> Index:
    """Build an untrained Index from a factory string (see module doc)."""
    quant = None          # (cls, M, K)
    rerank = None
    scan = backend
    for comp in spec.split(","):
        comp = comp.strip()
        if not comp:
            continue
        m = _QUANT_RE.match(comp)
        if m:
            if quant is not None:
                raise ValueError(f"multiple quantizers in {spec!r}")
            quant = (_QUANTIZERS[m.group(1)], int(m.group(2)),
                     int(m.group(3) or 256))
            continue
        m = _RERANK_RE.match(comp)
        if m:
            rerank = int(m.group(1))
            continue
        m = _SCAN_RE.match(comp)
        if m:
            scan = m.group(1)
            continue
        raise ValueError(
            f"cannot parse component {comp!r} of factory string {spec!r} "
            "(expected UNQ8x256 / PQ8 / OPQ8x256 / RVQ8 / Rerank500 / "
            "Scan(xla))")
    if quant is None:
        raise ValueError(f"no quantizer component in factory string {spec!r}")

    cls, num_books, book_size = quant
    kw: dict = {"backend": scan}
    if rerank is not None:
        kw["rerank"] = rerank
    if cls is UNQIndex:
        return cls(dim, num_codebooks=num_books, codebook_size=book_size,
                   **kw)
    return cls(dim, num_books=num_books, book_size=book_size, **kw)
