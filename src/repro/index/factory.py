"""FAISS-style string factory for compressed-domain indexes.

    index = index_factory("UNQ8x256,Rerank500", dim=96)
    index = index_factory("IVF1024,Residual,PQ8x256,Rerank500", dim=96)

Grammar — comma-separated components, exactly one quantizer (the
canonical component table is ``FACTORY_GRAMMAR`` below; ``docs/API.md``
renders it and ``tests/test_docs.py`` keeps the two in sync):

  quantizers                         modifiers
  ----------------------------       ---------------------------------
  UNQ{M}x{K}   neural (the paper)    IVF{nlist}  coarse k-means partition
  PQ{M}[x{K}]  product quant.                    in front of the scan
  OPQ{M}[x{K}] rotated PQ            NProbe{p}   cells probed per query
  RVQ{M}[x{K}] residual/additive                 (default 8; needs IVF)
                                     Residual    IVFADC: encode
                                                 x - centroid(x)
                                                 (needs IVF)
                                     Rerank{L}   stage-2 budget (d1)
                                     Scan(name)  pin a scan backend
                                                 (xla|onehot|pallas|auto)

M = codebooks (bytes/vector at K<=256), K = codebook size (default 256).
Without ``Rerank``, UNQ keeps its paper default (L=500) and the shallow
quantizers are ADC-only — the classic FAISS IndexPQ behavior. An ``IVF``
prefix wraps the quantizer in an ``IVFIndex``: vectors are assigned to
``nlist`` k-means cells on ``add`` and only ``nprobe`` cells are scanned
per query (``nprobe=nlist`` reproduces flat search bit-for-bit). Adding
``Residual`` turns the IVF index into the classic IVFADC refinement: the
quantizer trains on and encodes ``x - centroid(x)``, reconstructions
become ``centroid + decode(code)``, and search corrects distances
accordingly (exactly for table-decodable quantizers).
"""
from __future__ import annotations

import re

from repro.index.base import Index
from repro.index.ivf import IVFIndex
from repro.index.pq_index import OPQIndex, PQIndex, RVQIndex
from repro.index.unq_index import UNQIndex

_QUANT_RE = re.compile(r"^(UNQ|PQ|OPQ|RVQ)(\d+)(?:x(\d+))?$")
_IVF_RE = re.compile(r"^IVF(\d+)$")
_NPROBE_RE = re.compile(r"^NProbe(\d+)$")
_RERANK_RE = re.compile(r"^Rerank(\d+)$")
_SCAN_RE = re.compile(r"^Scan\((\w+)\)$")

_QUANTIZERS = {"UNQ": UNQIndex, "PQ": PQIndex, "OPQ": OPQIndex,
               "RVQ": RVQIndex}

#: The canonical factory grammar: one (component, description) row per
#: token the parser accepts. ``docs/API.md``'s grammar table renders
#: exactly these components and ``tests/test_docs.py`` asserts the doc
#: and the parser never drift apart.
FACTORY_GRAMMAR: tuple[tuple[str, str], ...] = (
    ("UNQ{M}x{K}", "neural quantizer (the paper); M codebooks, K codewords"),
    ("PQ{M}[x{K}]", "product quantization (K defaults to 256)"),
    ("OPQ{M}[x{K}]", "optimized PQ: learned rotation + PQ"),
    ("RVQ{M}[x{K}]", "residual (additive) vector quantization"),
    ("IVF{nlist}", "coarse k-means partition in front of the scan"),
    ("NProbe{p}", "cells probed per query (default 8; requires IVF)"),
    ("Residual", "IVFADC: encode x - centroid(x) (requires IVF)"),
    ("Rerank{L}", "stage-2 exact-reconstruction budget (d1)"),
    ("Scan(name)", "pin a scan backend: xla / onehot / pallas / auto"),
)


def index_factory(spec: str, dim: int, *, backend: str = "auto") -> Index:
    """Build an untrained Index from a factory string (see module doc)."""
    quant = None          # (cls, M, K)
    rerank = None
    nlist = None
    nprobe = None
    residual = False
    scan = backend
    for comp in spec.split(","):
        comp = comp.strip()
        if not comp:
            continue
        m = _QUANT_RE.match(comp)
        if m:
            if quant is not None:
                raise ValueError(f"multiple quantizers in {spec!r}")
            quant = (_QUANTIZERS[m.group(1)], int(m.group(2)),
                     int(m.group(3) or 256))
            continue
        m = _IVF_RE.match(comp)
        if m:
            if nlist is not None:
                raise ValueError(f"multiple IVF components in {spec!r}")
            nlist = int(m.group(1))
            continue
        m = _NPROBE_RE.match(comp)
        if m:
            nprobe = int(m.group(1))
            continue
        if comp == "Residual":
            residual = True
            continue
        m = _RERANK_RE.match(comp)
        if m:
            rerank = int(m.group(1))
            continue
        m = _SCAN_RE.match(comp)
        if m:
            scan = m.group(1)
            continue
        raise ValueError(
            f"cannot parse component {comp!r} of factory string {spec!r} "
            "(expected UNQ8x256 / PQ8 / OPQ8x256 / RVQ8 / IVF1024 / "
            "NProbe8 / Residual / Rerank500 / Scan(xla))")
    if quant is None:
        raise ValueError(f"no quantizer component in factory string {spec!r}")
    if nprobe is not None and nlist is None:
        raise ValueError(f"NProbe without an IVF component in {spec!r}")
    if residual and nlist is None:
        raise ValueError(
            f"Residual without an IVF component in {spec!r} (residual "
            "encoding is defined against the coarse centroids)")

    cls, num_books, book_size = quant
    kw: dict = {"backend": scan}
    if rerank is not None:
        kw["rerank"] = rerank
    if cls is UNQIndex:
        inner = cls(dim, num_codebooks=num_books, codebook_size=book_size,
                    **kw)
    else:
        inner = cls(dim, num_books=num_books, book_size=book_size, **kw)
    if nlist is None:
        return inner
    return IVFIndex(dim, inner=inner, nlist=nlist,
                    nprobe=nprobe if nprobe is not None else 8,
                    rerank=inner.rerank, backend=scan, residual=residual)
