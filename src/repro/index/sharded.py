"""ShardedIndex: distributed stage 1 over code shards.

Subsumes the old ``core.search.search_sharded`` free function and the
host-side shard driver in ``examples/serve_search.py``: each shard scans
its own code block with the (replicated) LUTs through the streaming
scan+top-L engine, the per-shard pools merge into a global candidate pool,
and stage 2 reranks the merged pool once — the pattern that scales the
paper's billion-vector experiments across a pod.

Placement modes:

  * ``device`` — the real thing: code (and bias) shards live RESIDENT on
    devices under ``shard_map`` (``repro.parallel.search``), one shard per
    device, per-device fused scan+top-L, all-gather of the (L, 2)
    candidate tuples, one rerank on the merged pool. Selected by
    ``placement="auto"`` whenever more than one device is visible.
  * ``host`` — logical shards (host-side views over one code matrix),
    scanned sequentially. The single-device fallback, and what
    ``from_shards`` uses for externally-supplied shard stores.

Wrapping an ``IVFIndex`` shards BY COARSE CELL: the cell-grouped buffer is
cut at cell boundaries (balanced by row count), so every inverted list
lives wholly on one shard and a probed cell touches exactly one shard —
shards none of the batch's probed cells map to are skipped outright in
host mode, and in device mode each device's ragged probe plan covers only
the cells it owns. Cross-shard pools merge with an explicit lexicographic
(score, global-id) top-L (``candidates.merge_topl``) because cell-grouped
shards interleave global ids.

``filter_mask`` threads through every mode: host shards see per-shard
slices of the lowered ±inf bias streams, device shards stream their slice
of the (Q, N) mask tiles, and IVF shards fold the mask into the probe
plan's slot bias.

All modes are bit-identical to the equivalent flat ``Index.search`` — the
per-shard top-L keeps everything the global top-L can contain, and merges
preserve ``lax.top_k``'s smaller-index tie-break.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import base
from repro.index.backend import backend_supports, resolve_scan_backend
from repro.index.candidates import candidate_generator_for, merge_topl
from repro.index.ivf import IVFIndex

_IMAX = np.iinfo(np.int32).max


class ShardedIndex:
    """Wraps a trained Index, presenting the same train/add/search surface
    with stage 1 executed per-shard and merged."""

    def __init__(self, inner: base.Index, num_shards: int = 8, *,
                 placement: str = "auto"):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        if placement not in ("auto", "host", "device"):
            raise ValueError(
                f"placement must be auto|host|device, got {placement!r}")
        self.inner = inner
        self.num_shards = num_shards
        self.placement = placement
        # explicit shard mode (from_shards): pre-split code blocks
        self._shards = None
        self._offsets = None
        self._biases = None

    @classmethod
    def from_shards(cls, inner: base.Index, shards, offsets,
                    biases=None) -> "ShardedIndex":
        """Wrap pre-split code shards (arbitrary offsets). Only stage-1
        candidate generation is available in this mode unless the shards
        are a contiguous split of the inner index's codes.

        ``biases``: per-shard (n_s,) score-bias arrays for additive
        quantizers (RVQ stores ||decode(code)||^2). Required whenever the
        inner index carries a bias — dropping it silently would corrupt
        the stage-1 ranking.
        """
        if isinstance(inner, IVFIndex):
            raise ValueError(
                "from_shards does not support IVF indexes — their shards "
                "are derived from the cell grouping; wrap the IVFIndex "
                "directly in ShardedIndex instead")
        index = cls(inner, num_shards=len(shards), placement="host")
        index._shards = [jnp.asarray(s) for s in shards]
        index._offsets = list(offsets)
        if biases is None and inner.bias is not None:
            raise ValueError(
                f"{type(inner).__name__} scores carry a per-point bias; "
                "pass the matching per-shard `biases` to from_shards")
        if biases is not None:
            biases = [jnp.asarray(b) for b in biases]
            if [int(b.shape[0]) for b in biases] != \
                    [int(s.shape[0]) for s in index._shards]:
                raise ValueError("biases/shards length mismatch")
        index._biases = biases
        return index

    # -- delegated surface -------------------------------------------------

    @property
    def dim(self) -> int:
        return self.inner.dim

    @property
    def ntotal(self) -> int:
        if self._shards is not None:
            return int(sum(s.shape[0] for s in self._shards))
        return self.inner.ntotal

    @property
    def is_trained(self) -> bool:
        return self.inner.is_trained

    def result_width(self, k: int) -> int:
        """See ``Index.result_width`` (against this wrapper's ntotal)."""
        return min(k, self.ntotal)

    def train(self, xs, **kw) -> "ShardedIndex":
        self.inner.train(xs, **kw)
        return self

    def add(self, xs) -> "ShardedIndex":
        if self._shards is not None:
            raise RuntimeError("add() is not supported in from_shards mode")
        self.inner.add(xs)
        return self

    @property
    def resolved_placement(self) -> str:
        """The stage-1 placement searches will actually use. Device-resident
        iff requested, or auto with a real mesh AND a streaming-capable
        backend (explicit from_shards stores are host-side by
        construction; the materialized onehot path stays host-logical)."""
        if self._shards is not None:
            return "host"
        if self.placement == "auto":
            streaming = backend_supports(
                resolve_scan_backend(self.inner.backend), "streaming_topl")
            return "device" if streaming and len(jax.devices()) > 1 \
                else "host"
        return self.placement

    def _shard_views(self):
        """[(codes, offset, bias)] — explicit shards, or a contiguous
        equal split of the inner code matrix (tail rides the last shard)."""
        if self._shards is not None:
            biases = self._biases or [None] * len(self._shards)
            return list(zip(self._shards, self._offsets, biases))
        codes, bias = self.inner.codes, self.inner.bias
        n = codes.shape[0]
        per = max(n // self.num_shards, 1)
        views = []
        for i in range(self.num_shards):
            lo = i * per
            hi = n if i == self.num_shards - 1 else min((i + 1) * per, n)
            if lo >= hi:
                break
            views.append((codes[lo:hi], lo,
                          None if bias is None else bias[lo:hi]))
        return views

    def _ivf_cell_bounds(self) -> list[int]:
        """Cell boundaries of the by-cell sharding: ``num_shards + 1``
        monotone cell ids cutting the cell-grouped buffer into row-balanced
        contiguous cell ranges (a cell never straddles two shards)."""
        off = self.inner._offsets
        n = int(off[-1])
        bounds = [0]
        for s in range(1, self.num_shards):
            target = round(s * n / self.num_shards)
            c = int(np.searchsorted(off, target, side="left"))
            bounds.append(min(max(c, bounds[-1]), self.inner.nlist))
        bounds.append(self.inner.nlist)
        return bounds

    # -- search ------------------------------------------------------------

    def stage1_candidates(self, queries, topl: int | None = None, *,
                          filter_mask=None, nprobe=None,
                          use_dispatch: bool | None = None):
        """Distributed stage 1: per-shard top-L merged into the global
        candidate pool. Returns (d2 scores, global indices), each
        (Q, min(topl, pool width)), closest-first. ``nprobe`` and
        ``use_dispatch`` only apply to IVF inners (probe width defaults
        to the index's own; a (Q,) per-query nprobe vector works in host
        placement only; the device placement rides the cell-batched
        dispatch face whenever the backend declares ``dispatch_topl``,
        pinnable either way for A/B runs)."""
        if topl is None:
            topl = self.inner.rerank
        queries = jnp.asarray(queries)
        if isinstance(self.inner, IVFIndex):
            return self._ivf_stage1(queries, topl, filter_mask, nprobe,
                                    use_dispatch)
        if use_dispatch:
            raise ValueError("use_dispatch applies to IVF inners only")
        luts = self.inner._build_luts(queries)
        impl = resolve_scan_backend(self.inner.backend)
        bias, qbias = self.inner._lower_filter(filter_mask,
                                               queries.shape[0])

        if self.resolved_placement == "device":
            if not backend_supports(impl, "streaming_topl"):
                raise ValueError(
                    f"placement='device' needs a streaming_topl-capable "
                    f"scan backend, and {impl!r} does not declare it; use "
                    "placement='host' or a streaming backend (xla/pallas)")
            from repro.parallel.search import device_stage1_topl
            return device_stage1_topl(self.inner.codes, luts, bias,
                                      qbias=qbias, topl=topl, impl=impl)

        gen = candidate_generator_for(self.inner.backend)
        all_scores, all_idx = [], []
        for shard, off, shard_bias in self._shard_views():
            if filter_mask is not None:
                hi = off + shard.shape[0]
                # bias is None for per-query masks on bias-less indexes
                shard_bias = None if bias is None else bias[off:hi]
                shard_qbias = None if qbias is None else qbias[:, off:hi]
            else:
                shard_qbias = None
            s, i = gen.topl(shard, luts, shard_bias,
                            topl=min(topl, shard.shape[0]),
                            qbias=shard_qbias)
            all_scores.append(s)
            # +inf slots (filtered-out pads) keep the _IMAX sentinel: adding
            # the shard offset would wrap int32 into garbage "global" ids
            all_idx.append(jnp.where(jnp.isposinf(s), _IMAX, i + off))
        scores = jnp.concatenate(all_scores, axis=1)     # (Q, n_shards*L)
        idx = jnp.concatenate(all_idx, axis=1)
        neg, order = jax.lax.top_k(-scores, min(topl, scores.shape[1]))
        return -neg, jnp.take_along_axis(idx, order, axis=1)

    def _ivf_stage1(self, queries, topl: int, filter_mask,
                    nprobe, use_dispatch: bool | None = None):
        """By-cell sharded IVF stage 1: each shard owns a contiguous cell
        range; only shards owning a probed cell are scanned (host mode
        skips the rest outright, device mode gives them empty plans); the
        per-shard gathered pools merge lexicographically by
        (score, global id).

        Device placement rides the cell-batched dispatch face by default
        on ``dispatch_topl``-capable backends — per-shard routing over
        clip-restricted CSR offsets, no host plan — with the gathered
        padded-plan face retained as the pinnable control
        (``use_dispatch=False``)."""
        ivf = self.inner
        q = queries.shape[0]
        nprobe_w, probe_lens = ivf._resolve_nprobe(nprobe, q)
        if probe_lens is not None and self.resolved_placement == "device":
            raise ValueError(
                "per-query nprobe vectors are host-plan only; device "
                "placement builds one shard_map plan per batch — use "
                "placement='host' or a uniform nprobe")
        probe, cd = ivf._probe_with_dists(queries, nprobe_w)
        luts = ivf._stage1_luts(queries, probe)
        cell_bias = cd if ivf._exact_residual else None
        bounds = self._ivf_cell_bounds()
        off = ivf._offsets

        if self.resolved_placement == "device":
            impl = resolve_scan_backend(ivf.backend)
            if not backend_supports(impl, "streaming_topl"):
                raise ValueError(
                    "placement='device' needs a streaming_topl-capable "
                    f"scan backend, and {impl!r} does not declare it")
            if use_dispatch is None:
                use_dispatch = backend_supports(impl, "dispatch_topl")
            elif use_dispatch and not backend_supports(impl,
                                                       "dispatch_topl"):
                raise ValueError(
                    f"use_dispatch=True but backend {impl!r} does not "
                    "declare the dispatch_topl capability")
            if use_dispatch:
                from repro.index.dispatch import build_shard_dispatch
                from repro.parallel.search import device_dispatch_topl
                routings = build_shard_dispatch(probe, off, bounds)
                shards = []
                for s, routing in enumerate(routings):
                    row_lo = int(off[bounds[s]])
                    row_hi = int(off[bounds[s + 1]])
                    ids, rowbias, qkeep, cellterm = ivf._dispatch_streams(
                        routing, q, filter_mask, cell_bias,
                        row_range=(row_lo, row_hi))
                    shards.append((row_lo, row_hi, routing, ids, rowbias,
                                   qkeep, cellterm))
                return device_dispatch_topl(ivf.codes, shards, luts,
                                            topl=topl, impl=impl)
            from repro.parallel.search import device_gather_topl
            plans = []
            for s in range(self.num_shards):
                c_lo, c_hi = bounds[s], bounds[s + 1]
                row_lo, row_hi = int(off[c_lo]), int(off[c_hi])
                rows, gids, cells = ivf._probe_plan(
                    probe, cell_range=(c_lo, c_hi), row_offset=row_lo)
                plans.append((row_lo, row_hi, rows, gids, cells))
            rowbias_fn = lambda rows, gids, cells, sb: ivf._plan_rowbias(  # noqa: E731
                rows, gids, sb, filter_mask, q,
                slot_cells=cells if cell_bias is not None else None,
                cell_bias=cell_bias)
            return device_gather_topl(ivf.codes, ivf.bias, plans, luts,
                                      rowbias_fn, topl=topl, impl=impl)

        gen = candidate_generator_for(ivf.backend)
        pool_s, pool_i = [], []
        for s in range(self.num_shards):
            c_lo, c_hi = bounds[s], bounds[s + 1]
            row_lo, row_hi = int(off[c_lo]), int(off[c_hi])
            if row_hi == row_lo:
                continue
            rows_np, gids_np, cells_np = ivf._probe_plan(
                probe, cell_range=(c_lo, c_hi), row_offset=row_lo,
                probe_lens=probe_lens)
            if (gids_np == _IMAX).all():
                continue                      # no query probes this shard
            rows = jnp.asarray(rows_np)
            gids = jnp.asarray(gids_np)
            shard_bias = None if ivf.bias is None \
                else ivf.bias[row_lo:row_hi]
            rowbias = ivf._plan_rowbias(
                rows, gids, shard_bias, filter_mask, q,
                slot_cells=cells_np if cell_bias is not None else None,
                cell_bias=cell_bias)
            s_s, s_i = gen.gather_topl(ivf.codes[row_lo:row_hi], rows,
                                       gids, luts, rowbias,
                                       topl=min(topl, rows.shape[1]))
            pool_s.append(s_s)
            pool_i.append(s_i)
        if not pool_s:                        # every probed cell was empty
            return (jnp.full((q, 1), jnp.inf, jnp.float32),
                    jnp.full((q, 1), _IMAX, jnp.int32))
        return merge_topl(jnp.concatenate(pool_s, axis=1),
                          jnp.concatenate(pool_i, axis=1), topl)

    def search(self, queries, k: int, *, use_rerank: bool | None = None,
               filter_mask=None, nprobe=None,
               use_dispatch: bool | None = None):
        """Full two-stage sharded search: merged stage-1 candidates, then
        ONE stage-2 rerank over the merged pool through the streaming
        rerank engine (``Index._rerank_topk`` resolves a ``Reranker`` per
        backend — fused table kernel or cross-query dedup; the merged
        pool's cross-query overlap is exactly what dedup exploits). Same
        (distances, indices) contract as ``Index.search``, including the
        ``filter_mask`` semantics."""
        queries = jnp.asarray(queries)
        if use_rerank is None:
            use_rerank = self.inner.rerank > 0
        topl = self.inner.rerank if use_rerank else k
        d2, cand = self.stage1_candidates(queries, topl=max(topl, k),
                                          filter_mask=filter_mask,
                                          nprobe=nprobe,
                                          use_dispatch=use_dispatch)
        if isinstance(self.inner, IVFIndex):
            return self.inner._finish_pool(queries, d2, cand, k,
                                           use_rerank=use_rerank)
        if not use_rerank:
            d, i = d2[:, :k], cand[:, :k]
            if filter_mask is not None:
                i = jnp.where(jnp.isposinf(d), -1, i)
            return d, i
        if self._shards is not None and not self._is_contiguous_view():
            raise RuntimeError(
                "stage-2 rerank in from_shards mode needs the shards to be "
                "a contiguous split of the inner index's code matrix "
                "(global candidate ids must index inner.codes)")
        # rerank AFTER the merge (host-side): bit-parity with flat search
        # requires reranking exactly the global top-L pool — a per-shard
        # local rerank would rank a superset and can disagree on top-k
        valid = jnp.isfinite(d2) if filter_mask is not None else None
        return self.inner._rerank_topk(queries, cand, k, valid=valid)

    def _is_contiguous_view(self) -> bool:
        """True iff the explicit shards tile inner.codes front to back, so
        shard-local index + offset is a valid row of inner.codes."""
        if self.inner.ntotal != self.ntotal:
            return False
        expect = 0
        for s, off in zip(self._shards, self._offsets):
            if off != expect:
                return False
            expect += int(s.shape[0])
        return True

    def __repr__(self):
        return (f"ShardedIndex({self.inner!r}, num_shards={self.num_shards}, "
                f"placement={self.resolved_placement!r})")
