"""``repro.index`` — the public entry point for compressed-domain
similarity search: a FAISS-style ``train / add / search / save / load``
surface over UNQ (the paper's method) and the shallow MCQ baselines.

    from repro.index import index_factory, Index

    index = index_factory("UNQ8x256,Rerank500", dim=96)
    index.train(train_vectors, epochs=30)
    index.add(base_vectors)
    distances, indices = index.search(queries, k=100)
    index.save("ckpt/index"); index = Index.load("ckpt/index")

Scan backends (xla | onehot | pallas) resolve per device via
``repro.index.backend``; stage-1 candidate generation resolves through
backend capabilities to the streaming scan+top-L engine
(``repro.index.candidates``), whose gathered face serves IVF probing;
stage-2 reranking resolves the same way to the streaming rerank engine
(``repro.index.rerank``: fused gather-decode-distance kernel, chunked
table decode, or cross-query dedup); an ``IVF{nlist}`` factory prefix
wraps any quantizer in ``IVFIndex`` (coarse k-means cells, ``nprobe``
probed per query, bit-exact vs flat search at full probe) and the
``Residual`` token turns it into IVFADC (encode ``x - centroid(x)``,
reconstruct ``centroid + decode(code)``, exact distance correction on
the bias streams for table quantizers); every
``search`` accepts ``filter_mask=`` (±inf bias streams through all
stage-1 paths); wrap any index in ``ShardedIndex`` for pod-style
per-device scanning — by coarse cell for IVF inners — with an
all-gathered merged rerank.
"""
from repro.index.backend import (available_scan_backends,
                                 backend_capabilities,
                                 backend_supports,
                                 register_scan_backend,
                                 resolve_scan_backend)
from repro.index.base import Index
from repro.index.candidates import (CandidateGenerator, MaterializedTopL,
                                    StreamingTopL, candidate_generator_for,
                                    merge_topl)
from repro.index.factory import FACTORY_GRAMMAR, index_factory
from repro.index.ivf import IVFIndex
from repro.index.pq_index import OPQIndex, PQIndex, RVQIndex
from repro.index.rerank import (DedupRerank, Reranker, ResidualRerank,
                                TableRerank, VmapRerank, reranker_for)
from repro.index.sharded import ShardedIndex
from repro.index.unq_index import UNQIndex

load_index = Index.load

__all__ = [
    "Index",
    "UNQIndex",
    "PQIndex",
    "OPQIndex",
    "RVQIndex",
    "IVFIndex",
    "ShardedIndex",
    "CandidateGenerator",
    "MaterializedTopL",
    "StreamingTopL",
    "candidate_generator_for",
    "merge_topl",
    "Reranker",
    "TableRerank",
    "DedupRerank",
    "VmapRerank",
    "ResidualRerank",
    "reranker_for",
    "index_factory",
    "FACTORY_GRAMMAR",
    "load_index",
    "available_scan_backends",
    "backend_capabilities",
    "backend_supports",
    "register_scan_backend",
    "resolve_scan_backend",
]
