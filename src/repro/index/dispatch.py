"""Device-resident MoE-style probe routing for IVF (cells = experts,
probed queries = routed tokens).

The padded gathered path builds its (Q, W) ragged plan host-side in numpy
on every search call. This module replaces that hot-path host work with
two jitted ``jnp``/``lax`` passes over the (Q, nprobe) probe matrix and
the CSR cell offsets — no host numpy, no per-batch plan transfer:

  1. ``_route_stats`` — one segment-sort pass that measures the routing:
     how many distinct cells are probed (E), the largest co-probing query
     batch (cap) and the chunk-aligned tile count (T). The three scalars
     cross to the host ONCE at the API edge and are bucketed on
     ENCODE_BUCKETS-style power-of-two ladders, so compile count stays
     logarithmic in traffic shape, not linear.
  2. ``_route`` — the bucketed dispatch build (static E/cap/T): a stable
     segment sort of the flattened probe pairs yields each distinct
     cell's dense query batch (``qidx``), the scatter map back from
     (cell, slot) partials to per-query pools (``comb_e``/``comb_slot``),
     and the chunk-aligned tile work-list the kernels execute
     (``kernels.dispatch_topl.DispatchPlan``).

Capacity semantics: by default every routed (query, cell) pair keeps its
slot — ``cap`` buckets the TRUE maximum batch, so routing is lossless and
the dispatch face stays bit-identical to the padded path. An explicit
``capacity_factor`` (the MoE knob: slots per cell ~ factor * Q * P / E)
bounds the batch instead; a dropped pair cannot be proven non-top-L, so
exceeding the bound never drops silently — ``build_dispatch`` reports the
overflow and the caller falls back LOUDLY to the padded path.

``combine_pools`` is the scatter-merge back: per-query gathers of the
per-cell partial top-Ls, merged by the exact lexicographic
(score, global id) ``candidates.merge_topl`` — the same merge the sharded
paths trust, so the final pools are bit-identical to the padded plan's.
"""
from __future__ import annotations

import functools
import threading
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import tune
from repro.kernels.dispatch_topl import DispatchPlan

_IMAX = np.iinfo(np.int32).max


class OverflowMeter:
    """Rate-limited accounting for capacity overflows (the loud padded
    fallback).

    Under a serving loop a hot cell can overflow the ``dispatch_capacity``
    budget on EVERY batch; one ``warnings.warn`` per batch is an unbounded
    warn stream that drowns real signal. The meter warns on the FIRST
    occurrence with full detail, then only every ``warn_every`` further
    occurrences with a since-last summary — and keeps an exact counter so
    load shedding is observable through the serve metrics
    (``repro.serve.metrics``) instead of through log volume.
    """

    def __init__(self, warn_every: int = 100):
        self.warn_every = warn_every
        self._lock = threading.Lock()
        self._count = 0
        self._last_warned = 0

    @property
    def count(self) -> int:
        """Total overflows recorded since process start (or ``reset``)."""
        return self._count

    def reset(self) -> None:
        with self._lock:
            self._count = 0
            self._last_warned = 0

    def record(self, detail: str) -> None:
        """Count one overflow; warn on the first and then one summary per
        ``warn_every`` further occurrences."""
        with self._lock:
            self._count += 1
            since = self._count - self._last_warned
            if self._last_warned and since < self.warn_every:
                return
            self._last_warned = self._count
            if self._count == since:          # first occurrence: full detail
                msg = (f"{detail} (further capacity overflows are "
                       f"rate-limited: one summary per {self.warn_every} "
                       "occurrences; exact count on "
                       "dispatch.OVERFLOWS.count / the serve metrics)")
            else:
                msg = (f"{since} dispatch capacity overflows since the "
                       f"last warning ({self._count} total); latest: "
                       f"{detail}")
        warnings.warn(msg, stacklevel=3)


#: process-wide overflow counter — ``IVFIndex._dispatch_pool`` records
#: here, ``repro.serve`` metrics read ``OVERFLOWS.count`` deltas
OVERFLOWS = OverflowMeter()


class Routing(NamedTuple):
    """A routed probe batch: the kernel work-list plus the index-layer
    side state (scatter-back maps, per-cell ranges, overflow count)."""
    plan: DispatchPlan
    cell_of: jax.Array    # (E+1,) i32 routed cell ids, -1 = unused row
    cell_lo: jax.Array    # (E+1,) i32 buffer row range per routed cell
    cell_hi: jax.Array
    comb_e: jax.Array     # (Q, P) i32 routed-cell row of each probe pair
    comb_slot: jax.Array  # (Q, P) i32 slot within the cell's query batch
    overflow: jax.Array   # () i32 pairs dropped by the capacity bound
    chunk: int = 0        # tile width the plan was built with — pass it
                          # to the scan so router and kernel agree


def _resolve_chunk(probe, offsets, chunk: int | None) -> int:
    """Tile width for a probe batch: the caller's explicit value, else the
    autotuner winner for the IMPL-AGNOSTIC ``adc_dispatch_topl`` registry
    entry at this batch's (n, q) bucket — one shared entry, so the router
    here and ``ops.adc_dispatch_topl`` resolve the SAME width by
    construction (a mismatch would silently mis-tile the plan)."""
    if chunk is not None:
        return chunk
    n = int(np.asarray(offsets).reshape(-1)[-1])
    q = int(np.asarray(probe).shape[0])
    return tune.best_config("adc_dispatch_topl", n=max(n, 1), q=q)["chunk"]


def _bucket(n: int, floor: int = 8) -> int:
    """Power-of-two shape bucket (ENCODE_BUCKETS-style compile ladder)."""
    b = floor
    while b < n:
        b *= 2
    return b


def _segments(flat, order):
    """Shared segment machinery over the cell-sorted probe pairs:
    (sorted cells, first-of-segment mask, segment index, rank within
    segment) — all (Q*P,)."""
    sc = flat[order]
    idx = jnp.arange(flat.shape[0], dtype=jnp.int32)
    first = jnp.concatenate(
        [jnp.ones((1,), bool), sc[1:] != sc[:-1]])
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1
    start = jax.lax.cummax(jnp.where(first, idx, 0))
    return sc, first, seg, idx - start


def _cell_tiles(lo, hi, active, chunk: int):
    """Chunk-ALIGNED tile counts per routed cell: tiles cover
    [lo // chunk * chunk, hi) so a tile index is directly a block index
    into the cell-grouped code buffer (empty active cells keep one tile —
    uniform heap init; inactive rows get none)."""
    a0 = lo // chunk
    span = hi - a0 * chunk
    ntiles = jnp.maximum(-(-span // chunk), 1)
    return a0, jnp.where(active, ntiles, 0)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _route_stats(probe, offsets, *, chunk: int):
    """(E, cap, T) routing measurements as one (3,) device vector — the
    single host sync of the dispatch path, read at the API edge."""
    flat = probe.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(flat, stable=True)
    sc, first, seg, rank = _segments(flat, order)
    e_count = seg[-1] + 1
    cap_needed = jnp.max(rank) + 1
    lo = jnp.take(offsets, sc)
    hi = jnp.take(offsets, sc + 1)
    _, ntiles = _cell_tiles(lo, hi, jnp.ones_like(lo, bool), chunk)
    t_count = jnp.sum(jnp.where(first, ntiles, 0))
    return jnp.stack([e_count, cap_needed, t_count])


@functools.partial(jax.jit,
                   static_argnames=("e_b", "cap", "t_b", "chunk"))
def _route(probe, offsets, *, e_b: int, cap: int, t_b: int, chunk: int):
    """The bucketed dispatch build (see module doc). Shapes are static in
    (e_b, cap, t_b, chunk); every dynamic quantity lives in array values,
    so one compile serves every batch that lands in the same buckets."""
    q, p = probe.shape
    qp = q * p
    flat = probe.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(flat, stable=True)
    sc, first, seg, rank = _segments(flat, order)
    sq = (jnp.arange(qp, dtype=jnp.int32) // p)[order]

    kept = (rank < cap) & (seg < e_b)
    dest_e = jnp.where(kept, seg, e_b)            # dropped pairs -> dummy row
    dest_c = jnp.where(kept, rank, 0)
    qidx = jnp.full((e_b + 1, cap), -1, jnp.int32).at[dest_e, dest_c].set(sq)
    qidx = qidx.at[e_b, :].set(-1)
    cell_of = jnp.full((e_b + 1,), -1, jnp.int32).at[
        jnp.where(first & (seg < e_b), seg, e_b)].set(sc)
    cell_of = cell_of.at[e_b].set(-1)
    safe_cell = jnp.clip(cell_of, 0, offsets.shape[0] - 2)
    active = cell_of >= 0
    cell_lo = jnp.where(active, jnp.take(offsets, safe_cell), 0)
    cell_hi = jnp.where(active, jnp.take(offsets, safe_cell + 1), 0)

    # scatter the routing back to probe order: where did pair (q, p) land?
    comb_e = jnp.zeros((qp,), jnp.int32).at[order].set(
        jnp.where(kept, seg, -1)).reshape(q, p)
    comb_slot = jnp.zeros((qp,), jnp.int32).at[order].set(
        dest_c).reshape(q, p)
    overflow = qp - jnp.sum(kept.astype(jnp.int32))

    # chunk-aligned tile work-list: cells in routed order, tiles of one
    # cell consecutive (the kernels' heap-residency contract), pads last
    a0, ntiles = _cell_tiles(cell_lo, cell_hi, active, chunk)
    cum = jnp.cumsum(ntiles)
    t_idx = jnp.arange(t_b, dtype=jnp.int32)
    te = jnp.clip(jnp.searchsorted(cum, t_idx, side="right"),
                  0, e_b).astype(jnp.int32)
    prev = jnp.where(te > 0, jnp.take(cum, jnp.maximum(te - 1, 0)), 0)
    within = t_idx - prev
    valid = t_idx < cum[-1]
    plan = DispatchPlan(
        qidx=qidx,
        tile_e=jnp.where(valid, te, e_b).astype(jnp.int32),
        tile_block=jnp.where(valid, jnp.take(a0, te) + within,
                             0).astype(jnp.int32),
        tile_first=(valid & (within == 0)).astype(jnp.int32),
        tile_lo=jnp.where(valid, jnp.take(cell_lo, te), 0).astype(jnp.int32),
        tile_hi=jnp.where(valid, jnp.take(cell_hi, te), 0).astype(jnp.int32))
    return Routing(plan, cell_of, cell_lo, cell_hi, comb_e, comb_slot,
                   overflow.astype(jnp.int32))


def route_stats(probe, offsets, *, chunk: int | None = None):
    """Measure a probe batch's routing: (E, cap_needed, T) host ints.
    ``chunk=None`` resolves the tuned tile width (``_resolve_chunk``)."""
    chunk = _resolve_chunk(probe, offsets, chunk)
    stats = np.asarray(_route_stats(jnp.asarray(probe),
                                    jnp.asarray(offsets, jnp.int32),
                                    chunk=chunk))
    return int(stats[0]), int(stats[1]), int(stats[2])


def build_dispatch(probe, offsets, *, chunk: int | None = None,
                   capacity_factor: float | None = None):
    """Route one probe batch. Returns (Routing | None, stats) where stats
    is the measured (E, cap_needed, T).

    ``chunk=None`` resolves the tuned tile width for this batch's shape
    bucket (``_resolve_chunk``); the width used is recorded on
    ``Routing.chunk`` so the scan call can reuse it verbatim.

    With the default ``capacity_factor=None`` the slot capacity buckets
    the TRUE maximum co-probing batch — nothing is ever dropped and the
    dispatch face is exactly the padded path. An explicit factor bounds
    capacity at ``ceil(factor * Q * P / E)``; a batch that exceeds it
    returns ``None`` (the caller's loud fallback) instead of silently
    dropping candidates that cannot be proven non-top-L.
    """
    chunk = _resolve_chunk(probe, offsets, chunk)
    probe = jnp.asarray(probe)
    offsets = jnp.asarray(offsets, jnp.int32)
    q, p = probe.shape
    e_count, cap_needed, t_count = route_stats(probe, offsets, chunk=chunk)
    if capacity_factor is not None:
        limit = max(1, -(-int(capacity_factor * q * p) // max(e_count, 1)))
        if cap_needed > limit:
            return None, (e_count, cap_needed, t_count)
    routing = _route(probe, offsets, e_b=_bucket(e_count),
                     cap=_bucket(cap_needed), t_b=_bucket(t_count),
                     chunk=chunk)
    return routing._replace(chunk=chunk), (e_count, cap_needed, t_count)


def build_shard_dispatch(probe, offsets, bounds, *,
                         chunk: int | None = None):
    """Per-shard routings for the cell-sharded device face.

    offsets the FULL host CSR (nlist + 1,); bounds the ``num_shards + 1``
    monotone cell boundaries of the by-cell sharding. Each shard routes
    the SAME global probe against its clip-restricted offsets
    (``clip(offsets, row_lo, row_hi) - row_lo``): cells the shard does
    not own become empty spans, so no probe masking is needed and the
    routed slot layout stays aligned across shards. All shards share one
    set of shape buckets (the max of the per-shard measurements, fetched
    in a single host sync), so their plan fields stack into the (S, ...)
    arrays one SPMD program consumes.

    Returns [Routing] of length ``len(bounds) - 1``. No capacity factor
    here: the sharded face always routes losslessly (per-shard drops
    could not fall back shard-locally without desyncing the SPMD step).
    """
    chunk = _resolve_chunk(probe, offsets, chunk)
    probe = jnp.asarray(probe)
    off_np = np.asarray(offsets, np.int64)
    clipped = []
    for s in range(len(bounds) - 1):
        row_lo = int(off_np[bounds[s]])
        row_hi = int(off_np[bounds[s + 1]])
        clipped.append(np.clip(off_np, row_lo, row_hi) - row_lo)
    offs = jnp.asarray(np.stack(clipped), jnp.int32)
    stats = np.asarray(jax.vmap(
        lambda o: _route_stats(probe, o, chunk=chunk))(offs))
    e_b = _bucket(int(stats[:, 0].max()))
    cap = _bucket(int(stats[:, 1].max()))
    t_b = _bucket(int(stats[:, 2].max()))
    return [_route(probe, offs[s], e_b=e_b, cap=cap, t_b=t_b,
                   chunk=chunk)._replace(chunk=chunk)
            for s in range(offs.shape[0])]


@functools.partial(jax.jit, static_argnames=("topl",))
def combine_pools(partial_s, partial_g, comb_e, comb_slot, *, topl: int):
    """Scatter-merge per-cell partial top-Ls back to per-query pools.

    partial_s/partial_g (E+1, cap, L) from ``ops.adc_dispatch_topl``,
    comb_e/comb_slot (Q, P) from the routing (-1 = dropped pair) ->
    (scores, gids), each (Q, min(topl, P*L)), sorted by (score asc,
    global id asc) — the exact lexicographic merge, so the result is
    bit-identical to the padded gathered path over the same probe.
    """
    from repro.index.candidates import merge_topl
    q, p = comb_e.shape
    l = partial_s.shape[-1]
    safe_e = jnp.where(comb_e >= 0, comb_e, partial_s.shape[0] - 1)
    ps = partial_s[safe_e, comb_slot]                     # (Q, P, L)
    pg = partial_g[safe_e, comb_slot]
    ps = jnp.where((comb_e >= 0)[..., None], ps, jnp.inf)
    pg = jnp.where(jnp.isposinf(ps), _IMAX, pg)
    return merge_topl(ps.reshape(q, p * l), pg.reshape(q, p * l), topl)
