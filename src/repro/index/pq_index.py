"""Shallow MCQ baselines behind the same Index protocol as UNQ: PQ, OPQ
and RVQ (the additive-family stand-in for LSQ). Sharing the protocol —
and the exact same batched ADC scan kernel — is what turns the paper's
Table 1-4 method comparisons into one loop over indexes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.index import base
from repro.kernels import ref


class PQIndex(base.Index):
    """Product Quantization (Jegou et al. 2011). ADC-only by default
    (``rerank=0`` matches classic IndexPQ); give a rerank budget to re-rank
    the top-L with reconstruction distances."""

    kind = "pq"

    def __init__(self, dim: int, *, num_books: int = 8, book_size: int = 256,
                 rerank: int = 0, backend: str = "auto"):
        super().__init__(dim, rerank=rerank, backend=backend)
        assert dim % num_books == 0, (dim, num_books)
        self.num_books = num_books
        self.book_size = book_size
        self.model: bl.PQModel | None = None

    @property
    def is_trained(self) -> bool:
        return self.model is not None

    def _fit_quantizer(self, xs, *, iters: int = 25, seed: int = 0, **_):
        self.model = bl.train_pq(jax.random.PRNGKey(seed), jnp.asarray(xs),
                                 self.num_books, self.book_size, iters=iters)

    def _encode(self, xs) -> jax.Array:
        return self.model.encode(xs)

    def _build_luts(self, queries) -> jax.Array:
        # per-subspace squared-L2 tables; summed over m this is the exact
        # compressed-domain distance (no per-query constant needed)
        return jax.vmap(self.model.lut)(queries)

    def _build_decode_table(self) -> jax.Array:
        # each sub-codebook embedded into its D-slice (zero elsewhere);
        # OPQ folds the inverse rotation into the table, so the additive
        # sum IS decode() in the original space
        m, k, d_sub = self.model.codebooks.shape
        table = jnp.zeros((m, k, self.dim), jnp.float32)
        for i in range(m):
            table = table.at[i, :, i * d_sub:(i + 1) * d_sub].set(
                self.model.codebooks[i])
        if self.model.rotation is not None:
            table = table @ self.model.rotation.T
        return table

    def _reconstruct(self, codes) -> jax.Array:
        # table decode (not model.decode): the one association every
        # stage-2 path shares, making fused/chunked/vmap bit-identical
        return ref.decode_with_table(codes, self._decode_table())

    # -- persistence -------------------------------------------------------

    def _tree(self):
        codes = self._codes if self._codes is not None else \
            jnp.zeros((0, self.num_books), jnp.uint8)
        tree = {"codebooks": self.model.codebooks, "codes": codes}
        if self.model.rotation is not None:
            tree["rotation"] = self.model.rotation
        return tree

    def _metadata(self) -> dict:
        return {"dim": self.dim, "num_books": self.num_books,
                "book_size": self.book_size, "rerank": self.rerank,
                "backend": self.backend, "ntotal": self.ntotal,
                "has_rotation": self.model.rotation is not None}

    @classmethod
    def _empty_from_metadata(cls, meta: dict):
        index = cls(meta["dim"], num_books=meta["num_books"],
                    book_size=meta["book_size"], rerank=meta["rerank"],
                    backend=meta["backend"])
        d_sub = meta["dim"] // meta["num_books"]
        rot = jnp.eye(meta["dim"]) if meta["has_rotation"] else None
        index.model = bl.PQModel(
            jnp.zeros((meta["num_books"], meta["book_size"], d_sub),
                      jnp.float32), rotation=rot)
        index._codes = jnp.zeros((meta["ntotal"], meta["num_books"]),
                                 jnp.uint8)
        return index

    def _set_tree(self, tree) -> None:
        self.model.codebooks = tree["codebooks"]
        if "rotation" in tree:
            self.model.rotation = tree["rotation"]
        self._codes = tree["codes"] if tree["codes"].shape[0] else None
        self._invalidate_caches()


class OPQIndex(PQIndex):
    """Optimized PQ (Ge et al. 2013): learned rotation + PQ."""

    kind = "opq"

    def _fit_quantizer(self, xs, *, outer_iters: int = 8,
                       kmeans_iters: int = 10, seed: int = 0, **_):
        self.model = bl.train_opq(jax.random.PRNGKey(seed), jnp.asarray(xs),
                                  self.num_books, self.book_size,
                                  outer_iters=outer_iters,
                                  kmeans_iters=kmeans_iters)


class RVQIndex(base.Index):
    """Residual Vector Quantization (additive family). ADC for additive
    codes needs ||decode(i)||^2 alongside the inner-product LUTs —
    ``||q - x~||^2 = ||x~||^2 - 2<q, x~> + const(q)`` — carried here as the
    per-point score bias (the standard extra-4-bytes trick)."""

    kind = "rvq"

    def __init__(self, dim: int, *, num_books: int = 8, book_size: int = 256,
                 rerank: int = 0, backend: str = "auto"):
        super().__init__(dim, rerank=rerank, backend=backend)
        self.num_books = num_books
        self.book_size = book_size
        self.model: bl.RVQModel | None = None

    @property
    def is_trained(self) -> bool:
        return self.model is not None

    def _fit_quantizer(self, xs, *, iters: int = 20, seed: int = 0, **_):
        self.model = bl.train_rvq(jax.random.PRNGKey(seed), jnp.asarray(xs),
                                  self.num_books, self.book_size, iters=iters)

    def _encode(self, xs) -> jax.Array:
        return self.model.encode(jnp.asarray(xs))

    def _encode_bias(self, codes) -> jax.Array:
        recon = self.model.decode(codes)
        return jnp.sum(recon * recon, axis=-1)

    def _build_luts(self, queries) -> jax.Array:
        # scaling by -2 inside the table keeps scan scores bit-identical to
        # ``norms - 2 * adc_scan(codes, lut_ip)`` (x2 is exact in fp)
        return -2.0 * jax.vmap(self.model.lut_ip)(queries)

    def _build_decode_table(self) -> jax.Array:
        # additive codebooks are already full-dimensional
        return self.model.codebooks.astype(jnp.float32)

    def _reconstruct(self, codes) -> jax.Array:
        # table decode (chained adds) rather than model.decode's axis
        # reduction: the association every stage-2 path shares
        return ref.decode_with_table(codes, self._decode_table())

    # -- persistence -------------------------------------------------------

    def _tree(self):
        codes = self._codes if self._codes is not None else \
            jnp.zeros((0, self.num_books), jnp.uint8)
        bias = self._bias if self._bias is not None else \
            jnp.zeros((0,), jnp.float32)
        return {"codebooks": self.model.codebooks, "codes": codes,
                "norms": bias}

    def _metadata(self) -> dict:
        return {"dim": self.dim, "num_books": self.num_books,
                "book_size": self.book_size, "rerank": self.rerank,
                "backend": self.backend, "ntotal": self.ntotal}

    @classmethod
    def _empty_from_metadata(cls, meta: dict) -> "RVQIndex":
        index = cls(meta["dim"], num_books=meta["num_books"],
                    book_size=meta["book_size"], rerank=meta["rerank"],
                    backend=meta["backend"])
        index.model = bl.RVQModel(jnp.zeros(
            (meta["num_books"], meta["book_size"], meta["dim"]), jnp.float32))
        index._codes = jnp.zeros((meta["ntotal"], meta["num_books"]),
                                 jnp.uint8)
        return index

    def _set_tree(self, tree) -> None:
        self.model.codebooks = tree["codebooks"]
        self._codes = tree["codes"] if tree["codes"].shape[0] else None
        self._bias = tree["norms"] if tree["norms"].shape[0] else None
        self._invalidate_caches()
