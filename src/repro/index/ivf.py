"""IVF coarse partitioning in front of the streaming scan (the paper's
billion-scale regime: DEEP1B-class corpora are never scanned linearly).

``IVFIndex`` wraps any trained ``Index`` quantizer behind the same
train/add/search/save/load surface and prepends a k-means coarse
quantizer with ``nlist`` cells:

  * ``train`` runs an ORDERED pipeline (``core.training.TrainStage``):
    the coarse k-means fits FIRST, then the wrapped quantizer — in
    residual mode on ``x - centroid(x)`` instead of ``x``;
  * ``add`` encodes as usual, assigns each vector to its nearest
    centroid, and keeps the codes in ONE contiguous cell-grouped buffer
    with CSR offsets (``_offsets[c]:_offsets[c+1]`` is cell c's inverted
    list) — no per-cell Python lists, so the probed cells of a whole
    query batch concatenate into a single padded (Q, W) ragged plan;
  * ``search`` ranks centroids per query, takes the top ``nprobe``
    cells, and feeds the stage-1 engine through one of two faces:

      - **dispatch** (backends with the ``dispatch_topl`` capability,
        the default there): the MoE-style device router
        (``repro.index.dispatch``) turns the (Q, nprobe) probe matrix +
        CSR offsets into dense per-cell query batches ON DEVICE — no
        host numpy, no padded-plan transfer — ``ops.adc_dispatch_topl``
        streams each probed cell's contiguous code range exactly once
        for all co-probing queries, and ``dispatch.combine_pools``
        scatter-merges the per-cell partial top-Ls back to per-query
        pools. A ``dispatch_capacity`` factor bounds the per-cell batch;
        overflow falls back LOUDLY to the padded path (never silent
        candidate drops).
      - **padded** (the retained oracle/control, and the fallback):
        builds the ragged plan (slot -> buffer row + global id + cell,
        sorted by global id, pads marked ``_IMAX``) host-side from the
        CSR offsets and hands it to the gathered face
        (``CandidateGenerator.gather_topl`` -> ``ops.adc_gather_topl``).

    Fused Pallas kernel, chunked xla, or the materialized control —
    all faces bit-identical, tie semantics included.

Exactness: a slot's score is computed with the same per-point math as the
flat scan (same left-to-right codebook chain / one-hot contraction on the
same code row), the plan lists every point exactly once at
``nprobe == nlist`` (cells partition the database), and every path breaks
score ties toward the smaller GLOBAL id — so full-probe IVF search is
bit-identical to flat search, scores and indices, on every backend. The
same plan carries the per-point bias stream (RVQ norms) and the lowered
``filter_mask`` (+inf drops a slot), so filtered IVF search composes for
free.

Residual encoding (IVFADC, ``residual=True`` / the ``Residual`` factory
token): vectors are encoded as ``x - centroid(x)``, so codebook capacity
is spent on the much-lower-variance residual distribution. Every point's
implied reconstruction becomes ``centroid + decode(code)`` and the d2
scan needs a distance correction; for table-decodable quantizers it is
EXACT and rides the existing bias streams, with no kernel changes::

    ||q - (c + d)||^2 = ||q - d||^2          the uncorrected LUT scan
                      + 2<c, d>              per-ROW cross term: computed
                                             at add time from the per-cell
                                             cross-LUT (2 * coarse @ table)
                                             and folded into the per-point
                                             ``bias`` stream
                      + ||c||^2 - 2<q, c>    per-(query, cell) term: the
                                             coarse-distance matrix already
                                             computed for probing, gathered
                                             per plan slot into the
                                             ``rowbias`` stream

Decoder quantizers (UNQ) have no exact LUT decomposition; their stage-1
scores stay a proxy (LUTs built from the query residualized against its
top-1 probed centroid, so the encoder sees residual-scale inputs) and
stage 2 reranks with the exact ``centroid + decode`` reconstruction
through ``rerank.ResidualRerank``. Plain (non-residual) indexes take
exactly the pre-residual code paths — bitwise unchanged.

Stage 2 translates candidate global ids to buffer rows through the stored
permutation and rides the streaming rerank engine (fused table kernel /
cross-query dedup) exactly like a flat index; residual indexes resolve a
``ResidualRerank`` wrapper that reconstructs ``centroid + decode(code)``
(see ``repro.index.rerank``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import kmeans
from repro.index import base
from repro.index.candidates import candidate_generator_for, supports_dispatch

_IMAX = np.iinfo(np.int32).max

#: "use the index's own dispatch_capacity" sentinel for the per-call
#: override (None is meaningful: it means lossless routing)
_INDEX_CAPACITY = object()


def _plan_width(w: int) -> int:
    """Pad the ragged plan width to a small ladder so repeated searches
    with similar probe sizes reuse one compiled scan."""
    if w <= 8:
        return 8
    if w <= 128:
        return -(-w // 8) * 8
    return -(-w // 128) * 128


class IVFIndex(base.Index):
    """Inverted-file index over any wrapped quantizer (see module doc)."""

    kind = "ivf"

    def __init__(self, dim: int, *, inner: base.Index, nlist: int,
                 nprobe: int = 8, rerank: int = 0, backend: str = "auto",
                 residual: bool = False,
                 dispatch_capacity: float | None = None):
        super().__init__(dim, rerank=rerank, backend=backend)
        if nlist < 1:
            raise ValueError(f"nlist must be >= 1, got {nlist}")
        if inner.ntotal:
            raise ValueError("wrap an EMPTY quantizer index; add vectors "
                             "through the IVFIndex so they are partitioned")
        self.inner = inner
        self.nlist = nlist
        self.nprobe = nprobe
        self.residual = bool(residual)
        #: MoE capacity factor for the dispatch face: None = lossless
        #: (capacity covers the true max per-cell batch); a float bounds
        #: slots per cell at ~factor * Q * nprobe / E, with capacity
        #: overflow falling back loudly to the padded plan
        self.dispatch_capacity = dispatch_capacity
        self.coarse: jax.Array | None = None     # (nlist, dim) centroids
        # cell-grouped buffer state (parallel to self._codes / self._bias)
        self._ids_np: np.ndarray | None = None   # (N,) buffer row -> gid
        self._cells_np: np.ndarray | None = None  # (N,) buffer row -> cell
        self._cells_dev: jax.Array | None = None  # device copy of the above
        self._offsets: np.ndarray | None = None  # (nlist + 1,) CSR
        self._offsets_dev: jax.Array | None = None  # device CSR (router)
        self._ids_dev: jax.Array | None = None   # device row -> gid
        self._pos_dev: jax.Array | None = None   # (N,) gid -> buffer row
        self._plan_cache: dict = {}              # padded-plan memo
        # residual-mode caches (dropped by _invalidate_caches)
        self._crosslut = None                    # (nlist, M, K) cross-LUT
        self._res_table = None                   # (M+1, K', D) stage-2 table
        self._res_rerank_fn = None               # jitted residual vmap oracle

    # -- delegated quantizer primitives ------------------------------------

    @property
    def is_trained(self) -> bool:
        return self.inner.is_trained and self.coarse is not None

    def _train_stages(self):
        """The ordered IVF pipeline: coarse k-means MUST finish before the
        wrapped quantizer trains — in residual mode the coarse stage
        transforms the training vectors into residuals for it."""
        from repro.core.training import TrainStage
        return [TrainStage("coarse", self._fit_coarse),
                TrainStage(self.inner.kind, self._fit_inner)]

    def _fit_coarse(self, xs, *, coarse_iters: int = 10,
                    coarse_seed: int = 0, **_):
        """Fit the k-means coarse partition; in residual mode return
        ``x - centroid(x)`` for the downstream quantizer stage."""
        xs = jnp.asarray(xs)        # the coarse fit runs on device anyway
        self.coarse = kmeans(jax.random.PRNGKey(coarse_seed), xs,
                             self.nlist, iters=coarse_iters)
        if not self.residual:
            return None
        cells = jnp.argmin(self._coarse_dists(xs), axis=1)
        return xs - jnp.take(self.coarse, cells, axis=0)

    def _fit_inner(self, xs, **kw):
        """Fit the wrapped quantizer (on residuals when residual mode is
        on). The coarse stage's own keyword parameters — read off its
        signature, so the two can never drift — are filtered out;
        everything else passes through (UNQ treats every leftover kwarg
        as a TrainConfig field, so leaking one would raise)."""
        import inspect
        coarse_params = {
            name for name, p in
            inspect.signature(self._fit_coarse).parameters.items()
            if p.kind is p.KEYWORD_ONLY}
        inner_kw = {k: v for k, v in kw.items() if k not in coarse_params}
        self.inner.train(xs, **inner_kw)

    def _encode(self, xs) -> jax.Array:
        self.inner.backend = self.backend       # keep encode impl in sync
        return self.inner._encode(xs)

    def _build_luts(self, queries) -> jax.Array:
        return self.inner._build_luts(queries)

    def _reconstruct(self, codes) -> jax.Array:
        return self.inner._reconstruct(codes)

    def _build_decode_table(self):
        return self.inner._build_decode_table()

    def _encode_bias(self, codes):
        return self.inner._encode_bias(codes)

    def _invalidate_caches(self) -> None:
        super()._invalidate_caches()
        self.inner._invalidate_caches()
        self._assign_fn = None
        self._crosslut = None
        self._res_table = None
        self._res_rerank_fn = None
        self._plan_cache = {}

    # -- residual machinery --------------------------------------------------

    @property
    def _exact_residual(self) -> bool:
        """True when residual mode can apply the EXACT stage-1 distance
        correction: the wrapped quantizer is table-decodable, so
        ``||q - (c + d)||^2`` decomposes onto the existing bias streams
        (see module doc). Decoder quantizers (UNQ) stay a proxy."""
        return self.residual and self.inner._decode_table() is not None

    def _crosstable(self) -> jax.Array:
        """(nlist, M, K) per-cell cross-LUT for the residual correction:
        ``crosslut[c, m, k] = 2 * <coarse[c], table[m, k]>``, so the
        per-row cross term ``2<c, decode(code)>`` is an M-term chained
        LUT sum over the row's own code — the same access pattern as the
        d2 scan itself."""
        if self._crosslut is None:
            with jax.ensure_compile_time_eval():
                table = self.inner._decode_table().astype(jnp.float32)
                self._crosslut = 2.0 * jnp.einsum(
                    "mkd,cd->cmk", table, self.coarse.astype(jnp.float32))
        return self._crosslut

    def _cross_bias(self, codes, cells) -> jax.Array:
        """Per-row residual cross term ``2<centroid(row), decode(code)>``
        (n,) f32, accumulated left-to-right over M like ``adc_scan_ref``
        so every path shares one association."""
        lut = self._crosstable()                           # (C, M, K)
        m_idx = jnp.arange(lut.shape[1])[None, :]          # (1, M)
        g = lut[jnp.asarray(cells)[:, None], m_idx,
                codes.astype(jnp.int32)]                   # (n, M)
        acc = g[:, 0]
        for m in range(1, lut.shape[1]):
            acc = acc + g[:, m]
        return acc

    def _residual_table(self) -> jax.Array:
        """(M+1, K', D) stage-2 decode table with the coarse centroids
        appended as an extra face (K' = max(K, nlist), zero-padded).
        Extending each candidate's code row with its cell id makes the
        UNCHANGED table rerank engine reconstruct
        ``decode(code) + centroid`` exactly: the centroid face is the
        last chained add, bit-identical to adding the centroid to
        ``ref.decode_with_table`` output.

        The inner-face padding is only free when ``nlist <= K`` —
        ``reranker_for`` routes ``nlist > K`` residual indexes through
        the dedup reranker instead, so in practice K' == max(K, nlist)
        never inflates the resident table on the path that uses it."""
        if self._res_table is None:
            with jax.ensure_compile_time_eval():
                table = self.inner._decode_table().astype(jnp.float32)
                m, k, d = table.shape
                kk = max(k, self.nlist)
                faces = jnp.zeros((m + 1, kk, d), jnp.float32)
                faces = faces.at[:m, :k, :].set(table)
                faces = faces.at[m, :self.nlist, :].set(
                    self.coarse.astype(jnp.float32))
                self._res_table = faces
        return self._res_table

    def reconstruct_rows(self, rows) -> jax.Array:
        """(n,) buffer rows -> (n, dim) implied reconstructions:
        ``decode(code)`` plus, in residual mode, the row's coarse
        centroid — the materialized oracle the residual search paths are
        validated against."""
        rows = jnp.asarray(rows, jnp.int32)
        recon = self._reconstruct(jnp.take(self._codes, rows, axis=0))
        if self.residual:
            cells = jnp.take(self._cells_dev, rows)
            recon = recon + jnp.take(self.coarse, cells, axis=0)
        return recon

    # -- cell-grouped database ---------------------------------------------

    def _coarse_dists(self, xs):
        """(n, dim) -> (n, nlist) squared distances up to a per-row
        constant (||x||^2 dropped: rankings are all we use — and the
        dropped term is per-QUERY, so the same matrix doubles as the
        residual correction's per-(query, cell) bias)."""
        if getattr(self, "_assign_fn", None) is None:
            self._assign_fn = jax.jit(
                lambda x, c: jnp.sum(c * c, axis=1)[None, :]
                - 2.0 * x @ c.T)
        return self._assign_fn(xs, self.coarse)

    def _probe_with_dists(self, queries, nprobe: int):
        """Clamped per-query top-``nprobe`` probe PLUS the coarse-distance
        matrix it was ranked by — the single implementation behind
        ``probe_cells``, ``search`` and the sharded IVF stage 1 (the
        matrix doubles as the residual correction's per-(query, cell)
        bias, so callers never recompute it). The probe stays a DEVICE
        array: the dispatch face routes it without a host round-trip;
        the padded plan builder converts at its own edge."""
        cd = self._coarse_dists(jnp.asarray(queries))
        nprobe = max(1, min(int(nprobe), self.nlist))
        _, cells = jax.lax.top_k(-cd, nprobe)
        return cells, cd

    def probe_cells(self, queries, nprobe: int) -> np.ndarray:
        """Per-query top-``nprobe`` coarse cells, (Q, nprobe) int32
        (closest centroid first)."""
        return np.asarray(self._probe_with_dists(queries, nprobe)[0])

    def _resolve_nprobe(self, nprobe, num_queries: int):
        """Normalize a ``search`` nprobe request to (probe width,
        per-query probe lengths).

        ``None`` -> the index default; an int -> that width (lengths
        ``None``); a (Q,) int vector — the serving fan-in, where each
        coalesced request carries its own probe budget — probes at the
        MAX width and returns the clipped lengths so each query's excess
        probe slots are masked out of its plan/pool. Because
        ``lax.top_k`` prefixes are exact, query i's first ``nprobe_i``
        probed cells at width P are exactly its solo top-``nprobe_i`` —
        the per-query results stay bit-identical to searching alone. A
        uniform vector collapses to its scalar (no masking needed)."""
        if nprobe is None:
            return max(1, min(int(self.nprobe), self.nlist)), None
        if np.ndim(nprobe) == 0:
            return max(1, min(int(nprobe), self.nlist)), None
        lens = np.asarray(nprobe)
        if lens.ndim != 1 or lens.shape[0] != num_queries:
            raise ValueError(
                f"per-query nprobe must be a ({num_queries},) int vector, "
                f"got shape {lens.shape}")
        lens = np.clip(lens.astype(np.int32), 1, self.nlist)
        width = int(lens.max())
        if int(lens.min()) == width:
            return width, None
        return width, lens

    def _stage1_luts(self, queries, probe: np.ndarray) -> jax.Array:
        """Per-query stage-1 score tables. Residual DECODER quantizers
        (no decode table, so no exact correction) residualize the query
        against its top-1 probed centroid first, keeping the encoder on
        residual-scale inputs; every other configuration scores raw
        queries (residual table quantizers correct through the bias
        streams instead)."""
        if self.residual and self.inner._decode_table() is None:
            anchor = jnp.take(self.coarse, jnp.asarray(probe[:, 0]), axis=0)
            return self._build_luts(queries - anchor)
        return self._build_luts(queries)

    def reset(self) -> None:
        super().reset()
        self._ids_np = None
        self._cells_np = None
        self._cells_dev = None
        self._offsets = None
        self._offsets_dev = None
        self._ids_dev = None
        self._pos_dev = None
        self._plan_cache = {}

    def with_codes(self, codes, bias=None):
        raise NotImplementedError(
            "IVFIndex code buffers are cell-grouped with id/offset side "
            "state; use add()/reset() instead of with_codes views")

    def subset(self, n: int):
        raise NotImplementedError(
            "nested-subset views are not defined for cell-grouped IVF "
            "buffers; build a flat index for subset scaling studies")

    def add(self, xs) -> "IVFIndex":
        """Encode, assign to coarse cells, and regroup the contiguous
        buffer (stable by cell) so every inverted list stays one CSR
        slice. Global ids are assignment order, exactly like a flat
        ``add`` — searches return them, not buffer positions.

        Residual mode encodes ``x - centroid(x)`` (assignment happens
        first) and, for table-decodable quantizers, folds the per-row
        cross term ``2<c, decode(code)>`` into the per-point bias stream
        alongside any quantizer-native bias (RVQ norms)."""
        if not self.is_trained:
            raise RuntimeError(f"{type(self).__name__}.add before train()")
        xs = jnp.asarray(xs)
        n = xs.shape[0]
        cells_dev = jnp.argmin(self._coarse_dists(xs), axis=1).astype(
            jnp.int32)
        cells = np.asarray(cells_dev, np.int32)
        enc_in = xs - jnp.take(self.coarse, cells_dev, axis=0) \
            if self.residual else xs
        bucket = self._encode_bucket(n)
        xp = jnp.pad(enc_in, ((0, bucket - n), (0, 0))) if bucket != n \
            else enc_in
        codes = self._encode(xp)[:n]
        bias = self._encode_bias(codes)
        if self._exact_residual:
            cross = self._cross_bias(codes, cells_dev)
            bias = cross if bias is None else bias + cross
        old_n = self.ntotal
        ids = np.arange(old_n, old_n + n, dtype=np.int32)
        if self._codes is not None:
            codes = jnp.concatenate([self._codes, codes], axis=0)
            if bias is not None:
                bias = jnp.concatenate([self._bias, bias], axis=0)
            cells = np.concatenate([self._cells_np, cells])
            ids = np.concatenate([self._ids_np, ids])
        order = np.argsort(cells, kind="stable")
        order_dev = jnp.asarray(order, jnp.int32)
        self._codes = jnp.take(codes, order_dev, axis=0)
        self._bias = None if bias is None else jnp.take(bias, order_dev)
        self._cells_np = cells[order]
        self._cells_dev = jnp.asarray(self._cells_np)
        self._ids_np = ids[order]
        counts = np.bincount(self._cells_np, minlength=self.nlist)
        self._offsets = np.concatenate(
            [[0], np.cumsum(counts)]).astype(np.int64)
        self._offsets_dev = jnp.asarray(self._offsets, jnp.int32)
        self._ids_dev = jnp.asarray(self._ids_np)
        pos = np.empty(self.ntotal, np.int32)
        pos[self._ids_np] = np.arange(self.ntotal, dtype=np.int32)
        self._pos_dev = jnp.asarray(pos)
        self._plan_cache = {}
        return self

    # -- probing -------------------------------------------------------------

    def _probe_plan(self, probe: np.ndarray, cell_range=None,
                    row_offset: int = 0, probe_lens=None):
        """Concatenate the CSR inverted lists of each query's probed cells
        into one padded ragged plan.

        probe (Q, P) int32 cell ids; ``cell_range=(lo, hi)`` restricts to
        a shard's owned cells (rows shifted by ``row_offset`` so they
        index the shard-local buffer slice); ``probe_lens`` (Q,) int32
        keeps only each query's first ``probe_lens[q]`` probe columns —
        the per-query nprobe fan-in (``_resolve_nprobe``), masked exactly
        like unowned cells so a query's plan is identical to probing at
        its own width alone.

        Returns (rows, gids, cells): np.int32 (Q, W) each — buffer rows
        to score, the global id behind each slot, and the slot's coarse
        cell (the residual correction's bias key), SORTED ascending by
        gid per query (pads last, gid = _IMAX, row = 0, cell = 0) — the
        plan contract of ``ops.adc_gather_topl``.

        Plans are memoized on the (probe bytes, shape, cell_range,
        row_offset, probe_lens bytes) fingerprint — repeated query
        batches (bench loops, the retained oracle path next to dispatch)
        stop rebuilding identical numpy plans. The cache dies with any
        buffer mutation (add / load / reset).
        """
        probe = np.asarray(probe, np.int32)
        key = (probe.tobytes(), probe.shape, cell_range, row_offset,
               None if probe_lens is None else probe_lens.tobytes())
        hit = self._plan_cache.get(key)
        if hit is not None:
            return hit
        off = self._offsets
        lens = (off[1:] - off[:-1]).astype(np.int64)
        q = probe.shape[0]
        cell_lens = lens[probe]                       # (Q, P)
        if cell_range is not None:
            owned = (probe >= cell_range[0]) & (probe < cell_range[1])
            cell_lens = np.where(owned, cell_lens, 0)
        if probe_lens is not None:
            within = np.arange(probe.shape[1])[None, :] < \
                np.asarray(probe_lens)[:, None]
            cell_lens = np.where(within, cell_lens, 0)
        starts = off[probe]                           # (Q, P)
        totals = cell_lens.sum(axis=1)                # (Q,)
        w = _plan_width(int(max(totals.max(initial=0), 1)))
        rows = np.zeros((q, w), np.int32)
        gids = np.full((q, w), _IMAX, np.int32)
        cells = np.zeros((q, w), np.int32)
        # flat ragged expansion of every (query, cell) list in one shot:
        # slot -> buffer row via the classic repeat/cumsum trick
        counts = cell_lens.ravel()
        total = int(counts.sum())
        if total:
            grp_starts = np.repeat(starts.ravel(), counts)
            within = np.arange(total, dtype=np.int64) - np.repeat(
                np.cumsum(counts) - counts, counts)
            flat_rows = (grp_starts + within).astype(np.int64)
            qidx = np.repeat(np.arange(q), totals)
            col = np.arange(total, dtype=np.int64) - np.repeat(
                np.cumsum(totals) - totals, totals)
            flat_gids = self._ids_np[flat_rows]
            # ONE flat stable sort by (query, gid) replaces the old padded
            # per-row argsort: lexsort's primary key (qidx, already
            # nondecreasing) confines the permutation to each query's own
            # span, so scattering through (qidx, col) lands each query's
            # slots gid-ascending — identical plans, ~W/avg-fill less sort
            # work and no (Q, W) take_along_axis passes
            perm = np.lexsort((flat_gids, qidx))
            sorted_rows = flat_rows[perm]
            rows[qidx, col] = (sorted_rows - row_offset).astype(np.int32)
            gids[qidx, col] = flat_gids[perm]
            cells[qidx, col] = self._cells_np[sorted_rows]
        plan = (rows, gids, cells)
        if len(self._plan_cache) >= 8:          # tiny FIFO: bench/serve
            self._plan_cache.pop(next(iter(self._plan_cache)))  # loops only
        self._plan_cache[key] = plan
        return plan

    def _plan_rowbias(self, rows, gids, shard_bias, filter_mask,
                      num_queries: int, slot_cells=None, cell_bias=None):
        """The per-slot additive stream for a plan: the gathered per-point
        bias (RVQ norms, residual cross terms — from the buffer/shard the
        rows index), plus the residual correction's per-(query, cell)
        term (``cell_bias`` (Q, nlist) gathered at each slot's cell),
        with the lowered filter mask applied last (+inf = filtered out,
        keyed by GLOBAL id). Returns (Q, W) f32 or None when there is
        nothing to add."""
        if shard_bias is None and filter_mask is None and cell_bias is None:
            return None
        rowbias = jnp.take(shard_bias, rows) if shard_bias is not None \
            else jnp.zeros(rows.shape, jnp.float32)
        if cell_bias is not None:
            rowbias = rowbias + jnp.take_along_axis(
                jnp.asarray(cell_bias), jnp.asarray(slot_cells), axis=1)
        if filter_mask is not None:
            mask = jnp.asarray(filter_mask, bool)
            safe = jnp.where(gids == _IMAX, 0, gids)
            if mask.ndim == 1:
                if mask.shape != (self.ntotal,):
                    raise ValueError(
                        f"filter_mask shape {mask.shape} != "
                        f"({self.ntotal},)")
                keep = jnp.take(mask, safe)
            else:
                if mask.shape != (num_queries, self.ntotal):
                    raise ValueError(
                        f"filter_mask shape {mask.shape} != "
                        f"({num_queries}, {self.ntotal})")
                keep = jnp.take_along_axis(mask, safe, axis=1)
            rowbias = jnp.where(keep, rowbias, jnp.inf)
        return rowbias

    # -- dispatch (cell-batched) stage 1 -------------------------------------

    def _dispatch_streams(self, routing, num_queries: int, filter_mask,
                          cell_bias, row_range=None):
        """The dispatch face's bias streams for one routed (sub)buffer:
        (ids, rowbias, qkeep, cellterm).

        ids (n,) row -> global id for the ``row_range`` slice (the whole
        buffer by default; a shard's rows under the sharded face);
        rowbias (n,) the per-point stream with any (N,) filter folded to
        +inf (keyed by GLOBAL id, like ``_plan_rowbias``); qkeep (Q, n)
        0/1 stream for per-(query, point) filters; cellterm (E+1, cap)
        the residual correction's per-(query, cell) term gathered at
        each routed slot. Composition order matches ``_plan_rowbias``
        exactly — score + (rowbias + cellterm), keep-mask applied last —
        which is what keeps dispatch bit-identical to the padded path.
        """
        lo, hi = row_range if row_range is not None else (0, self.ntotal)
        ids = self._ids_dev[lo:hi]
        rowbias = None if self._bias is None else self._bias[lo:hi]
        qkeep = None
        if filter_mask is not None:
            mask = jnp.asarray(filter_mask, bool)
            if mask.ndim == 1:
                if mask.shape != (self.ntotal,):
                    raise ValueError(
                        f"filter_mask shape {mask.shape} != "
                        f"({self.ntotal},)")
                keep = jnp.take(mask, ids)
                base = rowbias if rowbias is not None \
                    else jnp.zeros(ids.shape, jnp.float32)
                rowbias = jnp.where(keep, base, jnp.inf)
            else:
                if mask.shape != (num_queries, self.ntotal):
                    raise ValueError(
                        f"filter_mask shape {mask.shape} != "
                        f"({num_queries}, {self.ntotal})")
                qkeep = jnp.take(mask, ids, axis=1).astype(jnp.float32)
        qidx = routing.plan.qidx
        if cell_bias is not None:
            safe_q = jnp.clip(qidx, 0, num_queries - 1)
            safe_c = jnp.clip(routing.cell_of, 0, self.nlist - 1)
            cellterm = jnp.where(
                qidx >= 0, jnp.asarray(cell_bias)[safe_q, safe_c[:, None]],
                0.0).astype(jnp.float32)
        else:
            cellterm = jnp.zeros(qidx.shape, jnp.float32)
        return ids, rowbias, qkeep, cellterm

    def _dispatch_pool(self, queries, probe, cd, filter_mask, topl: int,
                       lut_dtype: str = "float32", overfetch: int = 1,
                       probe_lens=None, capacity=_INDEX_CAPACITY):
        """Stage 1 through the cell-batched dispatch face: route the
        probe on device, stream every probed cell once, scatter-merge the
        per-cell partials. Returns the (d2, global ids) pool —
        bit-identical to the padded gathered plan — or None when the
        capacity factor overflows (the caller's padded fallback: dropped
        probes could hide true top-L candidates; the overflow is counted
        and rate-limit-warned through ``dispatch.OVERFLOWS``).

        ``probe_lens`` (Q,) masks each query's probe columns past its own
        nprobe out of the scatter-merge (``comb_e = -1`` is the router's
        dropped-pair sentinel, so the excess cells never enter that
        query's pool) — the dispatch half of the per-query nprobe
        fan-in. ``capacity`` overrides the index's ``dispatch_capacity``
        for this call (the serving load-shed knob)."""
        from repro.index import dispatch as dsp
        if capacity is _INDEX_CAPACITY:
            capacity = self.dispatch_capacity
        routing, stats = dsp.build_dispatch(
            probe, self._offsets_dev, capacity_factor=capacity)
        if routing is None:
            dsp.OVERFLOWS.record(
                f"IVF dispatch capacity overflow: the busiest probed cell "
                f"batches {stats[1]} queries, over the "
                f"dispatch_capacity={capacity} budget for "
                f"{stats[0]} routed cells; falling back to the padded "
                "gathered plan for this batch")
            return None
        q = queries.shape[0]
        cell_bias = cd if self._exact_residual else None
        _, rowbias, qkeep, cellterm = self._dispatch_streams(
            routing, q, filter_mask, cell_bias)
        luts = self._stage1_luts(queries, probe)
        gen = candidate_generator_for(self.backend)
        part_s, part_g = gen.dispatch_topl(
            self._codes, self._ids_dev, rowbias, luts, cellterm,
            routing.plan, topl=topl, qkeep=qkeep, chunk=routing.chunk,
            pos=self._pos_dev, lut_dtype=lut_dtype, overfetch=overfetch)
        comb_e = routing.comb_e
        if probe_lens is not None:
            within = jnp.arange(probe.shape[1])[None, :] < \
                jnp.asarray(probe_lens)[:, None]
            comb_e = jnp.where(within, comb_e, -1)
        return dsp.combine_pools(part_s, part_g, comb_e,
                                 routing.comb_slot, topl=topl)

    # -- search --------------------------------------------------------------

    def search(self, queries, k: int, *, nprobe=None,
               use_rerank: bool | None = None, use_d2: bool = True,
               filter_mask=None, use_dispatch: bool | None = None,
               dispatch_capacity=_INDEX_CAPACITY,
               lut_dtype: str = "float32", overfetch: int = 1):
        """Probed two-stage search (same contract as ``Index.search`` plus
        ``nprobe``). Slots the probe misses simply never enter the pool;
        when the probed pool holds fewer than k points the tail is
        reported as (distance=+inf, index=-1).

        ``nprobe`` may be a scalar or a (Q,) int vector — one probe width
        per query, the serving fan-in for coalesced requests with
        different accuracy budgets. Per-query widths probe at the batch
        max and mask each query's excess cells out of its pool, so row i
        is bit-identical to searching that query alone with nprobe[i].

        ``use_dispatch`` pins stage 1 to the cell-batched dispatch face
        (True) or the padded gathered plan (False); the default resolves
        per backend via the ``dispatch_topl`` capability. Both faces are
        bit-identical — the knob is a perf/control choice, never a
        quality one. ``dispatch_capacity`` overrides the index's own
        capacity factor for this call (None = lossless routing): the
        load-shed knob a serving loop can tighten under pressure without
        mutating the shared index.

        ``lut_dtype``/``overfetch`` opt stage 1 into the reduced-precision
        pool scan + exact f32 re-score (``Index.search`` docstring) on
        either face; backends without the ``quantized_lut`` capability
        reject the request."""
        if self.ntotal == 0:
            raise RuntimeError("search on an empty index (call add first)")
        self._check_quantized_request(lut_dtype, overfetch)
        queries = jnp.asarray(queries)
        if use_rerank is None:
            use_rerank = self.rerank > 0
        if use_rerank and self.rerank <= 0:
            raise ValueError(
                f"{type(self).__name__} has no rerank budget (rerank=0); "
                "set index.rerank or pass use_rerank=False")
        if not use_d2:
            if filter_mask is not None:
                raise ValueError(
                    "filter_mask is not supported with use_d2=False")
            return self._exhaustive_rerank_topk(queries, k)
        if use_dispatch is None:
            use_dispatch = supports_dispatch(self.backend)
        elif use_dispatch and not supports_dispatch(self.backend):
            raise ValueError(
                f"use_dispatch=True but backend {self.backend!r} does not "
                "declare the dispatch_topl capability; use the padded "
                "path (use_dispatch=False) or an xla/pallas backend")
        nprobe_w, probe_lens = self._resolve_nprobe(nprobe, queries.shape[0])
        probe, cd = self._probe_with_dists(queries, nprobe_w)
        if use_dispatch:
            pool = self._dispatch_pool(
                queries, probe, cd, filter_mask,
                topl=self.rerank if use_rerank else k,
                lut_dtype=lut_dtype, overfetch=overfetch,
                probe_lens=probe_lens, capacity=dispatch_capacity)
            if pool is not None:
                return self._finish_pool(queries, pool[0], pool[1], k,
                                         use_rerank=use_rerank)
        rows_np, gids_np, cells_np = self._probe_plan(
            probe, probe_lens=probe_lens)
        rows = jnp.asarray(rows_np)
        gids = jnp.asarray(gids_np)
        exact = self._exact_residual
        rowbias = self._plan_rowbias(
            rows, gids, self._bias, filter_mask, queries.shape[0],
            slot_cells=cells_np if exact else None,
            cell_bias=cd if exact else None)
        luts = self._stage1_luts(queries, probe)
        topl = min(self.rerank if use_rerank else k, rows.shape[1])
        gen = candidate_generator_for(self.backend)
        d2, ids = gen.gather_topl(self._codes, rows, gids, luts, rowbias,
                                  topl=topl, lut_dtype=lut_dtype,
                                  overfetch=overfetch)
        return self._finish_pool(queries, d2, ids, k,
                                 use_rerank=use_rerank)

    def _finish_pool(self, queries, d2, ids, k: int, *, use_rerank: bool):
        """Shared tail over a gathered candidate pool (also used by
        ShardedIndex on the merged per-shard pools): optional stage-2
        rerank through the streaming engine, +inf pads reported as -1,
        and the result brought to EXACTLY the flat-search width
        min(k, ntotal) — padded with the documented (+inf, -1) tail when
        the probed pool is narrower, truncated when a pool face over-
        allocated (the dispatch scatter-merge can be P * L wide; every
        global id enters a pool at most once, so columns past ntotal are
        always pads)."""
        if not use_rerank:
            kk = min(k, d2.shape[1])
            d = d2[:, :kk]
            i = jnp.where(jnp.isposinf(d), -1, ids[:, :kk])
        else:
            valid = jnp.isfinite(d2)
            rows_cand = jnp.take(self._pos_dev, jnp.where(valid, ids, 0))
            d1 = self._rerank_distances(queries, rows_cand)
            d1 = jnp.where(valid, d1, jnp.inf)
            kk = min(k, d1.shape[1])
            neg, order = jax.lax.top_k(-d1, kk)
            d = -neg
            i = jnp.take_along_axis(ids, order, axis=1)
            i = jnp.where(jnp.isposinf(d), -1, i)
        width = min(k, self.ntotal)
        if d.shape[1] < width:
            pad = width - d.shape[1]
            d = jnp.pad(d, ((0, 0), (0, pad)), constant_values=jnp.inf)
            i = jnp.pad(i, ((0, 0), (0, pad)), constant_values=-1)
        elif d.shape[1] > width:
            d, i = d[:, :width], i[:, :width]
        return d, i

    def _exhaustive_rerank_topk(self, queries, k: int):
        """``use_d2=False`` over the ADD-ORDER view of the buffer, so tie
        resolution matches a flat index over the same vectors. Residual
        mode reconstructs ``decode(code) + centroid`` per chunk (the
        cells ride the scan payload alongside the codes)."""
        from repro.index.rerank import exhaustive_topk
        codes_add = jnp.take(self._codes, self._pos_dev, axis=0)
        if not self.residual:
            if self._exhaustive_fn is None:
                self._exhaustive_fn = jax.jit(
                    functools.partial(exhaustive_topk, self._reconstruct),
                    static_argnames=("k",))
            return self._exhaustive_fn(codes_add, queries,
                                       k=min(k, self.ntotal))
        cells_add = jnp.take(self._cells_dev, self._pos_dev)
        if self._exhaustive_fn is None:
            def recon(payload):
                codes, cells = payload
                return self._reconstruct(codes) + jnp.take(
                    self.coarse, cells, axis=0)

            self._exhaustive_fn = jax.jit(
                functools.partial(exhaustive_topk, recon),
                static_argnames=("k",))
        return self._exhaustive_fn((codes_add, cells_add), queries,
                                   k=min(k, self.ntotal))

    # -- persistence ---------------------------------------------------------

    def _tree(self):
        m = self._codes.shape[1] if self._codes is not None else \
            self.inner._tree()["codes"].shape[1]
        return {
            "inner": self.inner._tree(),
            "coarse": self.coarse,
            "codes": self._codes if self._codes is not None
            else jnp.zeros((0, m), jnp.uint8),
            "ids": jnp.asarray(self._ids_np, jnp.int32)
            if self._ids_np is not None else jnp.zeros((0,), jnp.int32),
            "cells": jnp.asarray(self._cells_np, jnp.int32)
            if self._cells_np is not None else jnp.zeros((0,), jnp.int32),
            "norms": self._bias if self._bias is not None
            else jnp.zeros((0,), jnp.float32),
        }

    def _metadata(self) -> dict:
        return {"dim": self.dim, "nlist": self.nlist, "nprobe": self.nprobe,
                "rerank": self.rerank, "backend": self.backend,
                "ntotal": self.ntotal, "residual": self.residual,
                "dispatch_capacity": self.dispatch_capacity,
                "has_bias": self._bias is not None,
                "inner_kind": self.inner.kind,
                "inner_meta": self.inner._metadata()}

    @classmethod
    def _empty_from_metadata(cls, meta: dict) -> "IVFIndex":
        inner = base._KINDS[meta["inner_kind"]]._empty_from_metadata(
            meta["inner_meta"])
        inner._codes = None                      # codes live on the wrapper
        index = cls(meta["dim"], inner=inner, nlist=meta["nlist"],
                    nprobe=meta["nprobe"], rerank=meta["rerank"],
                    backend=meta["backend"],
                    residual=meta.get("residual", False),
                    dispatch_capacity=meta.get("dispatch_capacity"))
        n = meta["ntotal"]
        m = inner._tree()["codes"].shape[1]
        index.coarse = jnp.zeros((meta["nlist"], meta["dim"]), jnp.float32)
        index._codes = jnp.zeros((n, m), jnp.uint8)
        index._ids_np = np.zeros(n, np.int32)
        index._cells_np = np.zeros(n, np.int32)
        if meta["has_bias"]:
            index._bias = jnp.zeros((n,), jnp.float32)
        return index

    def _set_tree(self, tree) -> None:
        self.inner._set_tree(tree["inner"])
        self.inner._codes = None
        self.coarse = tree["coarse"]
        n = int(tree["codes"].shape[0])
        self._codes = tree["codes"] if n else None
        self._bias = tree["norms"] if tree["norms"].shape[0] else None
        if n:
            self._ids_np = np.asarray(tree["ids"])
            self._cells_np = np.asarray(tree["cells"])
            self._cells_dev = jnp.asarray(self._cells_np)
            counts = np.bincount(self._cells_np, minlength=self.nlist)
            self._offsets = np.concatenate(
                [[0], np.cumsum(counts)]).astype(np.int64)
            self._offsets_dev = jnp.asarray(self._offsets, jnp.int32)
            self._ids_dev = jnp.asarray(self._ids_np)
            pos = np.empty(n, np.int32)
            pos[self._ids_np] = np.arange(n, dtype=np.int32)
            self._pos_dev = jnp.asarray(pos)
            self._plan_cache = {}
        else:
            self.reset()
        self._invalidate_caches()

    def __repr__(self):
        return (f"IVFIndex({self.inner!r}, nlist={self.nlist}, "
                f"nprobe={self.nprobe}, residual={self.residual}, "
                f"ntotal={self.ntotal}, rerank={self.rerank}, "
                f"backend={self.backend!r})")
