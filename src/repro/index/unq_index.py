"""UNQ-backed Index: the paper's neural quantizer behind the FAISS-style
surface (train = §3.4 objective, add = one feed-forward encode pass,
search = d2 LUT scan + d1 decoder rerank)."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import unq
from repro.index import base
from repro.index.backend import encode_impl_for, resolve_scan_backend
from repro.kernels import ops


@functools.partial(jax.jit, static_argnames=("cfg", "impl"))
def _encode_batch(params, state, cfg: unq.UNQConfig, xb, *, impl: str):
    """One feed-forward pass: (B, D) -> (B, M) uint8 (the paper's headline
    encoding speed — no iterative optimization, unlike AQ/LSQ)."""
    heads, _ = unq.encode_heads(params, state, cfg, xb, train=False)
    return ops.unq_encode(heads, params["codebooks"],
                          impl=impl).astype(jnp.uint8)


def encode_database(params, state, cfg: unq.UNQConfig, base_x, *,
                    batch_size: int = 8192, impl: str = "xla") -> jax.Array:
    """Compress a base set: (N, D) -> uint8 codes (N, M), batched."""
    n = base_x.shape[0]
    outs = []
    for s in range(0, n, batch_size):
        outs.append(_encode_batch(params, state, cfg, base_x[s:s + batch_size],
                                  impl=impl))
    return jnp.concatenate(outs, axis=0)


def build_luts(params, state, cfg: unq.UNQConfig, queries) -> jax.Array:
    """(Q, D) queries -> (Q, M, K) tables of -<net(q)_m, c_mk> (d2, Eq. 8)."""
    heads, _ = unq.encode_heads(params, state, cfg, queries, train=False)
    return -unq.head_logits(params, heads)


class UNQIndex(base.Index):
    """Unsupervised Neural Quantization index (Morozov & Babenko 2019)."""

    kind = "unq"

    def __init__(self, dim: int, *, num_codebooks: int = 8,
                 codebook_size: int = 256, rerank: int = 500,
                 backend: str = "auto", cfg: unq.UNQConfig | None = None):
        super().__init__(dim, rerank=rerank, backend=backend)
        self.cfg = cfg if cfg is not None else unq.UNQConfig(
            dim=dim, num_codebooks=num_codebooks,
            codebook_size=codebook_size)
        assert self.cfg.dim == dim
        self.params = None
        self.state = None
        self.history: list[dict] = []

    @classmethod
    def from_trained(cls, params, state, cfg: unq.UNQConfig, *, codes=None,
                     rerank: int = 500, backend: str = "auto") -> "UNQIndex":
        """Wrap an already-trained UNQ model (and optionally its codes)."""
        index = cls(cfg.dim, rerank=rerank, backend=backend, cfg=cfg)
        index.params, index.state = params, state
        if codes is not None:
            index._codes = jnp.asarray(codes)
        return index

    @property
    def is_trained(self) -> bool:
        return self.params is not None

    def _fit_quantizer(self, xs, *, train_cfg=None, callback=None,
                       **overrides):
        """Fit UNQ on (n, dim) vectors (paper §3.4: QHAdam + One-Cycle,
        L = L1 + alpha*L2 + beta*CV^2). ``overrides`` are TrainConfig
        fields (epochs=..., lr=..., alpha=...)."""
        from repro.core import training
        from repro.data import descriptors as ddata

        xs = np.asarray(xs, np.float32)
        tcfg = train_cfg if train_cfg is not None else \
            training.TrainConfig(**overrides)
        ds = ddata.DescriptorDataset(
            train=xs, base=xs[:0], queries=xs[:0],
            gt_nn=np.zeros((0,), np.int64), name="index-train")
        self.params, self.state, self.history = training.train_unq(
            ds, self.cfg, tcfg, callback=callback)

    def _encode(self, xs) -> jax.Array:
        impl = encode_impl_for(resolve_scan_backend(self.backend))
        return encode_database(self.params, self.state, self.cfg, xs,
                               impl=impl)

    def _build_luts(self, queries) -> jax.Array:
        return build_luts(self.params, self.state, self.cfg, queries)

    def _build_decode_table(self) -> None:
        # the MLP decoder is not an additive code table, so the stage-2
        # engine resolves to the cross-query dedup reranker (each unique
        # candidate decoded once) instead of the fused table kernel
        return None

    def _reconstruct(self, codes) -> jax.Array:
        return unq.decode_codes(self.params, self.state, self.cfg, codes)

    # -- persistence -------------------------------------------------------

    def _tree(self):
        codes = self._codes if self._codes is not None else \
            jnp.zeros((0, self.cfg.num_codebooks), jnp.uint8)
        return {"params": self.params, "state": self.state, "codes": codes}

    def _metadata(self) -> dict:
        cfg = {k: v for k, v in dataclasses.asdict(self.cfg).items()
               if k != "dtype"}   # dtype is not JSON; f32 is the only one used
        return {"cfg": cfg, "rerank": self.rerank, "backend": self.backend,
                "ntotal": self.ntotal}

    @classmethod
    def _empty_from_metadata(cls, meta: dict) -> "UNQIndex":
        cfg = unq.UNQConfig(**meta["cfg"])
        index = cls(cfg.dim, rerank=meta["rerank"], backend=meta["backend"],
                    cfg=cfg)
        index.params, index.state = unq.init(jax.random.PRNGKey(0), cfg)
        index._codes = jnp.zeros((meta["ntotal"], cfg.num_codebooks),
                                 jnp.uint8)
        return index

    def _set_tree(self, tree) -> None:
        self.params, self.state = tree["params"], tree["state"]
        self._codes = tree["codes"] if tree["codes"].shape[0] else None
        self._invalidate_caches()
