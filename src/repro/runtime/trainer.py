"""Fault-tolerant distributed training loop.

Production posture (scaled to this container's single host):

  * auto-resume — on construction the trainer restores the newest
    checkpoint (params + optimizer state + data-pipeline state + step) and
    continues; a SIGKILL'd job restarts bit-identical.
  * elastic restore — the restore path re-device_puts onto the *current*
    mesh, so a job that comes back with fewer/more devices (re-factorized
    mesh from launch.mesh.make_elastic_mesh) reshards transparently.
  * atomic periodic checkpoints, async by default (I/O overlaps compute).
  * straggler watchdog — each step carries a deadline derived from a
    rolling median; violations are logged with the step index (on real
    multi-host this feeds preemption/hot-spare logic; here it is the
    hook + the log). jax dispatch is async, so the watchdog measures the
    full dispatch+execute wall time via block_until_ready on the loss.
  * failure injection — ``crash_at_step`` raises mid-run (used by the
    restart tests to prove recovery).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import statistics
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0     # deadline = factor * rolling median
    straggler_window: int = 20
    crash_at_step: int | None = None  # failure injection (tests)


class Trainer:
    def __init__(self, tcfg: TrainerConfig, step_fn: Callable,
                 params: Any, opt_state: Any, data_stream: Any, *,
                 shardings: tuple | None = None,
                 metrics_path: str | None = None):
        self.tcfg = tcfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.stream = data_stream
        self.shardings = shardings
        self.step = 0
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      keep=tcfg.keep_checkpoints)
        self.metrics_path = metrics_path
        self._durations: list[float] = []
        self._straggler_events: list[dict] = []
        self._maybe_resume()

    # -- state = everything needed for bit-identical resume ---------------
    def _state_tree(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def _maybe_resume(self):
        like = self._state_tree()
        restored = self.ckpt.restore_latest(
            like,
            shardings={"params": self.shardings[0],
                       "opt_state": self.shardings[1]}
            if self.shardings else None)
        if restored is None:
            return
        tree, manifest = restored
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.step = int(manifest["step"])
        ds_state = manifest["metadata"].get("data_state")
        if ds_state and hasattr(self.stream, "load_state_dict"):
            self.stream.load_state_dict(ds_state)
        print(f"[trainer] resumed from step {self.step}")

    def _checkpoint(self, blocking=False):
        meta = {}
        if hasattr(self.stream, "state_dict"):
            meta["data_state"] = self.stream.state_dict()
        self.ckpt.save(self.step, self._state_tree(), metadata=meta,
                       blocking=blocking or not self.tcfg.async_checkpoint)

    def _watchdog(self, dt: float):
        self._durations.append(dt)
        window = self._durations[-self.tcfg.straggler_window:]
        if len(window) >= 5:
            med = statistics.median(window[:-1])
            if dt > self.tcfg.straggler_factor * med:
                event = {"step": self.step, "duration": dt, "median": med}
                self._straggler_events.append(event)
                print(f"[trainer] STRAGGLER step {self.step}: "
                      f"{dt * 1e3:.1f}ms vs median {med * 1e3:.1f}ms")

    def _log(self, metrics: dict):
        if self.metrics_path:
            rec = {"step": self.step,
                   **{k: float(v) for k, v in metrics.items()}}
            with open(self.metrics_path, "a") as f:
                f.write(json.dumps(rec) + "\n")

    def run(self) -> dict:
        """Run to total_steps (resuming included). Returns final metrics."""
        metrics = {}
        while self.step < self.tcfg.total_steps:
            if self.tcfg.crash_at_step is not None and \
                    self.step == self.tcfg.crash_at_step:
                raise RuntimeError(f"injected failure at step {self.step}")
            batch = self.stream.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch,
                jnp.asarray(self.step, jnp.int32))
            jax.block_until_ready(metrics["loss"])
            self._watchdog(time.time() - t0)
            self.step += 1
            if self.step % self.tcfg.log_every == 0:
                self._log(metrics)
            if self.step % self.tcfg.checkpoint_every == 0:
                self._checkpoint()
        self._checkpoint(blocking=True)
        self.ckpt.wait()
        return {k: float(v) for k, v in metrics.items()}
