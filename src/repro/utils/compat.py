"""Version-compat accessors for JAX APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``
along the way). Call sites use this wrapper with the NEW spelling and it
degrades to whatever the installed jax provides.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kw):
    """``jax.shard_map`` if available, else the experimental one.

    ``check_vma`` (the new name) maps onto ``check_rep`` on older jax;
    leave it None to take the installed default.
    """
    new = getattr(jax, "shard_map", None)
    if new is not None:
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as legacy
    if check_vma is not None:
        kw["check_rep"] = check_vma
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
