"""Deterministic PRNG-key sequencing."""
from __future__ import annotations

import jax


class PRNGSeq:
    """An infinite, deterministic sequence of PRNG keys.

    >>> keys = PRNGSeq(0)
    >>> k1, k2 = next(keys), next(keys)
    """

    def __init__(self, seed_or_key):
        if isinstance(seed_or_key, int):
            self._key = jax.random.PRNGKey(seed_or_key)
        else:
            self._key = seed_or_key

    def __next__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def __iter__(self):
        return self

    def take(self, n: int):
        return [next(self) for _ in range(n)]
