"""Pytree utilities shared across the framework.

Pure-JAX (no flax/optax available in this environment), so all parameter
containers in repro are plain nested dicts of jnp arrays and these helpers are
the substrate every other subsystem builds on.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    """Flatten a pytree to ``[("a/b/0/c", leaf), ...]`` with stable paths.

    Paths use '/' separators and work for dicts, lists, tuples and dataclass
    pytrees. Used by checkpointing (manifest keys) and debugging.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:  # FlattenedIndexKey and anything exotic
                parts.append(str(getattr(p, "key", p)))
        out.append(("/".join(parts), leaf))
    return out


def param_count(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree: Any) -> int:
    """Total bytes across all leaves (uses each leaf's dtype)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def global_norm(tree: Any) -> jax.Array:
    """L2 norm over all leaves (float32 accumulation)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def tree_zeros_like(tree: Any, dtype=None) -> Any:
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def tree_cast(tree: Any, dtype) -> Any:
    """Cast floating-point leaves to ``dtype``; leave integer leaves alone."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)


def tree_map_with_names(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(path, leaf)`` over a pytree, preserving structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = tree_flatten_with_names(tree)
    new_leaves = [fn(name, leaf) for (name, leaf) in named]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
