from repro.utils.pytree import (
    param_count,
    param_bytes,
    tree_flatten_with_names,
    global_norm,
    tree_zeros_like,
    tree_cast,
)
from repro.utils.prng import PRNGSeq
from repro.utils.compat import shard_map

__all__ = [
    "shard_map",
    "param_count",
    "param_bytes",
    "tree_flatten_with_names",
    "global_norm",
    "tree_zeros_like",
    "tree_cast",
    "PRNGSeq",
]
