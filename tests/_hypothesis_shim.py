"""Optional-import shim for ``hypothesis``.

The property tests use a small slice of the hypothesis API
(``@given`` with keyword strategies, ``@settings``, ``st.integers`` /
``st.sampled_from``). When hypothesis is installed (requirements-dev.txt)
this module re-exports the real thing; when it is absent — e.g. a minimal
container — it falls back to a deterministic sampler that runs each
property over a fixed number of seeded pseudo-random examples, so the
suite still collects and exercises the properties everywhere.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect

    import numpy as np

    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sampler, minimal):
            self._sampler = sampler
            self.minimal = minimal

        def sample(self, rng: np.random.Generator):
            return self._sampler(rng)

    class _Strategies:
        """The subset of ``hypothesis.strategies`` the tests use."""

        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                minimal=min_value)

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.integers(0, len(seq))],
                             minimal=seq[0])

    st = _Strategies()

    def given(**strategies):
        """Run the test over deterministic pseudo-random draws. The first
        example pins every strategy to its minimal value (hypothesis'
        shrink target), so degenerate shapes are always covered."""

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kw):
                # pytest passes fixtures as keywords — forward them
                fn(*args, **kw,
                   **{k: s.minimal for k, s in strategies.items()})
                rng = np.random.default_rng(0)
                for _ in range(_FALLBACK_EXAMPLES - 1):
                    fn(*args, **kw, **{k: s.sample(rng)
                                       for k, s in strategies.items()})
            # hide the strategy params from pytest's fixture resolution
            # (like real @given, the wrapper provides them itself);
            # remaining params (if any) stay visible as fixtures
            params = [p for name, p in
                      inspect.signature(fn).parameters.items()
                      if name not in strategies]
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper
        return deco

    def settings(**kw):  # max_examples/deadline are no-ops in the fallback
        return lambda fn: fn
