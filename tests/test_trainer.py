"""Fault-tolerant trainer: crash injection + bit-identical resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.data.tokens import TokenStream
from repro.models import registry
from repro.parallel import steps as steps_lib
from repro.runtime import Trainer, TrainerConfig


def _setup(ckpt_dir, crash_at=None, total=12):
    cfg = configs.get("yi-6b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = registry.init(key, cfg)
    train_step, opt = steps_lib.make_train_step(
        cfg, lr_fn=optim.constant(1e-3))
    opt_state = opt.init(params)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
    tcfg = TrainerConfig(total_steps=total, checkpoint_every=4,
                         checkpoint_dir=str(ckpt_dir), log_every=100,
                         crash_at_step=crash_at, async_checkpoint=False)
    return Trainer(tcfg, jax.jit(train_step), params, opt_state, stream)


def test_crash_and_resume_reaches_total(tmp_path):
    t1 = _setup(tmp_path / "ck", crash_at=9)
    with pytest.raises(RuntimeError, match="injected failure"):
        t1.run()
    assert t1.step == 9

    # "restart the job": fresh trainer, same dir -> resumes from step 8
    t2 = _setup(tmp_path / "ck")
    assert t2.step == 8
    # data stream resumed too (not restarted from 0)
    assert t2.stream.step == t2.step
    final = t2.run()
    assert t2.step == 12
    assert np.isfinite(final["loss"])


def test_resume_is_bit_identical_to_uninterrupted(tmp_path):
    """Crash/resume at step 8 must produce the same params as running
    straight through (deterministic data + optimizer)."""
    ta = _setup(tmp_path / "a", total=10)
    ta.run()

    tb1 = _setup(tmp_path / "b", crash_at=9, total=10)
    with pytest.raises(RuntimeError):
        tb1.run()
    tb2 = _setup(tmp_path / "b", total=10)
    tb2.run()

    for x, y in zip(jax.tree.leaves(ta.params), jax.tree.leaves(tb2.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_straggler_watchdog_fires(tmp_path):
    t = _setup(tmp_path / "ck", total=8)
    # inject one slow step by monkeypatching the step function
    inner = t.step_fn
    calls = {"n": 0}

    def slow_step(p, o, b, s):
        calls["n"] += 1
        if calls["n"] == 7:
            import time
            time.sleep(1.0)
        return inner(p, o, b, s)

    t.step_fn = slow_step
    t.run()
    # the 1s sleep dwarfs the tiny-model step median -> watchdog must fire
    assert t._straggler_events, "watchdog did not flag the injected straggler"
