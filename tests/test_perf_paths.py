"""Perf-path equivalence: the optimized implementations must match the
paper-faithful baselines exactly (EXPERIMENTS.md §Perf iterations 5-7)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rwkv6
from repro.models.config import ModelConfig


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_wkv_matches_sequential(chunk):
    rng = np.random.default_rng(chunk)
    b, t, h, dh = 2, 32, 2, 8
    r, k, v = (jnp.asarray(rng.normal(size=(b, t, h, dh)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.8, 0.999, (b, t, h, dh)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, dh)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(b, h, dh, dh)), jnp.float32)
    o_seq, s_seq = rwkv6._wkv_scan(r, k, v, w, u, s0)
    o_ch, s_ch = rwkv6._wkv_chunked(r, k, v, w, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(o_ch), np.asarray(o_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_ch), np.asarray(s_seq),
                               rtol=1e-4, atol=1e-4)


def test_chunked_wkv_model_level():
    cfg_s = ModelConfig(name="t", family="rwkv6", num_layers=2, d_model=128,
                        d_ff=256, vocab_size=64, compute_dtype=jnp.float32)
    params = rwkv6.init(jax.random.PRNGKey(0), cfg_s)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 64)
    l_seq = rwkv6.forward(params, cfg_s, {"tokens": toks})
    l_ch = rwkv6.forward(params, cfg_s.with_(rwkv_chunk=8),
                         {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l_ch), np.asarray(l_seq),
                               rtol=2e-4, atol=2e-4)


def test_moe_ep_matches_baseline_on_mesh():
    """shard_map expert parallelism == pjit baseline (dropless capacity)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import registry
from repro.parallel import hints, sharding as shard_lib

cfg = configs.get("deepseek-moe-16b", smoke=True).with_(capacity_factor=8.0)
params = registry.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 17)),
                               jnp.int32)}
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = dict(shard_lib.RULES_SINGLE_POD)
ps = shard_lib.params_pspecs(registry.logical_axes(cfg), rules)
with mesh, hints.activation_sharding(rules, mesh):
    sp = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), ps,
        is_leaf=lambda x: isinstance(x, P)))
    l_base, _ = jax.jit(lambda p, b: registry.loss_fn(p, cfg, b))(sp, batch)
    l_ep, _ = jax.jit(lambda p, b: registry.loss_fn(
        p, cfg.with_(moe_ep=True), b))(sp, batch)
np.testing.assert_allclose(float(l_base), float(l_ep), rtol=2e-3)
print("EP-MATCH-OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=540)
    assert "EP-MATCH-OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])


def test_microbatched_train_step_matches_single():
    """Gradient accumulation == single-batch step (up to fp summation)."""
    from repro import configs, optim
    from repro.models import registry
    from repro.parallel import steps as steps_lib

    cfg = configs.get("yi-6b", smoke=True)
    params = registry.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 17)), jnp.int32)}

    outs = {}
    for mb in (1, 2, 4):
        step, opt = steps_lib.make_train_step(
            cfg, lr_fn=optim.constant(1e-3), microbatches=mb)
        p, o, m = jax.jit(step)(params, opt.init(params), batch,
                                jnp.asarray(0))
        outs[mb] = (p, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[2][1], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)
