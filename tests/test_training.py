"""UNQ end-to-end training behaviour (paper §3.4) — integration level."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import unq
from repro.index.unq_index import encode_database


def test_loss_decreases(tiny_unq):
    cfg, params, state, history = tiny_unq
    first = np.mean([h["recon"] for h in history[:2]])
    last = np.mean([h["recon"] for h in history[-2:]])
    assert last < first * 0.85, (first, last)


def test_codebook_usage_not_collapsed(tiny_unq, tiny_dataset):
    """The CV^2 regularizer must keep a healthy fraction of codes in use
    (paper: 'a common problem ... codes are (almost) never used')."""
    cfg, params, state, _ = tiny_unq
    codes = encode_database(params, state, cfg,
                                   jnp.asarray(tiny_dataset.base))
    arr = np.asarray(codes)
    for m in range(cfg.num_codebooks):
        used = len(np.unique(arr[:, m]))
        assert used >= cfg.codebook_size * 0.3, (m, used)


def test_usage_entropy_increases_with_regularizer(tiny_dataset):
    """Train two tiny models, beta on vs off: the regularized one must use
    codes at least as uniformly (higher usage entropy)."""
    from repro.core import training

    cfg = unq.UNQConfig(dim=96, num_codebooks=4, codebook_size=32,
                        code_dim=16, hidden_dim=48)
    kw = dict(epochs=2, batch_size=256, lr=2e-3, log_every=5,
              use_triplet=False)
    _, _, h_on = training.train_unq(
        tiny_dataset, cfg, training.TrainConfig(**kw))
    _, _, h_off = training.train_unq(
        tiny_dataset, cfg,
        training.TrainConfig(**kw, use_regularizer=False))
    ent_on = np.mean([h["usage_entropy"] for h in h_on[-3:]])
    ent_off = np.mean([h["usage_entropy"] for h in h_off[-3:]])
    assert ent_on >= ent_off - 0.05, (ent_on, ent_off)


def test_encode_database_deterministic(tiny_unq, tiny_dataset):
    cfg, params, state, _ = tiny_unq
    base = jnp.asarray(tiny_dataset.base[:512])
    a = encode_database(params, state, cfg, base, batch_size=128)
    b = encode_database(params, state, cfg, base, batch_size=512)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
