"""UNQ model unit tests (paper §3.2) + objective terms (§3.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import losses, unq


CFG = unq.UNQConfig(dim=24, num_codebooks=4, codebook_size=16, code_dim=8,
                    hidden_dim=32)


def _setup(seed=0):
    key = jax.random.PRNGKey(seed)
    params, state = unq.init(key, CFG)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (12, CFG.dim))
    return key, params, state, x


def test_shapes_and_dtypes():
    key, params, state, x = _setup()
    heads, _ = unq.encode_heads(params, state, CFG, x, train=True)
    assert heads.shape == (12, 4, 8)
    codes = unq.encode(params, state, CFG, x)
    assert codes.shape == (12, 4) and codes.dtype == jnp.uint8
    assert int(codes.max()) < CFG.codebook_size
    recon = unq.decode_codes(params, state, CFG, codes)
    assert recon.shape == (12, CFG.dim)


def test_assignment_probs_normalized():
    key, params, state, x = _setup()
    heads, _ = unq.encode_heads(params, state, CFG, x, train=False)
    log_p = unq.assignment_log_probs(params, heads)
    np.testing.assert_allclose(np.exp(np.asarray(log_p)).sum(-1),
                               np.ones((12, 4)), rtol=1e-5)


def test_temperature_does_not_change_argmax():
    key, params, state, x = _setup()
    codes_a = unq.encode(params, state, CFG, x)
    params2 = {**params, "log_tau": params["log_tau"] + 2.0}
    codes_b = unq.encode(params2, state, CFG, x)
    np.testing.assert_array_equal(np.asarray(codes_a), np.asarray(codes_b))


def test_gumbel_st_is_onehot_forward():
    key, params, state, x = _setup()
    heads, _ = unq.encode_heads(params, state, CFG, x, train=True)
    log_p = unq.assignment_log_probs(params, heads)
    y = unq.gumbel_softmax_st(key, log_p, hard=True)
    arr = np.asarray(y)
    np.testing.assert_allclose(arr.sum(-1), 1.0, rtol=1e-5)
    assert ((arr == 0) | (np.isclose(arr.max(-1, keepdims=True), arr))).all()
    # soft version must be a proper simplex, not one-hot
    ys = np.asarray(unq.gumbel_softmax_st(key, log_p, hard=False))
    assert (ys.max(-1) < 1.0).any()


def test_gumbel_st_passes_gradients():
    key, params, state, x = _setup()

    def loss(p):
        out = unq.forward_train(key, p, state, CFG, x, hard=True)
        return jnp.mean(jnp.square(out["recon"] - x))

    g = jax.grad(loss)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # codebooks must receive gradient through the straight-through path
    assert float(jnp.sum(jnp.abs(g["codebooks"]))) > 0


def test_d2_matches_lut_scan():
    """d2 computed via codeword gather == LUT + ADC scan (Eq. 8)."""
    from repro.index.unq_index import build_luts
    from repro.kernels import ops
    key, params, state, x = _setup()
    q = x[:3]
    db = x[3:]
    codes = unq.encode(params, state, CFG, db)
    luts = build_luts(params, state, CFG, q)         # (3, M, K)
    heads, _ = unq.encode_heads(params, state, CFG, q, train=False)
    for i in range(3):
        via_lut = ops.adc_scan(codes, luts[i], impl="xla")
        direct = losses.d2_scores(
            params, jnp.broadcast_to(heads[i], (codes.shape[0],) +
                                     heads[i].shape), codes)
        np.testing.assert_allclose(np.asarray(via_lut), np.asarray(direct),
                                   rtol=1e-4, atol=1e-4)


def test_model_size_matches_paper_scaling():
    """Paper §4.2: 19.8 MB at M=8 vs 30.1 MB at M=16 for Deep (D=96).
    The delta comes from the encoder head + codebooks only (sum-decoder).
    Our implementation must reproduce both sizes within 15%."""
    c8 = unq.UNQConfig(dim=96, num_codebooks=8)
    c16 = c8.with_(num_codebooks=16)
    p8, _ = unq.init(jax.random.PRNGKey(0), c8)
    p16, _ = unq.init(jax.random.PRNGKey(0), c16)
    mb8 = unq.model_size_bytes(p8) / 2**20
    mb16 = unq.model_size_bytes(p16) / 2**20
    assert abs(mb8 - 19.8) / 19.8 < 0.15, mb8
    assert abs(mb16 - 30.1) / 30.1 < 0.15, mb16


# ---------------------------------------------------------------------------
# objective terms
# ---------------------------------------------------------------------------

def test_cv2_zero_for_uniform_and_large_for_collapsed():
    uniform = jnp.log(jnp.full((6, 4, 16), 1.0 / 16))
    assert float(losses.cv_squared_regularizer(uniform)) < 1e-6
    collapsed = jnp.full((6, 4, 16), -30.0).at[..., 0].set(0.0)
    collapsed = jax.nn.log_softmax(collapsed, axis=-1)
    assert float(losses.cv_squared_regularizer(collapsed)) > 5.0


def test_triplet_loss_zero_when_separated():
    key, params, state, x = _setup()
    heads, _ = unq.encode_heads(params, state, CFG, x, train=False)
    codes = unq.encode(params, state, CFG, x)
    # positive == own codes -> d2(x, pos) minimal; margin 0 -> loss ~ 0 when
    # negatives are farther (not guaranteed) but loss must be >= 0 always
    l = losses.triplet_loss(params, heads, codes, codes, margin=0.0)
    assert float(l) >= 0.0
    # identical pos/neg with positive margin -> exactly margin
    l2 = losses.triplet_loss(params, heads, codes, codes, margin=0.7)
    np.testing.assert_allclose(float(l2), 0.7, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_unq_loss_finite_and_beta_monotone(seed):
    key = jax.random.PRNGKey(seed)
    params, state = unq.init(key, CFG)
    x = jax.random.normal(key, (8, CFG.dim))
    batch = {"x": x, "pos": x, "neg": x[::-1]}
    vals = []
    for beta in (0.0, 0.5, 1.0):
        l, aux = losses.unq_loss(key, params, state, CFG, batch,
                                 alpha=0.0, beta=beta)
        assert np.isfinite(float(l))
        vals.append(float(l))
    # loss is affine in beta with nonneg CV^2 -> nondecreasing
    assert vals[0] <= vals[1] + 1e-6 <= vals[2] + 2e-6
