"""Exit-code regression for the CI smoke harness: ``benchmarks.run
--smoke`` must FAIL the process when a backend-parity check fails, not
just print the mismatch (a green CI over drifting backends is the worst
failure mode a parity harness can have).

Both directions run as real subprocesses — the exit code IS the contract
— restricted to the fast PQ spec via ``--specs`` so the regression does
not retrain the UNQ smoke model. The failing direction uses the
documented ``REPRO_SMOKE_FORCE_FAIL`` hook, which injects a synthetic
parity failure after the normal checks run.
"""
import os
import pathlib
import subprocess
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_SPEC = "PQ8x64,Rerank64"


def _run_smoke(extra_env):
    env = dict(os.environ, PYTHONPATH="src", REPRO_PALLAS_INTERPRET="1")
    env.pop("REPRO_SMOKE_FORCE_FAIL", None)
    env.update(extra_env)
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke",
         "--specs", _SPEC],
        env=env, cwd=_ROOT, capture_output=True, text=True, timeout=540)


def test_smoke_green_path_exits_zero():
    r = _run_smoke({})
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert f"smoke {_SPEC}: all backends agree" in r.stdout


def test_smoke_parity_failure_exits_nonzero():
    r = _run_smoke({"REPRO_SMOKE_FORCE_FAIL": "1"})
    assert r.returncode != 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "parity failure" in r.stdout
