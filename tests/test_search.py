"""Two-stage search behaviour (paper §3.3) + distributed shard merge,
through the canonical ``repro.index`` surface (the ``core.search``
deprecation shims are gone)."""
import jax.numpy as jnp
import numpy as np

from repro.core.search import recall_at_k
from repro.index import ShardedIndex, UNQIndex


def _index(tiny_unq, *, rerank):
    cfg, params, state, _ = tiny_unq
    return UNQIndex.from_trained(params, state, cfg, rerank=rerank)


def test_recall_pipeline_beats_random(tiny_unq, tiny_dataset):
    index = _index(tiny_unq, rerank=100).add(tiny_dataset.base)
    _, got = index.search(jnp.asarray(tiny_dataset.queries), 100)
    rec = recall_at_k(got, jnp.asarray(tiny_dataset.gt_nn))
    n = tiny_dataset.base.shape[0]
    random_r100 = 100 / n
    assert rec["recall@100"] > 10 * random_r100, rec
    assert rec["recall@1"] >= rec["recall@10"] * 0 and \
        rec["recall@10"] <= rec["recall@100"] + 1e-9


def test_rerank_improves_or_matches_recall_at_1(tiny_unq, tiny_dataset):
    queries = jnp.asarray(tiny_dataset.queries)[:100]
    gt = jnp.asarray(tiny_dataset.gt_nn)[:100]
    index = _index(tiny_unq, rerank=100).add(tiny_dataset.base)
    _, with_rr = index.search(queries, 10, use_rerank=True)
    _, without = index.search(queries, 10, use_rerank=False)
    r_with = recall_at_k(with_rr, gt, ks=(1,))["recall@1"]
    r_without = recall_at_k(without, gt, ks=(1,))["recall@1"]
    # paper Table 5: reranking helps R@1 (25.0 -> 34.6); allow slack on a
    # tiny undertrained model but it must not collapse
    assert r_with >= r_without - 0.02, (r_with, r_without)


def test_sharded_search_matches_single_shard(tiny_unq, tiny_dataset):
    """Candidate streams merged across from_shards splits == one shard —
    bit-exact, the streaming merge preserves top_k tie resolution."""
    queries = jnp.asarray(tiny_dataset.queries)[:20]
    index = _index(tiny_unq, rerank=50).add(tiny_dataset.base)
    codes = index.codes
    n = codes.shape[0]

    single = ShardedIndex.from_shards(index, [codes], [0])
    _, want = single.stage1_candidates(queries, topl=50)
    quarters = [codes[: n // 4], codes[n // 4: n // 2],
                codes[n // 2: 3 * n // 4], codes[3 * n // 4:]]
    offsets = [0, n // 4, n // 2, 3 * n // 4]
    sharded = ShardedIndex.from_shards(index, quarters, offsets)
    _, got = sharded.stage1_candidates(queries, topl=50)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_recall_at_k_exact_semantics():
    retrieved = jnp.asarray([[3, 1, 2], [9, 9, 9], [5, 0, 7]])
    gt = jnp.asarray([1, 9, 7])
    rec = recall_at_k(retrieved, gt, ks=(1, 3))
    np.testing.assert_allclose(rec["recall@1"], 1 / 3)
    np.testing.assert_allclose(rec["recall@3"], 1.0)


def test_full_pool_rerank_equals_exhaustive_d1(tiny_unq, tiny_dataset):
    """Invariant behind paper Table 5's 'Exhaustive reranking' row: when
    the d2 stage passes the WHOLE base as candidates, the two-stage search
    must return exactly the exhaustive-d1 ranking (the paper's quality
    ordering between the modes additionally needs paper-scale training —
    see EXPERIMENTS.md §Repro)."""
    base = jnp.asarray(tiny_dataset.base)[:800]
    queries = jnp.asarray(tiny_dataset.queries)[:20]
    index = _index(tiny_unq, rerank=800).add(base)
    _, two_stage = index.search(queries, 30)
    _, exhaustive = index.search(queries, 30, use_d2=False)
    for i in range(queries.shape[0]):
        a = set(np.asarray(two_stage[i]).tolist())
        b = set(np.asarray(exhaustive[i]).tolist())
        assert len(a & b) / len(a) > 0.95, i  # ties may swap at the edge
