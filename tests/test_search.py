"""Two-stage search behaviour (paper §3.3) + distributed shard merge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import search, unq
from repro.data.descriptors import exact_knn


def test_recall_pipeline_beats_random(tiny_unq, tiny_dataset):
    cfg, params, state, _ = tiny_unq
    base = jnp.asarray(tiny_dataset.base)
    queries = jnp.asarray(tiny_dataset.queries)
    codes = search.encode_database(params, state, cfg, base)
    scfg = search.SearchConfig(rerank=100, topk=100)
    got = search.search(params, state, cfg, scfg, queries, codes)
    rec = search.recall_at_k(got, jnp.asarray(tiny_dataset.gt_nn))
    n = tiny_dataset.base.shape[0]
    random_r100 = 100 / n
    assert rec["recall@100"] > 10 * random_r100, rec
    assert rec["recall@1"] >= rec["recall@10"] * 0 and \
        rec["recall@10"] <= rec["recall@100"] + 1e-9


def test_rerank_improves_or_matches_recall_at_1(tiny_unq, tiny_dataset):
    cfg, params, state, _ = tiny_unq
    base = jnp.asarray(tiny_dataset.base)
    queries = jnp.asarray(tiny_dataset.queries)[:100]
    gt = jnp.asarray(tiny_dataset.gt_nn)[:100]
    codes = search.encode_database(params, state, cfg, base)
    scfg = search.SearchConfig(rerank=100, topk=10)
    with_rr = search.search(params, state, cfg, scfg, queries, codes,
                            use_rerank=True)
    without = search.search(params, state, cfg, scfg, queries, codes,
                            use_rerank=False)
    r_with = search.recall_at_k(with_rr, gt, ks=(1,))["recall@1"]
    r_without = search.recall_at_k(without, gt, ks=(1,))["recall@1"]
    # paper Table 5: reranking helps R@1 (25.0 -> 34.6); allow slack on a
    # tiny undertrained model but it must not collapse
    assert r_with >= r_without - 0.02, (r_with, r_without)


def test_sharded_search_matches_single_shard(tiny_unq, tiny_dataset):
    cfg, params, state, _ = tiny_unq
    base = jnp.asarray(tiny_dataset.base)
    queries = jnp.asarray(tiny_dataset.queries)[:20]
    codes = search.encode_database(params, state, cfg, base)
    scfg = search.SearchConfig(rerank=50, topk=50)

    single = search.search_sharded(params, state, cfg, scfg, queries,
                                   [codes], [0])
    n = codes.shape[0]
    quarters = [codes[: n // 4], codes[n // 4: n // 2],
                codes[n // 2: 3 * n // 4], codes[3 * n // 4:]]
    offsets = [0, n // 4, n // 2, 3 * n // 4]
    sharded = search.search_sharded(params, state, cfg, scfg, queries,
                                    quarters, offsets)
    # same candidate SET for every query (order may differ on ties)
    for i in range(queries.shape[0]):
        a = set(np.asarray(single[i]).tolist())
        b = set(np.asarray(sharded[i]).tolist())
        overlap = len(a & b) / len(a)
        assert overlap > 0.95, (i, overlap)


def test_recall_at_k_exact_semantics():
    retrieved = jnp.asarray([[3, 1, 2], [9, 9, 9], [5, 0, 7]])
    gt = jnp.asarray([1, 9, 7])
    rec = search.recall_at_k(retrieved, gt, ks=(1, 3))
    np.testing.assert_allclose(rec["recall@1"], 1 / 3)
    np.testing.assert_allclose(rec["recall@3"], 1.0)


def test_full_pool_rerank_equals_exhaustive_d1(tiny_unq, tiny_dataset):
    """Invariant behind paper Table 5's 'Exhaustive reranking' row: when
    the d2 stage passes the WHOLE base as candidates, the two-stage search
    must return exactly the exhaustive-d1 ranking (the paper's quality
    ordering between the modes additionally needs paper-scale training —
    see EXPERIMENTS.md §Repro)."""
    cfg, params, state, _ = tiny_unq
    base = jnp.asarray(tiny_dataset.base)[:800]
    queries = jnp.asarray(tiny_dataset.queries)[:20]
    codes = search.encode_database(params, state, cfg, base)
    scfg = search.SearchConfig(rerank=codes.shape[0], topk=30)
    two_stage = search.search(params, state, cfg, scfg, queries, codes)
    exhaustive = search.search(params, state, cfg, scfg, queries, codes,
                               use_d2=False)
    for i in range(queries.shape[0]):
        a = set(np.asarray(two_stage[i]).tolist())
        b = set(np.asarray(exhaustive[i]).tolist())
        assert len(a & b) / len(a) > 0.95, i  # ties may swap at the edge
