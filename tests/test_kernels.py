"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes/dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [1, 7, 256, 1024, 2500])
@pytest.mark.parametrize("m,k", [(8, 256), (16, 256), (4, 64)])
@pytest.mark.parametrize("code_dtype", [jnp.uint8, jnp.int32])
def test_adc_scan_matches_oracle(n, m, k, code_dtype):
    rng = np.random.default_rng(n * m)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), code_dtype)
    lut = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    want = ref.adc_scan_ref(codes, lut)
    for impl in ("pallas", "onehot"):
        got = ops.adc_scan(codes, lut, impl=impl)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b", [1, 64, 300])
@pytest.mark.parametrize("m,k,d", [(8, 256, 64), (4, 32, 16)])
def test_unq_encode_matches_oracle(b, m, k, d):
    rng = np.random.default_rng(b + m)
    heads = jnp.asarray(rng.normal(size=(b, m, d)), jnp.float32)
    books = jnp.asarray(rng.normal(size=(m, k, d)), jnp.float32)
    want = ref.unq_encode_ref(heads, books)
    got = ops.unq_encode(heads, books, impl="pallas")
    np.testing.assert_array_equal(got, want)


def test_adc_scan_block_size_invariance():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 256, (2048, 8)), jnp.uint8)
    lut = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
    a = ops.adc_scan(codes, lut, impl="pallas", block_n=256)
    b = ops.adc_scan(codes, lut, impl="pallas", block_n=1024)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    m=st.integers(1, 16),
    k=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_adc_scan_property(n, m, k, seed):
    """Property: scores equal the sum of per-codebook table entries, and
    shifting one LUT row by a constant shifts every score by the same."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    base = np.asarray(ops.adc_scan(codes, lut, impl="pallas"))
    manual = np.take_along_axis(
        np.asarray(lut), np.asarray(codes, np.int64).T, axis=1).sum(0)
    np.testing.assert_allclose(base, manual, rtol=1e-4, atol=1e-4)
    shifted = np.asarray(ops.adc_scan(codes, lut + 1.0, impl="pallas"))
    np.testing.assert_allclose(shifted - base, np.full(n, float(m)),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_unq_encode_argmax_property(b, seed):
    """codes[b,m] must maximize the dot product within codebook m."""
    rng = np.random.default_rng(seed)
    m, k, d = 4, 16, 8
    heads = jnp.asarray(rng.normal(size=(b, m, d)), jnp.float32)
    books = jnp.asarray(rng.normal(size=(m, k, d)), jnp.float32)
    codes = np.asarray(ops.unq_encode(heads, books, impl="pallas"))
    scores = np.einsum("bmd,mkd->bmk", np.asarray(heads), np.asarray(books))
    np.testing.assert_array_equal(codes, scores.argmax(-1))


def test_kv_adc_attention_exact_when_lossless():
    """If every key/value lies exactly on a codeword, compressed-domain
    attention must equal dense attention."""
    rng = np.random.default_rng(0)
    h, m, k, d_sub, s = 2, 4, 8, 4, 24
    d = m * d_sub
    k_books = jnp.asarray(rng.normal(size=(h, m, k, d_sub)), jnp.float32)
    v_books = jnp.asarray(rng.normal(size=(h, m, k, d_sub)), jnp.float32)
    k_codes = jnp.asarray(rng.integers(0, k, (s, h, m)), jnp.int32)
    v_codes = jnp.asarray(rng.integers(0, k, (s, h, m)), jnp.int32)

    def decode(codes, books):
        m_idx = np.arange(m)
        # per head: (s, m, d_sub) -> (s, d)
        out = np.stack([
            np.asarray(books)[hh, m_idx][
                np.arange(m)[None, :], np.asarray(codes)[:, hh]]
            for hh in range(h)], axis=1)
        return out.reshape(s, h, d)

    keys = decode(k_codes, k_books)
    vals = decode(v_codes, v_books)
    q = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)

    got = ops.kv_adc_attention(q, k_codes, v_codes, k_books, v_books)
    logits = np.einsum("hd,shd->sh", np.asarray(q), keys) / np.sqrt(d)
    w = np.exp(logits - logits.max(0))
    w = w / w.sum(0)
    want = np.einsum("sh,shd->hd", w, vals)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_kv_adc_attention_respects_length_mask():
    rng = np.random.default_rng(1)
    h, m, k, d_sub, s = 1, 2, 4, 2, 10
    d = m * d_sub
    books = jnp.asarray(rng.normal(size=(h, m, k, d_sub)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, k, (s, h, m)), jnp.int32)
    q = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    full = ops.kv_adc_attention(q, codes, codes, books, books, length=5)
    # changing codes beyond the mask must not change the output
    codes2 = codes.at[7:].set((codes[7:] + 1) % k)
    masked = ops.kv_adc_attention(q, codes2, codes2, books, books, length=5)
    np.testing.assert_allclose(np.asarray(full), np.asarray(masked),
                               rtol=1e-5, atol=1e-5)
