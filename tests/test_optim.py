"""Optimizer math vs independent numpy references + schedule shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim


def _run_steps(opt, x0, grads, lrs):
    params = {"x": jnp.asarray(x0)}
    state = opt.init(params)
    for g, lr in zip(grads, lrs):
        params, state = opt.apply(params, {"x": jnp.asarray(g)}, state,
                                  jnp.asarray(lr))
    return np.asarray(params["x"])


def test_adam_matches_numpy_reference():
    rng = np.random.default_rng(0)
    x0 = rng.normal(size=(5,)).astype(np.float32)
    grads = [rng.normal(size=(5,)).astype(np.float32) for _ in range(7)]
    got = _run_steps(optim.adam(), x0, grads, [1e-2] * 7)

    b1, b2, eps = 0.9, 0.999, 1e-8
    m = np.zeros(5)
    v = np.zeros(5)
    x = x0.astype(np.float64).copy()
    for t, g in enumerate(grads, 1):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        x -= 1e-2 * mh / (np.sqrt(vh) + eps)
    np.testing.assert_allclose(got, x, rtol=1e-5, atol=1e-6)


def test_qhadam_matches_numpy_reference():
    """QHAdam (Ma & Yarats 2018): update interpolates raw grad and EMA."""
    rng = np.random.default_rng(1)
    x0 = rng.normal(size=(4,)).astype(np.float32)
    grads = [rng.normal(size=(4,)).astype(np.float32) for _ in range(5)]
    nu1, nu2, b1, b2, eps = 0.7, 1.0, 0.995, 0.999, 1e-8
    got = _run_steps(optim.qhadam(), x0, grads, [1e-2] * 5)

    m = np.zeros(4)
    v = np.zeros(4)
    x = x0.astype(np.float64).copy()
    for t, g in enumerate(grads, 1):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        num = (1 - nu1) * g + nu1 * mh
        den = np.sqrt((1 - nu2) * g * g + nu2 * vh) + eps
        x -= 1e-2 * num / den
    np.testing.assert_allclose(got, x, rtol=1e-5, atol=1e-6)


def test_qhadam_nu1_1_equals_adam_with_matching_betas():
    rng = np.random.default_rng(2)
    x0 = rng.normal(size=(3,)).astype(np.float32)
    grads = [rng.normal(size=(3,)).astype(np.float32) for _ in range(4)]
    qh = _run_steps(optim.qhadam(nu1=1.0, nu2=1.0, b1=0.9, b2=0.999),
                    x0, grads, [1e-3] * 4)
    ad = _run_steps(optim.adam(b1=0.9, b2=0.999), x0, grads, [1e-3] * 4)
    np.testing.assert_allclose(qh, ad, rtol=1e-6)


def test_sgd_momentum():
    x0 = np.array([1.0], np.float32)
    got = _run_steps(optim.sgd(momentum=0.9), x0,
                     [np.array([1.0], np.float32)] * 3, [0.1] * 3)
    # mu: 1, 1.9, 2.71; x: 1 - .1*(1+1.9+2.71)
    np.testing.assert_allclose(got, [1 - 0.1 * (1 + 1.9 + 2.71)], rtol=1e-6)


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0, 4.0])}       # norm 5
    clipped, norm = optim.clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-5)
    same, _ = optim.clip_by_global_norm(grads, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0], rtol=1e-6)


def test_one_cycle_schedule_shape():
    fn = optim.one_cycle(1.0, 100, pct_start=0.3, div_factor=10,
                         final_div_factor=100)
    lrs = np.array([float(fn(s)) for s in range(101)])
    assert abs(lrs[0] - 0.1) < 1e-6
    assert abs(lrs.max() - 1.0) < 1e-3
    assert np.argmax(lrs) == 30
    assert lrs[-1] <= 0.0101
    # monotone up then down
    assert (np.diff(lrs[:30]) >= -1e-9).all()
    assert (np.diff(lrs[31:]) <= 1e-9).all()


def test_linear_anneal_matches_paper_beta():
    fn = optim.linear_anneal(1.0, 0.05, 200)
    assert abs(float(fn(0)) - 1.0) < 1e-6
    assert abs(float(fn(100)) - 0.525) < 1e-6
    assert abs(float(fn(200)) - 0.05) < 1e-6
    assert abs(float(fn(400)) - 0.05) < 1e-6   # clamped


def test_optimizer_state_is_f32_regardless_of_param_dtype():
    opt = optim.adamw()
    params = {"w": jnp.zeros((3,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.float32
    new_params, _ = opt.apply(params, {"w": jnp.ones((3,), jnp.bfloat16)},
                              state, 1e-2)
    assert new_params["w"].dtype == jnp.bfloat16
