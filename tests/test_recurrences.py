"""Property tests for the recurrent substrates: the parallel formulations
must match sequential references (hypothesis-driven shapes/seeds)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.models import griffin
from repro.models.config import ModelConfig


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), t=st.integers(1, 24), w=st.sampled_from([4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_rg_lru_associative_scan_matches_sequential(b, t, w, seed):
    """h_t = a_t h_{t-1} + b_t via associative_scan == a python loop."""
    rng = np.random.default_rng(seed)
    cfg = ModelConfig(rnn_width=w, compute_dtype=jnp.float32)
    p = {
        "w_a": jnp.asarray(rng.normal(0, 0.5, (w, w)), jnp.float32),
        "b_a": jnp.asarray(rng.normal(0, 0.1, (w,)), jnp.float32),
        "w_i": jnp.asarray(rng.normal(0, 0.5, (w, w)), jnp.float32),
        "b_i": jnp.asarray(rng.normal(0, 0.1, (w,)), jnp.float32),
        "lambda_p": jnp.asarray(rng.normal(0.15, 0.05, (w,)), jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(b, t, w)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, w)), jnp.float32)

    h_par, h_last = griffin._rg_lru(p, x, h0)

    # sequential reference
    r = jax.nn.sigmoid(x @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(x @ p["w_i"] + p["b_i"])
    a = jnp.exp(-griffin.LRU_C * jax.nn.softplus(p["lambda_p"]) * r)
    bb = jnp.sqrt(jnp.maximum(1 - a**2, 1e-9)) * i * x
    hs = []
    h = h0
    for s in range(t):
        h = a[:, s] * h + bb[:, s]
        hs.append(h)
    ref = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(ref[:, -1]),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.sampled_from([2, 4]),
       k=st.sampled_from([8, 16]))
def test_kvq_quantize_roundtrip_properties(seed, m, k):
    """PQ-encode properties: codes in range; reconstruction error never
    exceeds the error of any other codeword choice (argmin optimality);
    exact roundtrip when inputs lie on codewords."""
    from repro.models import kvq
    rng = np.random.default_rng(seed)
    d_sub = 4
    dh = m * d_sub
    books = jnp.asarray(rng.normal(size=(m, k, d_sub)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(6, dh)), jnp.float32)
    codes = kvq.quantize_vectors(x, books)
    assert codes.shape == (6, m) and int(codes.max()) < k
    recon = kvq.dequantize_codes(codes, books)

    # optimality per subspace: chosen codeword error <= random codeword error
    xs = np.asarray(x).reshape(6, m, d_sub)
    rs = np.asarray(recon).reshape(6, m, d_sub)
    chosen_err = ((xs - rs) ** 2).sum(-1)
    rand_codes = rng.integers(0, k, (6, m))
    alt = np.asarray(books)[np.arange(m)[None], rand_codes]
    alt_err = ((xs - alt) ** 2).sum(-1)
    assert (chosen_err <= alt_err + 1e-5).all()

    # exact roundtrip for on-codebook points
    pts = np.asarray(books)[np.arange(m), rng.integers(0, k, m)].reshape(-1)
    codes2 = kvq.quantize_vectors(jnp.asarray(pts)[None], books)
    recon2 = kvq.dequantize_codes(codes2, books)
    np.testing.assert_allclose(np.asarray(recon2)[0], pts, rtol=1e-5)
