"""Autotuner registry + winner cache (``kernels/tune.py``) and the sweep
driver package (``repro.tune``): bucket math, the shared rounding
helpers, cache roundtrip + loud schema drift, ``best_config``
resolution precedence (cache winner > defaults; REPRO_TUNE_DISABLE
forces defaults), and registry/driver agreement."""
import json

import pytest

from repro.kernels import tune


@pytest.fixture
def cache(monkeypatch, tmp_path):
    """Fresh cache path + pinned device kind, isolated from the repo's
    real TUNE_CACHE.json."""
    p = tmp_path / "cache.json"
    monkeypatch.setenv(tune.CACHE_ENV, str(p))
    monkeypatch.delenv(tune.DISABLE_ENV, raising=False)
    monkeypatch.setattr(tune, "device_kind", lambda: "testdev")
    return p


def _doc(entries):
    return {"schema_version": tune.SCHEMA_VERSION, "entries": entries}


# ---------------------------------------------------------------------------
# bucket math + the ONE home of the rounding helpers
# ---------------------------------------------------------------------------

def test_shape_bucket_is_pow2_ceiling():
    assert tune.shape_bucket(1) == 8
    assert tune.shape_bucket(8) == 8
    assert tune.shape_bucket(9) == 16
    assert tune.shape_bucket(65536) == 65536
    assert tune.shape_bucket(65537) == 131072


def test_bucket_key_orders_registered_dims_and_rejects_missing():
    spec = tune.KERNELS["adc_scan_topl.xla"]
    assert tune.bucket_key(spec, {"topl": 100, "q": 20, "n": 60000}) == \
        "n=65536,q=32,topl=128"
    with pytest.raises(KeyError):
        tune.bucket_key(spec, {"n": 100, "q": 20})


def test_align_and_clamp_chunk():
    # align: round the dim up to the tile multiple, capped by the block
    assert tune.align(5, cap=256) == 8
    assert tune.align(9, cap=256) == 16
    assert tune.align(100, cap=64) == 64
    assert tune.align(3, cap=4, multiple=4) == 4
    # clamp_chunk: at most the request, at least the heap width, at most
    # ~dim/8 so short scans keep several steps
    assert tune.clamp_chunk(65536, cap=4096, floor=128) == 4096
    assert tune.clamp_chunk(100, cap=4096, floor=128) == 128
    assert tune.clamp_chunk(10_000, cap=4096, floor=128) == 1250


# ---------------------------------------------------------------------------
# cache I/O: roundtrip + loud drift
# ---------------------------------------------------------------------------

def test_save_load_roundtrip(cache):
    doc = _doc({"testdev": {"adc_scan_topl.xla": {
        "n=65536,q=32,topl=128": {"config": {"chunk_n": 8192},
                                  "us": 10.0, "default_us": 20.0}}}})
    tune.save_cache(doc)
    assert tune.load_cache(refresh=True) == doc
    assert cache.exists()


def test_missing_cache_is_empty_not_error(cache):
    doc = tune.load_cache(refresh=True)
    assert doc["entries"] == {}


@pytest.mark.parametrize("mutate,err", [
    (lambda d: d.update(schema_version=tune.SCHEMA_VERSION + 1),
     "schema_version"),
    (lambda d: d["entries"].update({"testdev": {"no_such_kernel": {}}}),
     "unknown kernel"),
    (lambda d: d["entries"]["testdev"]["adc_scan_topl.xla"]
        ["n=65536,q=32,topl=128"]["config"].update(bogus_param=4),
     "unknown param"),
    (lambda d: d["entries"]["testdev"]["adc_scan_topl.xla"]
        ["n=65536,q=32,topl=128"]["config"].update(chunk_n=1.5),
     "non-integer"),
])
def test_schema_drift_raises(cache, mutate, err):
    """A cache from a different build must fail LOUDLY at load, never
    silently mis-tune."""
    doc = _doc({"testdev": {"adc_scan_topl.xla": {
        "n=65536,q=32,topl=128": {"config": {"chunk_n": 8192}}}}})
    mutate(doc)
    cache.write_text(json.dumps(doc))
    with pytest.raises(tune.TuneCacheError, match=err):
        tune.load_cache(refresh=True)


def test_unparseable_cache_raises(cache):
    cache.write_text("{not json")
    with pytest.raises(tune.TuneCacheError, match="unparseable"):
        tune.load_cache(refresh=True)


# ---------------------------------------------------------------------------
# best_config resolution
# ---------------------------------------------------------------------------

def test_best_config_defaults_without_cache(cache):
    for key, spec in tune.KERNELS.items():
        dims = {d: 100 for d in spec.dims}
        kernel, _, impl = key.partition(".")
        assert tune.best_config(kernel, impl or None, **dims) == spec.params


def test_best_config_prefers_cached_winner_via_bucketing(cache):
    tune.save_cache(_doc({"testdev": {"adc_scan_topl.xla": {
        "n=65536,q=32,topl=128": {"config": {"chunk_n": 12345},
                                  "us": 1.0, "default_us": 2.0}}}}))
    # any shape landing in the bucket resolves the winner...
    got = tune.best_config("adc_scan_topl", "xla", n=60000, q=20, topl=100)
    assert got == {"chunk_n": 12345}
    # ...other buckets and devices fall back to the defaults
    other = tune.best_config("adc_scan_topl", "xla", n=70000, q=20, topl=100)
    assert other == tune.KERNELS["adc_scan_topl.xla"].params


def test_disable_env_forces_defaults(cache, monkeypatch):
    tune.save_cache(_doc({"testdev": {"adc_scan_topl.xla": {
        "n=65536,q=32,topl=128": {"config": {"chunk_n": 12345},
                                  "us": 1.0, "default_us": 2.0}}}}))
    monkeypatch.setenv(tune.DISABLE_ENV, "1")
    got = tune.best_config("adc_scan_topl", "xla", n=60000, q=20, topl=100)
    assert got == tune.KERNELS["adc_scan_topl.xla"].params


def test_registry_key_impl_agnostic_fallback_and_unknown():
    # the dispatch entry is shared across impls BY DESIGN: the router
    # bakes the tile width into the plan, so both must resolve one key
    assert tune.registry_key("adc_dispatch_topl", "xla") == \
        "adc_dispatch_topl"
    assert tune.registry_key("adc_dispatch_topl", "pallas") == \
        "adc_dispatch_topl"
    assert tune.registry_key("adc_scan_topl", "xla") == "adc_scan_topl.xla"
    with pytest.raises(KeyError):
        tune.registry_key("no_such_kernel", "xla")


def test_cache_fingerprint_counts_tuned_buckets(cache):
    assert tune.cache_fingerprint() == {
        "schema_version": tune.SCHEMA_VERSION, "device_kind": "testdev",
        "tuned_buckets": 0}
    tune.save_cache(_doc({"testdev": {
        "adc_scan_topl.xla": {
            "n=65536,q=32,topl=128": {"config": {"chunk_n": 8192}},
            "n=131072,q=32,topl=128": {"config": {"chunk_n": 8192}}},
        "adc_dispatch_topl": {
            "n=65536,q=32": {"config": {"chunk": 256}}}}}))
    assert tune.cache_fingerprint()["tuned_buckets"] == 3


def test_resolve_memo_lru_keeps_hot_buckets(cache, monkeypatch):
    """Regression: the resolution memo used to evict by wholesale
    ``.clear()`` at capacity, discarding a serving loop's hot buckets
    along with stale ones. Eviction must be LRU: a bucket that keeps
    getting hit survives unlimited one-off shape churn."""
    monkeypatch.setattr(tune, "_MEMO_CAP", 4)
    monkeypatch.setattr(tune, "_resolve_memo", {})

    def resolve(n):
        return tune.best_config("adc_scan_topl", "xla", n=n, q=8, topl=16)

    hot = 100          # buckets to n=128 — the serving loop's steady shape
    resolve(hot)
    hot_key = next(iter(tune._resolve_memo))
    # fill to capacity with three more distinct buckets...
    for n in (1000, 10_000, 100_000):
        resolve(n)
    assert len(tune._resolve_memo) == 4
    # ...touch the hot bucket (now the LRU-oldest), then overflow
    resolve(hot)
    resolve(7)                                   # 5th distinct bucket
    assert len(tune._resolve_memo) == 4          # one-at-a-time eviction
    assert hot_key in tune._resolve_memo         # the hit kept it resident
    # the true LRU entry (n=1000 -> the oldest untouched) was the victim
    assert not any("n=1024," in k[1] for k in tune._resolve_memo)


def test_resolve_memo_hit_skips_cache_reload(cache, monkeypatch):
    """Memoized resolutions never reparse the winner cache."""
    monkeypatch.setattr(tune, "_resolve_memo", {})
    want = tune.best_config("adc_scan_topl", "xla", n=100, q=8, topl=16)
    monkeypatch.setattr(tune, "load_cache", lambda refresh=False: (
        pytest.fail("memo hit must not reload the cache")))
    assert tune.best_config("adc_scan_topl", "xla",
                            n=100, q=8, topl=16) == want


def test_resolve_memo_thread_safe_under_churn(cache, monkeypatch):
    """Regression: eviction used ``pop(next(iter(memo)))`` with no lock,
    so a concurrent resolver (serve worker thread + a direct
    ``index.search`` caller) could remove that key between the iter and
    the pop — KeyError on the serving hot path. Hammer the memo past
    capacity from several threads; any exception fails."""
    import random
    import threading

    monkeypatch.setattr(tune, "_MEMO_CAP", 8)
    monkeypatch.setattr(tune, "_resolve_memo", {})
    errors = []

    def churn(seed):
        rng = random.Random(seed)
        try:
            for _ in range(400):
                tune.best_config("adc_scan_topl", "xla",
                                 n=rng.randrange(1, 1 << 20), q=8, topl=16)
        except Exception as exc:             # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=churn, args=(s,)) for s in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(tune._resolve_memo) <= 8


# ---------------------------------------------------------------------------
# sweep driver <-> registry agreement
# ---------------------------------------------------------------------------

def test_sweep_driver_covers_every_sweepable_kernel():
    """Every registry entry with a candidate ladder must have a runner
    and buckets in the driver (a ladder nobody sweeps is dead config),
    and every driver bucket must carry the registered dims."""
    from repro import tune as driver
    sweepable = {k for k, s in tune.KERNELS.items() if s.candidates}
    assert sweepable == set(driver.RUNNERS)
    for table in (driver.QUICK_BUCKETS, driver.FULL_BUCKETS):
        assert set(table) == sweepable
        for key, buckets in table.items():
            for dims in buckets:
                tune.bucket_key(tune.KERNELS[key], dims)   # must not raise


def test_candidate_ladders_only_name_registered_params():
    for key, spec in tune.KERNELS.items():
        assert set(spec.candidates) <= set(spec.params), key
        for values in spec.candidates.values():
            assert all(isinstance(v, int) for v in values), key
