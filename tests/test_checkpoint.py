"""Checkpoint manager: roundtrip, atomicity, retention, elastic restore."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 3)),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32),
                   "c": [jnp.ones((2,)), jnp.zeros((1,), jnp.bfloat16)]},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_pytree(tmp_path / "ck", tree, step=5, metadata={"foo": 1})
    restored, manifest = load_pytree(tmp_path / "ck", tree)
    assert manifest["step"] == 5 and manifest["metadata"]["foo"] == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_structure_mismatch_fails_loudly(tmp_path):
    save_pytree(tmp_path / "ck", _tree())
    other = {"a": jnp.zeros((4, 3)), "renamed": jnp.zeros((7,))}
    with pytest.raises(ValueError, match="structure mismatch"):
        load_pytree(tmp_path / "ck", other)


def test_no_tmp_left_behind_and_overwrite(tmp_path):
    save_pytree(tmp_path / "ck", _tree(0))
    save_pytree(tmp_path / "ck", _tree(1), step=2)
    assert not (tmp_path / "ck.tmp").exists()
    _, manifest = load_pytree(tmp_path / "ck", _tree())
    assert manifest["step"] == 2


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 5, 9, 12):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [9, 12]
    assert mgr.latest_step() == 12


def test_manager_ignores_corrupt_dirs(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(3, _tree())
    (tmp_path / "step_0000000099").mkdir()      # no manifest -> ignored
    assert mgr.latest_step() == 3
    restored = mgr.restore_latest(_tree())
    assert restored is not None


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree(7)
    mgr.save(4, tree, blocking=False)
    mgr.wait()
    restored, manifest = mgr.restore_latest(_tree())
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_restore_with_shapedtypestruct_skeleton(tmp_path):
    tree = _tree(3)
    save_pytree(tmp_path / "ck", tree, step=1)
    skeleton = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, _ = load_pytree(tmp_path / "ck", skeleton)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
