"""The serving layer (``repro.serve``).

  * batched-vs-individual BIT-parity property suite: any mix of requests
    with heterogeneous k / nprobe / filter_mask coalesced into one
    padded bucket returns results bitwise-equal (ties included) to each
    request searched alone — across xla and pallas-interpret, flat and
    IVF (padded AND dispatch stage-1 faces);
  * per-query nprobe vectors on ``IVFIndex.search`` directly (the index-
    layer fan-in the engine rides);
  * scheduler/queue units: EDF deadline ordering, prefix budget, bucket
    selection, drain on shutdown;
  * the warm-up satellite: after ``ServeEngine.warmup`` the serving path
    triggers ZERO fresh XLA compiles (the timed loop can never pay a
    jit), and the cold-compile bill is recorded as its own metric line;
  * the overflow satellite: capacity overflows warn ONCE (rate-limited)
    while the exact count stays observable through the serve metrics.
"""
import threading
import time
import warnings

import numpy as np
import pytest

from repro.index import dispatch as dsp
from repro.serve import (QUERY_BUCKETS, Request, RequestQueue, ServeConfig,
                         ServeEngine, Scheduler, coalesce, k_bucket,
                         query_bucket)

_FLAT_SPEC = "PQ4x32,Rerank50"
_IVF_SPEC = "IVF16,PQ4x32,Rerank50"


def _request_mix(rng, ds, *, ivf: bool, n: int = 6):
    """Heterogeneous submit-kwarg dicts: widths 1-4, k spanning buckets,
    scalar AND per-query-vector nprobe, sparse filter masks."""
    ntotal = ds.base.shape[0]
    reqs = []
    for t in range(n):
        q = int(rng.integers(1, 5))
        r = {"queries": np.asarray(ds.queries[rng.integers(0, 150, q)]),
             "k": int(rng.choice([1, 3, 10, 37]))}
        if ivf and t % 3 == 1:
            r["nprobe"] = int(rng.integers(1, 8))
        if ivf and t % 3 == 2:
            r["nprobe"] = rng.integers(1, 8, size=q)
        if t % 2 == 1:
            r["filter_mask"] = rng.random((q, ntotal)) > 0.3
        reqs.append(r)
    return reqs


def _solo(index, r, **face):
    kw = dict(face)
    if r.get("nprobe") is not None:
        kw["nprobe"] = r["nprobe"]
    if r.get("filter_mask") is not None:
        kw["filter_mask"] = r["filter_mask"]
    d, i = index.search(r["queries"], r["k"], **kw)
    return np.asarray(d), np.asarray(i)


# ---------------------------------------------------------------------------
# batched == individual, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_flat_batched_parity(trained_index_factory, tiny_dataset, backend):
    index = trained_index_factory(_FLAT_SPEC)
    index.backend = backend
    engine = ServeEngine(index, ServeConfig(max_batch_queries=32))
    rng = np.random.default_rng(0)
    reqs = _request_mix(rng, tiny_dataset, ivf=False)
    got = engine.search_requests(reqs)
    for r, (d, i) in zip(reqs, got):
        d_ref, i_ref = _solo(index, r)
        np.testing.assert_array_equal(d, d_ref, err_msg=f"{backend} d")
        np.testing.assert_array_equal(i, i_ref, err_msg=f"{backend} i")


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("face", [False, True],
                         ids=["padded", "dispatch"])
def test_ivf_batched_parity(trained_index_factory, tiny_dataset, backend,
                            face):
    index = trained_index_factory(_IVF_SPEC)
    index.backend = backend
    engine = ServeEngine(index, ServeConfig(max_batch_queries=32,
                                            use_dispatch=face))
    rng = np.random.default_rng(1)
    reqs = _request_mix(rng, tiny_dataset, ivf=True)
    got = engine.search_requests(reqs)
    for r, (d, i) in zip(reqs, got):
        d_ref, i_ref = _solo(index, r, use_dispatch=face)
        np.testing.assert_array_equal(
            d, d_ref, err_msg=f"{backend} dispatch={face} d")
        np.testing.assert_array_equal(
            i, i_ref, err_msg=f"{backend} dispatch={face} i")


def test_async_submit_matches_solo(trained_index_factory, tiny_dataset):
    """The queue/worker path (not just search_requests) delivers the
    same bits, through futures, with deadline accounting."""
    index = trained_index_factory(_IVF_SPEC)
    engine = ServeEngine(index, ServeConfig(max_batch_queries=16,
                                            linger_ms=1.0))
    rng = np.random.default_rng(2)
    reqs = _request_mix(rng, tiny_dataset, ivf=True, n=8)
    futures = [engine.submit(**r, deadline_ms=60_000.0) for r in reqs]
    for r, f in zip(reqs, futures):
        d, i = f.result(timeout=120)
        d_ref, i_ref = _solo(index, r)
        np.testing.assert_array_equal(d, d_ref)
        np.testing.assert_array_equal(i, i_ref)
    engine.close()
    s = engine.metrics.summary()
    assert s["requests"] == len(reqs)
    assert s["deadline_total"] == len(reqs)
    assert s["deadline_misses"] == 0


def test_per_query_nprobe_vector_on_index(trained_index_factory,
                                          tiny_dataset):
    """(Q,) nprobe on IVFIndex.search directly: row i bit-equal to a solo
    search at nprobe[i], on both stage-1 faces."""
    index = trained_index_factory(_IVF_SPEC)
    q = np.asarray(tiny_dataset.queries[:5])
    lens = np.array([1, 4, 2, 7, 3], dtype=np.int32)
    for face in (False, True):
        d_b, i_b = index.search(q, 10, nprobe=lens, use_dispatch=face)
        d_b, i_b = np.asarray(d_b), np.asarray(i_b)
        for r in range(5):
            d_s, i_s = index.search(q[r:r + 1], 10, nprobe=int(lens[r]),
                                    use_dispatch=face)
            np.testing.assert_array_equal(d_b[r], np.asarray(d_s)[0],
                                          err_msg=f"dispatch={face} r={r}")
            np.testing.assert_array_equal(i_b[r], np.asarray(i_s)[0],
                                          err_msg=f"dispatch={face} r={r}")
    with pytest.raises(ValueError, match="per-query nprobe"):
        index.search(q, 10, nprobe=np.array([1, 2]))


# ---------------------------------------------------------------------------
# bucketing / coalescing units
# ---------------------------------------------------------------------------

def test_bucket_selection():
    assert query_bucket(1) == 8
    assert query_bucket(8) == 8
    assert query_bucket(9) == 16
    assert query_bucket(QUERY_BUCKETS[-1]) == QUERY_BUCKETS[-1]
    with pytest.raises(ValueError, match="largest query bucket"):
        query_bucket(QUERY_BUCKETS[-1] + 1)
    assert k_bucket(1) == 1
    assert k_bucket(10) == 16
    assert k_bucket(16) == 16


def _req(q, k, **kw):
    return Request(queries=np.zeros((q, 4), np.float32), k=k, **kw)


def test_coalesce_shapes_and_defaults():
    batch = coalesce([_req(3, 10), _req(2, 37)], ntotal=100,
                     default_nprobe=8)
    assert batch.bucket == 8 and batch.spans == ((0, 3), (3, 5))
    assert batch.k_eff == 64                  # pow2 of max k
    assert batch.nprobe is None               # nobody pinned one
    assert batch.filter_mask is None          # nobody masked
    assert batch.num_pad == 3


def test_coalesce_nprobe_vector_and_mask_rows():
    reqs = [_req(2, 5, nprobe=3),
            _req(1, 5, filter_mask=np.zeros((1, 100), bool)),
            _req(2, 5, nprobe=np.array([1, 7]))]
    batch = coalesce(reqs, ntotal=100, default_nprobe=8)
    # nprobe: pinned 3,3 | default 8 | vector 1,7 | pads 1
    np.testing.assert_array_equal(batch.nprobe,
                                  [3, 3, 8, 1, 7, 1, 1, 1])
    # mask: maskless requests get all-True rows, pads all-False
    assert batch.filter_mask.shape == (8, 100)
    assert batch.filter_mask[:2].all()        # maskless request rows
    assert not batch.filter_mask[2].any()     # the request's own mask
    assert batch.filter_mask[3:5].all()       # maskless request rows
    assert not batch.filter_mask[5:].any()    # pad rows


def test_coalesce_uniform_nprobe_collapses_to_scalar():
    batch = coalesce([_req(4, 5, nprobe=6), _req(4, 5, nprobe=6)],
                     ntotal=100, default_nprobe=8)
    assert batch.nprobe == 6 and isinstance(batch.nprobe, int)


# ---------------------------------------------------------------------------
# queue / scheduler
# ---------------------------------------------------------------------------

def test_queue_edf_ordering_and_prefix_budget():
    q = RequestQueue()
    best_effort = q.submit(_req(2, 5))
    late = q.submit(_req(2, 5, deadline_ms=500.0))
    early = q.submit(_req(2, 5, deadline_ms=10.0))
    taken = q.take(4, block=False)
    # earliest deadline first; the budget (4 rows) cuts after two
    assert taken == [early, late]
    assert q.take(4, block=False) == [best_effort]


def test_queue_fifo_tie_break_and_oversize_head():
    q = RequestQueue()
    a, b = q.submit(_req(3, 5)), q.submit(_req(3, 5))
    assert q.take(2, block=False) == [a]   # head always pops, FIFO order
    assert q.take(2, block=False) == [b]


def test_queue_strict_budget_refuses_oversize_head():
    """The refill mode: a head wider than the remaining budget stays
    queued instead of being popped past it."""
    q = RequestQueue()
    wide = q.submit(_req(3, 5))
    assert q.take(2, block=False, strict_budget=True) == []
    assert len(q) == 1                     # left queued, not dropped
    assert q.take(2, block=True, timeout=0.05, strict_budget=True) == []
    assert q.take(3, block=False, strict_budget=True) == [wide]


def test_queue_drain_on_shutdown():
    q = RequestQueue()
    q.submit(_req(1, 5))
    q.close()
    with pytest.raises(RuntimeError, match="closed"):
        q.submit(_req(1, 5))
    assert not q.drained()                 # one item still pending
    assert len(q.take(8, block=True)) == 1 # drains without blocking
    assert q.drained()
    assert q.take(8, block=True) == []     # closed+empty: returns, no hang


def test_scheduler_lingers_for_followers():
    q = RequestQueue()
    sched = Scheduler(q, max_batch_queries=8, linger_ms=200.0)
    q.submit(_req(2, 5))
    t = threading.Timer(0.02, lambda: q.submit(_req(2, 5)))
    t.start()
    items = sched.next_items()
    t.join()
    assert len(items) == 2                 # the follower made the batch


def test_scheduler_refill_never_overfills_batch():
    """Regression: a request wider than the remaining budget arriving
    during the linger window used to be popped anyway, pushing the
    group past max_batch_queries — at the top bucket rung that fails
    the WHOLE group in coalesce (ValueError), and below it the batch
    lands on an un-warmed bucket. The refill must leave it queued to
    lead the next batch."""
    q = RequestQueue()
    sched = Scheduler(q, max_batch_queries=4, linger_ms=200.0)
    q.submit(_req(2, 5))
    t = threading.Timer(0.02, lambda: q.submit(_req(3, 5)))
    t.start()
    items = sched.next_items()
    t.join()
    assert [r.num_queries for r in items] == [2]
    assert sum(r.num_queries for r in items) <= 4
    assert [r.num_queries for r in sched.next_items()] == [3]


def test_scheduler_interrupt_cuts_linger():
    """The engine arms ``interrupt`` while a launched batch is in
    flight: the moment it reports ready, the linger is cut so fan-out
    is never delayed by the coalescing window."""
    q = RequestQueue()
    sched = Scheduler(q, max_batch_queries=8, linger_ms=500.0)
    q.submit(_req(2, 5))
    t0 = time.perf_counter()
    items = sched.next_items(interrupt=lambda: True)
    assert len(items) == 1
    assert time.perf_counter() - t0 < 0.25  # did not sit out the 500ms

    # a False interrupt still coalesces followers across poll slices
    sched = Scheduler(q, max_batch_queries=4, linger_ms=200.0)
    q.submit(_req(2, 5))
    t = threading.Timer(0.02, lambda: q.submit(_req(2, 5)))
    t.start()
    items = sched.next_items(interrupt=lambda: False)
    t.join()
    assert len(items) == 2


def test_scheduler_tight_deadline_cuts_immediately():
    q = RequestQueue()
    sched = Scheduler(q, max_batch_queries=8, linger_ms=500.0)
    sched.observe_service(5.0)
    q.submit(_req(2, 5, deadline_ms=1.0))  # no slack for lingering
    t0 = time.perf_counter()
    items = sched.next_items()
    assert len(items) == 1
    assert time.perf_counter() - t0 < 0.25 # did not sit out the 500ms


def test_engine_close_drains_pending(trained_index_factory, tiny_dataset):
    index = trained_index_factory(_FLAT_SPEC)
    engine = ServeEngine(index, ServeConfig(max_batch_queries=16))
    futures = [engine.submit(np.asarray(tiny_dataset.queries[:2]), k=5)
               for _ in range(5)]
    engine.close(drain=True)
    assert all(f.done() for f in futures)
    assert all(f.exception() is None for f in futures)


# ---------------------------------------------------------------------------
# the warm-up satellite: timed serving never pays a compile
# ---------------------------------------------------------------------------

def test_warmup_excludes_compile_from_serving(trained_index_factory,
                                              tiny_dataset):
    """After warming one batch per shape bucket, the serving path
    triggers ZERO fresh XLA compiles — so latency percentiles measure
    search, never jit. Flat index on purpose: IVF's probe-plan width
    varies with probe content, which is exactly why the engine pins the
    (Q bucket, k bucket) ladder on the shapes it CAN pin."""
    from repro.analysis.compilecount import count_compiles
    index = trained_index_factory(_FLAT_SPEC)
    engine = ServeEngine(index, ServeConfig(max_batch_queries=16,
                                            default_k=10))
    cold = engine.warmup(buckets=(8, 16), ks=(10,))
    assert set(cold) == {"q8_k16", "q16_k16"}
    assert all(ms > 0 for ms in cold.values())
    assert engine.metrics.cold_compile_ms == cold   # its own metric line

    rng = np.random.default_rng(3)
    with count_compiles() as log:
        for lo in (0, 6):     # two groups, both landing in warmed buckets
            reqs = [{"queries":
                     np.asarray(tiny_dataset.queries[lo + 2 * j:
                                                     lo + 2 * j + 2]),
                     "k": int(rng.integers(9, 17))} for j in range(3)]
            got = engine.search_requests(reqs)
        assert len(got) == 3
    assert log.count == 0, f"fresh compiles in timed path: {log.names()}"


def test_warmup_variants_cover_masked_and_vector_nprobe(
        trained_index_factory, tiny_dataset):
    """The base warm-up covers maskless default-nprobe programs only; a
    filter_mask adds a (Q, ntotal) operand, so masked traffic traces a
    DIFFERENT program. warmup(masks=True) pre-pays that compile too —
    the first masked request per bucket must not jit inside the timed
    path. Vector-nprobe warm-up is the IVF-only analogue."""
    from repro.analysis.compilecount import count_compiles
    index = trained_index_factory(_FLAT_SPEC)
    engine = ServeEngine(index, ServeConfig(max_batch_queries=8,
                                            default_k=10))
    cold = engine.warmup(buckets=(8,), ks=(10,), masks=True)
    assert set(cold) == {"q8_k16", "q8_k16_masked"}
    rng = np.random.default_rng(5)
    with count_compiles() as log:
        engine.search_requests(
            [{"queries": np.asarray(tiny_dataset.queries[:2]), "k": 10,
              "filter_mask": rng.random((2, index.ntotal)) > 0.3}])
    assert log.count == 0, f"masked path compiled: {log.names()}"

    with pytest.raises(ValueError, match="IVF-backed"):
        engine.warmup(buckets=(8,), nprobe_vectors=True)
    ivf = trained_index_factory(_IVF_SPEC)
    ivf_engine = ServeEngine(ivf, ServeConfig(max_batch_queries=8,
                                              default_k=10))
    cold = ivf_engine.warmup(buckets=(8,), ks=(10,), masks=True,
                             nprobe_vectors=True)
    assert set(cold) == {"q8_k16", "q8_k16_masked", "q8_k16_vnprobe"}
    # the vnprobe zeros-batch must have exercised the REAL vector path
    # (a uniform vector collapses to its scalar and warms nothing new)
    got = ivf_engine.search_requests(
        [{"queries": np.asarray(tiny_dataset.queries[:3]), "k": 10,
          "nprobe": np.array([2, 5, 3])}])
    assert got[0][0].shape == (3, 10)


# ---------------------------------------------------------------------------
# the overflow satellite: one warning, exact counter
# ---------------------------------------------------------------------------

def test_overflow_warns_once_and_counts(trained_index_factory,
                                        tiny_dataset):
    index = trained_index_factory(_IVF_SPEC)
    engine = ServeEngine(index, ServeConfig(max_batch_queries=16,
                                            use_dispatch=True,
                                            dispatch_capacity=1e-6))
    dsp.OVERFLOWS.reset()
    engine.metrics.reset()     # capture the overflow base AFTER the reset
    q = np.asarray(tiny_dataset.queries[:4])
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(5):
            engine.search_requests([{"queries": q, "k": 5}])
    assert len(rec) == 1                   # rate-limited: first only
    assert "overflow" in str(rec[0].message)
    assert engine.metrics.dispatch_overflows == 5   # exact count survives
    # the loud fallback stays correct: results equal the padded face
    d, i = engine.search_requests([{"queries": q, "k": 5}])[0]
    ref = ServeEngine(index, ServeConfig(max_batch_queries=16,
                                         use_dispatch=False))
    d_ref, i_ref = ref.search_requests([{"queries": q, "k": 5}])[0]
    np.testing.assert_array_equal(d, d_ref)
    np.testing.assert_array_equal(i, i_ref)


def test_overflow_meter_periodic_summary():
    meter = dsp.OverflowMeter(warn_every=3)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(7):
            meter.record("cap blown")
    assert meter.count == 7
    assert len(rec) == 3                   # 1st, 4th, 7th
    assert "3 dispatch capacity overflows" in str(rec[1].message)
