"""MoE dispatch correctness + balance losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models import moe


def _cfg(**kw):
    base = dict(moe=True, num_experts=4, top_k=2, moe_d_ff=16, d_model=8,
                num_shared_experts=0, capacity_factor=4.0, d_ff=16,
                compute_dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


def test_dropless_dispatch_matches_dense_reference():
    """With capacity >= n*k the sort-based dispatch must equal the dense
    per-token mixture computed directly."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    out, _ = moe.moe_block(p, cfg, x)

    # dense reference: route, then run every token through its experts
    x_flat = np.asarray(x).reshape(-1, cfg.d_model)
    logits = x_flat @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    order = np.argsort(-probs, axis=-1)[:, :cfg.top_k]
    ref = np.zeros_like(x_flat)
    for i, tok in enumerate(x_flat):
        gates = probs[i, order[i]]
        gates = gates / gates.sum()
        for gate, eidx in zip(gates, order[i]):
            h_g = np.maximum(tok @ np.asarray(p["w_gate"][eidx]), 0) * \
                jax.nn.sigmoid(tok @ np.asarray(p["w_gate"][eidx]))
            # silu(x) = x*sigmoid(x); recompute properly:
            z = tok @ np.asarray(p["w_gate"][eidx])
            h_g = z / (1 + np.exp(-z))
            h_u = tok @ np.asarray(p["w_up"][eidx])
            ref[i] += gate * ((h_g * h_u) @ np.asarray(p["w_down"][eidx]))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               ref, rtol=2e-4, atol=2e-4)


def test_capacity_drops_bounded():
    """With cf=0 (degenerate) capacity floors at min_capacity and the
    output stays finite; dropped tokens contribute zero, not garbage."""
    cfg = _cfg(capacity_factor=0.01, min_capacity=1)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg.d_model))
    out, _ = moe.moe_block(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_balance_losses():
    cfg = _cfg()
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, cfg.d_model))
    gates, idx, cv2 = moe.route(p, cfg, x.reshape(-1, cfg.d_model))
    assert gates.shape == (32, 2) and idx.shape == (32, 2)
    np.testing.assert_allclose(np.asarray(gates).sum(-1), 1.0, rtol=1e-5)
    assert float(cv2) >= 0

    # switch-style balance on the same routing
    cfg_sw = _cfg(router_balance="switch")
    _, _, sw = moe.route(p, cfg_sw, x.reshape(-1, cfg.d_model))
    assert float(sw) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz at optimum


def test_shared_experts_add_dense_path():
    cfg0 = _cfg(num_shared_experts=0)
    cfg2 = _cfg(num_shared_experts=2)
    p2 = moe.init_moe(jax.random.PRNGKey(0), cfg2)
    assert "shared" in p2
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 4, cfg2.d_model))
    out2, _ = moe.moe_block(p2, cfg2, x)
    # zeroing shared-expert output weights removes their contribution
    p_zero = jax.tree_util.tree_map(lambda a: a, p2)
    p_zero = {**p2, "shared": {**p2["shared"],
                               "w_down": jnp.zeros_like(p2["shared"]["w_down"])}}
    out0, _ = moe.moe_block(p_zero, cfg2, x)
    assert float(jnp.max(jnp.abs(out2 - out0))) > 0


def test_gradients_flow_to_router_and_experts():
    cfg = _cfg()
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 4, cfg.d_model))

    def loss(p):
        out, bal = moe.moe_block(p, cfg, x)
        return jnp.sum(jnp.square(out)) + 0.01 * bal

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_down"]))) > 0
