"""HLO analyzer: scan-scaled flops/bytes/collectives (the roofline source)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo


def test_scan_flops_scale_with_trip_count():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def flops(n_layers):
        w = jax.ShapeDtypeStruct((n_layers, 128, 128), jnp.float32)
        text = jax.jit(f).lower(x, w).compile().as_text()
        return hlo.executed_cost(text)["flops"]

    per_layer = 2 * 64 * 128 * 128
    np.testing.assert_allclose(flops(4), 4 * per_layer, rtol=1e-6)
    np.testing.assert_allclose(flops(16), 16 * per_layer, rtol=1e-6)


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, w)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    text = jax.jit(f).lower(x, w).compile().as_text()
    got = hlo.executed_cost(text)["flops"]
    np.testing.assert_allclose(got, 5 * 3 * 2 * 32 * 64 * 64, rtol=1e-6)


def test_collective_bytes_parsed_from_handcrafted_hlo():
    text = """
HloModule test

ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = f32[16,128]{1,0} all-reduce(%p0), to_apply=%add
  %rs = f32[4,128]{1,0} reduce-scatter(%p0), to_apply=%add
  ROOT %out = f32[16,128]{1,0} add(%ar, %ar)
}
"""
    stats = hlo.collective_bytes(text)
    assert stats["per_kind_bytes"]["all-gather"] == 64 * 128 * 4
    assert stats["per_kind_bytes"]["all-reduce"] == 16 * 128 * 4
    assert stats["per_kind_bytes"]["reduce-scatter"] == 4 * 128 * 4
    assert stats["counts"]["all-gather"] == 1


def test_collectives_inside_while_scale():
    text = """
HloModule test

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]{1,0}) parameter(0)
  %gte = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[8,8]{1,0} all-reduce(%gte), to_apply=%add
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%gte, %ar)
}

%cond (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %init = (s32[], f32[8,8]{1,0}) tuple(%p, %p)
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    stats = hlo.collective_bytes(text)
    assert stats["per_kind_bytes"]["all-reduce"] == 7 * 8 * 8 * 4
    assert stats["counts"]["all-reduce"] == 7


def test_dtype_bytes_table():
    text = """
HloModule t

ENTRY %main (p: bf16[4,4]) -> bf16[4,4] {
  %p = bf16[4,4]{1,0} parameter(0)
  ROOT %ag = bf16[8,4]{1,0} all-gather(%p), dimensions={0}
}
"""
    stats = hlo.collective_bytes(text)
    assert stats["per_kind_bytes"]["all-gather"] == 8 * 4 * 2


def test_unparsed_lines_are_counted_not_silently_skipped():
    """Satellite: op lines matching no parser regex used to vanish from
    the accounting — now they are counted and sampled."""
    text = """
HloModule t

ENTRY %main (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
  this line is not an instruction at all
  ROOT %r = f32[4,4]{1,0} add(%p, %p)
}
"""
    stats = hlo.executed_cost(text)
    assert stats["unparsed_lines"] == 1
    comp, lineno, snippet = stats["unparsed_sample"][0]
    assert comp == "main" and "not an instruction" in snippet
    # clean module -> zero
    clean = text.replace("  this line is not an instruction at all\n", "")
    assert hlo.executed_cost(clean)["unparsed_lines"] == 0


def test_narrow_dtype_bytes():
    """Sub-byte ints bill at their packed width; fnuz float8 at 1 byte."""
    text = """
HloModule t

ENTRY %main (p: s2[64,128]) -> s2[128,128] {
  %p = s2[64,128]{1,0} parameter(0)
  %f = f8e4m3fnuz[64,128]{1,0} all-reduce(%p), to_apply=%add
  ROOT %ag = s2[128,128]{1,0} all-gather(%p), dimensions={0}
}
"""
    stats = hlo.collective_bytes(text)
    assert stats["per_kind_bytes"]["all-gather"] == 128 * 128 * 0.25
    assert stats["per_kind_bytes"]["all-reduce"] == 64 * 128 * 1
    assert hlo.executed_cost(text)["unknown_dtypes"] == []


def test_unknown_dtypes_surface():
    text = """
HloModule t

ENTRY %main (p: zz9[8,8]) -> zz9[8,8] {
  %p = zz9[8,8]{1,0} parameter(0)
  ROOT %r = zz9[8,8]{1,0} add(%p, %p)
}
"""
    assert hlo.executed_cost(text)["unknown_dtypes"] == ["zz9"]


def test_peak_buffer_bytes_excludes_passthrough():
    """Peak reports the largest COMPUTE-op result; parameters and tuple
    plumbing route existing buffers and do not count."""
    text = """
HloModule t

ENTRY %main (p: f32[256,256]) -> f32[64,64] {
  %p = f32[256,256]{1,0} parameter(0)
  %t = (f32[256,256]{1,0}) tuple(%p)
  %g = f32[256,256]{1,0} get-tuple-element(%t), index=0
  %s = f32[64,64]{1,0} slice(%g), slice={[0:64], [0:64]}
  ROOT %b = f32[64,64]{1,0} add(%s, %s)
}
"""
    stats = hlo.executed_cost(text)
    assert stats["peak_buffer_bytes"] == 64 * 64 * 4


def test_iter_ops_yields_instructions():
    text = """
HloModule t

ENTRY %main (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
  ROOT %r = f32[4,4]{1,0} add(%p, %p)
}
"""
    ops = list(hlo.iter_ops(text))
    assert [(o.comp, o.op) for o in ops] == [("main", "parameter"),
                                             ("main", "add")]
    assert ops[1].name == "r" and "f32[4,4]" in ops[1].shape


def test_bytes_scale_with_scan():
    """Executed bytes must scale with the scan trip count (the whole point
    of the analyzer vs cost_analysis(), which counts the body once).

    The expected total is NOT hardcoded: how XLA lays out the loop decides
    whether per-iteration bytes are constant (body reads one weight slice)
    or grow with n (a fused consumer re-reads the stacked operand), i.e.
    bytes(n) = a + b*n + c*n^2 with coefficients owned by the compiler.
    So the scaling law is recomputed from the compiled HLO at three small
    trip counts and must then PREDICT a held-out larger one."""
    def f(x, w):
        def body(c, wi):
            return c * wi, None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def nbytes(n):
        w = jax.ShapeDtypeStruct((n, 256, 256), jnp.float32)
        text = jax.jit(f).lower(x, w).compile().as_text()
        return hlo.executed_cost(text)["bytes"]

    ns = np.array([2.0, 4.0, 8.0])
    bs = np.array([nbytes(int(n)) for n in ns])
    # fit bytes(n) = a + b*n + c*n^2 through the three measurements...
    coeffs = np.linalg.solve(np.vander(ns, 3, increasing=True), bs)
    # ...and require it to predict the held-out trip count:
    predicted = coeffs @ np.array([1.0, 16.0, 16.0 ** 2])
    b16 = nbytes(16)
    np.testing.assert_allclose(b16, predicted, rtol=0.02)
    # and the scan must actually be scaled: 2x the trips -> >=~2x the bytes
    # (a body-counted-once analyzer would report a ratio near 1)
    assert b16 / nbytes(8) > 1.8
