"""Quantized-LUT fast path (``kernels/lut_quant.py`` + the ``lut_dtype``
/ ``overfetch`` threading through ops -> candidates -> Index.search):

  * quantization scheme invariants (pow2 int8 scales -> exact dequant);
  * pool parity: both impls select bit-identically to the ``*_q_ref``
    oracles for every face and dtype;
  * full-pool identity: with the pool covering the population, the
    quantized path is BITWISE the exact path (scan order, re-score
    composition and tie handling all collapse to the exact semantics);
  * the recall floor the module docstring advertises: quantized pool +
    exact re-score keeps recall@L >= 0.999 at overfetch=2;
  * loud rejection everywhere a quantized request cannot be exact-ified
    (materialized generator, onehot backend, dispatch without pos).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.index import UNQIndex
from repro.index.candidates import MaterializedTopL
from repro.kernels import lut_quant, ops, ref

_IMAX = np.iinfo(np.int32).max


def _recall(got_ids, want_ids):
    got, want = np.asarray(got_ids), np.asarray(want_ids)
    return np.mean([len(set(got[q]) & set(want[q])) / want.shape[1]
                    for q in range(want.shape[0])])


# ---------------------------------------------------------------------------
# quantization scheme
# ---------------------------------------------------------------------------

def test_quantize_luts_shapes_and_pow2_scales():
    rng = np.random.default_rng(0)
    luts = jnp.asarray(rng.standard_normal((5, 8, 64)).astype(np.float32))
    f16, scale16 = lut_quant.quantize_luts(luts, "float16")
    assert f16.dtype == jnp.float16 and scale16 is None
    q8, scale = lut_quant.quantize_luts(luts, "int8")
    assert q8.dtype == jnp.int8 and scale.shape == (5, 8)
    assert int(jnp.max(jnp.abs(q8.astype(jnp.int32)))) <= 127
    # scales are powers of two: mantissa exactly 0.5 -> f32(q8) * scale
    # is exact, which is what makes the i8 chain FMA-contraction-immune
    m, _ = np.frexp(np.asarray(scale))
    np.testing.assert_array_equal(m, np.full_like(m, 0.5))
    # f32 passthrough + unknown dtype rejection
    same, none = lut_quant.quantize_luts(luts, "float32")
    assert same is luts and none is None
    with pytest.raises(ValueError, match="lut_dtype"):
        lut_quant.check_lut_dtype("bf16")


def test_pool_width_semantics():
    assert lut_quant.pool_width(10, 2, 1000) == 20
    assert lut_quant.pool_width(10, 200, 64) == 64      # clamped to pop.
    assert lut_quant.pool_width(10, 1, 1000) == 10
    with pytest.raises(ValueError, match="overfetch"):
        lut_quant.pool_width(10, 0, 1000)


def test_exact_topl_tie_contract():
    s = jnp.asarray([[2.0, 1.0, 1.0, 3.0]])
    g = jnp.asarray([[7, 9, 4, 1]], dtype=jnp.int32)
    ts, tg = lut_quant.exact_topl(s, g, 3)
    np.testing.assert_array_equal(np.asarray(ts), [[1.0, 1.0, 2.0]])
    np.testing.assert_array_equal(np.asarray(tg), [[4, 9, 7]])


# ---------------------------------------------------------------------------
# pool parity vs the *_q_ref oracles (both impls, both dtypes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("lut_dtype", ["float16", "int8"])
def test_flat_pool_matches_q_ref(scan_case, impl, lut_dtype):
    rng = np.random.default_rng(3)
    n, q, L = 700, 5, 33
    codes, luts = scan_case(rng, n, m=8, k=32, q=q, tie_heavy=True)
    bias = jnp.asarray(rng.integers(0, 3, (n,)), jnp.float32)
    qluts, scale = lut_quant.quantize_luts(luts, lut_dtype)
    want = ref.adc_scan_topl_q_ref(codes, qluts, scale, bias, L)
    got = ops._scan_topl_run(codes, qluts, scale, bias, None, topl=L,
                             impl=impl, block_n=128, block_q=8, chunk_n=96)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("lut_dtype", ["float16", "int8"])
def test_gather_pool_matches_q_ref(scan_case, impl, lut_dtype):
    rng = np.random.default_rng(4)
    n, q, w_max, L = 600, 4, 200, 25
    codes, luts = scan_case(rng, n, m=4, k=32, q=q, tie_heavy=True)
    rows = np.zeros((q, w_max), np.int32)
    gids = np.full((q, w_max), _IMAX, np.int32)
    for qi in range(q):
        w = rng.integers(L, w_max)
        sel = np.sort(rng.choice(n, size=w, replace=False)).astype(np.int32)
        rows[qi, :w], gids[qi, :w] = sel, sel
    rows, gids = jnp.asarray(rows), jnp.asarray(gids)
    rowbias = jnp.asarray(rng.integers(0, 2, (q, w_max)), jnp.float32)
    qluts, scale = lut_quant.quantize_luts(luts, lut_dtype)
    want = ref.adc_gather_topl_q_ref(codes, rows, gids, qluts, scale,
                                     rowbias, L)
    got = ops._gather_topl_run(codes, rows, gids, qluts, scale, rowbias,
                               topl=L, impl=impl, block_w=64, block_q=8,
                               chunk_w=48)
    for w_, g_ in zip(want, got):
        np.testing.assert_array_equal(np.asarray(g_), np.asarray(w_))


# ---------------------------------------------------------------------------
# full-pool identity + the recall floor (flat + gathered)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("lut_dtype", ["float16", "int8"])
def test_full_pool_is_bitwise_exact_path(scan_case, impl, lut_dtype):
    """With overfetch covering the whole population the pool is every
    candidate, so the exact re-score + lexicographic top-L must reproduce
    the exact path BIT FOR BIT — scores, ids, ties, +inf filters."""
    rng = np.random.default_rng(5)
    n, q, L = 500, 6, 29
    codes, luts = scan_case(rng, n, m=8, k=16, q=q, tie_heavy=True)
    bias = jnp.asarray(rng.integers(0, 2, (n,)), jnp.float32)
    qbias = jnp.where(jnp.asarray(rng.random((q, n))) < 0.05,
                      jnp.inf, 0.0).astype(jnp.float32)
    want = ops.adc_scan_topl(codes, luts, topl=L, bias=bias, qbias=qbias,
                             impl=impl)
    got = ops.adc_scan_topl(codes, luts, topl=L, bias=bias, qbias=qbias,
                            impl=impl, lut_dtype=lut_dtype, overfetch=n)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@settings(max_examples=10, deadline=None)
@given(
    lut_dtype=st.sampled_from(["float16", "int8"]),
    impl=st.sampled_from(["xla", "pallas"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_quantized_recall_floor(scan_case, lut_dtype, impl, seed):
    """The advertised contract: quantized pool selection + exact re-score
    keeps recall@L >= 0.999 at overfetch=2 (the lut_quant module doc and
    the bench rows both cite this bound)."""
    rng = np.random.default_rng(seed)
    n, L = 2048, 64
    q = int(rng.integers(3, 9))
    codes, luts = scan_case(rng, n, m=8, k=32, q=q,
                            tie_heavy=bool(rng.integers(0, 2)))
    _, want_i = ops.adc_scan_topl(codes, luts, topl=L, impl=impl)
    _, got_i = ops.adc_scan_topl(codes, luts, topl=L, impl=impl,
                                 lut_dtype=lut_dtype, overfetch=2)
    assert _recall(got_i, want_i) >= 0.999, (impl, lut_dtype)


@pytest.mark.parametrize("lut_dtype", ["float16", "int8"])
def test_overfetch_alone_is_bitwise_noop(scan_case, lut_dtype):
    """overfetch > 1 with lut_dtype='float32' (and the quantized modes at
    overfetch=1) still go through pool+re-score — but with f32 tables the
    pool order IS the exact order, so results stay bitwise identical."""
    rng = np.random.default_rng(6)
    codes, luts = scan_case(rng, 400, m=4, k=16, q=4, tie_heavy=True)
    want = ops.adc_scan_topl(codes, luts, topl=21, impl="xla")
    overfetched = ops.adc_scan_topl(codes, luts, topl=21, impl="xla",
                                    overfetch=3)
    for w, g in zip(want, overfetched):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# dispatch face: full-pool identity through pool combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("lut_dtype", ["float16", "int8"])
def test_dispatch_quantized_full_pool_and_pos_requirement(scan_case, impl,
                                                          lut_dtype):
    from repro.index.dispatch import build_dispatch
    rng = np.random.default_rng(7)
    nlist, P, q, topl = 10, 4, 8, 17
    sizes = rng.integers(10, 120, size=nlist)
    offsets = np.zeros(nlist + 1, np.int64)
    offsets[1:] = np.cumsum(sizes)
    n = int(offsets[-1])
    codes, luts = scan_case(rng, n, m=8, k=16, q=q, tie_heavy=True)
    gids = np.sort(rng.choice(3 * n, size=n, replace=False)).astype(np.int32)
    pos = np.zeros(int(gids.max()) + 1, np.int32)
    pos[gids] = np.arange(n, dtype=np.int32)
    probe = np.stack([rng.choice(nlist, size=P, replace=False)
                      for _ in range(q)]).astype(np.int32)
    routing, _ = build_dispatch(probe, offsets, chunk=64)
    cap = routing.plan.qidx.shape[1]
    cellterm = jnp.asarray(rng.integers(0, 2, (routing.cell_of.shape[0],
                                               cap)), jnp.float32)
    rowbias = jnp.asarray(rng.integers(0, 2, (n,)), jnp.float32)

    want = ops.adc_dispatch_topl(codes, jnp.asarray(gids), rowbias, luts,
                                 cellterm, routing.plan, topl=topl,
                                 impl=impl, chunk=routing.chunk)
    got = ops.adc_dispatch_topl(codes, jnp.asarray(gids), rowbias, luts,
                                cellterm, routing.plan, topl=topl,
                                impl=impl, chunk=routing.chunk,
                                pos=jnp.asarray(pos), lut_dtype=lut_dtype,
                                overfetch=n)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    # a quantized dispatch without the gid->row inverse cannot re-score
    with pytest.raises(ValueError, match="pos"):
        ops.adc_dispatch_topl(codes, jnp.asarray(gids), rowbias, luts,
                              cellterm, routing.plan, topl=topl, impl=impl,
                              chunk=routing.chunk, lut_dtype=lut_dtype,
                              overfetch=2)


# ---------------------------------------------------------------------------
# index surface: capability gate + end-to-end quantized search
# ---------------------------------------------------------------------------

def test_materialized_generator_rejects_quantized_requests(scan_case):
    rng = np.random.default_rng(8)
    codes, luts = scan_case(rng, 100, m=4, k=16, q=2, tie_heavy=False)
    gen = MaterializedTopL("onehot")
    with pytest.raises(ValueError, match="quantized"):
        gen.topl(codes, luts, None, topl=5, lut_dtype="float16")


def test_index_backend_gate_and_end_to_end_quantized_search(tiny_unq,
                                                            tiny_dataset):
    cfg, params, state, _ = tiny_unq
    queries = jnp.asarray(tiny_dataset.queries)[:32]
    index = UNQIndex.from_trained(params, state, cfg, rerank=0,
                                  backend="xla").add(tiny_dataset.base)
    _, want = index.search(queries, 32)
    # huge overfetch -> pool covers the base -> bitwise-identical ranking
    _, full = index.search(queries, 32, lut_dtype="float16",
                           overfetch=tiny_dataset.base.shape[0])
    np.testing.assert_array_equal(np.asarray(full), np.asarray(want))
    # the advertised operating point
    _, got = index.search(queries, 32, lut_dtype="float16", overfetch=2)
    assert _recall(got, want) >= 0.999
    # f32 default stays the untouched exact path
    _, dflt = index.search(queries, 32)
    np.testing.assert_array_equal(np.asarray(dflt), np.asarray(want))

    index.backend = "onehot"
    with pytest.raises(ValueError, match="quantized_lut"):
        index.search(queries, 8, lut_dtype="int8", overfetch=2)
