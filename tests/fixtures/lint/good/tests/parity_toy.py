"""Parity harness fixture: references the oracle AND the pallas path.

(Named parity_*.py, not test_*.py, so the real pytest run never collects
fixture code.)
"""
from kernels.ref import toy_add_ref          # noqa: F401
from kernels.toy import toy_add_pallas       # the pallas kernel under test


def check_parity(x, y):
    assert (toy_add_pallas(x, y) == toy_add_ref(x, y)).all()
