"""Known-good fixture: kernel dispatch resolving block parameters through
the autotuner registry (tuned-block-params rule must stay silent)."""

from repro.kernels import tune  # noqa: F401  (fixture import shape only)


def toy_scan_pallas(codes, *, block_n, interpret=True):
    return codes


def toy_scan(codes, *, block_n=None):
    cfg = tune.best_config("toy_scan", "pallas", n=codes.shape[0])
    bn = cfg["block_n"] if block_n is None else block_n
    return toy_scan_pallas(codes, block_n=bn)
