"""Known-good fixture: a pallas kernel with oracle + parity coverage."""
import functools

import jax
import numpy as np
from jax.experimental import pallas as pl


def _toy_add_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def toy_add_pallas(x, y, *, block=128, interpret=True):
    # trace-safe np usage: dtype objects resolve at trace time
    assert x.dtype == np.float32
    return pl.pallas_call(
        _toy_add_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, y)


def scale_rows(x, w):
    def step(carry, row):
        return carry, row * w
    return jax.lax.scan(step, None, x)[1]
