"""Known-good fixture oracles."""


def toy_add_ref(x, y):
    return x + y
