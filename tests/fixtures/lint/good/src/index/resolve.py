"""Known-good fixture resolution path: consumes the declared capability."""
from index.backend import backend_supports


def generator_for(name):
    if backend_supports(name, "streaming_fast"):
        return "streaming"
    return "materialized"
