"""Known-good fixture registry: every declared capability is consumed."""

_REGISTRY = {}


def register_scan_backend(name, *, priority, capabilities=()):
    _REGISTRY[name] = (priority, frozenset(capabilities))


def backend_supports(name, capability):
    return name in _REGISTRY and capability in _REGISTRY[name][1]


register_scan_backend("toy", priority=1, capabilities=("streaming_fast",))
