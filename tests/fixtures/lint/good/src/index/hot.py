"""Known-good fixture hot path: traced code with only trace-safe numpy
(dtype objects / constants) and eager-edge host sync kept OUT of here."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("k",))
def topk_stream(scores, *, k):
    init = jnp.full((k,), np.inf, np.float32)

    def step(carry, s):
        merged = jnp.sort(jnp.concatenate([carry, s]))[:k]
        return merged, None

    return jax.lax.scan(step, init, scores)[0]
