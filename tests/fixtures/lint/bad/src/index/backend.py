"""Known-bad fixture registry: a capability nothing ever consumes."""

_REGISTRY = {}


def register_scan_backend(name, *, priority, capabilities=()):
    _REGISTRY[name] = (priority, frozenset(capabilities))


def backend_supports(name, capability):
    return name in _REGISTRY and capability in _REGISTRY[name][1]


# BAD: "never_used" is declared but no resolution path reads it
register_scan_backend("toy", priority=1,
                      capabilities=("consumed_cap", "never_used"))
