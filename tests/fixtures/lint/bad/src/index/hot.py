"""Known-bad fixture hot path: every recompile hazard in one traced body,
plus host syncs in the search path (host-sync rule)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("k",))
def scores_topk(scores, *, k):
    # BAD: host round-trip on a tracer
    threshold = float(scores.max())
    # BAD: .item() forces a device sync per call
    first = scores.reshape(-1)[0].item()
    # BAD: host numpy on traced values
    logs = np.log(scores + 1.0)
    return jnp.sort(logs.reshape(-1))[: k + int(threshold) + int(first)]


def scan_driver(scores):
    def body(carry, s):
        # BAD: hazard inside a lax.scan body (traced without a decorator)
        return carry + float(s.sum()), None

    return jax.lax.scan(body, 0.0, scores)[0]


def eager_edge(x):
    # BAD twice: explicit host syncs in a hot-path module
    host = jax.device_get(x)
    x.block_until_ready()
    return host
