"""Known-bad fixture resolution: consumes only one of the two flags."""
from index.backend import backend_supports


def generator_for(name):
    return "fast" if backend_supports(name, "consumed_cap") else "slow"
