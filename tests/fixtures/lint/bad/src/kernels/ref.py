"""Known-bad fixture oracles: deliberately missing toy_mul_ref."""


def unrelated_ref(x):
    return x
