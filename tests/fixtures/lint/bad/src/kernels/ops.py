"""Known-bad fixture: kernel dispatch with hand-pinned block parameters
(tuned-block-params rule) — literal block_n at the call site, literal
chunk_l default, and no tune.best_config resolution anywhere."""


def toy_scan_pallas(codes, *, block_n, interpret=True):
    return codes


def toy_rerank_chunked_xla(codes, *, chunk_l):
    return codes


def toy_scan(codes):
    # BAD: hand-pinned literal instead of a tuner resolution
    return toy_scan_pallas(codes, block_n=1024)


def toy_rerank(codes, *, chunk_l=256):
    # BAD: integer-literal default on a block/chunk parameter
    return toy_rerank_chunked_xla(codes, chunk_l=chunk_l)
