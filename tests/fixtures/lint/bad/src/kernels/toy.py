"""Known-bad fixture: an oracle-less pallas kernel (kernel-oracle rule)."""
import functools

import jax
from jax.experimental import pallas as pl


def _toy_mul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] * y_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def toy_mul_pallas(x, y, *, block=128, interpret=True):
    # BAD: no toy_mul_ref in ref.py, no parity test anywhere
    return pl.pallas_call(
        _toy_mul_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, y)
