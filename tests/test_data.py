"""Data pipeline: determinism, ground-truth correctness, stream resume."""
import numpy as np
import pytest

from repro.data import descriptors as dd
from repro.data.tokens import TokenStream, masked_frame_batch


def test_synthetic_dataset_deterministic():
    a = dd.make_synthetic_dataset("deep", n_train=100, n_base=200,
                                  n_query=10, seed=7)
    b = dd.make_synthetic_dataset("deep", n_train=100, n_base=200,
                                  n_query=10, seed=7)
    np.testing.assert_array_equal(a.base, b.base)
    np.testing.assert_array_equal(a.gt_nn, b.gt_nn)
    c = dd.make_synthetic_dataset("deep", n_train=100, n_base=200,
                                  n_query=10, seed=8)
    assert not np.array_equal(a.base, c.base)


def test_deep_descriptors_unit_norm_sift_nonneg():
    deep = dd.make_synthetic_dataset("deep", n_train=50, n_base=50,
                                     n_query=5, compute_gt=False)
    np.testing.assert_allclose(np.linalg.norm(deep.base, axis=1), 1.0,
                               rtol=1e-4)
    sift = dd.make_synthetic_dataset("sift", n_train=50, n_base=50,
                                     n_query=5, compute_gt=False)
    assert sift.dim == 128 and (sift.base >= 0).all()
    assert sift.base.max() <= 255.0


def test_exact_knn_matches_numpy_bruteforce():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(300, 16)).astype(np.float32)
    q = rng.normal(size=(20, 16)).astype(np.float32)
    got = dd.exact_knn(q, base, k=5, batch=7)
    d = ((q[:, None] - base[None]) ** 2).sum(-1)
    want = np.argsort(d, axis=1)[:, :5]
    # argsort ties could differ: compare distances instead of raw indices
    np.testing.assert_allclose(
        np.take_along_axis(d, got, axis=1),
        np.take_along_axis(d, want, axis=1), rtol=1e-4)
    np.testing.assert_array_equal(got[:, 0], want[:, 0])


def test_triplet_sampling_ranges():
    rng = np.random.default_rng(0)
    train = rng.normal(size=(64, 8)).astype(np.float32)
    neighbors = dd.epoch_neighbors(train, k=33)
    assert neighbors.shape == (64, 32)
    # self excluded
    assert not (neighbors == np.arange(64)[:, None]).any()
    pos, neg = dd.sample_triplets(rng, train, neighbors)
    top3 = neighbors[:, :3]
    assert all(pos[i] in top3[i] for i in range(64))


def test_token_stream_shards_and_resumes():
    s0 = TokenStream(vocab_size=100, seq_len=8, batch_size=2, rank=0, world=2)
    s1 = TokenStream(vocab_size=100, seq_len=8, batch_size=2, rank=1, world=2)
    a0 = s0.next_batch()["tokens"]
    a1 = s1.next_batch()["tokens"]
    assert not np.array_equal(a0, a1)        # disjoint rank substreams
    b0 = s0.next_batch()["tokens"]

    # resume: a fresh stream loaded from state produces the same batch
    s0b = TokenStream(vocab_size=100, seq_len=8, batch_size=2, rank=0,
                      world=2)
    s0b.load_state_dict({"step": 1, "rank": 0, "seed": 0})
    np.testing.assert_array_equal(s0b.next_batch()["tokens"], b0)
    assert (a0 >= 0).all() and (a0 < 100).all()


def test_masked_frame_batch_shapes():
    b = masked_frame_batch(0, 3, 11, 24, 17, mask_prob=0.5)
    assert b["frames"].shape == (3, 11, 24)
    assert b["targets"].shape == (3, 11) and b["targets"].max() < 17
    assert b["mask"].dtype == bool and 0 < b["mask"].mean() < 1
