"""Per-architecture smoke tests: every assigned arch instantiates its
REDUCED config (same code path as the full config) and runs one forward +
one train step on CPU, asserting output shapes and finiteness. Decoder
archs additionally check decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.tokens import masked_frame_batch
from repro.models import registry
from repro.parallel import steps as steps_lib


def _batch_for(cfg, b=2, t=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.input_mode == "frames":
        mb = masked_frame_batch(seed, b, t, cfg.frame_dim, cfg.vocab_size)
        return {k: jnp.asarray(v) for k, v in mb.items()}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, t + 1)), jnp.int32)}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = registry.init(key, cfg)
    batch = _batch_for(cfg)

    # forward: shapes + finite
    fwd_in = (batch if cfg.input_mode == "frames"
              else {"tokens": batch["tokens"][:, :-1]})
    logits = registry.forward(params, cfg, fwd_in)
    t = fwd_in["tokens"].shape[1] if "tokens" in fwd_in else \
        fwd_in["frames"].shape[1]
    assert logits.shape == (2, t, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    # one train step: loss finite, params updated
    train_step, opt = steps_lib.make_train_step(cfg)
    opt_state = opt.init(params)
    new_params, _, metrics = jax.jit(train_step)(
        params, opt_state, batch, jnp.asarray(0, jnp.int32))
    assert np.isfinite(float(metrics["loss"])), arch
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0, f"{arch}: optimizer produced no update"


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_IDS
                                  if configs.get(a, smoke=True).kind
                                  == "decoder"])
def test_smoke_decode_matches_forward(arch):
    cfg = configs.get(arch, smoke=True)
    if cfg.moe:
        # capacity drops are batch-size dependent (24-token forward vs
        # 2-token decode steps); force dropless capacity so the dispatch
        # math itself is compared exactly.
        cfg = cfg.with_(capacity_factor=float(cfg.num_experts))
    key = jax.random.PRNGKey(1)
    params = registry.init(key, cfg)
    b, t = 2, 12
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    full = registry.forward(params, cfg, {"tokens": toks})
    caches = registry.init_cache(cfg, b, max_len=16, dtype=jnp.float32)
    step = jax.jit(lambda p, c, tok, pos: registry.decode_step(
        p, cfg, c, tok, pos))
    outs = []
    for pos in range(t):
        lg, caches = step(params, caches, toks[:, pos],
                          jnp.asarray(pos, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_IDS
                                  if configs.get(a, smoke=True).kind
                                  == "decoder"])
def test_smoke_prefill_matches_forward_last_logits(arch):
    cfg = configs.get(arch, smoke=True)
    key = jax.random.PRNGKey(2)
    params = registry.init(key, cfg)
    b, t = 2, 16   # multiple of smoke windows (8) for ring alignment
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    full = registry.forward(params, cfg, {"tokens": toks})
    last, caches = registry.family(cfg).prefill(params, cfg,
                                                {"tokens": toks})
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    assert jax.tree_util.tree_leaves(caches), arch


def test_gemma3_kvq_variant_decodes():
    cfg = configs.get("gemma3-12b", variant="SMOKE").with_(
        kvq=True, kvq_books=4, kvq_book_size=16)
    key = jax.random.PRNGKey(3)
    params = registry.init(key, cfg)
    caches = registry.init_cache(cfg, 2, max_len=16)
    step = jax.jit(lambda p, c, tok, pos: registry.decode_step(
        p, cfg, c, tok, pos))
    toks = jax.random.randint(key, (2, 6), 0, cfg.vocab_size)
    for pos in range(6):
        lg, caches = step(params, caches, toks[:, pos],
                          jnp.asarray(pos, jnp.int32))
    assert bool(jnp.all(jnp.isfinite(lg)))
    # compressed cache is uint8 codes
    k_codes = caches[-1]["k_codes"]
    assert k_codes.dtype == jnp.uint8


def test_full_configs_match_published_param_counts():
    """eval_shape the FULL configs (no allocation) and check total params
    against the published sizes (loose bands — configs follow the
    assignment sheet, which rounds)."""
    expected = {
        "yi-6b": (5.5e9, 7.5e9),
        "minitron-8b": (7.0e9, 10.0e9),
        "mistral-large-123b": (110e9, 130e9),
        "gemma3-12b": (10e9, 14e9),
        "deepseek-moe-16b": (14e9, 19e9),
        "hubert-xlarge": (0.8e9, 1.5e9),
        "chameleon-34b": (30e9, 42e9),
        "rwkv6-1.6b": (1.3e9, 2.0e9),
        "recurrentgemma-2b": (2.2e9, 3.4e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = configs.get(arch)
        shapes = jax.eval_shape(
            lambda c=cfg: registry.init(jax.random.PRNGKey(0), c))
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert lo < n < hi, f"{arch}: {n:.3e} params outside [{lo}, {hi}]"
