"""Unified ``repro.index`` API: factory parsing, protocol interchange,
save/load, batched-scan parity, sharded merge, stage-1 oracle
equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.search import recall_at_k
from repro.index import (Index, IVFIndex, OPQIndex, PQIndex, RVQIndex,
                         ShardedIndex, UNQIndex, index_factory,
                         resolve_scan_backend)
from repro.index.unq_index import build_luts, encode_database
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# factory-string parsing
# ---------------------------------------------------------------------------

def test_factory_parses_quantizers_and_modifiers():
    idx = index_factory("UNQ8x256,Rerank500", dim=96)
    assert isinstance(idx, UNQIndex)
    assert idx.cfg.num_codebooks == 8 and idx.cfg.codebook_size == 256
    assert idx.rerank == 500 and idx.dim == 96

    idx = index_factory("PQ4", dim=96)
    assert isinstance(idx, PQIndex)
    assert idx.num_books == 4 and idx.book_size == 256
    assert idx.rerank == 0          # classic ADC-only IndexPQ behavior

    idx = index_factory("OPQ8x64,Rerank100,Scan(onehot)", dim=96)
    assert isinstance(idx, OPQIndex)
    assert idx.book_size == 64 and idx.rerank == 100
    assert idx.backend == "onehot"

    idx = index_factory("RVQ4x32", dim=96)
    assert isinstance(idx, RVQIndex)

    idx = index_factory("IVF256,NProbe16,UNQ8x256", dim=96)
    assert isinstance(idx, IVFIndex) and isinstance(idx.inner, UNQIndex)
    assert idx.nlist == 256 and idx.nprobe == 16
    assert idx.rerank == 500        # inherits UNQ's paper default

    idx = index_factory("IVF64,PQ4,Rerank80,Scan(onehot)", dim=96)
    assert isinstance(idx, IVFIndex) and isinstance(idx.inner, PQIndex)
    assert idx.nprobe == 8 and idx.rerank == 80
    assert idx.backend == "onehot" and idx.inner.backend == "onehot"


@pytest.mark.parametrize("bad", ["", "Rerank500", "UNQ8x256,PQ4",
                                 "LSH16", "UNQ8x256,Foo",
                                 "IVF64", "NProbe8,PQ4"])
def test_factory_rejects_malformed_specs(bad):
    with pytest.raises(ValueError):
        index_factory(bad, dim=96)


def test_scan_backend_resolution():
    assert resolve_scan_backend("xla") == "xla"
    assert resolve_scan_backend("pallas") == "pallas"
    # auto never picks pallas off-TPU, and never picks the A/B-only onehot
    assert resolve_scan_backend("auto") == (
        "pallas" if jax.default_backend() == "tpu" else "xla")
    with pytest.raises(ValueError):
        resolve_scan_backend("cuda")


# ---------------------------------------------------------------------------
# batched multi-query scan vs per-query oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,k,q", [(1000, 8, 256, 3), (257, 16, 256, 33),
                                     (2048, 4, 64, 1)])
def test_adc_scan_batch_matches_per_query_oracle(n, m, k, q):
    rng = np.random.default_rng(n + q)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    luts = jnp.asarray(rng.normal(size=(q, m, k)), jnp.float32)
    want = jnp.stack([ops.adc_scan(codes, luts[i], impl="xla")
                      for i in range(q)])
    for impl in ("xla", "pallas"):
        got = ops.adc_scan_batch(codes, luts, impl=impl)
        assert got.shape == (q, n)
        # acceptance: interpret-mode kernel is bit-for-bit vs the oracle
        # (both accumulate the M partial sums left-to-right)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=impl)
    # the one-hot einsum reassociates the reduction; close, not bit-equal
    got = ops.adc_scan_batch(codes, luts, impl="onehot")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_adc_scan_batch_ref_is_vmap_of_single():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 64, (100, 8)), jnp.uint8)
    luts = jnp.asarray(rng.normal(size=(5, 8, 64)), jnp.float32)
    got = ref.adc_scan_batch_ref(codes, luts)
    want = jax.vmap(ref.adc_scan_ref, in_axes=(None, 0))(codes, luts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# protocol interchangeability: one loop over heterogeneous indexes
# ---------------------------------------------------------------------------

def _small_pq_family(tiny_dataset):
    return [
        index_factory("PQ4x32,Rerank50", dim=tiny_dataset.dim),
        index_factory("OPQ4x32,Rerank50", dim=tiny_dataset.dim),
        index_factory("RVQ2x32,Rerank50", dim=tiny_dataset.dim),
    ]


def test_protocol_interchangeability(tiny_dataset):
    """UNQ and every shallow baseline run the identical loop (what makes
    paper-table comparisons one loop instead of per-method scripts)."""
    queries = jnp.asarray(tiny_dataset.queries[:30])
    gt = jnp.asarray(tiny_dataset.gt_nn[:30])
    n = tiny_dataset.base.shape[0]
    for index in _small_pq_family(tiny_dataset):
        assert not index.is_trained
        index.train(tiny_dataset.train, iters=4)
        index.add(tiny_dataset.base)
        assert index.is_trained and index.ntotal == n
        distances, idx = index.search(queries, 20)
        assert distances.shape == idx.shape == (30, 20)
        # distances sorted ascending (closest first)
        d = np.asarray(distances)
        assert (np.diff(d, axis=1) >= -1e-5).all()
        rec = recall_at_k(idx, gt, ks=(10,))
        assert rec["recall@10"] > 10 * (10 / n), (type(index).__name__, rec)


def test_train_before_add_is_an_error():
    idx = index_factory("PQ4x32", dim=96)
    with pytest.raises(RuntimeError):
        idx.add(np.zeros((10, 96), np.float32))


def test_forced_rerank_without_budget_is_an_error(tiny_dataset,
                                                  trained_index_factory):
    idx = trained_index_factory("PQ4x32,Rerank50", iters=4)
    idx.rerank = 0                    # classic ADC-only IndexPQ behavior
    with pytest.raises(ValueError, match="rerank budget"):
        idx.search(jnp.asarray(tiny_dataset.queries[:5]), 10,
                   use_rerank=True)


# ---------------------------------------------------------------------------
# save / load roundtrip (checkpoint/manager-backed)
# ---------------------------------------------------------------------------

#: every registered index_factory shape (quantizer family x IVF wrapping),
#: with the train kwargs the session cache uses — the save/load roundtrip
#: below runs over ALL of them
REGISTRY_SPECS = [
    ("PQ4x32,Rerank50", dict(iters=4)),
    ("OPQ4x32,Rerank50", dict(iters=4)),
    ("RVQ2x32,Rerank50", dict(iters=4)),
    ("UNQ8x64,Rerank60", dict(epochs=2, log_every=1000)),
    ("IVF8,PQ4x32,Rerank50", dict(iters=4)),
    ("IVF8,NProbe3,RVQ2x32,Rerank50", dict(iters=4)),
    ("IVF8,UNQ8x64,Rerank60", dict(epochs=2, log_every=1000)),
    ("IVF8,Residual,PQ4x32,Rerank50", dict(iters=4)),
    ("IVF8,NProbe3,Residual,RVQ2x32,Rerank50", dict(iters=4)),
]


@pytest.mark.parametrize("spec,train_kw",
                         REGISTRY_SPECS, ids=[s for s, _ in REGISTRY_SPECS])
def test_save_load_roundtrip_registry(trained_index_factory, tiny_dataset,
                                      spec, train_kw, tmp_path):
    """Acceptance satellite: EVERY factory spec — the new IVF prefixes
    included — roundtrips through save/load with bitwise-equal search
    results (distances and indices), and IVF wrappers keep their coarse
    state (nlist/nprobe/cell grouping)."""
    index = trained_index_factory(spec, **train_kw)
    queries = jnp.asarray(tiny_dataset.queries[:10])
    want_d, want_i = index.search(queries, 15)
    index.save(tmp_path / "ckpt")
    loaded = Index.load(tmp_path / "ckpt")
    assert type(loaded) is type(index)
    assert loaded.ntotal == index.ntotal
    assert loaded.rerank == index.rerank
    got_d, got_i = loaded.search(queries, 15)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
    if isinstance(index, IVFIndex):
        assert isinstance(loaded, IVFIndex)
        assert (loaded.nlist, loaded.nprobe) == (index.nlist, index.nprobe)
        assert type(loaded.inner) is type(index.inner)
        np.testing.assert_array_equal(loaded._ids_np, index._ids_np)
        np.testing.assert_array_equal(loaded._offsets, index._offsets)
        # a partial probe exercises the restored CSR/coarse state
        want = index.search(queries, 10, nprobe=2)
        got = loaded.search(queries, 10, nprobe=2)
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want[1]))
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))


def test_save_load_roundtrip_unq(tiny_unq, tiny_dataset, tmp_path):
    cfg, params, state, _ = tiny_unq
    index = UNQIndex.from_trained(params, state, cfg, rerank=60)
    index.add(tiny_dataset.base)
    queries = jnp.asarray(tiny_dataset.queries[:10])
    _, want = index.search(queries, 15)
    index.save(tmp_path / "unq")
    loaded = Index.load(tmp_path / "unq")
    assert isinstance(loaded, UNQIndex) and loaded.cfg == cfg
    _, got = loaded.search(queries, 15)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_load_rejects_non_index_checkpoint(tmp_path):
    from repro.checkpoint.manager import save_pytree
    save_pytree(tmp_path / "ckpt", {"w": jnp.zeros((2,))}, metadata={})
    with pytest.raises(ValueError):
        Index.load(tmp_path / "ckpt")


# ---------------------------------------------------------------------------
# acceptance: factory index == hand-rolled two-stage pipeline on same
# params/codes (the oracle the deleted core.search shims used to provide)
# ---------------------------------------------------------------------------

def test_unq_index_matches_manual_two_stage_pipeline(tiny_unq, tiny_dataset):
    from repro.core import unq

    cfg, params, state, _ = tiny_unq
    base = jnp.asarray(tiny_dataset.base)
    queries = jnp.asarray(tiny_dataset.queries[:40])
    codes = encode_database(params, state, cfg, base)

    index = index_factory(
        f"UNQ{cfg.num_codebooks}x{cfg.codebook_size},Rerank100",
        dim=cfg.dim)
    index.cfg = cfg                      # tiny test cfg (small code_dim)
    index.params, index.state = params, state
    index.add(base)
    np.testing.assert_array_equal(np.asarray(index.codes), np.asarray(codes))

    # stage 1 oracle: materialized d2 matrix + top_k; stage 2: exact d1
    luts = build_luts(params, state, cfg, queries)
    scores = ref.adc_scan_batch_ref(codes, luts)
    neg, cand = jax.lax.top_k(-scores, 100)

    def rerank(cand_row, q_row):
        recon = unq.decode_codes(params, state, cfg, codes[cand_row])
        d1 = jnp.sum(jnp.square(recon - q_row[None, :]), axis=-1)
        neg1, order = jax.lax.top_k(-d1, 30)
        return cand_row[order]

    want = jnp.stack([rerank(cand[i], queries[i])
                      for i in range(queries.shape[0])])
    _, got = index.search(queries, 30)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # no-rerank ablation returns the raw d2 ranking
    _, got_nr = index.search(queries, 30, use_rerank=False)
    np.testing.assert_array_equal(np.asarray(got_nr), np.asarray(cand[:, :30]))


# ---------------------------------------------------------------------------
# ShardedIndex: merge correctness
# ---------------------------------------------------------------------------

def test_sharded_index_merge_matches_flat_search(tiny_unq, tiny_dataset):
    cfg, params, state, _ = tiny_unq
    index = UNQIndex.from_trained(params, state, cfg, rerank=80)
    index.add(tiny_dataset.base)
    queries = jnp.asarray(tiny_dataset.queries[:25])

    _, flat = index.search(queries, 30)
    for num_shards in (1, 4, 7):       # 7: uneven split, tail shard
        sharded = ShardedIndex(index, num_shards=num_shards)
        assert sharded.ntotal == index.ntotal
        _, got = sharded.search(queries, 30)
        # same candidate pool (rerank >= per-shard L keeps sets identical
        # up to d2 ties at the pool boundary)
        for i in range(queries.shape[0]):
            a = set(np.asarray(flat[i]).tolist())
            b = set(np.asarray(got[i]).tolist())
            assert len(a & b) / len(a) > 0.95, (num_shards, i)


def test_sharded_stage1_matches_flat_oracle(tiny_unq, tiny_dataset):
    """from_shards candidate merge == lax.top_k over the full d2 matrix,
    bit-exact (score AND index, ties included)."""
    cfg, params, state, _ = tiny_unq
    base = jnp.asarray(tiny_dataset.base)
    codes = encode_database(params, state, cfg, base)
    queries = jnp.asarray(tiny_dataset.queries[:20])
    n = codes.shape[0]
    shards = [codes[: n // 3], codes[n // 3: 2 * n // 3],
              codes[2 * n // 3:]]
    offsets = [0, n // 3, 2 * n // 3]

    luts = build_luts(params, state, cfg, queries)
    want_s, want_i = ref.adc_scan_topl_ref(codes, luts, None, 50)

    inner = UNQIndex.from_trained(params, state, cfg, rerank=50)
    sharded = ShardedIndex.from_shards(inner, shards, offsets)
    got_s, got_i = sharded.stage1_candidates(queries, topl=50)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


def test_sharded_rvq_carries_score_bias(tiny_dataset,
                                        trained_index_factory):
    """Additive quantizers carry a per-point bias (||decode||^2); sharded
    stage 1 must slice it per shard, and from_shards must refuse to drop
    it silently."""
    index = trained_index_factory("RVQ2x32,Rerank60", iters=4)
    queries = jnp.asarray(tiny_dataset.queries[:15])
    _, flat = index.search(queries, 20)

    sharded = ShardedIndex(index, num_shards=3)
    _, got = sharded.search(queries, 20)
    for i in range(queries.shape[0]):
        a = set(np.asarray(flat[i]).tolist())
        b = set(np.asarray(got[i]).tolist())
        assert len(a & b) / len(a) > 0.95, i

    n = index.ntotal
    shards = [index.codes[: n // 2], index.codes[n // 2:]]
    with pytest.raises(ValueError, match="bias"):
        ShardedIndex.from_shards(index, shards, [0, n // 2])
    biased = ShardedIndex.from_shards(
        index, shards, [0, n // 2],
        biases=[index.bias[: n // 2], index.bias[n // 2:]])
    _, got2 = biased.stage1_candidates(queries, topl=60)
    _, want2 = ShardedIndex(index, num_shards=2).stage1_candidates(
        queries, topl=60)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))


def test_sharded_pq_backend_pinning(tiny_dataset, trained_index_factory):
    """Sharded search honors the scan-backend registry per inner index."""
    index = trained_index_factory("PQ4x32,Rerank50", iters=4)
    index.backend = "onehot"          # as Scan(onehot) would pin it
    index.rerank = 40
    queries = jnp.asarray(tiny_dataset.queries[:10])
    _, want = index.search(queries, 10)
    index.backend = "xla"
    sharded = ShardedIndex(index, num_shards=3)
    _, got = sharded.search(queries, 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# subset views
# ---------------------------------------------------------------------------

def test_subset_view_restricts_results(tiny_dataset,
                                       trained_index_factory):
    index = trained_index_factory("PQ4x32,Rerank50", iters=4)
    half = index.subset(index.ntotal // 2)
    assert half.ntotal == index.ntotal // 2
    _, got = half.search(jnp.asarray(tiny_dataset.queries[:10]), 10)
    assert int(np.asarray(got).max()) < half.ntotal
    # the view shares the quantizer: full index unchanged
    assert index.ntotal == tiny_dataset.base.shape[0]
