"""Multi-device behaviour via subprocesses with forced host device counts
(the main test process must keep seeing 1 device — see conftest)."""
import os
import subprocess
import sys

import pytest


def _run(script: str) -> str:
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    return r.stdout


def test_pjit_train_step_on_2x4_mesh():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs, optim
from repro.models import registry
from repro.parallel import hints, sharding as shard_lib, steps as steps_lib

assert len(jax.devices()) == 8
cfg = configs.get("yi-6b", smoke=True)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = dict(shard_lib.RULES_SINGLE_POD)
params_ps = shard_lib.params_pspecs(registry.logical_axes(cfg), rules)
train_step, opt = steps_lib.make_train_step(cfg, lr_fn=optim.constant(1e-3))

with mesh, hints.activation_sharding(rules, mesh):
    params = jax.jit(lambda: registry.init(jax.random.PRNGKey(0), cfg),
                     out_shardings=jax.tree.map(
                         lambda s: NamedSharding(mesh, s), params_ps,
                         is_leaf=lambda x: isinstance(x, P)))()
    opt_state = jax.jit(opt.init)(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 17)), jnp.int32)}
    step = jax.jit(train_step)
    p1, o1, m1 = step(params, opt_state, batch, jnp.asarray(0))
    p2, o2, m2 = step(p1, o1, batch, jnp.asarray(1))
    assert np.isfinite(float(m2["loss"]))
    # loss decreases on a repeated batch
    assert float(m2["loss"]) < float(m1["loss"])
print("MESH-TRAIN-OK")
""")
    assert "MESH-TRAIN-OK" in out


def test_sharded_equals_single_device_loss():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import registry
from repro.parallel import hints, sharding as shard_lib

cfg = configs.get("deepseek-moe-16b", smoke=True)
params = registry.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(
    rng.integers(0, cfg.vocab_size, (4, 17)), jnp.int32)}

loss_single, _ = jax.jit(
    lambda p, b: registry.loss_fn(p, cfg, b))(params, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = dict(shard_lib.RULES_SINGLE_POD)
ps = shard_lib.params_pspecs(registry.logical_axes(cfg), rules)
with mesh, hints.activation_sharding(rules, mesh):
    sharded_params = jax.device_put(
        params, jax.tree.map(lambda s: NamedSharding(mesh, s), ps,
                             is_leaf=lambda x: isinstance(x, P)))
    loss_sharded, _ = jax.jit(
        lambda p, b: registry.loss_fn(p, cfg, b))(sharded_params, batch)

np.testing.assert_allclose(float(loss_single), float(loss_sharded),
                           rtol=2e-4)
print("SPMD-EQUIV-OK")
""")
    assert "SPMD-EQUIV-OK" in out


def test_elastic_restore_8_to_4_devices():
    """Save on an 8-device (2,4) mesh, restore onto a (4,) subset mesh with
    different sharding — the elastic-restart path."""
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np, tempfile, pathlib
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_elastic_mesh

tmp = tempfile.mkdtemp()
mesh8 = jax.make_mesh((2, 4), ("data", "model"))
tree = {"w": jax.device_put(
    jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
    NamedSharding(mesh8, P("data", "model")))}
mgr = CheckpointManager(tmp)
mgr.save(3, tree)

mesh4 = make_elastic_mesh(jax.devices()[:4], model_parallel=2)
assert dict(mesh4.shape) == {"data": 2, "model": 2}
sh = {"w": NamedSharding(mesh4, P("data", "model"))}
restored, manifest = mgr.restore_latest(
    {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}, shardings=sh)
assert manifest["step"] == 3
np.testing.assert_array_equal(
    np.asarray(restored["w"]),
    np.arange(64, dtype=np.float32).reshape(8, 8))
assert restored["w"].sharding.mesh.devices.size == 4
print("ELASTIC-OK")
""")
    assert "ELASTIC-OK" in out


def test_device_resident_sharded_search_matches_flat():
    """ShardedIndex device placement: code shards resident on 8 devices
    under shard_map, per-device streaming scan+top-L, all-gather merge —
    bit-exact vs the flat single-device search (ragged tail included)."""
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.index import ShardedIndex, StreamingTopL, index_factory
from repro.data.descriptors import make_synthetic_dataset

assert len(jax.devices()) == 8
ds = make_synthetic_dataset("deep", n_train=800, n_base=3001, n_query=30,
                            seed=0)   # 3001: ragged tail shard
index = index_factory("RVQ2x32,Rerank60", dim=ds.dim)   # RVQ: bias shards
index.train(ds.train, iters=3).add(ds.base)
queries = jnp.asarray(ds.queries[:20])

d_flat, i_flat = index.search(queries, 15)
sharded = ShardedIndex(index, num_shards=8)
assert sharded.resolved_placement == "device"
d_dev, i_dev = sharded.search(queries, 15)
np.testing.assert_array_equal(np.asarray(i_flat), np.asarray(i_dev))
np.testing.assert_array_equal(np.asarray(d_flat), np.asarray(d_dev))

# the merged stage-1 pool itself is also bit-exact, bias included
luts = index._build_luts(queries)
ws, wi = StreamingTopL("xla").topl(index.codes, luts, index.bias, topl=60)
gs, gi = sharded.stage1_candidates(queries, topl=60)
np.testing.assert_array_equal(np.asarray(wi), np.asarray(gi))
np.testing.assert_array_equal(np.asarray(ws), np.asarray(gs))
print("DEVICE-SHARD-OK")
""")
    assert "DEVICE-SHARD-OK" in out


def test_device_resident_ivf_and_filtered_search_match_flat():
    """By-cell device sharding of an IVF index (each device probes only
    the cells it owns) and the filtered device path both reproduce the
    single-device results bit-for-bit."""
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.index import ShardedIndex, index_factory
from repro.data.descriptors import make_synthetic_dataset

assert len(jax.devices()) == 8
ds = make_synthetic_dataset("deep", n_train=800, n_base=3001, n_query=30,
                            seed=0)
queries = jnp.asarray(ds.queries[:20])

# IVF (RVQ inner: the bias stream threads the per-device plans)
ivf = index_factory("IVF16,RVQ2x32,Rerank60", dim=ds.dim)
ivf.train(ds.train, iters=3).add(ds.base)
sharded = ShardedIndex(ivf, num_shards=8)
assert sharded.resolved_placement == "device"
for nprobe in (3, 16):
    d_flat, i_flat = ivf.search(queries, 15, nprobe=nprobe)
    d_dev, i_dev = sharded.search(queries, 15, nprobe=nprobe)
    np.testing.assert_array_equal(np.asarray(i_flat), np.asarray(i_dev))
    np.testing.assert_array_equal(np.asarray(d_flat), np.asarray(d_dev))

# residual IVF (IVFADC): the per-(query, cell) correction composes onto
# each device's slot-bias stream host-side before the plans ship
res = index_factory("IVF16,Residual,PQ4x32,Rerank60", dim=ds.dim)
res.train(ds.train, iters=3).add(ds.base)
shr = ShardedIndex(res, num_shards=8)
assert shr.resolved_placement == "device"
for nprobe in (3, 16):
    d_flat, i_flat = res.search(queries, 15, nprobe=nprobe)
    d_dev, i_dev = shr.search(queries, 15, nprobe=nprobe)
    np.testing.assert_array_equal(np.asarray(i_flat), np.asarray(i_dev))
    np.testing.assert_array_equal(np.asarray(d_flat), np.asarray(d_dev))

# flat index + filter masks through the device path's qbias stream
flat = index_factory("RVQ2x32,Rerank60", dim=ds.dim)
flat.train(ds.train, iters=3).add(ds.base)
shf = ShardedIndex(flat, num_shards=8)
assert shf.resolved_placement == "device"
rng = np.random.default_rng(0)
for mask in (rng.integers(0, 2, flat.ntotal).astype(bool),
             rng.integers(0, 2, (20, flat.ntotal)).astype(bool)):
    d_flat, i_flat = flat.search(queries, 15, filter_mask=mask)
    d_dev, i_dev = shf.search(queries, 15, filter_mask=mask)
    np.testing.assert_array_equal(np.asarray(i_flat), np.asarray(i_dev))
    np.testing.assert_array_equal(np.asarray(d_flat), np.asarray(d_dev))
print("DEVICE-IVF-OK")
""")
    assert "DEVICE-IVF-OK" in out


def test_unq_data_parallel_search_matches():
    """The paper's scan sharded over 8 devices == single-device scan."""
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.kernels import ops

rng = np.random.default_rng(0)
codes = jnp.asarray(rng.integers(0, 256, (4096, 8)), jnp.uint8)
lut = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
single = ops.adc_scan(codes, lut, impl="xla")

mesh = jax.make_mesh((8,), ("data",))
codes_sh = jax.device_put(codes, NamedSharding(mesh, P("data", None)))
lut_sh = jax.device_put(lut, NamedSharding(mesh, P()))
with mesh:
    sharded = jax.jit(lambda c, l: ops.adc_scan(c, l, impl="xla"))(
        codes_sh, lut_sh)
np.testing.assert_allclose(np.asarray(single), np.asarray(sharded),
                           rtol=1e-5, atol=1e-5)
print("UNQ-SPMD-OK")
""")
    assert "UNQ-SPMD-OK" in out
