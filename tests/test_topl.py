"""Streaming stage-1 engine: fused scan+top-L kernel vs chunked xla
fallback vs materialized oracle — exact (score, index) parity including
tie resolution — plus the HLO peak-memory guarantee and candidate
generator resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.analysis.contracts import assert_contract
from repro.index import (MaterializedTopL, StreamingTopL,
                         backend_capabilities, backend_supports,
                         candidate_generator_for)
from repro.kernels import ops, ref


# tie-heavy case construction lives in conftest (``scan_case``): integer
# tables make d2 collisions ubiquitous, so parity tests exercise tie
# RESOLUTION, not just score math


@pytest.mark.parametrize("tie_heavy", [False, True])
@pytest.mark.parametrize("n,L", [(1000, 37),     # N % block_n != 0
                                 (257, 300),     # L > N (clamped to N)
                                 (2048, 64),     # exact block multiple
                                 (1, 1)])        # degenerate
def test_topl_all_backends_bit_exact(scan_case, n, L, tie_heavy):
    rng = np.random.default_rng(n + L)
    codes, luts = scan_case(rng, n, m=8, k=64, q=5, tie_heavy=tie_heavy)
    want_s, want_i = ref.adc_scan_topl_ref(codes, luts, None, L)
    assert want_s.shape == (5, min(L, n))
    for impl in ("xla", "pallas"):
        got_s, got_i = ops.adc_scan_topl(codes, luts, topl=L, impl=impl,
                                         block_n=256, block_q=8, chunk_n=192)
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s),
                                      err_msg=impl)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i),
                                      err_msg=impl)


def test_topl_bias_flows_through_fused_path(scan_case):
    """Per-point biases (RVQ's ||decode||^2) must flow through both
    streaming paths, not just the materialized one."""
    rng = np.random.default_rng(0)
    codes, luts = scan_case(rng, 700, m=4, k=32, q=3, tie_heavy=True)
    bias = jnp.asarray(rng.integers(0, 3, (700,)), jnp.float32)
    want_s, want_i = ref.adc_scan_topl_ref(codes, luts, bias, 50)
    for impl in ("xla", "pallas"):
        got_s, got_i = ops.adc_scan_topl(codes, luts, topl=50, bias=bias,
                                         impl=impl, block_n=128, chunk_n=96)
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s),
                                      err_msg=impl)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i),
                                      err_msg=impl)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 400),
    L=st.integers(1, 80),
    block_n=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_topl_property_parity(scan_case, n, L, block_n, seed):
    """Property: for random shapes/blockings — N not a multiple of the
    block, L > N, tie-heavy tables — the fused kernel (interpret mode),
    the chunked xla fallback, and lax.top_k over the full matrix agree
    bit-for-bit in (score, index)."""
    rng = np.random.default_rng(seed)
    q = int(rng.integers(1, 7))
    codes, luts = scan_case(rng, n, m=4, k=16, q=q,
                            tie_heavy=bool(rng.integers(0, 2)))
    bias = (jnp.asarray(rng.integers(-1, 2, (n,)), jnp.float32)
            if rng.integers(0, 2) else None)
    want_s, want_i = ref.adc_scan_topl_ref(codes, luts, bias, L)
    for impl in ("xla", "pallas"):
        got_s, got_i = ops.adc_scan_topl(
            codes, luts, topl=L, bias=bias, impl=impl,
            block_n=block_n, block_q=8, chunk_n=max(1, block_n // 2))
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s),
                                      err_msg=f"{impl} scores")
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i),
                                      err_msg=f"{impl} idx")


def test_streaming_stage1_contracts():
    """The acceptance guarantee — no (Q, N) score matrix, temp memory
    below the matrix footprint — now declared ONCE in the contract
    registry (repro.analysis.contracts) and merely invoked here. The
    materialized control proves the detector would actually see the
    forbidden buffer."""
    assert_contract("stage1.stream.xla")
    assert_contract("stage1.fused.pallas")
    assert_contract("stage1.materialized.control")


def test_gathered_stage1_contracts():
    """IVF face of the same guarantee: the gathered (probing) paths never
    hold a (Q, W) slot-score batch or the (Q, N) matrix."""
    assert_contract("stage1.gathered.xla")
    assert_contract("stage1.gathered.pallas")


def test_backend_capability_matrix_and_generator_resolution():
    assert backend_supports("xla", "streaming_topl")
    assert backend_supports("pallas", "streaming_topl")
    assert backend_supports("pallas", "fused_topl")
    assert not backend_supports("onehot", "streaming_topl")
    assert backend_capabilities("onehot") == frozenset()
    with pytest.raises(ValueError):
        backend_capabilities("cuda")

    assert isinstance(candidate_generator_for("xla"), StreamingTopL)
    assert isinstance(candidate_generator_for("pallas"), StreamingTopL)
    assert isinstance(candidate_generator_for("onehot"), MaterializedTopL)
    auto = candidate_generator_for("auto")
    assert isinstance(auto, StreamingTopL)        # xla on CPU, pallas on TPU
    assert not auto.materializes_scores


def test_qbias_stream_flows_through_every_path(scan_case):
    """The per-(query, point) bias stream (the lowered filter mask) is
    bit-exact across the materialized oracle, the chunked xla path and
    the fused kernel — ±inf entries included."""
    rng = np.random.default_rng(7)
    n, q = 900, 5
    codes, luts = scan_case(rng, n, m=4, k=32, q=q, tie_heavy=True)
    bias = jnp.asarray(rng.integers(0, 3, (n,)), jnp.float32)
    qbias = jnp.where(jnp.asarray(rng.integers(0, 3, (q, n))) == 0,
                      jnp.inf, 0.0)
    scores = ref.adc_scan_batch_ref(codes, luts) + bias[None, :] + qbias
    neg, idx = jax.lax.top_k(-scores, 60)
    want_s, want_i = -neg, idx
    for impl in ("xla", "pallas"):
        got_s, got_i = ops.adc_scan_topl(codes, luts, topl=60, bias=bias,
                                         qbias=qbias, impl=impl,
                                         block_n=256, chunk_n=192)
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s),
                                      err_msg=impl)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i),
                                      err_msg=impl)


def test_generators_bit_identical_on_index_data(tiny_dataset,
                                                trained_index_factory):
    """End-to-end generator interchange on a real trained index (RVQ so the
    per-point bias is exercised): streaming == materialized bit-for-bit."""
    index = trained_index_factory("RVQ2x32,Rerank60", iters=4)
    luts = index._build_luts(jnp.asarray(tiny_dataset.queries[:25]))
    m_s, m_i = MaterializedTopL("xla").topl(index.codes, luts, index.bias,
                                            topl=60)
    for impl in ("xla", "pallas"):
        s_s, s_i = StreamingTopL(impl).topl(index.codes, luts, index.bias,
                                            topl=60)
        np.testing.assert_array_equal(np.asarray(s_s), np.asarray(m_s),
                                      err_msg=impl)
        np.testing.assert_array_equal(np.asarray(s_i), np.asarray(m_i),
                                      err_msg=impl)


def test_index_bias_is_public(trained_index_factory):
    """Satellite: wrappers read ``Index.bias``, never ``_bias`` (custom
    subclasses only need the public surface)."""
    pq = trained_index_factory("PQ4x32,Rerank50", iters=4)
    assert pq.bias is None
    rvq = trained_index_factory("RVQ2x32,Rerank60", iters=4)
    assert rvq.bias is not None and rvq.bias.shape == (rvq.ntotal,)
